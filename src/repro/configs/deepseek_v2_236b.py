"""DeepSeek-V2 236B — 60L, d_model=5120, 128H, vocab=102400. MLA with
kv_lora_rank=512 (+64 rope dims), q_lora_rank=1536; MoE: 2 shared + 160
routed experts top-6, expert d_ff=1536; first block dense (d_ff=12288).
[arXiv:2405.04434]"""

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    source="arXiv:2405.04434",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,               # informational; MLA replaces GQA caching
    head_dim=128,
    d_ff=12288,                   # the dense first block
    vocab_size=102400,
    max_seq_len=32768,
    rope_theta=10000.0,
    moe=MoEConfig(n_experts=160, top_k=6, expert_d_ff=1536,
                  n_shared_experts=2, shared_d_ff=1536,
                  capacity_factor=1.25),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536, qk_rope_dim=64,
                  qk_nope_dim=128, v_head_dim=128),
    dense_block_ids=(0,),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
