"""RecurrentGemma-9B (Griffin) — 38 blocks, d_model=4096, 16H (MQA kv=1),
d_ff=12288, vocab=256000. Pattern: 2 RG-LRU recurrent blocks : 1 local
(window 2048) attention block. Sub-quadratic -> runs the long_500k shape.
[arXiv:2402.19427]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256000,
    max_seq_len=8192,            # local attention window bounds KV memory
    activation="geglu",
    mixer_pattern=("rglru", "rglru", "local_gqa"),
    local_window=2048,
    lru_width=4096,
    conv_width=4,
    logit_softcap=30.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
