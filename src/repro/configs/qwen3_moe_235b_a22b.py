"""Qwen3-MoE 235B-A22B — 94L, d_model=4096, 64H (GQA kv=4), expert d_ff=1536,
vocab=151936, 128 experts top-8.  [hf:Qwen/Qwen3-30B-A3B family / Qwen3
Technical Report arXiv:2505.09388]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B; arXiv:2505.09388",
    n_layers=94,
    d_model=4096,
    n_heads=64,
    n_kv_heads=4,
    head_dim=128,
    d_ff=12288,                      # unused: every block is MoE
    vocab_size=151936,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=1536,
                  capacity_factor=1.25),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
