"""MiniCPM-2B — 40L, d_model=2304, 36H (MHA kv=36), d_ff=5760, vocab=122753.
Llama-like arch; trained with the WSD schedule (exercised by the training
substrate).  [arXiv:2404.06395]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minicpm-2b",
    family="dense",
    source="arXiv:2404.06395",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    head_dim=64,
    d_ff=5760,
    vocab_size=122753,
    max_seq_len=4096,
    tie_embeddings=True,
    rope_theta=10000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
