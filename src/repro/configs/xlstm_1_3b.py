"""xLSTM-1.3B — 48 blocks, d_model=2048, 4 heads, vocab=50304. d_ff=0:
projections are integrated in the m/sLSTM blocks. Paper's 7:1 mLSTM:sLSTM
interleave. Pure recurrent state -> runs the long_500k shape.
[arXiv:2405.04517]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    source="arXiv:2405.04517",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    max_seq_len=8192,
    norm="layernorm",
    norm_eps=1e-5,
    mixer_pattern=("mlstm",) * 7 + ("slstm",),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
