"""Phi-4-mini 3.8B — 32L, d_model=3072, 24H (GQA kv=8), d_ff=8192,
vocab=200064. RoPE + SwiGLU + GQA.  [arXiv:2412.08905]

``--variant sliding`` (serve launcher) adds a 4096-token sliding window so
one dense arch exercises the sub-quadratic long_500k path (see DESIGN.md
§Shape skips)."""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="phi4-mini-3.8b",
    family="dense",
    source="arXiv:2412.08905",
    n_layers=32,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=200064,
    max_seq_len=4096,
    tie_embeddings=True,
    rope_theta=10000.0,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
