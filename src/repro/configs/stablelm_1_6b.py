"""StableLM-2-1.6B — 24L, d_model=2048, 32H (MHA kv=32), d_ff=5632,
vocab=100352. LayerNorm + partial rotary (25%).
[hf:stabilityai/stablelm-2-1_6b]"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=5632,
    vocab_size=100352,
    max_seq_len=4096,
    norm="layernorm",
    norm_eps=1e-5,
    pos_emb="rope_partial",
    rotary_pct=0.25,
    dtype="bfloat16",
    param_dtype="bfloat16",
)
