"""Whisper-base — enc-dec, 6L encoder + 6L decoder, d_model=512, 8H,
d_ff=2048, vocab=51865. Conv/mel frontend STUBBED per the brief: the encoder
consumes precomputed frame embeddings (B, 1500, 512).  [arXiv:2212.04356]

Note: real whisper caps the decoder at 448 positions; the learned-position
table here is sized by max_seq_len so the framework's decode_32k shape can
exercise the enc-dec path (recorded as a deviation in DESIGN.md)."""

from repro.models.config import EncoderConfig, ModelConfig

CONFIG = ModelConfig(
    name="whisper-base",
    family="audio",
    source="arXiv:2212.04356",
    n_layers=6,
    d_model=512,
    n_heads=8,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2048,
    vocab_size=51865,
    max_seq_len=32768,
    norm="layernorm",
    norm_eps=1e-5,
    activation="gelu",
    pos_emb="learned",
    tie_embeddings=True,
    encoder=EncoderConfig(n_layers=6, n_frames=1500),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
