"""The four assigned input shapes + per-(arch, shape) applicability rules
and ShapeDtypeStruct input builders for the multi-pod dry-run.

Shapes (from the brief):
  train_4k     seq=4096    global_batch=256   (training step)
  prefill_32k  seq=32768   global_batch=32    (inference prefill)
  decode_32k   seq=32768   global_batch=128   (one decode token, 32k KV)
  long_500k    seq=524288  global_batch=1     (long-context decode)

``long_500k`` requires sub-quadratic attention: it runs for the SSM/hybrid
archs (xlstm, recurrentgemma) and is SKIPPED for pure full-attention archs
(see DESIGN.md §Shape skips). ``applicable`` returns (ok, reason).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig


@dataclass(frozen=True)
class InputShape:
    name: str
    kind: str            # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int


SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", "train", 4096, 256),
    "prefill_32k": InputShape("prefill_32k", "prefill", 32768, 32),
    "decode_32k": InputShape("decode_32k", "decode", 32768, 128),
    "long_500k": InputShape("long_500k", "decode", 524288, 1),
}

# archs with sub-quadratic token mixing (bounded attention state)
_SUBQUADRATIC = {"recurrentgemma-9b", "xlstm-1.3b"}


def applicable(cfg: ModelConfig, shape: InputShape) -> Tuple[bool, str]:
    if shape.name == "long_500k":
        if cfg.name in _SUBQUADRATIC or cfg.sliding_window:
            return True, ""
        return False, ("full quadratic attention at 524k context — skipped "
                       "per DESIGN.md §Shape skips (run for SSM/hybrid and "
                       "sliding-window variants)")
    if shape.kind == "train" and cfg.name == "whisper-base":
        return True, ""   # enc-dec trains with stub encoder embeddings
    return True, ""


def cache_len_for(cfg: ModelConfig, shape: InputShape) -> int:
    if shape.kind in ("prefill", "decode"):
        # VLM backbones prepend n_patches stub patch embeddings to the text
        # tokens — the KV cache must cover them too.
        return shape.seq_len + cfg.vision.n_patches
    return 0


def input_specs(cfg: ModelConfig, shape: InputShape, model=None) -> dict:
    """ShapeDtypeStruct stand-ins for every input of the step function
    lowered for this (arch, shape) — weak-type-correct, no allocation."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["targets"] = jax.ShapeDtypeStruct((b, s), i32)
        specs["mask"] = jax.ShapeDtypeStruct((b, s), jnp.bool_)
        if cfg.encoder.enabled:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
        if cfg.encoder.enabled:
            specs["enc_out"] = jax.ShapeDtypeStruct(
                (b, cfg.encoder.n_frames, cfg.d_model), cfg.dtype)
        if cfg.vision.enabled:
            specs["extra_embeds"] = jax.ShapeDtypeStruct(
                (b, cfg.vision.n_patches, cfg.d_model), cfg.dtype)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((b, 1), i32)
        specs["offsets"] = jax.ShapeDtypeStruct((b,), i32)
    return specs


def cache_specs(model, shape: InputShape) -> Optional[list]:
    if shape.kind == "train":
        return None
    return jax.eval_shape(
        lambda: model.init_cache(shape.global_batch,
                                 cache_len_for(model.cfg, shape)))
