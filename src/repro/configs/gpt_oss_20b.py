"""GPT-OSS-20B — the paper's second evaluation model ("GPT"). 24L,
d_model=2880, 64H (GQA kv=8, head_dim=64), 32 experts top-4, vocab=201088.
[arXiv:2508.10925; paper Table 3]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="gpt-oss-20b",
    family="moe",
    source="arXiv:2508.10925; paper Table 3",
    n_layers=24,
    d_model=2880,
    n_heads=64,
    n_kv_heads=8,
    head_dim=64,
    d_ff=2880,
    vocab_size=201088,
    max_seq_len=32768,
    rope_theta=150_000.0,
    moe=MoEConfig(n_experts=32, top_k=4, expert_d_ff=2880,
                  capacity_factor=1.25),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
