"""Qwen3-30B-A3B — the paper's primary evaluation model ("Qwen"). 48L,
d_model=2048, 32H (GQA kv=4, head_dim=128), 128 experts top-8, expert
d_ff=768, vocab=151936.  [hf:Qwen/Qwen3-30B-A3B; paper Table 3]"""

from repro.models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-30b-a3b",
    family="moe",
    source="hf:Qwen/Qwen3-30B-A3B; paper Table 3",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    head_dim=128,
    d_ff=6144,
    vocab_size=151936,
    max_seq_len=32768,
    rope_theta=1_000_000.0,
    moe=MoEConfig(n_experts=128, top_k=8, expert_d_ff=768,
                  capacity_factor=1.25),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
