"""Qwen2-VL-72B language backbone — 80L, d_model=8192, 64H (GQA kv=8),
d_ff=29568, vocab=152064, M-RoPE (sections t/h/w = 16/24/24 over the 64
rotary pairs), dynamic-resolution ViT frontend STUBBED per the brief
(``input_specs`` provides patch embeddings).  [arXiv:2409.12191]"""

from repro.models.config import ModelConfig, VisionStubConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    source="arXiv:2409.12191",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=29568,
    vocab_size=152064,
    max_seq_len=32768,
    pos_emb="mrope",
    mrope_sections=(16, 24, 24),
    rope_theta=1_000_000.0,
    vision=VisionStubConfig(n_patches=256),
    dtype="bfloat16",
    param_dtype="bfloat16",
)
