"""Architecture registry: the 10 assigned architectures plus the paper's
own two evaluation models (Qwen3-30B-A3B and GPT-OSS-20B).

Every entry cites its source in the config's ``source`` field. Access via
``get_config(name)`` / ``list_configs()``; smoke variants via
``get_smoke_config(name)``.
"""

from __future__ import annotations

import importlib
from typing import List

from repro.models.config import ModelConfig, reduced

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "qwen2-vl-72b": "qwen2_vl_72b",
    "minicpm-2b": "minicpm_2b",
    "stablelm-1.6b": "stablelm_1_6b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "whisper-base": "whisper_base",
    "yi-34b": "yi_34b",
    "phi4-mini-3.8b": "phi4_mini_3_8b",
    "xlstm-1.3b": "xlstm_1_3b",
    "deepseek-v2-236b": "deepseek_v2_236b",
    # the paper's evaluation models
    "qwen3-30b-a3b": "qwen3_30b_a3b",
    "gpt-oss-20b": "gpt_oss_20b",
}

ASSIGNED = list(_MODULES)[:10]


def list_configs() -> List[str]:
    return list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {list(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG.validate()


def get_smoke_config(name: str) -> ModelConfig:
    return reduced(get_config(name))
