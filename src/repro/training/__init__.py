from repro.training.optimizer import adamw, cosine_schedule, wsd_schedule
from repro.training.train import Trainer, make_train_step

__all__ = ["adamw", "wsd_schedule", "cosine_schedule", "Trainer",
           "make_train_step"]
