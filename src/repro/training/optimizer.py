"""Pure-JAX AdamW and LR schedules (no optax dependency).

Includes the WSD (Warmup-Stable-Decay) schedule from MiniCPM
(arXiv:2404.06395) — one of the assigned architectures' signature training
features — alongside standard cosine decay.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    mu: object          # pytree like params
    nu: object


@dataclass(frozen=True)
class AdamW:
    lr_fn: Callable[[jax.Array], jax.Array]
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        z = lambda: jax.tree_util.tree_map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), mu=z(), nu=z())

    def update(self, grads, state: AdamWState, params):
        step = state.step + 1
        # global-norm clip
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree_util.tree_leaves(grads)))
        scale = jnp.minimum(1.0, self.grad_clip / (gnorm + 1e-9))
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * scale, grads)

        mu = jax.tree_util.tree_map(
            lambda m, g: self.b1 * m + (1 - self.b1) * g, state.mu, grads)
        nu = jax.tree_util.tree_map(
            lambda v, g: self.b2 * v + (1 - self.b2) * jnp.square(g),
            state.nu, grads)
        bc1 = 1 - self.b1 ** step.astype(jnp.float32)
        bc2 = 1 - self.b2 ** step.astype(jnp.float32)
        lr = self.lr_fn(step)

        def upd(p, m, v):
            mhat = m / bc1
            vhat = v / bc2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

        new_params = jax.tree_util.tree_map(upd, params, mu, nu)
        return new_params, AdamWState(step=step, mu=mu, nu=nu), {
            "grad_norm": gnorm, "lr": lr}


def adamw(lr: float = 3e-4, schedule: str = "cosine", total_steps: int = 1000,
          warmup: int = 100, **kw) -> AdamW:
    if schedule == "wsd":
        fn = wsd_schedule(lr, total_steps, warmup)
    elif schedule == "cosine":
        fn = cosine_schedule(lr, total_steps, warmup)
    else:
        fn = lambda step: jnp.asarray(lr, jnp.float32)
    return AdamW(lr_fn=fn, **kw)


def cosine_schedule(peak: float, total_steps: int, warmup: int):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        prog = jnp.clip((s - warmup) / max(total_steps - warmup, 1), 0, 1)
        cos = 0.1 * peak + 0.9 * peak * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return jnp.where(s < warmup, warm, cos)
    return fn


def wsd_schedule(peak: float, total_steps: int, warmup: int,
                 decay_frac: float = 0.1, floor_frac: float = 0.01):
    """MiniCPM Warmup-Stable-Decay: linear warmup, long stable plateau at
    peak, exponential decay over the final ``decay_frac`` of training."""
    decay_start = int(total_steps * (1 - decay_frac))

    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak * s / max(warmup, 1)
        stable = jnp.asarray(peak, jnp.float32)
        prog = jnp.clip((s - decay_start) / max(total_steps - decay_start, 1),
                        0, 1)
        decay = peak * jnp.power(floor_frac, prog)
        out = jnp.where(s < warmup, warm,
                        jnp.where(s < decay_start, stable, decay))
        return out
    return fn
