"""msgpack pytree checkpointing (params + optimizer state + step).

Flat-key encoding: every leaf is stored under its '/'-joined tree path with
dtype/shape preserved; restoration rebuilds into a template pytree so the
format is stable across refactors that keep leaf paths."""

from __future__ import annotations

import os
from typing import Any, Dict

import jax
import jax.numpy as jnp
import msgpack
import numpy as np


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def _encode_leaf(x) -> Dict[str, Any]:
    a = np.asarray(x)
    return {"dtype": a.dtype.str, "shape": list(a.shape),
            "data": a.tobytes()}


def save(path: str, tree) -> None:
    flat = {}
    def visit(p, x):
        flat[_path_str(p)] = _encode_leaf(x)
        return x
    jax.tree_util.tree_map_with_path(visit, tree)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(msgpack.packb(flat, use_bin_type=True))
    os.replace(tmp, path)


def restore(path: str, template):
    with open(path, "rb") as f:
        flat = msgpack.unpackb(f.read(), raw=False)

    def rebuild(p, x):
        key = _path_str(p)
        if key not in flat:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        rec = flat[key]
        a = np.frombuffer(rec["data"], dtype=np.dtype(rec["dtype"]))
        a = a.reshape(rec["shape"])
        assert tuple(a.shape) == tuple(np.shape(x)), (key, a.shape, np.shape(x))
        return jnp.asarray(a)

    return jax.tree_util.tree_map_with_path(rebuild, template)
