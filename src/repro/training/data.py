"""Synthetic-corpus data pipeline: tokenizer-free document generator with
learnable structure, sequence packing, and a deterministic batch iterator.

Documents are emitted by a seeded order-1 Markov chain over the vocab with
a power-law stationary distribution plus periodic copy motifs — structured
enough that a ~100M model's loss visibly drops within a few hundred steps
(examples/train_small.py) while requiring no external data.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Tuple

import numpy as np


@dataclass
class SyntheticCorpus:
    vocab_size: int
    seed: int = 0
    branch: int = 16          # out-degree of the Markov chain
    motif_period: int = 64    # every ~period tokens, repeat a recent span

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        v = self.vocab_size
        # per-state successor table (sparse transition structure)
        self._succ = rng.integers(0, v, size=(v, self.branch))
        # zipfian state-visit tendencies
        p = 1.0 / np.arange(1, self.branch + 1) ** 1.2
        self._succ_p = p / p.sum()

    def document(self, rng: np.random.Generator, length: int) -> np.ndarray:
        toks = np.empty(length, np.int64)
        toks[0] = rng.integers(0, self.vocab_size)
        i = 1
        while i < length:
            if i % self.motif_period == 0 and i >= 16 and rng.random() < 0.5:
                # copy motif: repeat a recent span (teaches induction)
                span = min(8, length - i)
                start = rng.integers(max(0, i - 32), i - span + 1)
                toks[i:i + span] = toks[start:start + span]
                i += span
                continue
            prev = toks[i - 1]
            toks[i] = self._succ[prev, rng.choice(self.branch, p=self._succ_p)]
            i += 1
        return toks


@dataclass
class PackedDataset:
    """Packs variable-length documents into fixed (batch, seq+1) examples;
    targets are inputs shifted by one. A BOS token (id 0) separates docs and
    the loss mask zeroes predictions across document boundaries."""

    corpus: SyntheticCorpus
    seq_len: int
    batch_size: int
    seed: int = 0
    mean_doc_len: int = 512

    def __iter__(self) -> Iterator[Tuple[np.ndarray, np.ndarray, np.ndarray]]:
        rng = np.random.default_rng(self.seed)
        buf = np.empty(0, np.int64)
        bound = np.empty(0, bool)
        need = self.batch_size * (self.seq_len + 1)
        while True:
            while len(buf) < need:
                n = max(16, int(rng.exponential(self.mean_doc_len)))
                doc = self.corpus.document(rng, n)
                b = np.zeros(n + 1, bool)
                b[0] = True
                buf = np.concatenate([buf, [0], doc])
                bound = np.concatenate([bound, b])
            chunk, buf = buf[:need], buf[need:]
            bchunk, bound = bound[:need], bound[need:]
            x = chunk.reshape(self.batch_size, self.seq_len + 1)
            bm = bchunk.reshape(self.batch_size, self.seq_len + 1)
            tokens = x[:, :-1].astype(np.int32)
            targets = x[:, 1:].astype(np.int32)
            mask = ~bm[:, 1:]          # don't predict across doc starts
            yield tokens, targets, mask
