"""Training loop: masked cross-entropy (+ MoE load-balance aux loss),
jit/pjit train_step factory and a small Trainer driver with checkpointing.

``make_train_step`` is also what the multi-pod dry-run lowers for the
``train_4k`` input shape: it is mesh-agnostic — shardings are applied by the
launcher via in_shardings/out_shardings and the shard_hint constraints
inside the model.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.models.model import DecoderModel
from repro.training.optimizer import AdamW
from repro.sharding.partition import shard_hint


def loss_fn(model: DecoderModel, params, tokens, targets, mask,
            enc_out=None):
    """Masked next-token cross entropy + router aux loss."""
    logits, _, aux = model.forward(params, tokens, enc_out=enc_out)
    logits = logits.astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    m = mask.astype(jnp.float32)
    ce = jnp.sum(nll * m) / jnp.maximum(jnp.sum(m), 1.0)
    total = ce + aux["aux_loss"]
    return total, {"loss": total, "ce": ce, "aux_loss": aux["aux_loss"],
                   "dropped": aux["dropped"]}


def make_train_step(model: DecoderModel, opt: AdamW,
                    has_encoder: bool = False) -> Callable:
    def train_step(params, opt_state, batch):
        tokens = shard_hint(batch["tokens"], "batch", None)
        targets = shard_hint(batch["targets"], "batch", None)
        mask = shard_hint(batch["mask"], "batch", None)
        enc_out = batch.get("enc_out") if has_encoder else None

        grad_fn = jax.value_and_grad(
            lambda p: loss_fn(model, p, tokens, targets, mask, enc_out),
            has_aux=True)
        (_, metrics), grads = grad_fn(params)
        params, opt_state, opt_metrics = opt.update(grads, opt_state, params)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step


@dataclass
class Trainer:
    model: DecoderModel
    opt: AdamW
    params: object
    opt_state: object = None
    step: int = 0
    history: list = field(default_factory=list)

    def __post_init__(self):
        if self.opt_state is None:
            self.opt_state = self.opt.init(self.params)
        self._step_fn = jax.jit(make_train_step(
            self.model, self.opt, self.model.cfg.encoder.enabled))

    def fit(self, batches: Iterator, steps: int,
            log_every: int = 10, checkpoint_path: Optional[str] = None,
            checkpoint_every: int = 100) -> list:
        from repro.training import checkpoint as ckpt
        it = iter(batches)
        t0 = time.time()
        for _ in range(steps):
            tokens, targets, mask = next(it)
            batch = {"tokens": jnp.asarray(tokens),
                     "targets": jnp.asarray(targets),
                     "mask": jnp.asarray(mask)}
            if self.model.cfg.encoder.enabled:
                b, _ = tokens.shape
                batch["enc_out"] = jnp.zeros(
                    (b, self.model.cfg.encoder.n_frames,
                     self.model.cfg.d_model), self.model.cfg.dtype)
            self.params, self.opt_state, m = self._step_fn(
                self.params, self.opt_state, batch)
            self.step += 1
            if self.step % log_every == 0 or self.step == 1:
                rec = {k: float(v) for k, v in m.items()}
                rec["step"] = self.step
                rec["wall"] = time.time() - t0
                self.history.append(rec)
            if checkpoint_path and self.step % checkpoint_every == 0:
                ckpt.save(checkpoint_path,
                          {"params": self.params, "opt": self.opt_state})
        return self.history
