"""Pure-jnp oracles for every Pallas kernel (the allclose targets for the
per-kernel shape/dtype sweep tests)."""

from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        scale: Optional[float] = None) -> jax.Array:
    """q: (B,S,H,hd); k/v: (B,S,Hkv,hd) -> (B,S,H,hd)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    # g-major GQA grouping (q head h -> kv head h % hkv), matching the
    # model's sharding-friendly convention.
    qf = q.reshape(b, s, g, hkv, hd).astype(jnp.float32) * scale
    scores = jnp.einsum("bqgkd,bskd->bgkqs", qf, k.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = jnp.ones((s, s), bool)
    if causal:
        mask &= pos[None, :] <= pos[:, None]
    if window is not None:
        mask &= pos[None, :] > pos[:, None] - window
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgkqs,bskd->bqgkd", w, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         lengths: jax.Array, *,
                         window: Optional[int] = None,
                         scale: Optional[float] = None) -> jax.Array:
    """q: (B,H,hd); caches: (B,S,Hkv,hd); lengths: (B,) -> (B,H,hd)."""
    b, h, hd = q.shape
    s, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)
    qf = q.reshape(b, g, hkv, hd).astype(jnp.float32) * scale
    scores = jnp.einsum("bgkd,bskd->bgks", qf, k_cache.astype(jnp.float32))
    pos = jnp.arange(s)
    mask = pos[None, :] < lengths[:, None]
    if window is not None:
        mask &= pos[None, :] >= (lengths[:, None] - window)
    scores = jnp.where(mask[:, None, None, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bgks,bskd->bgkd", w, v_cache.astype(jnp.float32))
    return out.reshape(b, h, hd).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array, *,
                               window: Optional[int] = None,
                               scale: Optional[float] = None) -> jax.Array:
    """Oracle for the page-table-aware decode kernel: gather each
    sequence's pages into a contiguous cache row (the logical view the
    block table encodes), then defer to the contiguous-cache oracle.
    q: (B,H,hd); pages: (n_pages, page_size, Hkv, hd);
    block_tables: (B, max_pages) int32; lengths: (B,) -> (B,H,hd)."""
    b = q.shape[0]
    n_pages, page_size, hkv, hd = k_pages.shape
    max_pages = block_tables.shape[1]
    k = k_pages[block_tables].reshape(b, max_pages * page_size, hkv, hd)
    v = v_pages[block_tables].reshape(b, max_pages * page_size, hkv, hd)
    return decode_attention_ref(q, k, v, lengths, window=window, scale=scale)


def paged_verify_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, block_tables: jax.Array,
                               lengths: jax.Array, *,
                               window: Optional[int] = None,
                               scale: Optional[float] = None) -> jax.Array:
    """Oracle for the verify-window paged kernel: run the decode oracle
    once per window position w with the causally-shrunk length
    ``lengths - (W-1) + w``.  q: (B, W, H, hd); lengths include all W
    window tokens' K/V -> (B, W, H, hd)."""
    b, w_len = q.shape[0], q.shape[1]
    outs = []
    for w in range(w_len):
        lens_w = lengths - (w_len - 1 - w)
        outs.append(paged_decode_attention_ref(
            q[:, w], k_pages, v_pages, block_tables, lens_w,
            window=window, scale=scale))
    return jnp.stack(outs, axis=1)


def moe_gmm_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                w_down: jax.Array) -> jax.Array:
    """x: (E,C,d) -> (E,C,d), fused SwiGLU per expert."""
    xf = x.astype(jnp.float32)
    g = jnp.einsum("ecd,edf->ecf", xf, w_gate.astype(jnp.float32))
    u = jnp.einsum("ecd,edf->ecf", xf, w_up.astype(jnp.float32))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h,
                      w_down.astype(jnp.float32)).astype(x.dtype)


def moe_gmm_ragged_ref(rows: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                       w_down: jax.Array, tile_expert: jax.Array,
                       m_blk: int) -> jax.Array:
    """Oracle for the ragged grouped matmul: per row-tile, apply the fused
    SwiGLU FFN of the tile's owning expert; sentinel tiles
    (tile_expert == E) produce zero rows. rows: (n_rows, d) -> (n_rows, d).

    The per-tile weight gather reads exactly one expert's weights per active
    tile — the same traffic shape as the kernel's scalar-prefetched DMA."""
    n_rows, d = rows.shape
    e = w_gate.shape[0]
    tiles = rows.reshape(-1, m_blk, d).astype(jnp.float32)
    sel = jnp.minimum(tile_expert, e - 1)
    wg = w_gate[sel].astype(jnp.float32)                 # (n_tiles, d, F)
    wu = w_up[sel].astype(jnp.float32)
    wd = w_down[sel].astype(jnp.float32)                 # (n_tiles, F, d)
    g = jnp.einsum("tmd,tdf->tmf", tiles, wg)
    u = jnp.einsum("tmd,tdf->tmf", tiles, wu)
    h = jax.nn.silu(g) * u
    y = jnp.einsum("tmf,tfd->tmd", h, wd)
    y = jnp.where((tile_expert < e)[:, None, None], y, 0.0)
    return y.reshape(n_rows, d).astype(rows.dtype)
