"""Jit-wrapped public entry points for the Pallas kernels.

On CPU (this container) the kernels execute through Pallas interpret mode —
bit-accurate algorithm validation without a TPU. On TPU backends they lower
to Mosaic. ``interpret`` is auto-detected from the default backend; padding
to tile multiples happens here so the kernels stay shape-strict.

The model plugs these in via ``gmm_fn=`` (MoE) or by swapping the attention
reference path; correctness of the swap is covered by tests/test_kernels.py.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention import (decode_attention_pallas,
                                            paged_decode_attention_pallas,
                                            paged_verify_attention_pallas)
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.moe_gmm import moe_gmm_pallas
from repro.kernels.moe_gmm_ragged import moe_gmm_ragged_pallas


def _auto_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_to(x: jax.Array, axis: int, mult: int):
    n = x.shape[axis]
    pad = (-n) % mult
    if pad == 0:
        return x, n
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), n


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "kv_blk", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True,
                    window: Optional[int] = None, q_blk: int = 128,
                    kv_blk: int = 128,
                    interpret: Optional[bool] = None) -> jax.Array:
    """Padding-safe wrapper: pads S to tile multiples; padded queries are
    discarded, padded keys are causally masked out (pos > any real q)."""
    interpret = _auto_interpret() if interpret is None else interpret
    s0 = q.shape[1]
    blk = max(q_blk, kv_blk)
    q_p, _ = _pad_to(q, 1, blk)
    k_p, _ = _pad_to(k, 1, blk)
    v_p, _ = _pad_to(v, 1, blk)
    if not causal and s0 != q_p.shape[1]:
        # non-causal needs an explicit mask for padded keys; window/causal
        # paths mask padding structurally.
        raise ValueError("non-causal flash attention requires S % tile == 0")
    out = flash_attention_pallas(q_p, k_p, v_p, causal=causal, window=window,
                                 q_blk=q_blk, kv_blk=kv_blk,
                                 interpret=interpret)
    return out[:, :s0]


@functools.partial(jax.jit, static_argnames=("window", "kv_blk", "interpret"))
def decode_attention(q, k_cache, v_cache, lengths, *,
                     window: Optional[int] = None, kv_blk: int = 128,
                     interpret: Optional[bool] = None) -> jax.Array:
    interpret = _auto_interpret() if interpret is None else interpret
    k_p, _ = _pad_to(k_cache, 1, kv_blk)
    v_p, _ = _pad_to(v_cache, 1, kv_blk)
    return decode_attention_pallas(q, k_p, v_p, lengths, window=window,
                                   kv_blk=kv_blk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Decode attention over the PagedKVAllocator's scattered physical
    layout: ``block_tables`` (B, max_pages) holds each sequence's physical
    page ids (``PagedKVAllocator.block_table``, padded with 0 — any valid
    page id works, padded entries are masked by ``lengths``). Page count
    and size come from the pool shape; no padding is needed because pages
    are fixed-size by construction."""
    interpret = _auto_interpret() if interpret is None else interpret
    return paged_decode_attention_pallas(q, k_pages, v_pages, block_tables,
                                         lengths, window=window,
                                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_verify_attention(q, k_pages, v_pages, block_tables, lengths, *,
                           window: Optional[int] = None,
                           interpret: Optional[bool] = None) -> jax.Array:
    """Speculative verify-k attention over the paged pool: ``q`` is a
    (B, W, H, hd) window of W = k+1 query tokens per sequence (oldest
    first) whose K/V have already been written; ``lengths`` counts valid
    KV INCLUDING the window.  One KV stream per sequence serves the whole
    window — the dispatch-amortization the speculative scheduler rides."""
    interpret = _auto_interpret() if interpret is None else interpret
    return paged_verify_attention_pallas(q, k_pages, v_pages, block_tables,
                                         lengths, window=window,
                                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("c_blk", "f_blk", "interpret"))
def moe_gmm(x, w_gate, w_up, w_down, *, c_blk: int = 128, f_blk: int = 128,
            interpret: Optional[bool] = None) -> jax.Array:
    interpret = _auto_interpret() if interpret is None else interpret
    x_p, c0 = _pad_to(x, 1, min(c_blk, max(x.shape[1], 1)))
    wg_p, f0 = _pad_to(w_gate, 2, min(f_blk, max(w_gate.shape[2], 1)))
    wu_p, _ = _pad_to(w_up, 2, min(f_blk, max(w_up.shape[2], 1)))
    wd_p, _ = _pad_to(w_down, 1, min(f_blk, max(w_down.shape[1], 1)))
    out = moe_gmm_pallas(x_p, wg_p, wu_p, wd_p, c_blk=c_blk, f_blk=f_blk,
                         interpret=interpret)
    return out[:, :c0]


def gather_slot_rows(cache, slots: jax.Array):
    """Gather a slot VECTOR of KV-cache rows — one ``jnp.take`` per leaf
    instead of B full-tree dynamic slices (the engine's packed layer-group
    batches; DESIGN.md §Engine hot path).  Leaves are ``(reps, n_slots,
    ...)``; ``slots`` is ``(B,)`` int32.  Padding rows carry the
    out-of-range id ``n_slots``: ``mode="clip"`` reads the last real row
    (its output is masked downstream and its writeback is dropped by
    ``scatter_slot_rows``), never a NaN fill that could poison the batch's
    shared MoE dispatch."""
    return jax.tree_util.tree_map(
        lambda c: jnp.take(c, slots, axis=1, mode="clip"), cache)


def scatter_slot_rows(cache, rows, slots: jax.Array):
    """Scatter gathered rows back into the multi-slot cache with one
    ``.at[:, slots].set`` per leaf.  ``mode="drop"`` discards writes from
    padding rows (slot id ``n_slots`` is out of range), so a bucket-padded
    batch can never corrupt a live slot.  Real slot ids are distinct by
    construction (one resident request per slot), so the scatter has no
    duplicate-index races."""
    return jax.tree_util.tree_map(
        lambda f, r: f.at[:, slots].set(r.astype(f.dtype), mode="drop"),
        cache, rows)


def fetch_expert_ids(tile_expert: jax.Array, n_experts: int) -> jax.Array:
    """Replace sentinel tile ids (== n_experts) with the last active expert
    id (forward fill), so skipped tiles drive the weight DMA at an already-
    resident block instead of fetching a fresh one. All-sentinel inputs
    (fully masked batch) fall back to expert 0."""
    n_tiles = tile_expert.shape[0]
    idx = jnp.where(tile_expert < n_experts,
                    jnp.arange(n_tiles, dtype=jnp.int32), -1)
    last = jax.lax.cummax(idx)
    return jnp.where(last >= 0, tile_expert[jnp.maximum(last, 0)],
                     0).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("m_blk", "f_blk", "interpret"))
def moe_gmm_ragged(rows, w_gate, w_up, w_down, tile_expert, *,
                   m_blk: int = 128, f_blk: int = 128,
                   interpret: Optional[bool] = None) -> jax.Array:
    """Ragged grouped matmul: rows (n_rows, d) sorted by expert with
    tile-aligned groups, tile_expert (n_rows/m_blk,) the per-tile owner
    (n_experts = sentinel). Pads F to the tile multiple; rows must already
    be m_blk-aligned (models.moe.ragged_dispatch pads them)."""
    interpret = _auto_interpret() if interpret is None else interpret
    assert rows.shape[0] % m_blk == 0, (rows.shape, m_blk)
    n_experts = w_gate.shape[0]
    wg_p, f0 = _pad_to(w_gate, 2, min(f_blk, max(w_gate.shape[2], 1)))
    wu_p, _ = _pad_to(w_up, 2, min(f_blk, max(w_up.shape[2], 1)))
    wd_p, _ = _pad_to(w_down, 1, min(f_blk, max(w_down.shape[1], 1)))
    fetch = fetch_expert_ids(tile_expert, n_experts)
    return moe_gmm_ragged_pallas(rows, wg_p, wu_p, wd_p, tile_expert, fetch,
                                 m_blk=m_blk, f_blk=f_blk,
                                 interpret=interpret)


def model_gmm_fn(cfg=None):
    """Adapter matching models.moe.apply_moe's dense ``gmm_fn`` contract."""
    def fn(cfg_, p, buf):
        return moe_gmm(buf, p["w_gate"], p["w_up"], p["w_down"])
    fn.ragged = False
    return fn


def ragged_gmm_fn(cfg=None):
    """Adapter matching models.moe.apply_moe's ragged ``gmm_fn`` contract
    (moe_dispatch="ragged"): receives the expert-sorted row buffer plus the
    per-tile expert metadata and runs the scalar-prefetch Pallas kernel."""
    def fn(cfg_, p, rows, tile_expert, m_blk):
        return moe_gmm_ragged(rows, p["w_gate"], p["w_up"], p["w_down"],
                              tile_expert, m_blk=m_blk)
    fn.ragged = True
    return fn
