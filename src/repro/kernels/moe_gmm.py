"""Pallas TPU batched per-expert FFN kernel (fused SwiGLU "grouped matmul").

THE kernel of the paper's cost argument: expert weight blocks stream
HBM→VMEM once per grid step, so total expert-weight traffic is exactly
(#experts touched × bytes/expert) per pass — the quantity layered prefill
keeps at one pass per layer while chunked prefill multiplies it by the
chunk count.

Computes, for each expert e over its capacity buffer row:
    out[e] = (silu(x[e] @ w_gate[e]) * (x[e] @ w_up[e])) @ w_down[e]

Grid (E, C/c_blk, F/f_blk); the f axis is a reduction for the down
projection, accumulated in the output block (revisited across f steps —
Pallas keeps the block resident in VMEM). Tiles default to MXU-aligned
128×128.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gmm_kernel(x_ref, wg_ref, wu_ref, wd_ref, o_ref):
    fi = pl.program_id(2)
    x = x_ref[0].astype(jnp.float32)                     # (c_blk, d)
    wg = wg_ref[0].astype(jnp.float32)                   # (d, f_blk)
    wu = wu_ref[0].astype(jnp.float32)
    wd = wd_ref[0].astype(jnp.float32)                   # (f_blk, d)
    h = jax.nn.silu(x @ wg) * (x @ wu)                   # (c_blk, f_blk)
    part = h @ wd                                        # (c_blk, d)

    @pl.when(fi == 0)
    def _init():
        o_ref[0] = part.astype(o_ref.dtype)

    @pl.when(fi > 0)
    def _acc():
        o_ref[0] = (o_ref[0].astype(jnp.float32) + part).astype(o_ref.dtype)


def moe_gmm_pallas(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
                   w_down: jax.Array, *, c_blk: int = 128, f_blk: int = 128,
                   interpret: bool = False) -> jax.Array:
    """x: (E, C, d); w_gate/w_up: (E, d, F); w_down: (E, F, d) -> (E, C, d).
    C and F must be multiples of the tile sizes (ops.py pads)."""
    e, c, d = x.shape
    f = w_gate.shape[-1]
    c_blk = min(c_blk, c)
    f_blk = min(f_blk, f)
    assert c % c_blk == 0 and f % f_blk == 0, (c, f, c_blk, f_blk)

    out = pl.pallas_call(
        functools.partial(_gmm_kernel),
        grid=(e, c // c_blk, f // f_blk),
        in_specs=[
            pl.BlockSpec((1, c_blk, d), lambda ei, ci, fi: (ei, ci, 0)),
            pl.BlockSpec((1, d, f_blk), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, d, f_blk), lambda ei, ci, fi: (ei, 0, fi)),
            pl.BlockSpec((1, f_blk, d), lambda ei, ci, fi: (ei, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, c_blk, d), lambda ei, ci, fi: (ei, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        interpret=interpret,
    )(x, w_gate, w_up, w_down)
    return out
