"""Pallas TPU ragged grouped-matmul (fused SwiGLU) for dropless MoE.

MegaBlocks-style layout: token assignments are sorted by expert id into one
flat ``(rows, d)`` buffer whose per-expert groups are padded to row-tile
boundaries, so every ``m_blk``-row tile is wholly owned by ONE expert (or by
no expert — trailing alignment padding). The owner of each tile arrives as
*scalar-prefetched* metadata (``pltpu.PrefetchScalarGridSpec``): the weight
BlockSpec index maps read ``tile_expert[ti]`` before the kernel body runs,
so the DMA engine streams exactly the touched experts' weight blocks
HBM→VMEM and consecutive tiles of the same expert re-use the resident block
(Pallas skips the copy when the index map output is unchanged).

Compared to the dense ``(E, C, d)`` capacity-buffer kernel (moe_gmm.py) at
dropless capacity ``C = T``, the grid walks ``sum_e ceil(count_e / m_blk)``
row tiles instead of ``E * T / c_blk`` — compute and traffic scale with the
routed work ``sum(counts)``, not ``E × T`` (≈ ``E / top_k`` × smaller; 16×
for qwen3-30b-a3b), and experts with zero tokens cost nothing at all.

Grid ``(n_tiles, F/f_blk)``; the f axis is a reduction for the down
projection accumulated in the revisited output block, exactly as in the
dense kernel. Sentinel tiles (``tile_expert[ti] == n_experts``) skip the
MXU work and zero their output rows; their weight index map points at
``fetch_expert[ti]`` — the last active expert — so no fresh DMA is issued
for them.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_ragged_kernel(n_experts: int, te_ref, fe_ref, x_ref, wg_ref, wu_ref,
                       wd_ref, o_ref):
    del fe_ref  # consumed by the weight index maps only
    ti = pl.program_id(0)
    fi = pl.program_id(1)
    te = te_ref[ti]

    @pl.when(te == n_experts)                 # alignment-padding tile
    def _sentinel():
        @pl.when(fi == 0)
        def _zero():
            o_ref[...] = jnp.zeros_like(o_ref)

    @pl.when(te < n_experts)
    def _active():
        x = x_ref[...].astype(jnp.float32)               # (m_blk, d)
        wg = wg_ref[0].astype(jnp.float32)               # (d, f_blk)
        wu = wu_ref[0].astype(jnp.float32)
        wd = wd_ref[0].astype(jnp.float32)               # (f_blk, d)
        h = jax.nn.silu(x @ wg) * (x @ wu)               # (m_blk, f_blk)
        part = h @ wd                                    # (m_blk, d)

        @pl.when(fi == 0)
        def _init():
            o_ref[...] = part.astype(o_ref.dtype)

        @pl.when(fi > 0)
        def _acc():
            o_ref[...] = (o_ref[...].astype(jnp.float32)
                          + part).astype(o_ref.dtype)


def moe_gmm_ragged_pallas(rows: jax.Array, w_gate: jax.Array,
                          w_up: jax.Array, w_down: jax.Array,
                          tile_expert: jax.Array, fetch_expert: jax.Array, *,
                          m_blk: int = 128, f_blk: int = 128,
                          interpret: bool = False) -> jax.Array:
    """rows: (n_rows, d) expert-sorted tile-aligned token buffer;
    w_gate/w_up: (E, d, F); w_down: (E, F, d);
    tile_expert: (n_rows / m_blk,) int32 in [0, E] (E = padding sentinel);
    fetch_expert: same shape, sentinel replaced by a valid expert id (drives
    the weight DMA for skipped tiles so they issue no fresh copy).
    Returns (n_rows, d). n_rows % m_blk == 0 and F % f_blk == 0 (ops.py
    pads)."""
    n_rows, d = rows.shape
    e, _, f = w_gate.shape
    f_blk = min(f_blk, f)
    assert n_rows % m_blk == 0 and f % f_blk == 0, (n_rows, f, m_blk, f_blk)
    n_tiles = n_rows // m_blk
    assert tile_expert.shape == (n_tiles,), (tile_expert.shape, n_tiles)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles, f // f_blk),
        in_specs=[
            pl.BlockSpec((m_blk, d), lambda ti, fi, te, fe: (ti, 0)),
            pl.BlockSpec((1, d, f_blk), lambda ti, fi, te, fe: (fe[ti], 0, fi)),
            pl.BlockSpec((1, d, f_blk), lambda ti, fi, te, fe: (fe[ti], 0, fi)),
            pl.BlockSpec((1, f_blk, d), lambda ti, fi, te, fe: (fe[ti], fi, 0)),
        ],
        out_specs=pl.BlockSpec((m_blk, d), lambda ti, fi, te, fe: (ti, 0)),
    )
    return pl.pallas_call(
        functools.partial(_gmm_ragged_kernel, e),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_rows, d), rows.dtype),
        interpret=interpret,
    )(tile_expert.astype(jnp.int32), fetch_expert.astype(jnp.int32),
      rows, w_gate, w_up, w_down)
