"""Pallas TPU flash-attention kernel (prefill path).

Causal (optionally sliding-window) self-attention with GQA, online-softmax
over KV tiles. Tiling is MXU/VMEM-aware: the q tile (q_blk × head_dim) and
one kv tile (kv_blk × head_dim) plus the (q_blk × kv_blk) score tile live in
VMEM; accumulation is float32.

Grid: (batch, q_heads, n_q_tiles). The kv BlockSpec index maps a q head to
its kv head (h % hkv — g-major grouping, matching the model's
sharding-friendly convention) so GQA never materialises repeated K/V.

This kernel is the TPU analogue of the FlashAttention-3 prefill kernels the
paper's system uses — the compute-bound stage whose scheduling layered
prefill rearranges.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, kv_blk: int, causal: bool,
                  window: Optional[int], scale: float, seq_len: int):
    qi = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * scale           # (q_blk, hd)
    q_blk = q.shape[0]
    q_start = qi * q_blk

    n_kv = seq_len // kv_blk
    if causal:
        # tiles beyond the causal frontier contribute nothing
        hi = jnp.minimum((q_start + q_blk + kv_blk - 1) // kv_blk, n_kv)
    else:
        hi = n_kv
    if window is not None:
        lo = jnp.maximum((q_start - window) // kv_blk, 0)
    else:
        lo = 0

    acc = jnp.zeros((q_blk, q_ref.shape[-1]), jnp.float32)
    m = jnp.full((q_blk,), NEG_INF, jnp.float32)
    l = jnp.zeros((q_blk,), jnp.float32)

    q_pos = q_start + jax.lax.iota(jnp.int32, q_blk)

    def body(t, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.ds(t * kv_blk, kv_blk)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(t * kv_blk, kv_blk)].astype(jnp.float32)
        s = q @ k.T                                        # (q_blk, kv_blk)
        kv_pos = t * kv_blk + jax.lax.iota(jnp.int32, kv_blk)
        mask = jnp.ones_like(s, dtype=bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window is not None:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc, m, l))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention_pallas(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           window: Optional[int] = None,
                           q_blk: int = 128, kv_blk: int = 128,
                           scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B, S, H, hd); k/v: (B, S, Hkv, hd) -> (B, S, H, hd).
    S must be a multiple of the tile sizes (ops.py pads)."""
    b, s, h, hd = q.shape
    hkv = k.shape[2]
    assert s % q_blk == 0 and s % kv_blk == 0, (s, q_blk, kv_blk)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qt = q.transpose(0, 2, 1, 3)      # (B, H, S, hd)
    kt = k.transpose(0, 2, 1, 3)      # (B, Hkv, S, hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_flash_kernel, kv_blk=kv_blk, causal=causal,
                               window=window, scale=scale, seq_len=s)
    out = pl.pallas_call(
        kernel,
        grid=(b, h, s // q_blk),
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, s, hd),
                         lambda bi, hi, qi: (bi, hi % hkv, 0, 0)),
            pl.BlockSpec((1, 1, s, hd),
                         lambda bi, hi, qi: (bi, hi % hkv, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
