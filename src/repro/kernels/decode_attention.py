"""Pallas TPU decode-attention kernel.

One new query token per sequence attending over a padded slot KV cache with
per-row valid lengths — the memory-bound stage whose stall-freeness the
schedulers protect. Grid is (batch, kv_heads): each step streams that kv
head's cache once from HBM through VMEM while computing all ``group`` query
heads that share it (GQA reuse), with online softmax over KV tiles.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, kv_blk: int,
                   scale: float, max_len: int, window: Optional[int]):
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (g, hd)
    g = q.shape[0]
    length = len_ref[0]                                    # valid kv entries

    n_kv = max_len // kv_blk
    hi = jnp.minimum((length + kv_blk - 1) // kv_blk, n_kv)
    if window is not None:
        lo = jnp.maximum((length - window) // kv_blk, 0)
    else:
        lo = 0

    acc = jnp.zeros((g, q.shape[-1]), jnp.float32)
    m = jnp.full((g,), NEG_INF, jnp.float32)
    l = jnp.zeros((g,), jnp.float32)

    def body(t, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.ds(t * kv_blk, kv_blk)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(t * kv_blk, kv_blk)].astype(jnp.float32)
        s = q @ k.T                                        # (g, kv_blk)
        kv_pos = t * kv_blk + jax.lax.iota(jnp.int32, kv_blk)
        mask = kv_pos[None, :] < length
        if window is not None:
            mask &= kv_pos[None, :] >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc, m, l))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array, *,
                            kv_blk: int = 128,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); caches: (B, S_max, Hkv, hd); lengths: (B,) int32
    (#valid entries INCLUDING the new token's K/V already written).
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    assert s_max % kv_blk == 0, (s_max, kv_blk)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # g-major grouping (q head h -> kv head h % hkv): gather each kv
    # head's g query heads into a contiguous block for the kernel.
    qg = q.reshape(b, g, hkv, hd).transpose(0, 2, 1, 3)
    kt = k_cache.transpose(0, 2, 1, 3)    # (B, Hkv, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, kv_blk=kv_blk, scale=scale,
                               max_len=s_max, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s_max, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s_max, hd), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.transpose(0, 2, 1, 3).reshape(b, h, hd)
