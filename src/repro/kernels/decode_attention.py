"""Pallas TPU decode-attention kernels: contiguous and paged.

One new query token per sequence attending over the KV cache with per-row
valid lengths — the memory-bound stage whose stall-freeness the schedulers
protect.

``decode_attention_pallas`` assumes the slot layout: each sequence owns a
contiguous ``max_len`` cache row.  Grid is (batch, kv_heads): each step
streams that kv head's cache once from HBM through VMEM while computing all
``group`` query heads that share it (GQA reuse), with online softmax over
KV tiles.

``paged_decode_attention_pallas`` is the page-table-aware variant backing
the PagedKVAllocator's scattered physical layout: K/V live in a global
``(n_pages, page_size, Hkv, hd)`` pool and each sequence's *block table*
(scalar-prefetched, so the index maps can read it before the body runs)
names the physical pages holding its KV in logical order.  Grid is (batch,
kv_heads, max_pages): the DMA engine streams exactly the pages the block
table names — one page per grid step — while online-softmax state persists
in VMEM scratch across the page axis, exactly the structure of the slot
kernel with the contiguous row replaced by a block-table walk.

``paged_verify_attention_pallas`` generalizes the paged kernel to a
``W``-token query *window* per sequence — the speculative verify-k shape
(W = k+1 drafted-plus-bonus tokens; DESIGN.md §Speculative decode).  The
window's rows are packed into the same per-kv-head register block the GQA
group already occupies (``W*g`` rows), so the KV stream is read from HBM
ONCE for the whole window — the kernel-level expression of the verify-k
amortization the cost model prices.  Inside the window the mask is
causal: query ``w`` sees kv positions ``< length - W + 1 + w``.
"""

from __future__ import annotations

import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, *, kv_blk: int,
                   scale: float, max_len: int, window: Optional[int]):
    q = q_ref[0, 0].astype(jnp.float32) * scale            # (g, hd)
    g = q.shape[0]
    length = len_ref[0]                                    # valid kv entries

    n_kv = max_len // kv_blk
    hi = jnp.minimum((length + kv_blk - 1) // kv_blk, n_kv)
    if window is not None:
        lo = jnp.maximum((length - window) // kv_blk, 0)
    else:
        lo = 0

    acc = jnp.zeros((g, q.shape[-1]), jnp.float32)
    m = jnp.full((g,), NEG_INF, jnp.float32)
    l = jnp.zeros((g,), jnp.float32)

    def body(t, carry):
        acc, m, l = carry
        k = k_ref[0, 0, pl.ds(t * kv_blk, kv_blk)].astype(jnp.float32)
        v = v_ref[0, 0, pl.ds(t * kv_blk, kv_blk)].astype(jnp.float32)
        s = q @ k.T                                        # (g, kv_blk)
        kv_pos = t * kv_blk + jax.lax.iota(jnp.int32, kv_blk)
        mask = kv_pos[None, :] < length
        if window is not None:
            mask &= kv_pos[None, :] >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=-1)
        acc = acc * alpha[:, None] + p @ v
        return acc, m_new, l_new

    acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc, m, l))
    o_ref[0, 0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def decode_attention_pallas(q: jax.Array, k_cache: jax.Array,
                            v_cache: jax.Array, lengths: jax.Array, *,
                            kv_blk: int = 128,
                            window: Optional[int] = None,
                            scale: Optional[float] = None,
                            interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); caches: (B, S_max, Hkv, hd); lengths: (B,) int32
    (#valid entries INCLUDING the new token's K/V already written).
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    g = h // hkv
    assert s_max % kv_blk == 0, (s_max, kv_blk)
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # g-major grouping (q head h -> kv head h % hkv): gather each kv
    # head's g query heads into a contiguous block for the kernel.
    qg = q.reshape(b, g, hkv, hd).transpose(0, 2, 1, 3)
    kt = k_cache.transpose(0, 2, 1, 3)    # (B, Hkv, S, hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    kernel = functools.partial(_decode_kernel, kv_blk=kv_blk, scale=scale,
                               max_len=s_max, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(b, hkv),
        in_specs=[
            pl.BlockSpec((1,), lambda bi, hi: (bi,)),
            pl.BlockSpec((1, 1, g, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s_max, hd), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, s_max, hd), lambda bi, hi: (bi, hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd), lambda bi, hi: (bi, hi, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, kt, vt)
    return out.transpose(0, 2, 1, 3).reshape(b, h, hd)


# ---------------------------------------------------------------------------
# Page-table-aware variant (PagedKVAllocator physical layout)
# ---------------------------------------------------------------------------


def _paged_decode_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page_size: int,
                         scale: float, max_pages: int,
                         window: Optional[int]):
    bi = pl.program_id(0)
    pi = pl.program_id(2)
    length = len_ref[bi]

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n_seq_pages = (length + page_size - 1) // page_size

    @pl.when(pi < n_seq_pages)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = q @ k.T                                        # (g, page)
        kv_pos = pi * page_size + jax.lax.iota(jnp.int32, page_size)
        mask = kv_pos[None, :] < length
        if window is not None:
            mask &= kv_pos[None, :] >= length - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v

    @pl.when(pi == max_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def _paged_verify_kernel(len_ref, bt_ref, q_ref, k_ref, v_ref, o_ref,
                         acc_ref, m_ref, l_ref, *, page_size: int,
                         scale: float, max_pages: int, win: int,
                         group: int, window: Optional[int]):
    bi = pl.program_id(0)
    pi = pl.program_id(2)
    length = len_ref[bi]

    @pl.when(pi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    n_seq_pages = (length + page_size - 1) // page_size
    rows = win * group

    @pl.when(pi < n_seq_pages)
    def _page():
        q = q_ref[0, 0].astype(jnp.float32) * scale        # (W*g, hd)
        k = k_ref[0, :, 0].astype(jnp.float32)             # (page, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = q @ k.T                                        # (W*g, page)
        kv_pos = pi * page_size + jax.lax.iota(jnp.int32, page_size)
        # row r holds window query w = r // g; its causal KV horizon is
        # length - W + 1 + w valid entries (the last row sees everything)
        w_idx = jax.lax.iota(jnp.int32, rows) // group
        row_len = length - win + 1 + w_idx                 # (W*g,)
        mask = kv_pos[None, :] < row_len[:, None]
        if window is not None:
            mask &= kv_pos[None, :] >= row_len[:, None] - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
        p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
        alpha = jnp.exp(m_prev - m_new)
        m_ref[...] = m_new
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=-1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + p @ v

    @pl.when(pi == max_pages - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def paged_verify_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  block_tables: jax.Array,
                                  lengths: jax.Array, *,
                                  window: Optional[int] = None,
                                  scale: Optional[float] = None,
                                  interpret: bool = False) -> jax.Array:
    """Verify-window paged attention.  q: (B, W, H, hd) — the W = k+1
    window query tokens per sequence, oldest first; k_pages/v_pages:
    (n_pages, page_size, Hkv, hd) global pool; block_tables:
    (B, max_pages) int32; lengths: (B,) int32 valid KV tokens INCLUDING
    all W window tokens' K/V already written.  Returns (B, W, H, hd).
    Each sequence's KV stream is read once for the whole window."""
    b, win, h, hd = q.shape
    n_pages, page_size, hkv, _ = k_pages.shape
    g = h // hkv
    max_pages = block_tables.shape[1]
    assert block_tables.shape == (b, max_pages), block_tables.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    # pack the window into the GQA row block: (B, Hkv, W*g, hd), rows
    # w-major so row r <-> (w = r // g, head-in-group r % g)
    qg = q.reshape(b, win, g, hkv, hd).transpose(0, 3, 1, 2, 4) \
          .reshape(b, hkv, win * g, hd)
    bt_flat = block_tables.reshape(-1).astype(jnp.int32)

    kernel = functools.partial(_paged_verify_kernel, page_size=page_size,
                               scale=scale, max_pages=max_pages, win=win,
                               group=g, window=window)
    rows = win * g
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # lengths, flat block tables
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, rows, hd),
                         lambda bi, hi, pi, lens, bt: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, pi, lens, bt:
                         (bt[bi * max_pages + pi], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, pi, lens, bt:
                         (bt[bi * max_pages + pi], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, rows, hd),
                               lambda bi, hi, pi, lens, bt: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((rows, hd), jnp.float32),   # acc
            pltpu.VMEM((rows, 1), jnp.float32),    # running max
            pltpu.VMEM((rows, 1), jnp.float32),    # running denom
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, rows, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), bt_flat, qg, k_pages, v_pages)
    return out.reshape(b, hkv, win, g, hd).transpose(0, 2, 3, 1, 4) \
              .reshape(b, win, h, hd)


def paged_decode_attention_pallas(q: jax.Array, k_pages: jax.Array,
                                  v_pages: jax.Array,
                                  block_tables: jax.Array,
                                  lengths: jax.Array, *,
                                  window: Optional[int] = None,
                                  scale: Optional[float] = None,
                                  interpret: bool = False) -> jax.Array:
    """q: (B, H, hd); k_pages/v_pages: (n_pages, page_size, Hkv, hd) —
    the global page pool; block_tables: (B, max_pages) int32 physical page
    ids in logical order (entries past a sequence's page count are ignored
    but must be valid indices — pad with 0); lengths: (B,) int32 valid KV
    tokens INCLUDING the new token's K/V already written.
    Returns (B, H, hd)."""
    b, h, hd = q.shape
    n_pages, page_size, hkv, _ = k_pages.shape
    g = h // hkv
    max_pages = block_tables.shape[1]
    assert block_tables.shape == (b, max_pages), block_tables.shape
    scale = scale if scale is not None else 1.0 / math.sqrt(hd)

    qg = q.reshape(b, g, hkv, hd).transpose(0, 2, 1, 3)   # (B, Hkv, g, hd)
    bt_flat = block_tables.reshape(-1).astype(jnp.int32)

    kernel = functools.partial(_paged_decode_kernel, page_size=page_size,
                               scale=scale, max_pages=max_pages,
                               window=window)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                 # lengths, flat block tables
        grid=(b, hkv, max_pages),
        in_specs=[
            pl.BlockSpec((1, 1, g, hd),
                         lambda bi, hi, pi, lens, bt: (bi, hi, 0, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, pi, lens, bt:
                         (bt[bi * max_pages + pi], 0, hi, 0)),
            pl.BlockSpec((1, page_size, 1, hd),
                         lambda bi, hi, pi, lens, bt:
                         (bt[bi * max_pages + pi], 0, hi, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, g, hd),
                               lambda bi, hi, pi, lens, bt: (bi, hi, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, hd), jnp.float32),   # acc
            pltpu.VMEM((g, 1), jnp.float32),    # running max
            pltpu.VMEM((g, 1), jnp.float32),    # running denom
        ],
    )
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, hd), q.dtype),
        interpret=interpret,
    )(lengths.astype(jnp.int32), bt_flat, qg, k_pages, v_pages)
    return out.transpose(0, 2, 1, 3).reshape(b, h, hd)
