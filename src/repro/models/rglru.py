"""RecurrentGemma's recurrent block: causal depthwise conv + RG-LRU
(Real-Gated Linear Recurrent Unit), arXiv:2402.19427.

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)
    a_t = exp(-c * softplus(Lambda) * sigmoid(r_t)),  c = 8

The recurrence is linear in h, so prefill uses ``jax.lax.associative_scan``
(TPU-friendly log-depth scan) rather than a sequential loop; decode is a
single fused step.  State per block: conv tail (B, conv_width-1, W) and the
LRU hidden (B, W).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _dense

Array = jax.Array
_C = 8.0
_N_GATE_BLOCKS = 16


def lru_width(cfg: ModelConfig) -> int:
    return cfg.lru_width or cfg.d_model


def _block_diag(x: Array, w: Array) -> Array:
    """x: (..., W) @ block-diagonal w: (NB, W/NB, W/NB) -> (..., W)."""
    nb, bw, _ = w.shape
    xb = x.reshape(x.shape[:-1] + (nb, bw))
    yb = jnp.einsum("...nb,nbc->...nc", xb, w)
    return yb.reshape(x.shape)


def init_rglru(cfg: ModelConfig, key) -> dict:
    d, w = cfg.d_model, lru_width(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    # Lambda init so that a^2 ~ U[0.81, 0.999] (paper's init)
    u = jax.random.uniform(ks[0], (w,), minval=0.81, maxval=0.999)
    log_a = 0.5 * jnp.log(u)                              # log a
    a_param = jnp.log(jnp.expm1(-log_a / _C))             # inv softplus
    return {
        "w_x": _dense(ks[1], (d, w), dt),                 # input branch
        "w_gate": _dense(ks[2], (d, w), dt),              # multiplicative gate
        "w_out": _dense(ks[3], (w, d), dt),
        "conv_w": _dense(ks[4], (cfg.conv_width, w), dt, scale=0.1),
        "conv_b": jnp.zeros((w,), dt),
        # Griffin uses block-diagonal gate projections (block_width blocks);
        # this is also what keeps the gates tensor-parallel friendly.
        "w_input_gate": _dense(ks[5], (_N_GATE_BLOCKS, w // _N_GATE_BLOCKS,
                                       w // _N_GATE_BLOCKS), dt, scale=0.02),
        "w_rec_gate": _dense(ks[6], (_N_GATE_BLOCKS, w // _N_GATE_BLOCKS,
                                     w // _N_GATE_BLOCKS), dt, scale=0.02),
        "a_param": a_param.astype(jnp.float32),
    }


def init_cache_rglru(cfg: ModelConfig, batch: int, dtype=None) -> dict:
    w = lru_width(cfg)
    dt = dtype or cfg.dtype
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), dt),
        "h": jnp.zeros((batch, w), jnp.float32),
    }


def _causal_conv(p, x: Array, conv_state: Optional[Array],
                 valid: Optional[Array] = None) -> Tuple[Array, Array]:
    """Depthwise causal conv1d. x: (B,S,W). Returns (y, new_tail).

    With ``valid`` (B,S), the returned tail is taken at each row's true
    length (the last cw-1 REAL inputs), so right-padding never leaks into
    the decode-time conv state. Assumes valid tokens are a prefix."""
    cw = p["conv_w"].shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)  # (B,S+cw-1,W)
    y = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i] for i in range(cw))
    y = y + p["conv_b"]
    if valid is None:
        new_tail = xp[:, -(cw - 1):, :]
    else:
        lengths = valid.sum(axis=-1).astype(jnp.int32)      # (B,)
        idx = lengths[:, None] + jnp.arange(cw - 1)[None]   # xp rows [L, L+cw-2]
        new_tail = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return y, new_tail


def _lru_scan(a: Array, b: Array, h0: Array) -> Array:
    """Linear recurrence h_t = a_t h_{t-1} + b_t over axis=1 via an
    associative scan; h0: (B,W) initial state. Returns h for every t."""
    # fold h0 into the first step
    b = b.at[:, 0].add(a[:, 0] * h0)

    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def apply_rglru(cfg: ModelConfig, p, x: Array, *,
                cache: Optional[dict] = None,
                valid: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    """x: (B,S,D) -> (out (B,S,D), new_cache). ``valid`` (B,S) turns masked
    timesteps into identity state updates (a=1, b=0) so padding never
    perturbs the recurrent state."""
    b, s, d = x.shape
    gate = jax.nn.gelu(x @ p["w_gate"])                   # (B,S,W)
    xin = x @ p["w_x"]                                    # (B,S,W)
    conv_state = cache["conv"] if cache is not None else None
    xc, new_tail = _causal_conv(p, xin, conv_state, valid)

    xf = xc.astype(jnp.float32)
    rg = jax.nn.sigmoid(_block_diag(xf, p["w_rec_gate"].astype(jnp.float32)))
    ig = jax.nn.sigmoid(_block_diag(xf, p["w_input_gate"].astype(jnp.float32)))
    log_a = -_C * jax.nn.softplus(p["a_param"]) * rg      # (B,S,W)
    a = jnp.exp(log_a)
    mult = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bt = mult * (ig * xf)
    if valid is not None:
        v = valid[..., None]
        a = jnp.where(v, a, 1.0)
        bt = jnp.where(v, bt, 0.0)

    h0 = cache["h"] if cache is not None else jnp.zeros((b, xin.shape[-1]), jnp.float32)
    h = _lru_scan(a, bt, h0)                              # (B,S,W)

    y = (h.astype(x.dtype) * gate) @ p["w_out"]
    new_cache = None
    if cache is not None:
        row_ok = valid.any(-1)[:, None] if valid is not None else None
        tail = new_tail.astype(cache["conv"].dtype)
        if row_ok is not None:
            tail = jnp.where(row_ok[..., None], tail, cache["conv"])
        new_cache = {"conv": tail, "h": h[:, -1]}
    return y, new_cache
