"""Mixture-of-Experts FFN: top-k router, two dispatch layouts, shared
experts, and — central to the paper — *expert-load accounting*.

Dispatch layouts (``moe_dispatch``):

- ``"dense"`` — sort-based capacity dispatch into a dense (E, C, d) buffer;
  the per-expert GEMM is a batched matmul whose leading axis can be sharded
  over the ``model`` mesh axis. GShard-style capacity drops in training;
  ``dropless=True`` sizes C = T (worst case), which computes/streams
  ``E / top_k`` × more rows than were actually routed (16× for
  qwen3-30b-a3b, E=128 top-8).

- ``"ragged"`` — MegaBlocks-style dropless dispatch: assignments are sorted
  by expert id into ONE flat (rows, d) buffer whose per-expert groups are
  padded to row-tile boundaries, so compute and HBM traffic scale with
  ``sum(expert_counts)`` (+ ≤ one tile of alignment padding per active
  expert), never with ``E × T``, and empty experts cost nothing. The Pallas
  ``moe_gmm_ragged`` kernel consumes this layout with scalar-prefetched
  per-tile expert ids, so its weight traffic is ``active_experts ×
  bytes_per_expert`` — the exact quantity the serving engine's
  ``expert_load_bytes`` counter measures (§5.4, Table 7). Ragged dispatch
  never drops an assignment (it is inherently dropless); the serving engine
  uses it by default. Measured traffic/compute ratio vs the dense dropless
  buffer (benchmarks/gmm_ragged_vs_dense.py): GMM rows shrink to
  ``top_k/E`` once coverage saturates — 0.064× at T=32k for qwen3-30b-a3b
  (E=128, top-8) — and the CPU jnp data path runs ~4–16× faster at
  T=2048 (top_k 8 → 1, E=32).

Both layouts run under ``shard_map`` expert parallelism: the ragged "a2a"
path moves per-destination-shard ragged groups (static worst-case chunk
size, per-source counts communicated alongside) through the same pair of
all-to-alls as the dense path; "psum" keeps tokens replicated over the
expert axis and combines with one psum.

Every forward returns an ``aux`` dict containing, per MoE block:
  - ``expert_counts`` (E,) int32 — tokens routed to each expert,
  - ``active_experts`` ()  int32 — #experts with >=1 token: multiplied by
    bytes-per-expert this is exactly the paper's "expert weight load bytes"
    counter (§5.4, Table 7),
  - ``aux_loss`` — Switch-style load-balance loss (training).
"""

from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _dense
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.sharding.partition import active_context

Array = jax.Array


def init_moe(cfg: ModelConfig, key) -> dict:
    e = cfg.moe
    d, f = cfg.d_model, e.expert_d_ff
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    p = {
        "router": _dense(ks[0], (d, e.n_experts), dt, scale=0.02),
        "w_gate": _dense(ks[1], (e.n_experts, d, f), dt),
        "w_up": _dense(ks[2], (e.n_experts, d, f), dt),
        "w_down": _dense(ks[3], (e.n_experts, f, d), dt),
    }
    if e.n_shared_experts:
        fs = e.shared_d_ff * e.n_shared_experts
        p["shared"] = {
            "w_gate": _dense(ks[4], (d, fs), dt),
            "w_up": _dense(ks[5], (d, fs), dt),
            "w_down": _dense(ks[6], (fs, d), dt),
        }
    return p


def capacity(cfg: ModelConfig, n_tokens: int) -> int:
    e = cfg.moe
    c = int(math.ceil(n_tokens * e.top_k / e.n_experts * e.capacity_factor))
    c = max(c, e.top_k)
    # round up to an MXU-friendly multiple when big enough
    if c > 8:
        c = (c + 7) // 8 * 8
    return min(c, n_tokens)


def route(cfg: ModelConfig, p, x_flat: Array) -> Tuple[Array, Array, Array]:
    """x_flat: (T, d) -> (expert_idx (T,k), weights (T,k), probs (T,E))."""
    e = cfg.moe
    logits = (x_flat.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, e.top_k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)        # Qwen3/DeepSeek renorm
    return idx, w, probs


def dispatch_indices(expert_idx: Array, n_experts: int, cap: int):
    """Sort-based ranking: for each (token,k) assignment compute its slot in
    the (E, C) capacity buffer; assignments beyond capacity are dropped.

    Assignments with expert id == n_experts (masked padding) are dropped.
    Returns (slot (T*k,), keep (T*k,), counts (E,))."""
    flat = expert_idx.reshape(-1)                      # (T*k,)
    counts = jnp.bincount(flat, length=n_experts)      # (E,) — excludes id==E
    order = jnp.argsort(flat, stable=True)             # sorted assignment ids
    # position within the expert group for each sorted element
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    sorted_expert = flat[order]
    pos_sorted = (jnp.arange(flat.shape[0], dtype=jnp.int32)
                  - starts[jnp.minimum(sorted_expert, n_experts - 1)])
    pos = jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)
    keep = (pos < cap) & (flat < n_experts)
    slot = flat * cap + jnp.minimum(pos, cap - 1)
    return slot, keep, counts


def _dispatch_gmm_combine(cfg: ModelConfig, p, xf: Array, idx: Array,
                          w: Array, cap: int, n_local: int, gmm_fn):
    """Core MoE data path over n_local experts: gather tokens into the
    (E, C, d) capacity buffer, batched per-expert GEMM, weighted combine.
    idx entries >= n_local are dropped (masking / non-local experts).

    Layout note (§Perf iteration 4): the buffer is built by ONE gather of
    (E*C, d) via an inverted slot->token map, and the combine reads one
    (t, d) gather per top-k slot — the (t*k, d) duplicated-token tensor of
    the naive formulation (8x token bytes, with fp32 converts) never
    materializes. This halved the memory roofline term of qwen3-moe
    prefill_32k."""
    e = cfg.moe
    t, d = xf.shape
    slot, keep, counts = dispatch_indices(idx, n_local, cap)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), e.top_k)
    # invert: which token feeds each (expert, slot) cell (0 if unused —
    # the row is computed by the GEMM but never read back by the combine)
    tok_of_slot = jnp.zeros((n_local * cap,), jnp.int32).at[
        jnp.where(keep, slot, n_local * cap).astype(jnp.int32)
    ].set(tok_ids, mode="drop")
    buf = xf[tok_of_slot].reshape(n_local, cap, d)
    if gmm_fn is None:
        gmm_fn = expert_ffn_ref
    y = gmm_fn(cfg, p, buf)                               # (E_loc, C, d)
    y_flat = y.reshape(n_local * cap, d)

    slot_k = slot.reshape(t, e.top_k)
    keep_k = keep.reshape(t, e.top_k)
    out = jnp.zeros((t, d), y_flat.dtype)
    for i in range(e.top_k):                              # static, <= 8
        contrib = y_flat[slot_k[:, i]]                    # (t, d)
        gate = jnp.where(keep_k[:, i], w[:, i], 0.0)
        out = out + contrib * gate[:, None].astype(contrib.dtype)
    dropped = jnp.sum((idx.reshape(-1) < n_local) & ~keep)
    return out, counts, dropped


def ragged_tile_rows(n_assign: int, n_experts: int,
                     m_blk_max: int = 128) -> Tuple[int, int]:
    """Static (row-tile size, padded row count) for the ragged buffer.

    The tile size tracks the ceil-average expert load so tiny batches
    (decode) don't pay E × (m_blk - 1) alignment rows; the row count is the
    worst case ``sum_e ceil(count_e / m_blk) * m_blk`` — at most one tile of
    padding per expert — rounded up to a whole tile."""
    avg = max(1, -(-n_assign // max(n_experts, 1)))
    m_blk = 8
    while m_blk < min(avg, m_blk_max):
        m_blk *= 2
    rows = n_assign + n_experts * (m_blk - 1)
    rows = -(-rows // m_blk) * m_blk
    return m_blk, rows


def _group_ranks(flat: Array, counts: Array, n_experts: int) -> Array:
    """Rank of each flat assignment within its expert group (stable sort
    order). Entries with id >= n_experts get garbage ranks — callers mask
    them via ``keep``."""
    a = flat.shape[0]
    order = jnp.argsort(flat, stable=True)
    sorted_expert = flat[order]
    gstarts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1].astype(jnp.int32)])
    pos_sorted = (jnp.arange(a, dtype=jnp.int32)
                  - gstarts[jnp.minimum(sorted_expert, n_experts - 1)])
    return jnp.zeros_like(pos_sorted).at[order].set(pos_sorted)


def _combine_topk(y_flat: Array, slot: Array, keep: Array, w: Array) -> Array:
    """Weighted combine out[t] = sum_i w[t,i] * y_flat[slot[t,i]], masked
    by keep (dropped/masked assignments contribute nothing)."""
    t, top_k = w.shape
    n_rows = y_flat.shape[0]
    slot_k = slot.reshape(t, top_k)
    keep_k = keep.reshape(t, top_k)
    out = jnp.zeros((t, y_flat.shape[1]), y_flat.dtype)
    for i in range(top_k):                             # static, <= 8
        contrib = y_flat[jnp.minimum(slot_k[:, i], n_rows - 1)]
        gate = jnp.where(keep_k[:, i], w[:, i], 0.0)
        out = out + contrib * gate[:, None].astype(contrib.dtype)
    return out


def ragged_dispatch_indices(expert_idx: Array, n_experts: int, m_blk: int,
                            n_rows: int):
    """Ragged tile-aligned ranking: for each (token, k) assignment compute
    its row in the expert-sorted flat buffer whose per-expert groups start
    on ``m_blk`` boundaries. Assignments with expert id == n_experts
    (masked padding) get the out-of-range row ``n_rows`` and keep=False;
    nothing else is ever dropped.

    Returns (slot (T*k,), keep (T*k,), counts (E,),
    tile_expert (n_rows/m_blk,) — the expert owning each row tile, or the
    sentinel ``n_experts`` for alignment-padding tiles)."""
    flat = expert_idx.reshape(-1).astype(jnp.int32)    # (A,)
    counts = jnp.bincount(flat, length=n_experts).astype(jnp.int32)
    padded = (-(-counts // m_blk) * m_blk).astype(jnp.int32)
    pcum = jnp.cumsum(padded).astype(jnp.int32)        # inclusive
    starts = pcum - padded                             # tile-aligned starts
    pos = _group_ranks(flat, counts, n_experts)
    keep = flat < n_experts
    slot = jnp.where(keep, starts[jnp.minimum(flat, n_experts - 1)] + pos,
                     n_rows).astype(jnp.int32)
    # per-tile owner: the expert whose padded group covers the tile's first
    # row (groups are tile-aligned, so one owner per tile); rows beyond the
    # last group -> sentinel n_experts
    row0 = jnp.arange(n_rows // m_blk, dtype=jnp.int32) * m_blk
    tile_expert = jnp.searchsorted(pcum, row0,
                                   side="right").astype(jnp.int32)
    return slot, keep, counts, tile_expert


def ragged_ffn_ref(cfg: ModelConfig, p, rows: Array, tile_expert: Array,
                   m_blk: int) -> Array:
    """jnp fallback for the ragged grouped matmul (same contract as
    kernels/ops.ragged_gmm_fn): per row tile, the owning expert's fused
    SwiGLU FFN; sentinel tiles produce zeros. Thin adapter over the single
    oracle in kernels/ref.py — its per-tile weight gather mirrors the
    kernel's scalar-prefetched DMA (only touched experts' weights read)."""
    from repro.kernels.ref import moe_gmm_ragged_ref
    return moe_gmm_ragged_ref(rows, p["w_gate"], p["w_up"], p["w_down"],
                              tile_expert, m_blk)


def _dispatch_gmm_combine_ragged(cfg: ModelConfig, p, xf: Array, idx: Array,
                                 w: Array, n_local: int, gmm_fn):
    """Ragged counterpart of ``_dispatch_gmm_combine``: gather tokens into
    the expert-sorted tile-aligned (rows, d) buffer, ragged grouped GEMM,
    weighted combine. Inherently dropless — rows scale with the routed
    assignments, not E × T. idx entries >= n_local are masked out."""
    e = cfg.moe
    t, d = xf.shape
    m_blk, n_rows = ragged_tile_rows(t * e.top_k, n_local)
    slot, keep, counts, tile_expert = ragged_dispatch_indices(
        idx, n_local, m_blk, n_rows)
    tok_ids = jnp.repeat(jnp.arange(t, dtype=jnp.int32), e.top_k)
    tok_of_row = jnp.zeros((n_rows,), jnp.int32).at[
        jnp.where(keep, slot, n_rows)
    ].set(tok_ids, mode="drop")
    rows = xf[tok_of_row]                              # (n_rows, d)
    y = (gmm_fn or ragged_ffn_ref)(cfg, p, rows, tile_expert, m_blk)
    out = _combine_topk(y, slot, keep, w)
    return out, counts, jnp.zeros((), jnp.int32)


def _sharded_moe_plan(cfg: ModelConfig, b: int, s: int):
    """If a sharding context is active and the shapes divide, return the
    shard_map plan for the expert-parallel MoE path. mode "a2a" partitions
    tokens over batch x model and moves the capacity buffer by all-to-all
    (zero gathers, zero psums); mode "psum" (s not divisible by the TP
    degree, e.g. decode s=1) replicates tokens over the expert axis and
    psums the combine."""
    ctx = active_context()
    if ctx is None:
        return None
    mesh, rules = ctx
    tp = rules.get("tp") or ()
    batch = rules.get("batch") or ()
    tp_n = 1
    for a in tp:
        tp_n *= mesh.shape.get(a, 1)
    b_n = 1
    for a in batch:
        b_n *= mesh.shape.get(a, 1)
    e = cfg.moe
    if tp_n <= 1 or b_n <= 1:
        return None
    if e.n_experts % tp_n or (b * s) % b_n:
        return None
    # Gate by regime: with few tokens (decode) most experts are idle and
    # the XLA-auto path streams only the routed experts' weights; the
    # shard_map region would gather every device's full expert block per
    # layer (measured: qwen3-moe decode_32k collective 0.04 -> 3.2 s when
    # ungated). Threshold: expected tokens/expert >= 16 (~60 % coverage).
    if (b * s) * e.top_k < 16 * e.n_experts:
        return None
    mode = "a2a" if (b % b_n == 0 and s % tp_n == 0
                     and len(tp) == 1) else "psum"
    return mesh, tuple(batch), tuple(tp), b_n, tp_n, mode


def apply_moe(cfg: ModelConfig, p, x: Array, *,
              valid: Optional[Array] = None,
              gmm_fn=None, dropless: bool = False,
              moe_dispatch: str = "dense") -> Tuple[Array, dict]:
    """x: (B, S, d) -> (out (B,S,d), aux). ``gmm_fn`` optionally overrides the
    per-expert GEMM (the Pallas kernels plug in here; a dense gmm_fn takes
    the (E, C, d) capacity buffer, a ragged one — marked ``fn.ragged=True``
    — takes the expert-sorted (rows, d) buffer + per-tile expert ids).
    ``valid`` (B, S) masks padding tokens out of routing, capacity and the
    expert-load counters (they contribute nothing and load nothing).

    ``moe_dispatch`` picks the layout: "dense" (capacity buffer) or
    "ragged" (tile-aligned sorted buffer; inherently dropless, compute and
    traffic scale with the routed work — the serving engine's default).

    ``dropless=True`` sizes the dense capacity buffer to the worst case
    (every token on one expert) so no assignment is ever dropped — the
    serving engine uses this so outputs are schedule-invariant (vLLM-style
    serving never drops); training keeps GShard capacity dispatch.

    DISTRIBUTION (§Perf iteration 2): when a sharding context is active the
    routed-expert path runs under ``shard_map`` — tokens stay on their batch
    shard (GShard group-wise local dispatch with per-group capacity),
    experts are partitioned over the ``model`` axis, each device's expert
    weight block is gathered once per layer at the shard_map boundary, and
    one psum over the expert axis combines contributions. This replaces the
    XLA-auto path that re-materialized the global (E, C, d) capacity buffer
    with per-layer all-gathers (13.3 TB/device on qwen3-moe prefill_32k)."""
    e = cfg.moe
    if moe_dispatch not in ("dense", "ragged"):
        raise ValueError(f"unknown moe_dispatch {moe_dispatch!r}")
    if gmm_fn is not None and getattr(gmm_fn, "ragged", None) is not None \
            and gmm_fn.ragged != (moe_dispatch == "ragged"):
        raise ValueError(
            f"gmm_fn implements the "
            f"{'ragged' if gmm_fn.ragged else 'dense'} contract but "
            f"moe_dispatch={moe_dispatch!r}")
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    vflat = valid.reshape(t) if valid is not None else None

    plan = _sharded_moe_plan(cfg, b, s)
    if plan is not None:
        out, counts, dropped, pbar = _apply_moe_shard_map(
            cfg, p, xf, vflat, gmm_fn, dropless, plan, moe_dispatch)
    else:
        idx, w, probs = route(cfg, p, xf)
        if vflat is not None:
            # invalid tokens route out-of-bounds => dropped from dispatch
            idx = jnp.where(vflat[:, None], idx, e.n_experts)
        if moe_dispatch == "ragged":
            out, counts, dropped = _dispatch_gmm_combine_ragged(
                cfg, p, xf, idx, w, e.n_experts, gmm_fn)
        else:
            cap = t if dropless else capacity(cfg, t)
            out, counts, dropped = _dispatch_gmm_combine(
                cfg, p, xf, idx, w, cap, e.n_experts, gmm_fn)
        pbar = jnp.mean(probs, axis=0)

    if e.n_shared_experts:
        sp = p["shared"]
        g = xf @ sp["w_gate"]
        u = xf @ sp["w_up"]
        out = out + (jax.nn.silu(g) * u) @ sp["w_down"]

    # Load-balance aux loss (Switch): E * sum_i f_i * P_i
    f = counts.astype(jnp.float32) / jnp.maximum(t * e.top_k, 1)
    aux_loss = e.n_experts * jnp.sum(f * pbar) * e.router_aux_coef

    aux = {
        "expert_counts": counts.astype(jnp.int32),
        "active_experts": jnp.sum(counts > 0).astype(jnp.int32),
        "dropped": dropped.astype(jnp.int32),
        "aux_loss": aux_loss,
    }
    return out.reshape(b, s, d).astype(x.dtype), aux


def _apply_moe_shard_map(cfg: ModelConfig, p, xf: Array,
                         vflat: Optional[Array], gmm_fn, dropless: bool,
                         plan, moe_dispatch: str = "dense"):
    """Expert-parallel MoE under shard_map (see apply_moe docstring).

    mode "a2a" (§Perf iteration 7): tokens arrive partitioned over
    (batch x model) — aligned with the sequence-parallel residual, so no
    boundary gather. Each device dispatches its t/256 tokens into a
    (E, cap, d) buffer; one all_to_all over the expert axis turns it into
    (E_loc, cap * tp, d) for the local GEMM; the reverse all_to_all brings
    each token's expert outputs home; the combine is local. The only
    per-layer MoE collectives are the two all-to-alls.

    Ragged a2a (moe_dispatch="ragged"): each device lays its assignments
    out destination-shard-major — per shard j, a tile-aligned ragged buffer
    of the tokens routed to shard j's experts, statically sized to the
    worst case (all local assignments on one shard). One symmetric
    all_to_all moves the (tp, S_pair, d) chunk stack; the per-source padded
    group sizes travel through a second (tiny) all_to_all so the receiver
    can rebuild the per-tile expert metadata; the ragged GEMM skips the
    slack tiles, so compute still scales with the routed work even though
    the wire format is worst-case sized. The reverse all_to_all brings each
    source's rows home and the combine is local, exactly as in dense mode.

    mode "psum": tokens replicated over the expert axis; each shard
    processes its local experts (dense capacity or ragged dispatch) and one
    psum combines (used when the sequence does not divide the TP degree,
    e.g. single-token decode)."""
    mesh, batch_axes, expert_axes, b_n, tp_n, mode = plan
    e = cfg.moe
    e_loc = e.n_experts // tp_n
    t = xf.shape[0]

    def route_local(router, xr, vr):
        logits = xr.astype(jnp.float32) @ router.astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        w, idx = jax.lax.top_k(probs, e.top_k)
        w = w / jnp.sum(w, axis=-1, keepdims=True)
        if vr is not None:
            idx = jnp.where(vr[:, None], idx, e.n_experts)
        return idx, w, probs

    def tele(counts_l, dropped_l, probs, all_axes):
        counts = jax.lax.psum(counts_l, all_axes)
        dropped = jax.lax.psum(dropped_l, all_axes)
        pbar = jax.lax.psum(jnp.sum(probs, axis=0), all_axes) / t
        return counts, dropped, pbar

    if mode == "a2a":
        t_loc = t // (b_n * tp_n)
        tok_axes = batch_axes + expert_axes
        a2a_axis = expert_axes[0]

        if moe_dispatch == "ragged":
            a_loc = t_loc * e.top_k
            m_blk, s_pair = ragged_tile_rows(a_loc, e_loc)
            n_send = tp_n * s_pair

            def body(router, wg, wu, wd, xr, vr):
                d = xr.shape[1]
                idx, w, probs = route_local(router, xr, vr)
                flat = idx.reshape(-1).astype(jnp.int32)       # global ids
                counts_l = jnp.bincount(
                    flat, length=e.n_experts).astype(jnp.int32)
                padded = (-(-counts_l // m_blk) * m_blk).astype(jnp.int32)
                # destination-shard-major layout: shard j's groups live in
                # chunk j of the send buffer, tile-aligned within the chunk
                p2 = padded.reshape(tp_n, e_loc)
                pcum_l = jnp.cumsum(p2, axis=1).astype(jnp.int32)
                starts = ((pcum_l - p2)
                          + jnp.arange(tp_n, dtype=jnp.int32)[:, None]
                          * s_pair).reshape(-1)
                pos = _group_ranks(flat, counts_l, e.n_experts)
                keep = flat < e.n_experts
                slot = jnp.where(
                    keep,
                    starts[jnp.minimum(flat, e.n_experts - 1)] + pos,
                    n_send).astype(jnp.int32)
                tok_ids = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32),
                                     e.top_k)
                tok_of_row = jnp.zeros((n_send,), jnp.int32).at[
                    jnp.where(keep, slot, n_send)
                ].set(tok_ids, mode="drop")
                buf = xr[tok_of_row].reshape(tp_n, s_pair, d)
                # dispatch a2a: chunk j -> device j; symmetric layout, the
                # receiver holds one s_pair chunk per source shard
                buf = jax.lax.all_to_all(buf, a2a_axis, split_axis=0,
                                         concat_axis=0, tiled=True)
                # per-source padded group sizes for MY local experts
                sizes = jax.lax.all_to_all(p2, a2a_axis, split_axis=0,
                                           concat_axis=0, tiled=True)
                ccum = jnp.cumsum(sizes, axis=1).astype(jnp.int32)
                r0 = jnp.arange(s_pair // m_blk, dtype=jnp.int32) * m_blk
                tile_expert = jax.vmap(
                    lambda c: jnp.searchsorted(c, r0, side="right"))(
                        ccum).reshape(-1).astype(jnp.int32)
                rows = buf.reshape(n_send, d)
                pl_ = {"w_gate": wg, "w_up": wu, "w_down": wd}
                y = (gmm_fn or ragged_ffn_ref)(cfg, pl_, rows, tile_expert,
                                               m_blk)
                # combine a2a: each source's rows come home in place
                y = jax.lax.all_to_all(y.reshape(tp_n, s_pair, d), a2a_axis,
                                       split_axis=0, concat_axis=0,
                                       tiled=True)
                out = _combine_topk(y.reshape(n_send, d), slot, keep, w)
                counts, dropped, pbar = tele(counts_l,
                                             jnp.zeros((), jnp.int32),
                                             probs, tok_axes)
                return out, counts, dropped, pbar

            e_spec = P(expert_axes, None, None)
            in_specs = (P(), e_spec, e_spec, e_spec, P(tok_axes, None),
                        P(tok_axes) if vflat is not None else P())
            out_specs = (P(tok_axes, None), P(), P(), P())
            if vflat is None:
                fn = shard_map(lambda r, g_, u_, d_, xr, _:
                               body(r, g_, u_, d_, xr, None), mesh=mesh,
                               in_specs=in_specs, out_specs=out_specs,
                               check_rep=False)
            else:
                fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                               out_specs=out_specs, check_rep=False)
            v_arg = vflat if vflat is not None else jnp.ones((), bool)
            return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], xf,
                      v_arg)

        def body(router, wg, wu, wd, xr, vr):
            idx, w, probs = route_local(router, xr, vr)
            cap = t_loc if dropless else capacity(cfg, t_loc)
            slot, keep, counts_l = dispatch_indices(idx, e.n_experts, cap)
            tok_ids = jnp.repeat(jnp.arange(t_loc, dtype=jnp.int32),
                                 e.top_k)
            tok_of_slot = jnp.zeros((e.n_experts * cap,), jnp.int32).at[
                jnp.where(keep, slot, e.n_experts * cap).astype(jnp.int32)
            ].set(tok_ids, mode="drop")
            buf = xr[tok_of_slot].reshape(e.n_experts, cap, d := xr.shape[1])
            # dispatch all-to-all: (E, cap, d) -> (E_loc, cap * tp, d)
            buf = jax.lax.all_to_all(buf, a2a_axis, split_axis=0,
                                     concat_axis=1, tiled=True)
            y = (gmm_fn or expert_ffn_ref)(
                cfg, {"w_gate": wg, "w_up": wu, "w_down": wd}, buf)
            # combine all-to-all: back to (E, cap, d), token-major
            y = jax.lax.all_to_all(y, a2a_axis, split_axis=1,
                                   concat_axis=0, tiled=True)
            y_flat = y.reshape(e.n_experts * cap, d)
            slot_k = slot.reshape(t_loc, e.top_k)
            keep_k = keep.reshape(t_loc, e.top_k)
            out = jnp.zeros((t_loc, d), y_flat.dtype)
            for i in range(e.top_k):
                contrib = y_flat[slot_k[:, i]]
                gate = jnp.where(keep_k[:, i], w[:, i], 0.0)
                out = out + contrib * gate[:, None].astype(contrib.dtype)
            dropped_l = jnp.sum((idx.reshape(-1) < e.n_experts) & ~keep)
            counts, dropped, pbar = tele(counts_l, dropped_l, probs,
                                         tok_axes)
            return out, counts, dropped, pbar

        e_spec = P(expert_axes, None, None)
        in_specs = (P(), e_spec, e_spec, e_spec, P(tok_axes, None),
                    P(tok_axes) if vflat is not None else P())
        out_specs = (P(tok_axes, None), P(), P(), P())
        if vflat is None:
            fn = shard_map(lambda r, g_, u_, d_, xr, _:
                           body(r, g_, u_, d_, xr, None), mesh=mesh,
                           in_specs=in_specs, out_specs=out_specs,
                           check_rep=False)
        else:
            fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, check_rep=False)
        v_arg = vflat if vflat is not None else jnp.ones((), bool)
        return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], xf,
                  v_arg)

    # -- mode == "psum" -----------------------------------------------------
    t_loc = t // b_n

    def body(router, wg, wu, wd, xr, vr):
        # per-device: xr (t_loc, d); wg/wu/wd hold this shard's e_loc
        # experts with FULL inner dim (gathered at the shard_map boundary).
        j = jax.lax.axis_index(expert_axes)
        idx, w, probs = route_local(router, xr, vr)
        # keep only this shard's experts; others become the drop sentinel
        local = (idx >= j * e_loc) & (idx < (j + 1) * e_loc)
        idx_l = jnp.where(local, idx - j * e_loc, e_loc)
        pl = {"w_gate": wg, "w_up": wu, "w_down": wd}
        if moe_dispatch == "ragged":
            out, counts_l, dropped_l = _dispatch_gmm_combine_ragged(
                cfg, pl, xr, idx_l, w, e_loc, gmm_fn)
        else:
            cap = t_loc if dropless else capacity(cfg, t_loc)
            out, counts_l, dropped_l = _dispatch_gmm_combine(
                cfg, pl, xr, idx_l, w, cap, e_loc, gmm_fn)
        # combine expert contributions across the expert axis
        out = jax.lax.psum(out, expert_axes)
        # counts_l covers this shard's experts only; assemble the global
        # (E,) vector, then sum token groups
        counts = jax.lax.all_gather(counts_l, expert_axes, tiled=True)
        counts = jax.lax.psum(counts, batch_axes)
        dropped = jax.lax.psum(jax.lax.psum(dropped_l, expert_axes),
                               batch_axes)
        pbar = jax.lax.psum(jnp.sum(probs, axis=0), batch_axes) / t
        return out, counts, dropped, pbar

    e_spec = P(expert_axes, None, None)
    in_specs = (P(), e_spec, e_spec, e_spec, P(batch_axes, None),
                P(batch_axes) if vflat is not None else P())
    out_specs = (P(batch_axes, None), P(), P(), P())
    if vflat is None:
        fn = shard_map(lambda r, g_, u_, d_, xr, _:
                       body(r, g_, u_, d_, xr, None), mesh=mesh,
                       in_specs=in_specs, out_specs=out_specs,
                       check_rep=False)
    else:
        fn = shard_map(body, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs, check_rep=False)
    v_arg = vflat if vflat is not None else jnp.ones((), bool)
    return fn(p["router"], p["w_gate"], p["w_up"], p["w_down"], xf, v_arg)


def expert_ffn_ref(cfg: ModelConfig, p, buf: Array) -> Array:
    """Batched per-expert SwiGLU FFN: (E, C, d) -> (E, C, d). This is the
    pure-jnp oracle; kernels/moe_gmm.py implements the same contract."""
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(buf.dtype))
    h = jax.nn.silu(g) * u
    return jnp.einsum("ecf,efd->ecd", h, p["w_down"].astype(buf.dtype))


def empty_moe_aux(cfg: ModelConfig) -> dict:
    """Aux pytree with the same structure for non-MoE blocks so scans over
    heterogeneous stacks stay pytree-uniform."""
    n = max(cfg.moe.n_experts, 1)
    return {
        "expert_counts": jnp.zeros((n,), jnp.int32),
        "active_experts": jnp.zeros((), jnp.int32),
        "dropped": jnp.zeros((), jnp.int32),
        "aux_loss": jnp.zeros((), jnp.float32),
    }
