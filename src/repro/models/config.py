"""Model configuration covering all assigned architecture families.

One dataclass describes every architecture in the pool: dense llama-family
(GQA/MHA), MoE (Qwen3-MoE style and DeepSeek-V2 MLA+shared-expert style),
hybrid recurrent (RecurrentGemma RG-LRU + local attention), xLSTM
(sLSTM/mLSTM), VLM language backbones (M-RoPE) and enc-dec audio backbones
(whisper).  The block stack is an explicit sequence of ``BlockSpec``s so the
scheduler (core/) and the model runtime (models/model.py) share a single
source of truth for what "layer i" means.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block-level specification
# ---------------------------------------------------------------------------

# Temporal-mixing choices.
MIXER_GQA = "gqa"            # (grouped-query / multi-head) full attention
MIXER_LOCAL = "local_gqa"    # sliding-window local attention
MIXER_MLA = "mla"            # DeepSeek-V2 multi-head latent attention
MIXER_RGLRU = "rglru"        # RecurrentGemma real-gated LRU block
MIXER_MLSTM = "mlstm"        # xLSTM matrix-memory LSTM
MIXER_SLSTM = "slstm"        # xLSTM scalar-memory LSTM

# FFN choices.
FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_NONE = "none"            # xLSTM blocks integrate their own projections


# Bytes per element by dtype name — the single source of truth for weight
# traffic accounting (engine counter, cost model). Substring heuristics like
# `2 if "16" in dtype else 4` misreport fp8/int8 as 4 B/elem.
DTYPE_BYTES = {
    "float64": 8, "int64": 8,
    "float32": 4, "int32": 4,
    "bfloat16": 2, "float16": 2, "int16": 2,
    "float8_e4m3fn": 1, "float8_e5m2": 1, "float8_e4m3": 1,
    "fp8": 1, "int8": 1, "uint8": 1, "int4": 1,
}


def dtype_bytes(name: str) -> int:
    """Bytes/element for a dtype name; falls back on bit-width parsing for
    names not in DTYPE_BYTES (e.g. jnp dtype str spellings)."""
    if name in DTYPE_BYTES:
        return DTYPE_BYTES[name]
    for bits, nbytes in (("64", 8), ("32", 4), ("16", 2), ("8", 1), ("4", 1)):
        if bits in name:
            return nbytes
    return 4


@dataclass(frozen=True)
class BlockSpec:
    """What one decoder block is made of."""

    mixer: str = MIXER_GQA
    ffn: str = FFN_DENSE
    cross_attn: bool = False          # whisper decoder blocks
    window: Optional[int] = None      # sliding/local attention window

    def is_attention(self) -> bool:
        return self.mixer in (MIXER_GQA, MIXER_LOCAL, MIXER_MLA)

    def is_recurrent(self) -> bool:
        return self.mixer in (MIXER_RGLRU, MIXER_MLSTM, MIXER_SLSTM)


# ---------------------------------------------------------------------------
# Model-level configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0              # per-expert hidden size
    n_shared_experts: int = 0         # DeepSeek-V2 shared experts
    shared_d_ff: int = 0              # hidden size of the shared expert(s)
    capacity_factor: float = 1.25     # GShard-style dispatch capacity
    router_aux_coef: float = 0.001    # load-balance aux loss (training)

    @property
    def enabled(self) -> bool:
        return self.n_experts > 0


@dataclass(frozen=True)
class MLAConfig:
    kv_lora_rank: int = 0
    q_lora_rank: int = 0              # 0 => no query compression
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128

    @property
    def enabled(self) -> bool:
        return self.kv_lora_rank > 0


@dataclass(frozen=True)
class EncoderConfig:
    """Encoder stack for enc-dec models (whisper). The modality frontend
    (mel-spectrogram + conv subsampling) is stubbed per the brief: inputs
    arrive as precomputed frame embeddings of shape (B, n_frames, d_model)."""

    n_layers: int = 0
    n_frames: int = 1500              # whisper: 30 s audio -> 1500 frames

    @property
    def enabled(self) -> bool:
        return self.n_layers > 0


@dataclass(frozen=True)
class VisionStubConfig:
    """VLM frontends are stubbed: ``input_specs`` provides patch embeddings
    already projected to d_model. M-RoPE still runs in the backbone with
    (temporal, height, width) position ids."""

    n_patches: int = 0                # extra multimodal tokens prepended

    @property
    def enabled(self) -> bool:
        return self.n_patches > 0


@dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"             # dense | moe | hybrid | ssm | vlm | audio
    source: str = ""                  # citation: paper / model card

    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0                 # 0 => d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1024
    max_seq_len: int = 4096

    norm: str = "rmsnorm"             # rmsnorm | layernorm
    norm_eps: float = 1e-6
    activation: str = "swiglu"        # swiglu | gelu | geglu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # Positional encoding: "rope" | "rope_partial" | "mrope" | "learned" | "none"
    pos_emb: str = "rope"
    rope_theta: float = 10000.0
    rotary_pct: float = 1.0           # stablelm uses 0.25
    mrope_sections: Tuple[int, ...] = ()   # qwen2-vl (t, h, w) split of rope dims

    # Sub-structures
    moe: MoEConfig = field(default_factory=MoEConfig)
    mla: MLAConfig = field(default_factory=MLAConfig)
    encoder: EncoderConfig = field(default_factory=EncoderConfig)
    vision: VisionStubConfig = field(default_factory=VisionStubConfig)

    # Hybrid / recurrent structure ------------------------------------------------
    # Pattern of mixers tiled over the depth, e.g. ("rglru","rglru","gqa").
    # Empty tuple => homogeneous attention stack.
    mixer_pattern: Tuple[str, ...] = ()
    # MoE only on some blocks (DeepSeek-V2 uses a dense first block).
    dense_block_ids: Tuple[int, ...] = ()
    local_window: int = 2048          # window for MIXER_LOCAL blocks
    sliding_window: Optional[int] = None  # window applied to ALL gqa blocks
    lru_width: int = 0                # RG-LRU recurrence width (0 => d_model)
    conv_width: int = 4               # RG-LRU temporal-conv width

    # Numerics
    dtype: str = "float32"            # activation dtype
    param_dtype: str = "float32"

    # -- derived -----------------------------------------------------------------

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def q_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def block_specs(self) -> Tuple[BlockSpec, ...]:
        """The explicit per-block structure for the whole decoder stack."""
        specs = []
        for i in range(self.n_layers):
            if self.mixer_pattern:
                mixer = self.mixer_pattern[i % len(self.mixer_pattern)]
            elif self.mla.enabled:
                mixer = MIXER_MLA
            else:
                mixer = MIXER_GQA
            window = None
            if mixer == MIXER_LOCAL:
                window = self.local_window
            elif mixer == MIXER_GQA and self.sliding_window:
                window = self.sliding_window
            if mixer in (MIXER_MLSTM, MIXER_SLSTM):
                ffn = FFN_NONE if self.d_ff == 0 else FFN_DENSE
            elif self.moe.enabled and i not in self.dense_block_ids:
                ffn = FFN_MOE
            else:
                ffn = FFN_DENSE
            specs.append(
                BlockSpec(mixer=mixer, ffn=ffn,
                          cross_attn=self.encoder.enabled, window=window)
            )
        return tuple(specs)

    def scan_segments(self) -> Tuple[Tuple[Tuple[BlockSpec, ...], int], ...]:
        """Group the block stack into (pattern, repeats) segments so the full
        forward pass can lax.scan over stacked parameters instead of unrolling
        n_layers HLO copies. A homogeneous stack yields one segment with a
        1-block pattern; RecurrentGemma yields ((r,r,a), 12) + ((r,), 2)."""
        specs = self.block_specs()
        if not specs:
            return ()
        # Find the smallest period p such that specs is (pattern * k) + prefix
        # of pattern. Try small periods first.
        n = len(specs)
        for p in range(1, min(n, 16) + 1):
            if all(specs[i] == specs[i % p] for i in range(n)):
                reps, rem = divmod(n, p)
                segs = [(tuple(specs[:p]), reps)]
                if rem:
                    segs.append((tuple(specs[:rem]), 1))
                return tuple(segs)
        # Fallback: irregular stack — single segment per contiguous run.
        segs = []
        run_start = 0
        for i in range(1, n + 1):
            if i == n or specs[i] != specs[run_start]:
                segs.append(((specs[run_start],), i - run_start))
                run_start = i
        return tuple(segs)

    def block_index_map(self) -> Tuple[Tuple[int, int, int], ...]:
        """block id -> (segment, repeat, position-in-pattern)."""
        out = []
        b = 0
        for s, (pattern, reps) in enumerate(self.scan_segments()):
            for r in range(reps):
                for p in range(len(pattern)):
                    out.append((s, r, p))
                    b += 1
        return tuple(out)

    # -- sizes (used by the cost model and roofline) -------------------------------

    def attn_param_count(self, spec: BlockSpec) -> int:
        d, hd = self.d_model, self.head_dim_
        if spec.mixer == MIXER_MLA:
            m = self.mla
            qdim = self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
            n = 0
            if m.q_lora_rank:
                n += d * m.q_lora_rank + m.q_lora_rank * qdim
            else:
                n += d * qdim
            n += d * (m.kv_lora_rank + m.qk_rope_dim)
            n += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
            n += self.n_heads * m.v_head_dim * d
            return n
        if spec.mixer in (MIXER_GQA, MIXER_LOCAL):
            return d * hd * (self.n_heads + 2 * self.n_kv_heads) + self.n_heads * hd * d
        if spec.mixer == MIXER_RGLRU:
            w = self.lru_width or d
            nb = 16  # block-diagonal gates (Griffin block_width)
            return 2 * d * w + w * d + self.conv_width * w + 2 * nb * (w // nb) ** 2
        if spec.mixer in (MIXER_MLSTM, MIXER_SLSTM):
            # qkv + gates + output over the (2x) inner dim
            inner = 2 * d
            return d * inner * 2 + inner * d + 3 * inner * (inner // max(self.n_heads, 1))
        raise ValueError(spec.mixer)

    def ffn_param_count(self, spec: BlockSpec) -> int:
        d = self.d_model
        if spec.ffn == FFN_NONE:
            return 0
        if spec.ffn == FFN_MOE:
            e = self.moe
            per_expert = 3 * d * e.expert_d_ff
            shared = e.n_shared_experts * 3 * d * e.shared_d_ff
            router = d * e.n_experts
            return e.n_experts * per_expert + shared + router
        mult = 3 if self.activation in ("swiglu", "geglu") else 2
        return mult * d * self.d_ff

    def expert_bytes(self, bytes_per_param: int = 2) -> int:
        """Bytes of ONE routed expert's weights (the unit of the paper's
        expert-load counter)."""
        return 3 * self.d_model * self.moe.expert_d_ff * bytes_per_param

    def param_count(self) -> int:
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        for spec in self.block_specs():
            n += self.attn_param_count(spec) + self.ffn_param_count(spec)
            n += 2 * self.d_model  # norms
        if self.encoder.enabled:
            enc_spec = BlockSpec(mixer=MIXER_GQA, ffn=FFN_DENSE)
            n += self.encoder.n_layers * (
                self.attn_param_count(enc_spec) + self.ffn_param_count(enc_spec)
            )
        return n

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: top-k + shared only)."""
        n = self.vocab_size * self.d_model * (1 if self.tie_embeddings else 2)
        for spec in self.block_specs():
            n += self.attn_param_count(spec) + 2 * self.d_model
            if spec.ffn == FFN_MOE:
                e = self.moe
                n += e.top_k * 3 * self.d_model * e.expert_d_ff
                n += e.n_shared_experts * 3 * self.d_model * e.shared_d_ff
                n += self.d_model * e.n_experts
            else:
                n += self.ffn_param_count(spec)
        return n

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per token across all blocks (0 for pure recurrent)."""
        total = 0
        for spec in self.block_specs():
            if spec.mixer == MIXER_MLA:
                total += (self.mla.kv_lora_rank + self.mla.qk_rope_dim) * bytes_per_el
            elif spec.is_attention():
                total += 2 * self.n_kv_heads * self.head_dim_ * bytes_per_el
        return total

    def stash_token_factor(self) -> float:
        """KV-token-equivalents charged per layered-prefill boundary-
        activation token (one d_model vector) — PagedKVAllocator's
        ``stash_factor``. Element size cancels, so this is dtype-free;
        pure-recurrent stacks (no KV growth) fall back to 1.0."""
        kv_els = self.kv_bytes_per_token(1)
        return self.d_model / kv_els if kv_els > 0 else 1.0

    def validate(self) -> "ModelConfig":
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name
        if self.moe.enabled:
            assert self.moe.top_k <= self.moe.n_experts
        if self.mixer_pattern:
            for m in self.mixer_pattern:
                assert m in (MIXER_GQA, MIXER_LOCAL, MIXER_MLA, MIXER_RGLRU,
                             MIXER_MLSTM, MIXER_SLSTM), m
        return self


def reduced(cfg: ModelConfig, *, n_layers: int = 2, d_model: int = 256,
            n_experts: int = 4, vocab: int = 512, seq: int = 512) -> ModelConfig:
    """A tiny same-family variant for CPU smoke tests (per the brief: <=2
    layers, d_model<=512, <=4 experts). Preserves structural features
    (GQA ratio, MoE-ness, MLA, mixer pattern, enc-dec)."""
    d_model = min(d_model, 512)
    n_heads = max(4, min(cfg.n_heads, 8))
    # preserve grouping ratio approximately
    ratio = max(1, cfg.n_heads // max(cfg.n_kv_heads, 1))
    n_kv = max(1, n_heads // min(ratio, n_heads))
    head_dim = d_model // n_heads
    moe = cfg.moe
    if moe.enabled:
        k = min(moe.top_k, 2)
        moe = dataclasses.replace(
            moe, n_experts=min(moe.n_experts, n_experts), top_k=k,
            expert_d_ff=d_model, shared_d_ff=d_model if moe.n_shared_experts else 0,
            n_shared_experts=min(moe.n_shared_experts, 1))
    mla = cfg.mla
    if mla.enabled:
        mla = dataclasses.replace(mla, kv_lora_rank=64,
                                  q_lora_rank=64 if mla.q_lora_rank else 0,
                                  qk_rope_dim=16, qk_nope_dim=head_dim,
                                  v_head_dim=head_dim)
    enc = cfg.encoder
    if enc.enabled:
        enc = dataclasses.replace(enc, n_layers=min(enc.n_layers, 2), n_frames=64)
    pattern = cfg.mixer_pattern
    if pattern:
        n_layers = max(n_layers, len(pattern))  # keep one full period
    mrope = cfg.mrope_sections
    if mrope:
        # rescale sections to the reduced rotary dim (head_dim // 2 pairs)
        half = head_dim // 2
        base = half // len(mrope)
        mrope = tuple([half - base * (len(mrope) - 1)] + [base] * (len(mrope) - 1))
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=n_layers, d_model=d_model, n_heads=n_heads, n_kv_heads=n_kv,
        head_dim=head_dim, d_ff=2 * d_model, vocab_size=vocab,
        max_seq_len=seq, moe=moe, mla=mla, encoder=enc,
        lru_width=d_model if cfg.lru_width else 0,
        local_window=min(cfg.local_window, 128),
        sliding_window=min(cfg.sliding_window, 128) if cfg.sliding_window else None,
        mrope_sections=mrope,
        dense_block_ids=tuple(i for i in cfg.dense_block_ids if i < n_layers),
        dtype="float32", param_dtype="float32",
    ).validate()
