"""DecoderModel: the full model runtime.

Two execution paths over the same block definitions:

- ``forward`` / ``__call__``: full stack via ``lax.scan`` over the config's
  scan segments (compile-efficient for 94-layer models — HLO size is
  independent of depth). Used by training, the dry-run and full prefill.

- ``run_blocks(start, n)``: partial *vertical* execution of blocks
  [start, start+n) with boundary activations in/out. This is the mechanism
  layered prefill schedules over: group g of an admitted request runs here
  while all other groups only decode. ``start``/``n`` are Python ints
  (static) — the engine jit-caches one executable per group shape, the TPU
  analogue of the paper's CUDA-graph-per-bucket.

Caches mirror the segment structure: ``cache[s][p]`` is a pytree stacked
over that segment's repeats, so both scan (slice per repeat) and engine
(index ``[r]``) paths address the same storage.
"""

from __future__ import annotations

from typing import List, Optional

import jax
import jax.numpy as jnp

from repro.models import attention, blocks, layers
from repro.models.config import BlockSpec, ModelConfig
from repro.sharding.partition import shard_hint, shard_seq_hint

Array = jax.Array


def _stack_init(fn, reps: int, key):
    keys = jax.random.split(key, reps)
    return jax.vmap(fn)(keys)


def _stack_zeros(tree, reps: int):
    """Stack a freshly-initialised cache pytree over a segment's repeats,
    preserving non-zero init values (e.g. the xLSTM stabilizer m=-inf)."""
    return jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x[None], (reps,) + x.shape), tree)


def sinusoidal_positions(n: int, d: int) -> Array:
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    ang = pos / jnp.power(10000.0, dim / d)
    pe = jnp.zeros((n, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(ang)).at[:, 1::2].set(jnp.cos(ang))
    return pe


class DecoderModel:
    def __init__(self, cfg: ModelConfig, *, unroll: bool = False,
                 remat: bool = False):
        self.cfg = cfg.validate()
        self.specs = cfg.block_specs()
        self.segments = cfg.scan_segments()
        self.index_map = cfg.block_index_map()
        self.n_blocks = cfg.n_layers
        # unroll=True replaces the segment lax.scan with a python loop:
        # bigger HLO but exact cost_analysis (XLA counts while bodies once)
        # — used by the dry-run for faithful roofline numbers.
        self.unroll = unroll
        # remat=True checkpoints each block in the no-cache (training)
        # forward so the backward pass recomputes activations — required to
        # fit 4k-seq training batches in 16 GB HBM.
        self.remat = remat

    # -- init ---------------------------------------------------------------

    def init(self, key) -> dict:
        cfg = self.cfg
        k_embed, k_blocks, k_norm, k_enc = jax.random.split(key, 4)
        params: dict = {"embed": layers.init_embed(cfg, k_embed),
                        "final_norm": layers.init_norm(cfg)}
        seg_params = []
        bkeys = jax.random.split(k_blocks, len(self.segments))
        for (pattern, reps), sk in zip(self.segments, bkeys):
            pkeys = jax.random.split(sk, len(pattern))
            seg_params.append({
                "pattern": [
                    _stack_init(lambda k, sp=sp: blocks.init_block(cfg, sp, k),
                                reps, pk)
                    for sp, pk in zip(pattern, pkeys)
                ]
            })
        params["segments"] = seg_params
        if cfg.encoder.enabled:
            enc_spec = BlockSpec(mixer="gqa", ffn="dense")
            ekeys = jax.random.split(k_enc, cfg.encoder.n_layers + 1)
            params["encoder"] = {
                "blocks": [blocks.init_block(cfg, enc_spec, ek)
                           for ek in ekeys[:-1]],
                "final_norm": layers.init_norm(cfg),
            }
        return params

    def init_cache(self, batch: int, max_len: int, dtype=None) -> list:
        cfg = self.cfg
        cache = []
        for pattern, reps in self.segments:
            cache.append([
                _stack_zeros(
                    blocks.init_block_cache(cfg, sp, batch, max_len, dtype), reps)
                for sp in pattern
            ])
        return cache

    # -- encoder (whisper) ----------------------------------------------------

    def encode(self, params, frames: Array) -> Array:
        """frames: (B, T, D) precomputed frontend embeddings (stub)."""
        cfg = self.cfg
        x = frames.astype(cfg.dtype) + sinusoidal_positions(
            frames.shape[1], cfg.d_model).astype(cfg.dtype)
        pos = jnp.broadcast_to(
            jnp.arange(frames.shape[1], dtype=jnp.int32)[None], frames.shape[:2])
        enc_spec = BlockSpec(mixer="gqa", ffn="dense")
        for bp in params["encoder"]["blocks"]:
            # bidirectional self-attention: reuse the block with causal masking
            # disabled by giving every query the max position.
            h = layers.apply_norm(cfg, bp["ln1"], x)
            full_pos = jnp.full_like(pos, frames.shape[1] - 1)
            out, _ = attention.apply_gqa(cfg, enc_spec, bp["attn"], h,
                                         positions=full_pos, cache=None)
            x = x + out
            h2 = layers.apply_norm(cfg, bp["ln2"], x)
            x = x + layers.apply_mlp(cfg, bp["mlp"], h2)
        return layers.apply_norm(cfg, params["encoder"]["final_norm"], x)

    def precompute_cross_kv(self, params, enc_out: Array) -> list:
        """Per-block encoder K/V, in segment layout, to merge into a cache."""
        out = []
        for (pattern, reps), seg in zip(self.segments, params["segments"]):
            pos_list = []
            for p_idx, sp in enumerate(pattern):
                if not sp.cross_attn or not sp.is_attention():
                    pos_list.append(None)
                    continue
                def one(bp):
                    xk, xv = attention.encode_cross_kv(self.cfg, bp["attn"], enc_out)
                    return {"xk": xk, "xv": xv}
                pos_list.append(jax.vmap(one)(seg["pattern"][p_idx]))
            out.append(pos_list)
        return out

    # -- embedding / head ------------------------------------------------------

    def embed(self, params, tokens: Array,
              extra_embeds: Optional[Array] = None,
              positions: Optional[Array] = None) -> Array:
        x = layers.embed_tokens(self.cfg, params["embed"], tokens)
        if extra_embeds is not None:
            # VLM stub: precomputed patch embeddings prepended to the text.
            x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
        if self.cfg.pos_emb == "learned" and positions is not None:
            x = x + params["embed"]["pos"][
                jnp.clip(positions, 0, self.cfg.max_seq_len - 1)].astype(x.dtype)
        return shard_hint(x, "batch", None, None)

    def logits(self, params, x: Array) -> Array:
        x = layers.apply_norm(self.cfg, params["final_norm"], x)
        return layers.unembed(self.cfg, params["embed"], x)

    # -- full forward (scan over segments) -------------------------------------

    def run_all(self, params, x: Array, *, positions: Array,
                offset: Optional[Array] = None, cache: Optional[list] = None,
                enc_out: Optional[Array] = None, valid: Optional[Array] = None,
                gmm_fn=None, dropless: bool = False,
                moe_dispatch: str = "dense"):
        cfg = self.cfg
        new_cache: Optional[list] = [] if cache is not None else None
        aux_counts: List[Array] = []
        aux_loss = jnp.zeros((), jnp.float32)
        aux_dropped = jnp.zeros((), jnp.int32)

        for s, (pattern, reps) in enumerate(self.segments):
            seg = params["segments"][s]["pattern"]

            def body(h, xs):
                ps, cs = xs
                new_cs, auxes = [], []
                for p_idx, sp in enumerate(pattern):
                    def block_fn(bp, h_, sp=sp, c_=(cs[p_idx] if cs is not None
                                                    else None)):
                        return blocks.apply_block(
                            cfg, sp, bp, h_, positions=positions,
                            offset=offset, cache=c_, enc_out=enc_out,
                            valid=valid, gmm_fn=gmm_fn, dropless=dropless,
                            moe_dispatch=moe_dispatch)
                    if self.remat and cs is None:
                        block_fn = jax.checkpoint(block_fn)
                    h, nc, aux = block_fn(ps[p_idx], h)
                    h = shard_seq_hint(h)
                    new_cs.append(nc)
                    auxes.append(aux)
                return h, (new_cs if cs is not None else None, auxes)

            cs_stacked = cache[s] if cache is not None else None
            if self.unroll and reps > 1:
                auxes_acc = None
                ncs_acc = [] if cache is not None else None
                for r in range(reps):
                    ps = [jax.tree_util.tree_map(lambda a: a[r], t)
                          for t in seg]
                    cs = ([jax.tree_util.tree_map(lambda a: a[r], t)
                           for t in cs_stacked] if cache is not None else None)
                    x, (ncs, auxes) = body(x, (ps, cs))
                    if cache is not None:
                        ncs_acc.append(ncs)
                    if auxes_acc is None:
                        auxes_acc = [[a] for a in auxes]
                    else:
                        for lst, a in zip(auxes_acc, auxes):
                            lst.append(a)
                auxes_stacked = [
                    jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *lst)
                    for lst in auxes_acc]
                if cache is not None:
                    new_cache.append([
                        jax.tree_util.tree_map(
                            lambda *xs: jnp.stack(xs),
                            *[ncs_acc[r][p_i] for r in range(reps)])
                        for p_i in range(len(pattern))])
            elif reps == 1:
                # no scan needed; avoids degenerate length-1 scans
                ps = [jax.tree_util.tree_map(lambda a: a[0], t) for t in seg]
                cs = ([jax.tree_util.tree_map(lambda a: a[0], t)
                       for t in cs_stacked] if cache is not None else None)
                x, (ncs, auxes) = body(x, (ps, cs))
                if cache is not None:
                    new_cache.append([jax.tree_util.tree_map(
                        lambda a: a[None], t) for t in ncs])
                auxes_stacked = [jax.tree_util.tree_map(lambda a: a[None], a_)
                                 for a_ in auxes]
            else:
                xs = (seg, cs_stacked) if cache is not None else (seg, None)
                if cache is not None:
                    x, (ncs, auxes_stacked) = jax.lax.scan(body, x, xs)
                    new_cache.append(ncs)
                else:
                    x, (_, auxes_stacked) = jax.lax.scan(
                        lambda h, ps: body(h, (ps, None)), x, seg)
            # collect aux in block order: (reps, P, E) -> (reps*P, E)
            counts = jnp.stack([a["expert_counts"] for a in auxes_stacked],
                               axis=1)
            aux_counts.append(counts.reshape(-1, counts.shape[-1]))
            aux_loss = aux_loss + sum(jnp.sum(a["aux_loss"]) for a in auxes_stacked)
            aux_dropped = aux_dropped + sum(
                jnp.sum(a["dropped"]) for a in auxes_stacked)

        aux = {
            "expert_counts": jnp.concatenate(aux_counts, axis=0),  # (L, E)
            "aux_loss": aux_loss,
            "dropped": aux_dropped,
        }
        return x, new_cache, aux

    def forward(self, params, tokens: Array, *,
                positions: Optional[Array] = None,
                offset: Optional[Array] = None,
                cache: Optional[list] = None,
                enc_out: Optional[Array] = None,
                extra_embeds: Optional[Array] = None,
                valid: Optional[Array] = None,
                gmm_fn=None, dropless: bool = False,
                moe_dispatch: str = "dense"):
        """tokens: (B,S) -> (logits (B,S,V), new_cache, aux)."""
        b, s = tokens.shape
        if offset is None and cache is not None:
            offset = jnp.zeros((b,), jnp.int32)
        if positions is None:
            base = offset if offset is not None else jnp.zeros((b,), jnp.int32)
            positions = base[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
        if extra_embeds is not None:
            s_all = s + extra_embeds.shape[1]
            base = offset if offset is not None else jnp.zeros((b,), jnp.int32)
            positions = base[:, None] + jnp.arange(s_all, dtype=jnp.int32)[None]
        x = self.embed(params, tokens, extra_embeds, positions=positions)
        x, new_cache, aux = self.run_all(params, x, positions=positions,
                                         offset=offset, cache=cache,
                                         enc_out=enc_out, valid=valid,
                                         gmm_fn=gmm_fn, dropless=dropless,
                                         moe_dispatch=moe_dispatch)
        return self.logits(params, x), new_cache, aux

    __call__ = forward

    # -- partial vertical execution (the layered-prefill primitive) -------------

    def block_params(self, params, b: int):
        s, r, p_idx = self.index_map[b]
        return jax.tree_util.tree_map(
            lambda a: a[r], params["segments"][s]["pattern"][p_idx])

    def run_blocks(self, params, x: Array, start: int, n: int, *,
                   positions: Array, offset: Optional[Array] = None,
                   cache: Optional[list] = None,
                   enc_out: Optional[Array] = None,
                   valid: Optional[Array] = None, gmm_fn=None,
                   dropless: bool = False, moe_dispatch: str = "dense"):
        """Run blocks [start, start+n) over x (B,S,D). start/n are static.
        Returns (x', cache', aux-list-in-block-order).

        B is the caller's batch axis and is fully vectorized: the engine's
        packed layer-group path runs ALL prefill slices sharing this block
        range as one call, with ``cache`` holding a slot-VECTOR of rows
        (leaves ``(reps, B, ...)`` gathered by ``ops.gather_slot_rows``),
        per-row ``offset``/``valid`` masking, and bucket-padded rows that
        are no-ops end to end (their KV writes and recurrent-state updates
        are suppressed by ``valid``)."""
        auxes = []
        if cache is not None:
            # one shallow per-segment copy up front (NOT per block): the
            # caller's list structure is never mutated, and the packed hot
            # path does not rebuild the tree n times per call
            cache = [list(seg) for seg in cache]
        for b in range(start, start + n):
            s, r, p_idx = self.index_map[b]
            spec = self.specs[b]
            bp = self.block_params(params, b)
            c = (jax.tree_util.tree_map(lambda a: a[r], cache[s][p_idx])
                 if cache is not None else None)
            x, nc, aux = blocks.apply_block(
                self.cfg, spec, bp, x, positions=positions, offset=offset,
                cache=c, enc_out=enc_out, valid=valid, gmm_fn=gmm_fn,
                dropless=dropless, moe_dispatch=moe_dispatch)
            if cache is not None:
                cache[s][p_idx] = jax.tree_util.tree_map(
                    lambda full, new: full.at[r].set(new.astype(full.dtype)),
                    cache[s][p_idx], nc)
            auxes.append(aux)
        return x, cache, auxes
