"""Block-level dispatch: one decoder block = mixer (+ optional cross-attn)
+ FFN (dense / MoE / none), pre-norm residual style.

``apply_block`` is the single entry point used by both execution paths:
the lax.scan full-forward (training / dry-run) and the serving engine's
``run_blocks(start, n)`` partial vertical execution (layered prefill).
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax

from repro.models import attention, layers, moe, rglru, xlstm
from repro.models.config import (FFN_MOE, FFN_NONE, MIXER_MLSTM,
                                 MIXER_RGLRU, MIXER_SLSTM, BlockSpec,
                                 ModelConfig)

Array = jax.Array


def init_block(cfg: ModelConfig, spec: BlockSpec, key) -> dict:
    ks = jax.random.split(key, 4)
    p = {"ln1": layers.init_norm(cfg)}
    if spec.is_attention():
        p["attn"] = attention.init_attn(cfg, spec, ks[0])
    elif spec.mixer == MIXER_RGLRU:
        p["rglru"] = rglru.init_rglru(cfg, ks[0])
    elif spec.mixer == MIXER_MLSTM:
        p["lstm"] = xlstm.init_mlstm(cfg, ks[0])
    elif spec.mixer == MIXER_SLSTM:
        p["lstm"] = xlstm.init_slstm(cfg, ks[0])
    else:
        raise ValueError(spec.mixer)

    if spec.ffn != FFN_NONE:
        p["ln2"] = layers.init_norm(cfg)
        if spec.ffn == FFN_MOE:
            p["moe"] = moe.init_moe(cfg, ks[1])
        else:
            p["mlp"] = layers.init_mlp(cfg, ks[1])
    return p


def init_block_cache(cfg: ModelConfig, spec: BlockSpec, batch: int,
                     max_len: int, dtype=None) -> dict:
    if spec.is_attention():
        return attention.init_cache_attn(cfg, spec, batch, max_len, dtype)
    if spec.mixer == MIXER_RGLRU:
        return rglru.init_cache_rglru(cfg, batch, dtype)
    if spec.mixer == MIXER_MLSTM:
        return xlstm.init_cache_mlstm(cfg, batch, dtype)
    if spec.mixer == MIXER_SLSTM:
        return xlstm.init_cache_slstm(cfg, batch, dtype)
    raise ValueError(spec.mixer)


def apply_block(cfg: ModelConfig, spec: BlockSpec, p, x: Array, *,
                positions: Array, offset: Optional[Array] = None,
                cache: Optional[dict] = None, enc_out: Optional[Array] = None,
                valid: Optional[Array] = None,
                positions3: Optional[Array] = None,
                gmm_fn=None, dropless: bool = False,
                moe_dispatch: str = "dense"
                ) -> Tuple[Array, Optional[dict], dict]:
    """x: (B,S,D) -> (x', new_cache, aux). aux has uniform pytree structure
    across block kinds so heterogeneous stacks scan cleanly."""
    h = layers.apply_norm(cfg, p["ln1"], x)
    if spec.is_attention():
        out, new_cache = attention.apply_mixer_attn(
            cfg, spec, p["attn"], h, positions=positions, offset=offset,
            cache=cache, valid=valid, positions3=positions3)
        x = x + out
        if spec.cross_attn:
            hx = layers.apply_norm(cfg, p["attn"]["x_norm"], x)
            # fresh encoder output takes precedence over cached cross-K/V
            if enc_out is not None:
                xk, xv = attention.encode_cross_kv(cfg, p["attn"], enc_out)
                xc = {"xk": xk, "xv": xv}
            else:
                assert cache is not None and "xk" in cache, \
                    "cross-attn needs enc_out or cached K/V"
                xc = cache
            x = x + attention.apply_cross_attn(cfg, p["attn"], hx, xc)
            if new_cache is not None and "xk" in (cache or {}):
                new_cache = dict(new_cache, xk=cache["xk"], xv=cache["xv"])
    elif spec.mixer == MIXER_RGLRU:
        out, new_cache = rglru.apply_rglru(cfg, p["rglru"], h, cache=cache,
                                           valid=valid)
        x = x + out
    elif spec.mixer == MIXER_MLSTM:
        out, new_cache = xlstm.apply_mlstm(cfg, p["lstm"], h, cache=cache,
                                           valid=valid)
        x = x + out
    elif spec.mixer == MIXER_SLSTM:
        out, new_cache = xlstm.apply_slstm(cfg, p["lstm"], h, cache=cache,
                                           valid=valid)
        x = x + out
    else:
        raise ValueError(spec.mixer)

    aux = moe.empty_moe_aux(cfg)
    if spec.ffn != FFN_NONE:
        h2 = layers.apply_norm(cfg, p["ln2"], x)
        if spec.ffn == FFN_MOE:
            out2, aux = moe.apply_moe(cfg, p["moe"], h2, valid=valid,
                                      gmm_fn=gmm_fn, dropless=dropless,
                                      moe_dispatch=moe_dispatch)
        else:
            out2 = layers.apply_mlp(cfg, p["mlp"], h2)
        x = x + out2
    return x, new_cache, aux
