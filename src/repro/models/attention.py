"""Attention mixers: GQA (full / sliding-window / local), DeepSeek-V2 MLA,
and whisper-style cross attention — with a uniform KV-cache protocol.

Cache protocol (per attention block):
  GQA:  {"k": (B, S_max, n_kv, hd), "v": (B, S_max, n_kv, hd)}
  MLA:  {"ckv": (B, S_max, kv_lora), "kr": (B, S_max, rope_dim)}
  cross (extra, read-only after admission): {"xk": (B, T, n_kv, hd), "xv": ...}

The *filled length* is tracked by the caller as ``offset`` (B,) int32: new
tokens are written at [offset, offset+S) per row and attention is masked to
positions < offset + S (plus causal/window masks).  This is the slot-cache
layout used by the serving engine and by the decode dry-run.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.models import layers
from repro.models.config import (MIXER_GQA, MIXER_LOCAL, MIXER_MLA, BlockSpec,
                                 ModelConfig)
from repro.models.layers import _dense
from repro.sharding.partition import active_context

Array = jax.Array

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------


def init_attn(cfg: ModelConfig, spec: BlockSpec, key) -> dict:
    d, hd = cfg.d_model, cfg.head_dim_
    dt = cfg.param_dtype
    ks = jax.random.split(key, 12)
    if spec.mixer == MIXER_MLA:
        m = cfg.mla
        qdim = cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
        p = {}
        if m.q_lora_rank:
            p["w_dq"] = _dense(ks[0], (d, m.q_lora_rank), dt)
            p["q_norm"] = layers.init_norm(cfg, m.q_lora_rank)
            p["w_uq"] = _dense(ks[1], (m.q_lora_rank, qdim), dt)
        else:
            p["w_q"] = _dense(ks[1], (d, qdim), dt)
        p["w_dkv"] = _dense(ks[2], (d, m.kv_lora_rank), dt)
        p["kv_norm"] = layers.init_norm(cfg, m.kv_lora_rank)
        p["w_kr"] = _dense(ks[3], (d, m.qk_rope_dim), dt)
        p["w_uk"] = _dense(ks[4], (m.kv_lora_rank, cfg.n_heads * m.qk_nope_dim), dt)
        p["w_uv"] = _dense(ks[5], (m.kv_lora_rank, cfg.n_heads * m.v_head_dim), dt)
        p["w_o"] = _dense(ks[6], (cfg.n_heads * m.v_head_dim, d), dt)
        return p
    p = {
        "w_q": _dense(ks[0], (d, cfg.n_heads * hd), dt),
        "w_k": _dense(ks[1], (d, cfg.n_kv_heads * hd), dt),
        "w_v": _dense(ks[2], (d, cfg.n_kv_heads * hd), dt),
        "w_o": _dense(ks[3], (cfg.n_heads * hd, d), dt),
    }
    if spec.cross_attn:
        p["x_q"] = _dense(ks[4], (d, cfg.n_heads * hd), dt)
        p["x_k"] = _dense(ks[5], (d, cfg.n_kv_heads * hd), dt)
        p["x_v"] = _dense(ks[6], (d, cfg.n_kv_heads * hd), dt)
        p["x_o"] = _dense(ks[7], (cfg.n_heads * hd, d), dt)
        p["x_norm"] = layers.init_norm(cfg)
    return p


def init_cache_attn(cfg: ModelConfig, spec: BlockSpec, batch: int,
                    max_len: int, dtype=None) -> dict:
    dt = dtype or cfg.dtype
    if spec.mixer == MIXER_MLA:
        m = cfg.mla
        return {
            "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
            "kr": jnp.zeros((batch, max_len, m.qk_rope_dim), dt),
        }
    hd = cfg.head_dim_
    c = {
        "k": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
        "v": jnp.zeros((batch, max_len, cfg.n_kv_heads, hd), dt),
    }
    if spec.cross_attn:
        t = cfg.encoder.n_frames
        c["xk"] = jnp.zeros((batch, t, cfg.n_kv_heads, hd), dt)
        c["xv"] = jnp.zeros((batch, t, cfg.n_kv_heads, hd), dt)
    return c


# ---------------------------------------------------------------------------
# Core masked attention (pure jnp reference path; the Pallas kernels in
# repro.kernels implement the same contract and are swapped in via ops)
# ---------------------------------------------------------------------------


# query-chunk size above which attention switches to the memory-bounded
# chunked path (never materialises Sq×Skv scores — the pure-jnp analogue of
# the Pallas flash kernel's tiling; keeps dry-run activation memory real).
_CHUNK_THRESHOLD = 1024
_Q_CHUNK = 512


def masked_attention(q: Array, k: Array, v: Array, q_pos: Array, kv_pos: Array,
                     kv_valid: Array, *, causal: bool,
                     window: Optional[int] = None,
                     scale: Optional[float] = None) -> Array:
    """q: (B,Sq,H,hd); k/v: (B,Skv,Hkv,hd'); q_pos: (B,Sq); kv_pos: (B,Skv)
    or (Skv,); kv_valid: (B,Skv) bool. GQA is handled by head grouping."""
    sq_ = q.shape[1]
    if sq_ >= _CHUNK_THRESHOLD and sq_ % _Q_CHUNK == 0:
        return _masked_attention_chunked(q, k, v, q_pos, kv_pos, kv_valid,
                                         causal=causal, window=window,
                                         scale=scale)
    return _masked_attention_dense(q, k, v, q_pos, kv_pos, kv_valid,
                                   causal=causal, window=window, scale=scale)


def _masked_attention_dense(q, k, v, q_pos, kv_pos, kv_valid, *, causal,
                            window=None, scale=None):
    b, sq, h, hd = q.shape
    hkv = k.shape[2]
    g = h // hkv
    scale = scale if scale is not None else (1.0 / (q.shape[-1] ** 0.5))
    # g-major grouping: query head h serves kv head h % hkv, so the merged
    # head dim shards contiguously over TP (DESIGN.md §Hardware adaptation;
    # a checkpoint loader permutes w_q columns to match).
    qf = q.reshape(b, sq, g, hkv, hd).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqgkd,bskd->bgkqs", qf, kf) * scale
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (b, kv_pos.shape[0]))
    mask = kv_valid[:, None, None, None, :]
    if causal:
        mask = mask & (kv_pos[:, None, None, None, :] <= q_pos[:, None, None, :, None])
    if window is not None:
        mask = mask & (kv_pos[:, None, None, None, :]
                       > q_pos[:, None, None, :, None] - window)
    scores = jnp.where(mask, scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    # guard fully-masked rows (e.g. padding queries)
    w = jnp.where(jnp.any(mask, axis=-1, keepdims=True), w, 0.0)
    out = jnp.einsum("bgkqs,bskd->bqgkd", w, v.astype(jnp.float32))
    return out.reshape(b, sq, h, v.shape[-1]).astype(q.dtype)


_KV_CHUNK = 1024


def _masked_attention_chunked(q, k, v, q_pos, kv_pos, kv_valid, *, causal,
                              window=None, scale=None):
    """lax.map over query chunks of _Q_CHUNK; within each query chunk the
    KV axis is processed by an online-softmax lax.scan over _KV_CHUNK
    blocks when S_kv is long (flash-attention recurrence in pure jnp) —
    peak score buffer is (B, Hkv, G, Qc, KVc) and the S_q x S_kv matrix
    never reaches HBM. Same numerics as dense (fp32 accumulators)."""
    b, sq, h, hd = q.shape
    n_chunks = sq // _Q_CHUNK
    qc = q.reshape(b, n_chunks, _Q_CHUNK, h, hd).transpose(1, 0, 2, 3, 4)
    pc = q_pos.reshape(b, n_chunks, _Q_CHUNK).transpose(1, 0, 2)
    skv = k.shape[1]
    # flash only where the (Qc x Skv) buffer truly explodes: at 4k-train
    # scale the kv-scan's backward residuals cost MORE than the dense
    # score buffer (measured on minicpm train_4k: 2.9 -> 8.2 s memory;
    # and the fusion-free byte count also loses slightly at 32 k prefill)
    flash = skv >= 65536 and skv % _KV_CHUNK == 0

    def one(args):
        q_i, pos_i = args
        if flash:
            return _masked_attention_flash(q_i, k, v, pos_i, kv_pos,
                                           kv_valid, causal=causal,
                                           window=window, scale=scale)
        return _masked_attention_dense(q_i, k, v, pos_i, kv_pos, kv_valid,
                                       causal=causal, window=window,
                                       scale=scale)

    out = jax.lax.map(one, (qc, pc))            # (n_chunks, B, cq, H, hd')
    return out.transpose(1, 0, 2, 3, 4).reshape(b, sq, h, out.shape[-1])


def _masked_attention_flash(q, k, v, q_pos, kv_pos, kv_valid, *, causal,
                            window=None, scale=None):
    """Online-softmax scan over KV chunks (exact, fp32 running max/denom)."""
    b, sq, h, hd = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    hdv = v.shape[-1]
    g = h // hkv
    n_kv = skv // _KV_CHUNK
    scale = scale if scale is not None else (1.0 / (hd ** 0.5))
    qf = q.reshape(b, sq, g, hkv, hd).astype(jnp.float32) * scale
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (b, skv))

    kc = k.reshape(b, n_kv, _KV_CHUNK, hkv, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_kv, _KV_CHUNK, hkv, hdv).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(b, n_kv, _KV_CHUNK).transpose(1, 0, 2)
    mc = kv_valid.reshape(b, n_kv, _KV_CHUNK).transpose(1, 0, 2)

    acc0 = jnp.zeros((b, g, hkv, sq, hdv), jnp.float32)
    m0 = jnp.full((b, g, hkv, sq), -1e30, jnp.float32)
    l0 = jnp.zeros((b, g, hkv, sq), jnp.float32)

    def step(carry, blk):
        acc, m, l = carry
        k_i, v_i, pos_i, ok_i = blk
        s_blk = jnp.einsum("bqgkd,bskd->bgkqs", qf,
                           k_i.astype(jnp.float32))
        mask = ok_i[:, None, None, None, :]
        if causal:
            mask = mask & (pos_i[:, None, None, None, :]
                           <= q_pos[:, None, None, :, None])
        if window is not None:
            mask = mask & (pos_i[:, None, None, None, :]
                           > q_pos[:, None, None, :, None] - window)
        s_blk = jnp.where(mask, s_blk, -1e30)
        m_new = jnp.maximum(m, jnp.max(s_blk, axis=-1))
        p_blk = jnp.where(mask, jnp.exp(s_blk - m_new[..., None]), 0.0)
        corr = jnp.exp(m - m_new)
        l = l * corr + jnp.sum(p_blk, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bgkqs,bskd->bgkqd", p_blk, v_i.astype(jnp.float32))
        return (acc, m_new, l), None

    (acc, _, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, pc, mc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.where((l > 0)[..., None], out, 0.0)
    # (B, G, Hkv, Sq, hdv) -> (B, Sq, H, hdv); h = g * hkv + kv (g-major)
    out = out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, hdv)
    return out.astype(q.dtype)


def _write_cache(buf: Array, new: Array, offset: Array,
                 tok_ok: Optional[Array] = None) -> Array:
    """Write ``new`` (B,S,...) into ``buf`` (B,S_max,...) at per-row offsets.
    Tokens with ``tok_ok == False`` keep their previous buffer contents (the
    engine's full-pool decode step and bucket-padded packed prefill batches
    must not corrupt slots that are idle, mid-way through a layered
    prefill, or merely padding inside a bucketed row).

    Implemented as a per-token scatter with out-of-range indices DROPPED —
    never a ``dynamic_update_slice``.  The slice form clamps the start
    index, so a short row bucket-padded to a long window (prefix-cache
    restore packed with a cold full-prompt row: offset ~ prompt_len,
    S ~ prompt_len) would silently slide the write backwards and overwrite
    live KV below ``offset``.  The scatter stays O(B*S): one index per new
    token, masked tokens routed out of range."""
    new = new.astype(buf.dtype)
    b, s = new.shape[:2]
    s_max = buf.shape[1]
    pos = offset[:, None] + jnp.arange(s, dtype=jnp.int32)[None]
    if tok_ok is not None:
        pos = jnp.where(tok_ok, pos, s_max)        # masked -> OOB -> dropped
    rows = jnp.arange(b, dtype=jnp.int32)[:, None]
    return buf.at[rows, pos].set(new, mode="drop")


# ---------------------------------------------------------------------------
# GQA / local / sliding-window attention block mixer
# ---------------------------------------------------------------------------


def apply_gqa(cfg: ModelConfig, spec: BlockSpec, p, x: Array, *,
              positions: Array, offset: Optional[Array] = None,
              cache: Optional[dict] = None,
              valid: Optional[Array] = None,
              positions3: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    """x: (B,S,D). With a cache: writes the S new tokens at ``offset`` and
    attends over the whole (masked) cache. Without: plain causal attention
    over x (training path)."""
    b, s, d = x.shape
    hd = cfg.head_dim_
    q = (x @ p["w_q"]).reshape(b, s, cfg.n_heads, hd)
    k = (x @ p["w_k"]).reshape(b, s, cfg.n_kv_heads, hd)
    v = (x @ p["w_v"]).reshape(b, s, cfg.n_kv_heads, hd)

    if cfg.pos_emb == "mrope":
        p3 = positions3 if positions3 is not None else layers.position_plane(positions)
        q = layers.apply_mrope(q, p3, cfg.rope_theta, cfg.mrope_sections)
        k = layers.apply_mrope(k, p3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.pos_emb in ("rope", "rope_partial"):
        pct = cfg.rotary_pct if cfg.pos_emb == "rope_partial" else 1.0
        q = layers.apply_rope(q, positions, cfg.rope_theta, pct)
        k = layers.apply_rope(k, positions, cfg.rope_theta, pct)

    # Windowed (local) attention in the no-cache training forward does
    # fine under auto-sharding (recurrentgemma train: 6.2 -> 6.9 s when
    # ungated); full attention and every cached path need the shard_map
    # (qwen3-moe train collective is 7x worse without it).
    skip = cache is None and spec.window is not None
    plan = None if skip else _attn_shard_plan(cfg, b, s)
    if cache is not None:
        kbuf = _write_cache(cache["k"], k, offset, valid)
        vbuf = _write_cache(cache["v"], v, offset, valid)
        s_max = kbuf.shape[1]
        kv_pos = jnp.arange(s_max, dtype=jnp.int32)
        kv_valid = kv_pos[None, :] < (offset + s)[:, None]
        if plan is not None:
            out = _sharded_masked_attention(plan, q, kbuf, vbuf, positions,
                                            kv_pos, kv_valid, causal=True,
                                            window=spec.window)
        else:
            out = masked_attention(q, kbuf, vbuf, positions, kv_pos,
                                   kv_valid, causal=True,
                                   window=spec.window)
        new_cache = dict(cache, k=kbuf, v=vbuf)
    else:
        kv_valid = jnp.ones((b, s), dtype=bool)
        if plan is not None:
            out = _sharded_masked_attention(plan, q, k, v, positions,
                                            positions, kv_valid, causal=True,
                                            window=spec.window)
        else:
            out = masked_attention(q, k, v, positions, positions, kv_valid,
                                   causal=True, window=spec.window)
        new_cache = None
    return out.reshape(b, s, -1) @ p["w_o"], new_cache


def _attn_shard_plan(cfg: ModelConfig, b: int, s: int, n_kv: int = None,
                     force_mha: bool = False):
    """shard_map plan for head-parallel attention: batch over the batch
    axes, q heads over the TP axes (g-major grouping makes each device's
    contiguous head block cover whole kv groups), K/V replicated over TP
    inside the region (gathered once per layer at the boundary — cheap for
    GQA's few kv heads). Falls back to XLA auto-sharding when shapes do
    not divide (see DESIGN.md §Perf).

    Two modes: "gqa" (few kv heads — K/V replicated over TP inside) and
    "mha" (n_kv == n_heads, e.g. MLA/stablelm — K/V heads sharded with the
    query heads). Gated to s >= 256: for decode steps the XLA-auto
    sharding (seq-sharded KV stream) is strictly better than gathering
    K/V per layer (measured: recurrentgemma decode collective 0.4 ms ->
    129 ms under an ungated shard_map)."""
    ctx = active_context()
    if ctx is None or s < 256:
        return None
    n_kv = cfg.n_kv_heads if n_kv is None else n_kv
    mesh, rules = ctx
    tp = rules.get("tp") or ()
    batch = rules.get("batch") or ()
    tp_n = 1
    for a in tp:
        tp_n *= mesh.shape.get(a, 1)
    b_n = 1
    for a in batch:
        b_n *= mesh.shape.get(a, 1)
    if tp_n <= 1 or b_n <= 1 or b % b_n:
        return None
    h_loc = cfg.n_heads // tp_n
    if cfg.n_heads % tp_n:
        return None
    if force_mha and n_kv == cfg.n_heads:
        mode = "mha"                     # kv heads shard with q heads (MLA)
    elif n_kv < tp_n and h_loc % n_kv == 0:
        # GQA with fewer kv heads than the TP degree — the regime where
        # XLA-auto loses (it cannot shard the kv-head dim and falls into
        # full rematerialization of the 2-D-sharded cache). Plain MHA
        # archs (stablelm) do BETTER under auto-sharding: measured
        # stablelm train 1.61 -> 2.50 s with an ungated mha mode.
        mode = "gqa"
    else:
        return None
    return mesh, tuple(batch), tuple(tp), mode


def _sharded_masked_attention(plan, q, k, v, q_pos, kv_pos, kv_valid, *,
                              causal, window, scale=None):
    mesh, batch_axes, tp_axes, mode = plan
    kv_spec = (P(batch_axes, None, tp_axes, None) if mode == "mha"
               else P(batch_axes, None, None, None))
    if kv_pos.ndim == 1:
        kv_pos = jnp.broadcast_to(kv_pos[None], (q.shape[0],
                                                 kv_pos.shape[0]))

    def body(q_, k_, v_, qp_, kp_, kvv_):
        return masked_attention(q_, k_, v_, qp_, kp_, kvv_, causal=causal,
                                window=window, scale=scale)

    ba = batch_axes
    return shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, None, tp_axes, None), kv_spec, kv_spec,
                  P(ba, None), P(ba, None), P(ba, None)),
        out_specs=P(ba, None, tp_axes, None), check_rep=False,
    )(q, k, v, q_pos, kv_pos, kv_valid)


def apply_cross_attn(cfg: ModelConfig, p, x: Array, cache: dict) -> Array:
    """Whisper decoder cross attention over precomputed encoder K/V."""
    b, s, d = x.shape
    hd = cfg.head_dim_
    q = (x @ p["x_q"]).reshape(b, s, cfg.n_heads, hd)
    t = cache["xk"].shape[1]
    kv_valid = jnp.ones((b, t), dtype=bool)
    q_pos = jnp.zeros((b, s), dtype=jnp.int32)
    out = masked_attention(q, cache["xk"], cache["xv"], q_pos,
                           jnp.arange(t, dtype=jnp.int32), kv_valid,
                           causal=False)
    return out.reshape(b, s, -1) @ p["x_o"]


def encode_cross_kv(cfg: ModelConfig, p, enc_out: Array) -> Tuple[Array, Array]:
    """Project encoder output once at admission; stored in the cache."""
    b, t, d = enc_out.shape
    hd = cfg.head_dim_
    xk = (enc_out @ p["x_k"]).reshape(b, t, cfg.n_kv_heads, hd)
    xv = (enc_out @ p["x_v"]).reshape(b, t, cfg.n_kv_heads, hd)
    return xk, xv


# ---------------------------------------------------------------------------
# DeepSeek-V2 Multi-head Latent Attention
# ---------------------------------------------------------------------------


def apply_mla(cfg: ModelConfig, spec: BlockSpec, p, x: Array, *,
              positions: Array, offset: Optional[Array] = None,
              cache: Optional[dict] = None,
              valid: Optional[Array] = None,
              positions3: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    m = cfg.mla
    b, s, d = x.shape
    h = cfg.n_heads

    if m.q_lora_rank:
        cq = layers.apply_norm(cfg, p["q_norm"], x @ p["w_dq"])
        q = (cq @ p["w_uq"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    else:
        q = (x @ p["w_q"]).reshape(b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = q[..., :m.qk_nope_dim], q[..., m.qk_nope_dim:]
    q_rope = layers.apply_rope(q_rope, positions, cfg.rope_theta)

    ckv = layers.apply_norm(cfg, p["kv_norm"], x @ p["w_dkv"])   # (B,S,r)
    kr = (x @ p["w_kr"])[:, :, None, :]                           # (B,S,1,rope)
    kr = layers.apply_rope(kr, positions, cfg.rope_theta)[:, :, 0, :]

    if cache is not None:
        ckv_buf = _write_cache(cache["ckv"], ckv, offset, valid)
        kr_buf = _write_cache(cache["kr"], kr, offset, valid)
        s_kv = ckv_buf.shape[1]
        kv_valid = (jnp.arange(s_kv, dtype=jnp.int32)[None, :]
                    < (offset + s)[:, None])
        ckv_att, kr_att = ckv_buf, kr_buf
        new_cache = {"ckv": ckv_buf, "kr": kr_buf}
    else:
        s_kv = s
        kv_valid = jnp.ones((b, s), dtype=bool)
        ckv_att, kr_att = ckv, kr
        new_cache = None

    # Decompress (naive path; the absorbed path lives in kernels/ops as a
    # perf variant): k_nope (B,Skv,H,nope), v (B,Skv,H,vdim)
    k_nope = (ckv_att @ p["w_uk"]).reshape(b, s_kv, h, m.qk_nope_dim)
    vv = (ckv_att @ p["w_uv"]).reshape(b, s_kv, h, m.v_head_dim)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(kr_att[:, :, None, :], (b, s_kv, h, m.qk_rope_dim))],
        axis=-1)
    q_full = jnp.concatenate([q_nope, q_rope], axis=-1)
    kv_pos = jnp.arange(s_kv, dtype=jnp.int32)
    scale = 1.0 / ((m.qk_nope_dim + m.qk_rope_dim) ** 0.5)
    plan = _attn_shard_plan(cfg, b, s, n_kv=h, force_mha=True)
    if plan is not None:
        out = _sharded_masked_attention(plan, q_full, k, vv, positions,
                                        kv_pos, kv_valid, causal=True,
                                        window=spec.window, scale=scale)
    else:
        out = masked_attention(q_full, k, vv, positions, kv_pos, kv_valid,
                               causal=True, window=spec.window, scale=scale)
    return out.reshape(b, s, -1) @ p["w_o"], new_cache


def apply_mixer_attn(cfg: ModelConfig, spec: BlockSpec, p, x: Array, **kw):
    if spec.mixer == MIXER_MLA:
        return apply_mla(cfg, spec, p, x, **kw)
    assert spec.mixer in (MIXER_GQA, MIXER_LOCAL), spec.mixer
    return apply_gqa(cfg, spec, p, x, **kw)
