"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory, fully
parallelizable-in-principle) and sLSTM (scalar memory with recurrent gate
connections).  Both use exponential gating with the max-stabilizer state m.

State per mLSTM block:  C (B,H,dk,dv), n (B,H,dk), m (B,H), conv tail.
State per sLSTM block:  c, n, h (B,D_inner), m (B,D_inner).

Prefill runs a time-major ``lax.scan`` (the chunkwise-parallel mLSTM form is
a recorded perf-iteration candidate); decode is one step.  The xLSTM-1.3b
config uses the paper's 7:1 mLSTM:sLSTM interleave.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.models.layers import _dense

Array = jax.Array


def _inner(cfg: ModelConfig) -> int:
    return 2 * cfg.d_model


def _heads(cfg: ModelConfig) -> int:
    return max(cfg.n_heads, 1)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def init_mlstm(cfg: ModelConfig, key) -> dict:
    d, di = cfg.d_model, _inner(cfg)
    h = _heads(cfg)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 10)
    return {
        "w_up": _dense(ks[0], (d, di), dt),
        "w_z": _dense(ks[1], (d, di), dt),                 # output gate branch
        "conv_w": _dense(ks[2], (4, di), dt, scale=0.1),
        "conv_b": jnp.zeros((di,), dt),
        "w_q": _dense(ks[3], (di, di), dt),
        "w_k": _dense(ks[4], (di, di), dt),
        "w_v": _dense(ks[5], (di, di), dt),
        "w_if": _dense(ks[6], (di, 2 * h), dt, scale=0.02),  # i,f gate logits
        "b_if": jnp.concatenate([jnp.zeros((h,)), 3.0 * jnp.ones((h,))]).astype(dt),
        "w_down": _dense(ks[7], (di, d), dt),
    }


def init_cache_mlstm(cfg: ModelConfig, batch: int, dtype=None) -> dict:
    di = _inner(cfg)
    h = _heads(cfg)
    dk = di // h
    return {
        "C": jnp.zeros((batch, h, dk, dk), jnp.float32),
        "n": jnp.zeros((batch, h, dk), jnp.float32),
        "m": jnp.full((batch, h), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, 3, di), dtype or cfg.dtype),
    }


def _conv4(p, x: Array, tail: Optional[Array],
           valid: Optional[Array] = None) -> Tuple[Array, Array]:
    cw = p["conv_w"].shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1], :] * p["conv_w"][i] for i in range(cw))
    if valid is None:
        new_tail = xp[:, -(cw - 1):, :]
    else:
        lengths = valid.sum(axis=-1).astype(jnp.int32)
        idx = lengths[:, None] + jnp.arange(cw - 1)[None]
        new_tail = jnp.take_along_axis(xp, idx[..., None], axis=1)
    return jax.nn.silu(y + p["conv_b"]), new_tail


def _mlstm_step(q, k, v, log_i, log_f, state):
    """One timestep. q,k,v: (B,H,dk); log_i/log_f: (B,H)."""
    C, n, m = state
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    C = f_p[..., None] * C + i_p[..., None] * (k[..., :, None] * v[..., None, :])
    n = f_p * n + i_p * k
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, q)), 1.0)
    h = jnp.einsum("bhkv,bhk->bhv", C, q) / denom[..., None]
    return (C, n, m_new), h


_CHUNK_W = 128


def _mlstm_chunkwise(q, k, v, log_i, log_f, state, valid_sb=None):
    """Chunkwise-parallel mLSTM (§Perf iteration: xlstm train_4k).

    Exact reformulation of the per-step recurrence: with F_t = cumsum(log_f)
    and g_t = log_i_t - F_t, the stabilizer is m_t = F_t + M_t where
    M_t = max(m_0, cummax_{j<=t} g_j), the contribution of step j at time t
    is exp(g_j - M_t) k_j v_j^T, and the carry-in state scales by
    exp(m_0 - M_t). All exponents are <= 0 by construction. Sequential
    length drops from S to S/W (W = _CHUNK_W) and the intra-chunk term becomes
    a masked matmul — this is what makes 4k-token mLSTM training fit HBM
    (the per-step scan saved a (B,H,dk,dk) matrix per timestep for the
    backward pass).

    q,k,v: (B,S,H,dk) fp32; log_i/log_f: (B,S,H); state: (C, n, m).
    Returns (state', h (B,S,H,dk)).
    """
    b, s_len, hh, dk = q.shape
    w_ = _CHUNK_W
    nc = s_len // w_

    def to_chunks(x):
        return x.reshape((b, nc, w_) + x.shape[2:]).swapaxes(0, 1)

    qc, kc, vc = to_chunks(q), to_chunks(k), to_chunks(v)
    ic, fc = to_chunks(log_i), to_chunks(log_f)
    mask_c = (to_chunks(valid_sb) if valid_sb is not None
              else jnp.ones((nc, b, w_), bool))

    causal = jnp.tril(jnp.ones((w_, w_), bool))

    def chunk_step(st, inp):
        C0, n0, m0 = st                       # (B,H,dk,dk) (B,H,dk) (B,H)
        q_i, k_i, v_i, li, lf, ok = inp       # (B,W,H,dk) ... (B,W)
        li = jnp.where(ok[:, :, None], li, -1e30)
        lf = jnp.where(ok[:, :, None], lf, 0.0)
        F = jnp.cumsum(lf, axis=1)            # (B,W,H)
        g = li - F
        M = jnp.maximum(jax.lax.cummax(g, axis=1), m0[:, None])  # (B,W,H)

        # intra-chunk: scores_ij = (q_i . k_j) exp(g_j - M_i), j <= i
        qh = q_i.transpose(0, 2, 1, 3)        # (B,H,W,dk)
        kh = k_i.transpose(0, 2, 1, 3)
        vh = v_i.transpose(0, 2, 1, 3)
        dots = jnp.einsum("bhid,bhjd->bhij", qh, kh)
        expo = (g.transpose(0, 2, 1)[:, :, None, :]
                - M.transpose(0, 2, 1)[:, :, :, None])
        okj = ok[:, None, None, :]            # (B,1,1,W)
        keep = causal[None, None] & okj
        # mask BEFORE exp: j>i entries have positive exponents (overflow)
        scores = dots * jnp.exp(jnp.where(keep, expo, -1e30))
        h_intra = jnp.einsum("bhij,bhjd->bhid", scores, vh)

        # inter-chunk: carry-in state scaled by exp(m0 - M_i)
        a = jnp.exp(m0[:, None] - M).transpose(0, 2, 1)   # (B,H,W)
        h_inter = jnp.einsum("bhid,bhde->bhie", qh, C0) * a[..., None]
        num = h_inter + h_intra
        qn0 = jnp.einsum("bhid,bhd->bhi", qh, n0) * a
        denom = qn0 + jnp.sum(scores, axis=-1)
        h = num / jnp.maximum(jnp.abs(denom), 1.0)[..., None]

        # end-of-chunk state
        M_W = M[:, -1]                        # (B,H)
        F_W = F[:, -1]                        # (B,H)
        decay = jnp.exp(g - M_W[:, None])     # (B,W,H), <= 1
        decay = decay * ok[:, :, None]
        C = jnp.exp(m0 - M_W)[..., None, None] * C0 + jnp.einsum(
            "bhjd,bhje->bhde", kh * decay.transpose(0, 2, 1)[..., None], vh)
        n = jnp.exp(m0 - M_W)[..., None] * n0 + jnp.sum(
            kh * decay.transpose(0, 2, 1)[..., None], axis=2)
        m = F_W + M_W
        return (C, n, m), h.transpose(0, 2, 1, 3)   # back to (B,W,H,dk)

    state, hs = jax.lax.scan(chunk_step, state,
                             (qc, kc, vc, ic, fc, mask_c))
    h = hs.swapaxes(0, 1).reshape(b, s_len, hh, dk)
    return state, h


def apply_mlstm(cfg: ModelConfig, p, x: Array, *,
                cache: Optional[dict] = None,
                valid: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    b, s, d = x.shape
    di = _inner(cfg)
    hh = _heads(cfg)
    dk = di // hh
    xin = x @ p["w_up"]
    z = x @ p["w_z"]
    xc, new_tail = _conv4(p, xin, cache["conv"] if cache else None, valid)

    q = (xc @ p["w_q"]).reshape(b, s, hh, dk).astype(jnp.float32) / (dk ** 0.5)
    k = (xc @ p["w_k"]).reshape(b, s, hh, dk).astype(jnp.float32) / (dk ** 0.5)
    v = (xin @ p["w_v"]).reshape(b, s, hh, dk).astype(jnp.float32)
    gates = xc.astype(jnp.float32) @ p["w_if"].astype(jnp.float32) + p["b_if"].astype(jnp.float32)
    log_i, f_raw = gates[..., :hh], gates[..., hh:]
    log_f = -jax.nn.softplus(-f_raw)                       # log sigmoid(f)

    if cache is not None:
        state = (cache["C"], cache["n"], cache["m"])
    else:
        state = (jnp.zeros((b, hh, dk, dk), jnp.float32),
                 jnp.zeros((b, hh, dk), jnp.float32),
                 jnp.full((b, hh), -1e30, jnp.float32))

    if s % _CHUNK_W == 0 and s >= 2 * _CHUNK_W:
        vb = valid.astype(bool) if valid is not None else None
        state, hs_bshd = _mlstm_chunkwise(q, k, v, log_i, log_f, state,
                                          valid_sb=vb)
        h = hs_bshd.reshape(b, s, di).astype(x.dtype)
    else:
        valid_sb = (jnp.ones((s, b), bool) if valid is None
                    else valid.T.astype(bool))

        def step(st, inp):
            qt, kt, vt, it, ft, vm = inp
            new_st, h = _mlstm_step(qt, kt, vt, it, ft, st)
            # masked steps keep the old state verbatim (C, n, m untouched)
            st = jax.tree_util.tree_map(
                lambda nw, old: jnp.where(
                    vm.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, old),
                new_st, st)
            return st, h

        xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
              log_i.swapaxes(0, 1), log_f.swapaxes(0, 1), valid_sb)
        state, hs = jax.lax.scan(step, state, xs)          # hs: (S,B,H,dk)
        h = hs.swapaxes(0, 1).reshape(b, s, di).astype(x.dtype)

    out = (h * jax.nn.silu(z)) @ p["w_down"]
    new_cache = None
    if cache is not None:
        new_cache = {"C": state[0], "n": state[1], "m": state[2],
                     "conv": new_tail.astype(cache["conv"].dtype)}
    return out, new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def init_slstm(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    h = _heads(cfg)
    dh = d // h
    dt = cfg.param_dtype
    ks = jax.random.split(key, 8)
    return {
        # input weights for z,i,f,o
        "w_zifo": _dense(ks[0], (d, 4 * d), dt),
        "b_zifo": jnp.concatenate(
            [jnp.zeros((2 * d,)), 3.0 * jnp.ones((d,)), jnp.zeros((d,))]).astype(dt),
        # block-diagonal (per-head) recurrent weights for z,i,f,o
        "r_zifo": _dense(ks[1], (4, h, dh, dh), dt, scale=0.02),
        "w_up": _dense(ks[2], (d, 2 * d), dt),
        "w_down": _dense(ks[3], (d, d), dt),               # after GLU halves
    }


def init_cache_slstm(cfg: ModelConfig, batch: int, dtype=None) -> dict:
    d = cfg.d_model
    return {
        "c": jnp.zeros((batch, d), jnp.float32),
        "n": jnp.zeros((batch, d), jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
        "m": jnp.full((batch, d), -1e30, jnp.float32),
    }


def _slstm_step(cfg: ModelConfig, p, xt: Array, state):
    """xt: (B,D). Sequential by construction (recurrent gate connections)."""
    c, n, h, m = state
    b, d = xt.shape
    hh = _heads(cfg)
    dh = d // hh
    wx = xt.astype(jnp.float32) @ p["w_zifo"].astype(jnp.float32) + p["b_zifo"].astype(jnp.float32)
    hheads = h.reshape(b, hh, dh)
    rec = jnp.einsum("bhd,ghde->gbhe", hheads, p["r_zifo"].astype(jnp.float32))
    rec = rec.reshape(4, b, d)
    z_raw, i_raw, f_raw, o_raw = jnp.split(wx, 4, axis=-1)
    z = jnp.tanh(z_raw + rec[0])
    log_i = i_raw + rec[1]
    log_f = -jax.nn.softplus(-(f_raw + rec[2]))            # log sigmoid
    o = jax.nn.sigmoid(o_raw + rec[3])
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    c = f_p * c + i_p * z
    n = f_p * n + i_p
    h_new = o * c / jnp.maximum(n, 1.0)
    return (c, n, h_new, m_new)


def apply_slstm(cfg: ModelConfig, p, x: Array, *,
                cache: Optional[dict] = None,
                valid: Optional[Array] = None) -> Tuple[Array, Optional[dict]]:
    b, s, d = x.shape
    if cache is not None:
        state = (cache["c"], cache["n"], cache["h"], cache["m"])
    else:
        state = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
                 jnp.zeros((b, d), jnp.float32), jnp.full((b, d), -1e30, jnp.float32))

    if valid is None:
        valid = jnp.ones((b, s), dtype=bool)

    def step(st, inp):
        xt, vt = inp
        new = _slstm_step(cfg, p, xt, st)
        st = jax.tree_util.tree_map(
            lambda n, o: jnp.where(vt[:, None], n, o), new, st)
        return st, st[2]

    state, hs = jax.lax.scan(step, state, (x.swapaxes(0, 1), valid.T))
    h = hs.swapaxes(0, 1).astype(x.dtype)                  # (B,S,D)

    # post up-projection (GLU)
    u = h @ p["w_up"]
    a, g = jnp.split(u, 2, axis=-1)
    out = (a * jax.nn.sigmoid(g)) @ p["w_down"]
    new_cache = None
    if cache is not None:
        new_cache = {"c": state[0], "n": state[1], "h": state[2], "m": state[3]}
    return out, new_cache
