"""Shared primitive layers: norms, positional embeddings, MLPs.

Pure-JAX, pytree-parameter style: each layer is an ``init_*`` returning a
param pytree plus an ``apply_*`` function. No framework dependency.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

Array = jax.Array


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------


def init_norm(cfg: ModelConfig, dim: Optional[int] = None):
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), dtype=cfg.param_dtype)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype=cfg.param_dtype)
    return p


def apply_norm(cfg: ModelConfig, p, x: Array) -> Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps) * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard / partial / M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(dim: int, theta: float) -> Array:
    """Inverse frequencies for a rotary dim (must be even)."""
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))


def _rotate(x: Array, cos: Array, sin: Array) -> Array:
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)


def apply_rope(x: Array, positions: Array, theta: float,
               rotary_pct: float = 1.0) -> Array:
    """x: (B, S, H, hd); positions: (B, S) int32. Rotates the first
    ``rotary_pct`` fraction of the head dim (stablelm-style partial RoPE)."""
    hd = x.shape[-1]
    rot = int(hd * rotary_pct)
    rot -= rot % 2
    if rot == 0:
        return x
    inv = rope_freqs(rot, theta)                      # (rot/2,)
    ang = positions.astype(jnp.float32)[..., None] * inv  # (B, S, rot/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x_rot = _rotate(x_rot.astype(jnp.float32), cos, sin).astype(x.dtype)
    return jnp.concatenate([x_rot, x_pass], axis=-1) if x_pass.shape[-1] else x_rot


def apply_mrope(x: Array, positions3: Array, theta: float,
                sections: Tuple[int, ...]) -> Array:
    """Qwen2-VL M-RoPE. positions3: (3, B, S) — (temporal, height, width)
    position ids; ``sections`` splits the rot/2 frequency channels among the
    three axes. For pure text all three id planes are equal, which makes
    M-RoPE degenerate to standard RoPE (the property tests assert this)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang_all = positions3.astype(jnp.float32)[..., None] * inv  # (3, B, S, hd/2)
    # which position plane drives each frequency channel
    import numpy as _np
    sel = _np.repeat(_np.arange(len(sections)), _np.asarray(sections))  # (hd/2,)
    assert sel.shape[0] == hd // 2, (sections, hd)
    ang = ang_all[sel, :, :, _np.arange(hd // 2)]     # (hd/2, B, S)
    ang = jnp.moveaxis(ang, 0, -1)                    # (B, S, hd/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    return _rotate(x.astype(jnp.float32), cos, sin).astype(x.dtype)


def position_plane(positions: Array) -> Array:
    """Text-only M-RoPE position ids: (B,S) -> (3,B,S) with equal planes."""
    return jnp.broadcast_to(positions[None], (3,) + positions.shape)


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU / GeGLU / GeLU)
# ---------------------------------------------------------------------------


def _dense(key, shape, dtype, scale: Optional[float] = None):
    fan_in = shape[0]
    std = scale if scale is not None else (1.0 / jnp.sqrt(fan_in))
    return (jax.random.normal(key, shape, dtype=jnp.float32) * std).astype(dtype)


def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None):
    d, f = cfg.d_model, (d_ff or cfg.d_ff)
    dt = cfg.param_dtype
    ks = jax.random.split(key, 3)
    if cfg.activation in ("swiglu", "geglu"):
        return {
            "w_gate": _dense(ks[0], (d, f), dt),
            "w_up": _dense(ks[1], (d, f), dt),
            "w_down": _dense(ks[2], (f, d), dt),
        }
    return {"w_up": _dense(ks[0], (d, f), dt), "w_down": _dense(ks[1], (f, d), dt)}


def apply_mlp(cfg: ModelConfig, p, x: Array) -> Array:
    if cfg.activation in ("swiglu", "geglu"):
        g = x @ p["w_gate"]
        u = x @ p["w_up"]
        act = jax.nn.silu(g) if cfg.activation == "swiglu" else jax.nn.gelu(g)
        return (act * u) @ p["w_down"]
    h = jax.nn.gelu(x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embed(cfg: ModelConfig, key):
    ks = jax.random.split(key, 3)
    p = {"tok": _dense(ks[0], (cfg.vocab_size, cfg.d_model), cfg.param_dtype, scale=0.02)}
    if not cfg.tie_embeddings:
        p["lm_head"] = _dense(ks[1], (cfg.d_model, cfg.vocab_size), cfg.param_dtype)
    if cfg.pos_emb == "learned":
        p["pos"] = _dense(ks[2], (cfg.max_seq_len, cfg.d_model), cfg.param_dtype,
                          scale=0.02)
    return p


def embed_tokens(cfg: ModelConfig, p, tokens: Array) -> Array:
    return p["tok"][tokens].astype(cfg.dtype)


def unembed(cfg: ModelConfig, p, x: Array) -> Array:
    w = p["tok"].T if cfg.tie_embeddings else p["lm_head"]
    logits = (x @ w.astype(x.dtype)).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits
