from repro.models.config import (BlockSpec, EncoderConfig, MLAConfig,
                                 ModelConfig, MoEConfig, reduced)
from repro.models.model import DecoderModel

__all__ = ["ModelConfig", "MoEConfig", "MLAConfig", "EncoderConfig",
           "BlockSpec", "DecoderModel", "reduced"]
