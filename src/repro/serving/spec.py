"""Drafters for speculative verify-k decoding (DESIGN.md §Speculative
decode).

Two drafters sit behind one tiny interface — ``propose(history, k)``
returns up to ``k`` proposed continuation tokens for one request:

  * ``NgramDrafter`` — draft-free prompt/self-lookup: match the last
    ``n`` tokens of the request's own history (prompt + everything
    generated) against an earlier occurrence and propose the tokens that
    followed it.  Pure host-side numpy, zero extra dispatches; proposals
    are naturally variable-length (no match -> no speculation for that
    request this iteration).
  * the draft-model path — a tiny ``DecoderModel`` (any config from
    ``configs/``, same vocab as the target) greedily extended ``k`` steps
    by the engine in ONE jitted ``lax.scan`` over the full (padded)
    history.  The draft is *stateless* — it keeps no KV cache — so
    preemption, folding and swap need no draft-side bookkeeping at all.
    The engine owns the jitted executables (they share the prefill LRU);
    this module only builds the model.

Correctness never depends on the drafter: verification accepts exactly
the prefix that matches the target's own greedy argmax, so any proposal
stream yields bit-identical output tokens — drafters only change how many
tokens each dispatch commits.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np


class NgramDrafter:
    """Prompt-lookup / self-lookup n-gram proposer.

    Tries suffix lengths ``max_n .. 1``: for each, scans earlier
    occurrences of the history's suffix most-recent-first and proposes
    the (up to ``k``) tokens that followed one.  A match near the end of
    the history has its continuation truncated by the history boundary —
    on periodic histories (the n-gram sweet spot) the most recent match
    would propose a single token where an earlier occurrence of the same
    suffix offers the full window — so the scan returns the most recent
    match whose continuation fills ``k``, falling back to the most recent
    longest one.  Deterministic, O(len(history)^2) worst case on
    histories bounded by ``max_len`` — negligible next to a dispatch.
    """

    def __init__(self, max_n: int = 3):
        assert max_n >= 1
        self.max_n = max_n

    def propose(self, history: np.ndarray, k: int) -> np.ndarray:
        h = np.asarray(history)
        n_hist = len(h)
        for n in range(min(self.max_n, n_hist - 1), 0, -1):
            suffix = h[n_hist - n:]
            best = np.empty(0, dtype=np.int64)
            for s in range(n_hist - n - 1, -1, -1):
                if np.array_equal(h[s:s + n], suffix):
                    cont = h[s + n:s + n + k]
                    if len(cont) == k:
                        return cont.astype(np.int64)
                    if len(cont) > len(best):
                        best = cont.astype(np.int64)
            if len(best):
                return best
        return np.empty(0, dtype=np.int64)


def build_draft_model(config_name: str, vocab_size: int,
                      seed: int = 1) -> Tuple[object, object]:
    """Construct a tiny draft model from a registered config's smoke
    variant.  The draft must share the target's vocabulary — token ids are
    what verification compares."""
    from repro.configs import get_smoke_config
    from repro.models.model import DecoderModel

    import jax

    cfg = get_smoke_config(config_name)
    if cfg.vocab_size != vocab_size:
        raise ValueError(
            f"draft config {config_name!r} has vocab {cfg.vocab_size}, "
            f"target has {vocab_size}; drafts must share the tokenizer")
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(seed))
    return model, params


def accepted_prefix(proposed: np.ndarray, target: np.ndarray) -> int:
    """Length of the verified prefix: ``proposed[j]`` is accepted iff it
    equals the target argmax after position ``j`` (``target[j]``), and
    every earlier draft was accepted too."""
    a = 0
    for j in range(len(proposed)):
        if int(proposed[j]) != int(target[j]):
            break
        a += 1
    return a
