"""Discrete-event serving simulator.

Drives the SAME scheduler classes as the real-execution engine through the
analytic cost model, producing paper-scale latency/energy numbers on CPU:
iterations are events whose durations come from CostModel; arrivals are an
exogenous trace (Poisson or bursty). This is the apparatus behind the
Figure 3/4 SLO sweeps, Tables 2/6/8 and Figure 5.

The serving loop itself — arrival injection, stepping, timestamping —
is the shared ``serving.runtime.ServingRuntime`` (the same loop that
drives the real engine); this module only prices iterations and
aggregates the analytic accounting into a ``SimResult``.

The functional-correctness of the schedulers is established separately by
tests/test_engine_equivalence.py on real models; here only TIME and TRAFFIC
are modelled.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.base import Scheduler, make_scheduler
from repro.core.plan import Request
from repro.models.config import ModelConfig
from repro.serving.cost_model import CostModel, HardwareSpec, kv_pool_pages
from repro.serving.kvcache import PagedKVAllocator
from repro.serving.runtime import ServingRuntime, SimExecutor
from repro.serving.traffic import TraceRequest


@dataclass
class SimResult:
    requests: List[Request]
    total_energy: float = 0.0
    total_expert_bytes: float = 0.0
    total_hbm_bytes: float = 0.0
    total_flops: float = 0.0
    n_iterations: int = 0
    sim_time: float = 0.0
    decode_batch_sizes: List[int] = field(default_factory=list)
    # paged-KV memory subsystem accounting
    n_preemptions: int = 0
    recompute_tokens: int = 0      # prefill tokens re-run due to preemption
    pages_high_water: int = 0
    n_pool_pages: int = 0
    # swap-to-host accounting
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    swap_bytes: float = 0.0        # host-link traffic, both directions
    swap_dma_time: float = 0.0     # host-link busy time, both directions
    swap_stall_time: float = 0.0   # DMA time the iteration compute could
    #                                not hide (== swap_dma_time when the
    #                                serial model is selected)
    host_pages_high_water: int = 0
    n_host_pages: int = 0
    # speculative decode accounting (analytic acceptance)
    total_drafted: int = 0
    total_accepted: int = 0
    # automatic prefix caching (traces must carry prompt_tokens to hit)
    n_prefix_hits: int = 0
    prefix_cached_tokens: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        """Token-weighted: cached prompt tokens over all admitted prompt
        tokens (recompute re-admissions included)."""
        admitted = sum(r.admitted_prompt_tokens for r in self.requests)
        return self.prefix_cached_tokens / admitted if admitted else 0.0

    @property
    def acceptance_rate(self) -> float:
        return self.total_accepted / self.total_drafted \
            if self.total_drafted else float("nan")

    @property
    def total_tokens(self) -> int:
        """prompt + generated tokens (paper's energy/token denominator).
        Folded recompute tokens are already inside prompt_len — subtract
        them so preempted requests are not counted twice."""
        return sum(r.prompt_len - r.n_folded + r.n_generated
                   for r in self.requests)

    @property
    def energy_per_token(self) -> float:
        t = self.total_tokens
        return self.total_energy / t if t else float("nan")

    @property
    def mean_decode_batch(self) -> float:
        xs = [b for b in self.decode_batch_sizes if b > 0]
        return sum(xs) / len(xs) if xs else 0.0


class Simulator:
    def __init__(self, cfg: ModelConfig, scheduler, hw: HardwareSpec,
                 moe_dispatch: str = "ragged", n_pages: Optional[int] = None,
                 page_size: int = 16, preemption: bool = True,
                 preemption_mode: str = "recompute",
                 host_pages: Optional[int] = None,
                 swap_in_budget: Optional[int] = None,
                 decode_reserve: Optional[int] = None,
                 swap_overlap: bool = True,
                 class_headroom: Optional[Dict[str, int]] = None,
                 prefix_cache: bool = True,
                 prefix_lru_pages: Optional[int] = None,
                 spec_mode: str = "off", spec_k: int = 4,
                 spec_adaptive: bool = True,
                 spec_acceptance: float = 0.7, spec_seed: int = 0,
                 **sched_kw):
        """The simulator shares the scheduler's ``PagedKVAllocator`` so page
        occupancy, queueing delay, preemption counts and recompute/swap cost
        are first-class outputs of the paper-scale sweeps. ``n_pages``
        defaults to the page count the hardware's HBM can actually hold
        after model weights (see cost_model.kv_pool_pages);
        ``preemption_mode`` picks the eviction flavour ("recompute" |
        "swap" | "auto" — auto prices each victim's DMA round-trip against
        its recompute prefill on this hardware), ``host_pages`` sizes the
        host pool (default 4x the device pool) and ``swap_in_budget`` caps
        DMA-back KV tokens per iteration.  ``swap_overlap`` charges swap
        DMA as overlappable with the iteration's compute (stall =
        max(0, dma - compute)); False restores the PR-3 fully-serial stall
        for comparison.  ``class_headroom`` reserves admission pages per
        SLO class (see core.base.Scheduler.attach_kv).

        ``prefix_cache`` (default on) enables automatic prefix caching on
        the shared allocator; hits need traces that carry
        ``prompt_tokens`` (see traffic.attach_prompt_tokens /
        shared_prefix_trace) — the cost model then prices only the
        uncached prefill rectangles, mirroring the engine.
        ``prefix_lru_pages`` caps retained refcount-0 cached pages.

        ``spec_mode``/``spec_k`` enable speculative verify-k decoding in
        the planned iterations; the simulator has no tokens, so acceptance
        is ANALYTIC — a run of consecutive Bernoulli(``spec_acceptance``)
        successes per verify window, seeded by ``spec_seed`` (token
        counts and durations are deterministic per seed).  The cost model
        prices each window's extra decode-query tokens and the MoE
        expert-load amortization they ride on."""
        self.cfg = cfg
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, cfg.n_layers, **sched_kw)
        self.scheduler: Scheduler = scheduler
        self.cost = CostModel(cfg, hw, moe_dispatch=moe_dispatch)
        if n_pages is None:
            n_pages = kv_pool_pages(cfg, hw, page_size)
        if host_pages is None:
            host_pages = 4 * n_pages if preemption_mode != "recompute" else 0
        self.kv = PagedKVAllocator(n_pages, page_size,
                                   stash_factor=cfg.stash_token_factor(),
                                   n_host_pages=host_pages,
                                   prefix_caching=prefix_cache,
                                   prefix_lru_pages=prefix_lru_pages)
        swap_cost_fn = None
        if preemption_mode == "auto":
            swap_cost_fn = lambda r: self.cost.swap_beats_recompute(  # noqa: E731
                r.prompt_len + r.n_generated - r.n_folded)
        self.scheduler.attach_kv(self.kv, decode_reserve=decode_reserve,
                                 preemption=preemption,
                                 mode=preemption_mode,
                                 swap_in_budget=swap_in_budget,
                                 swap_cost_fn=swap_cost_fn,
                                 class_headroom=class_headroom)
        self.swap_overlap = swap_overlap
        if spec_mode != "off":
            self.scheduler.configure_speculation(spec_mode, spec_k,
                                                 adaptive=spec_adaptive)
        self.spec_acceptance = spec_acceptance
        self._spec_rng = np.random.default_rng(spec_seed)

    def draw_accepted(self, k: int) -> int:
        """Consecutive-success draw: each of the k drafts is accepted with
        probability ``spec_acceptance`` GIVEN every earlier one was."""
        a = 0
        while a < k and self._spec_rng.random() < self.spec_acceptance:
            a += 1
        return a

    def run(self, trace: List[TraceRequest],
            max_iterations: int = 2_000_000, *,
            on_token=None, clock: str = "executor") -> SimResult:
        """Replay ``trace`` through the shared ServingRuntime loop with the
        analytic backend.  ``on_token``/``clock`` pass straight through to
        the runtime (tokens stream as ``None`` — the simulator carries no
        model; ``clock="iteration"`` interprets arrival times as iteration
        indices for deterministic cross-backend replay)."""
        ex = SimExecutor(self)
        runtime = ServingRuntime(ex, on_token=on_token, clock=clock)
        rr = runtime.run(trace, max_iterations=max_iterations)
        return SimResult(
            requests=rr.requests,
            total_energy=ex.total_energy,
            total_expert_bytes=ex.total_expert_bytes,
            total_hbm_bytes=ex.total_hbm_bytes,
            total_flops=ex.total_flops,
            n_iterations=rr.n_iterations,
            sim_time=rr.clock,
            decode_batch_sizes=rr.decode_batch_sizes,
            n_preemptions=rr.n_preemptions,
            recompute_tokens=rr.recompute_tokens,
            pages_high_water=self.kv.pages_high_water,
            n_pool_pages=self.kv.n_pages,
            n_swap_outs=rr.n_swap_outs,
            n_swap_ins=rr.n_swap_ins,
            swap_bytes=ex.swap_bytes,
            swap_dma_time=ex.swap_dma_time,
            swap_stall_time=ex.swap_stall_time,
            host_pages_high_water=self.kv.host_pages_high_water,
            n_host_pages=self.kv.n_host_pages,
            total_drafted=ex.total_drafted,
            total_accepted=ex.total_accepted,
            n_prefix_hits=self.kv.n_prefix_hits,
            prefix_cached_tokens=self.kv.n_prefix_tokens,
        )
