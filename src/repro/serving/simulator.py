"""Discrete-event serving simulator.

Drives the SAME scheduler classes as the real-execution engine through the
analytic cost model, producing paper-scale latency/energy numbers on CPU:
iterations are events whose durations come from CostModel; arrivals are an
exogenous trace (Poisson or bursty). This is the apparatus behind the
Figure 3/4 SLO sweeps, Tables 2/6/8 and Figure 5.

The serving loop itself — arrival injection, stepping, timestamping —
is the shared ``serving.runtime.ServingRuntime`` (the same loop that
drives the real engine); this module only prices iterations and
aggregates the analytic accounting into a ``SimResult``.

The functional-correctness of the schedulers is established separately by
tests/test_engine_equivalence.py on real models; here only TIME and TRAFFIC
are modelled.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.core.base import Scheduler, make_scheduler
from repro.core.plan import Request
from repro.models.config import ModelConfig
from repro.serving.cost_model import CostModel, HardwareSpec, kv_pool_pages
from repro.serving.kvcache import PagedKVAllocator
from repro.serving.runtime import (DisaggRuntime, Migration, ServingRuntime,
                                   SimExecutor)
from repro.serving.traffic import TraceRequest


@dataclass
class SimResult:
    requests: List[Request]
    total_energy: float = 0.0
    total_expert_bytes: float = 0.0
    total_hbm_bytes: float = 0.0
    total_flops: float = 0.0
    n_iterations: int = 0
    sim_time: float = 0.0
    decode_batch_sizes: List[int] = field(default_factory=list)
    # paged-KV memory subsystem accounting
    n_preemptions: int = 0
    recompute_tokens: int = 0      # prefill tokens re-run due to preemption
    pages_high_water: int = 0
    n_pool_pages: int = 0
    # swap-to-host accounting
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    swap_bytes: float = 0.0        # host-link traffic, both directions
    swap_dma_time: float = 0.0     # host-link busy time, both directions
    swap_stall_time: float = 0.0   # DMA time the iteration compute could
    #                                not hide (== swap_dma_time when the
    #                                serial model is selected)
    host_pages_high_water: int = 0
    n_host_pages: int = 0
    # speculative decode accounting (analytic acceptance)
    total_drafted: int = 0
    total_accepted: int = 0
    # automatic prefix caching (traces must carry prompt_tokens to hit)
    n_prefix_hits: int = 0
    prefix_cached_tokens: int = 0

    @property
    def prefix_hit_rate(self) -> float:
        """Token-weighted: cached prompt tokens over all admitted prompt
        tokens (recompute re-admissions included)."""
        admitted = sum(r.admitted_prompt_tokens for r in self.requests)
        return self.prefix_cached_tokens / admitted if admitted else 0.0

    @property
    def acceptance_rate(self) -> float:
        return self.total_accepted / self.total_drafted \
            if self.total_drafted else float("nan")

    @property
    def total_tokens(self) -> int:
        """prompt + generated tokens (paper's energy/token denominator).
        Folded recompute tokens are already inside prompt_len — subtract
        them so preempted requests are not counted twice."""
        return sum(r.prompt_len - r.n_folded + r.n_generated
                   for r in self.requests)

    @property
    def energy_per_token(self) -> float:
        t = self.total_tokens
        return self.total_energy / t if t else float("nan")

    @property
    def mean_decode_batch(self) -> float:
        xs = [b for b in self.decode_batch_sizes if b > 0]
        return sum(xs) / len(xs) if xs else 0.0


class Simulator:
    def __init__(self, cfg: ModelConfig, scheduler, hw: HardwareSpec,
                 moe_dispatch: str = "ragged", n_pages: Optional[int] = None,
                 page_size: int = 16, preemption: bool = True,
                 preemption_mode: str = "recompute",
                 host_pages: Optional[int] = None,
                 swap_in_budget: Optional[int] = None,
                 decode_reserve: Optional[int] = None,
                 swap_overlap: bool = True,
                 class_headroom: Optional[Dict[str, int]] = None,
                 prefix_cache: bool = True,
                 prefix_lru_pages: Optional[int] = None,
                 spec_mode: str = "off", spec_k: int = 4,
                 spec_adaptive: bool = True,
                 spec_acceptance: float = 0.7, spec_seed: int = 0,
                 **sched_kw):
        """The simulator shares the scheduler's ``PagedKVAllocator`` so page
        occupancy, queueing delay, preemption counts and recompute/swap cost
        are first-class outputs of the paper-scale sweeps. ``n_pages``
        defaults to the page count the hardware's HBM can actually hold
        after model weights (see cost_model.kv_pool_pages);
        ``preemption_mode`` picks the eviction flavour ("recompute" |
        "swap" | "auto" — auto prices each victim's DMA round-trip against
        its recompute prefill on this hardware), ``host_pages`` sizes the
        host pool (default 4x the device pool) and ``swap_in_budget`` caps
        DMA-back KV tokens per iteration.  ``swap_overlap`` charges swap
        DMA as overlappable with the iteration's compute (stall =
        max(0, dma - compute)); False restores the PR-3 fully-serial stall
        for comparison.  ``class_headroom`` reserves admission pages per
        SLO class (see core.base.Scheduler.attach_kv).

        ``prefix_cache`` (default on) enables automatic prefix caching on
        the shared allocator; hits need traces that carry
        ``prompt_tokens`` (see traffic.attach_prompt_tokens /
        shared_prefix_trace) — the cost model then prices only the
        uncached prefill rectangles, mirroring the engine.
        ``prefix_lru_pages`` caps retained refcount-0 cached pages.

        ``spec_mode``/``spec_k`` enable speculative verify-k decoding in
        the planned iterations; the simulator has no tokens, so acceptance
        is ANALYTIC — a run of consecutive Bernoulli(``spec_acceptance``)
        successes per verify window, seeded by ``spec_seed`` (token
        counts and durations are deterministic per seed).  The cost model
        prices each window's extra decode-query tokens and the MoE
        expert-load amortization they ride on."""
        self.cfg = cfg
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, cfg.n_layers, **sched_kw)
        self.scheduler: Scheduler = scheduler
        self.cost = CostModel(cfg, hw, moe_dispatch=moe_dispatch)
        if n_pages is None:
            n_pages = kv_pool_pages(cfg, hw, page_size)
        if host_pages is None:
            host_pages = 4 * n_pages if preemption_mode != "recompute" else 0
        self.kv = PagedKVAllocator(n_pages, page_size,
                                   stash_factor=cfg.stash_token_factor(),
                                   n_host_pages=host_pages,
                                   prefix_caching=prefix_cache,
                                   prefix_lru_pages=prefix_lru_pages)
        swap_cost_fn = None
        if preemption_mode == "auto":
            swap_cost_fn = lambda r: self.cost.swap_beats_recompute(  # noqa: E731
                r.prompt_len + r.n_generated - r.n_folded)
        self.scheduler.attach_kv(self.kv, decode_reserve=decode_reserve,
                                 preemption=preemption,
                                 mode=preemption_mode,
                                 swap_in_budget=swap_in_budget,
                                 swap_cost_fn=swap_cost_fn,
                                 class_headroom=class_headroom)
        self.swap_overlap = swap_overlap
        if spec_mode != "off":
            self.scheduler.configure_speculation(spec_mode, spec_k,
                                                 adaptive=spec_adaptive)
        self.spec_acceptance = spec_acceptance
        self._spec_rng = np.random.default_rng(spec_seed)

    def draw_accepted(self, k: int) -> int:
        """Consecutive-success draw: each of the k drafts is accepted with
        probability ``spec_acceptance`` GIVEN every earlier one was."""
        a = 0
        while a < k and self._spec_rng.random() < self.spec_acceptance:
            a += 1
        return a

    def run(self, trace: List[TraceRequest],
            max_iterations: int = 2_000_000, *,
            on_token=None, clock: str = "executor",
            faults=None, retry_budget: int = 3) -> SimResult:
        """Replay ``trace`` through the shared ServingRuntime loop with the
        analytic backend.  ``on_token``/``clock`` pass straight through to
        the runtime (tokens stream as ``None`` — the simulator carries no
        model; ``clock="iteration"`` interprets arrival times as iteration
        indices for deterministic cross-backend replay).  ``faults`` takes
        a ``serving.faults.FaultInjector`` to chaos-test the analytic
        stack under the same supervision the engine runs with."""
        ex = SimExecutor(self)
        runtime = ServingRuntime(ex, on_token=on_token, clock=clock,
                                 faults=faults, retry_budget=retry_budget)
        rr = runtime.run(trace, max_iterations=max_iterations)
        return self._result(ex, rr.requests, rr.n_iterations, rr.clock,
                            rr.decode_batch_sizes, rr.n_preemptions,
                            rr.recompute_tokens, rr.n_swap_outs,
                            rr.n_swap_ins)

    def _result(self, ex: SimExecutor, requests, n_iterations, sim_time,
                decode_batch_sizes, n_preemptions, recompute_tokens,
                n_swap_outs, n_swap_ins) -> SimResult:
        """Fold one executor's accounting plus this pool's allocator
        counters into a ``SimResult`` (shared by the monolithic ``run``
        and the per-pool reports of ``DisaggSimulator``)."""
        return SimResult(
            requests=requests,
            total_energy=ex.total_energy,
            total_expert_bytes=ex.total_expert_bytes,
            total_hbm_bytes=ex.total_hbm_bytes,
            total_flops=ex.total_flops,
            n_iterations=n_iterations,
            sim_time=sim_time,
            decode_batch_sizes=decode_batch_sizes,
            n_preemptions=n_preemptions,
            recompute_tokens=recompute_tokens,
            pages_high_water=self.kv.pages_high_water,
            n_pool_pages=self.kv.n_pages,
            n_swap_outs=n_swap_outs,
            n_swap_ins=n_swap_ins,
            swap_bytes=ex.swap_bytes,
            swap_dma_time=ex.swap_dma_time,
            swap_stall_time=ex.swap_stall_time,
            host_pages_high_water=self.kv.host_pages_high_water,
            n_host_pages=self.kv.n_host_pages,
            total_drafted=ex.total_drafted,
            total_accepted=ex.total_accepted,
            n_prefix_hits=self.kv.n_prefix_hits,
            prefix_cached_tokens=self.kv.n_prefix_tokens,
        )


class SimHandoff:
    """Analytic ``HandoffBridge``: the inter-pool link is a FIFO resource
    priced by ``CostModel.link_transfer``.  In ``stream`` mode every layer
    group whose KV completes enqueues its chunk at that iteration's end,
    so the transfer overlaps the REMAINING groups' prefill compute and the
    export-time stall is only the residual —
    ``stall = max(0, transfer_done - export_time)``, the paper's
    ``max(0, transfer - remaining_prefill_compute)`` realized on a link
    timeline that also captures cross-request queueing.  ``whole`` mode
    enqueues the full prompt's KV only at export, hiding nothing: with
    G >= 2 layer groups streaming strictly dominates because at most the
    LAST group's chunk (~1/G of the bytes) is left unhidden."""

    def __init__(self, src: Simulator, dst: Simulator,
                 mode: str = "stream"):
        if mode not in ("stream", "whole"):
            raise ValueError(f"unknown handoff mode {mode!r}")
        self.src = src
        self.dst = dst
        self.mode = mode
        self.cost = src.cost
        self._link_free = 0.0          # when the link finishes its queue
        self._done_t: Dict[int, float] = {}
        self._chunks: Dict[int, int] = {}
        self._bytes: Dict[int, float] = {}
        self.link_bytes = 0.0
        self.link_energy = 0.0
        self.n_chunks = 0

    def _enqueue(self, rid: int, n_tokens: float, now: float) -> None:
        x = self.cost.link_transfer(n_tokens)
        start = max(self._link_free, now)
        self._link_free = start + x["duration"]
        self._done_t[rid] = self._link_free
        self._chunks[rid] = self._chunks.get(rid, 0) + 1
        self._bytes[rid] = self._bytes.get(rid, 0.0) + x["bytes"]
        self.link_bytes += x["bytes"]
        self.link_energy += x["energy"]
        self.n_chunks += 1

    def decode_free_pages(self) -> int:
        return self.dst.kv.n_free_pages

    def stage(self, plan, requests, t_end: float, duration: float) -> None:
        if self.mode != "stream":
            return
        nb = self.src.scheduler.n_blocks
        for sl in plan.prefill:
            r = requests[sl.req_id]
            if sl.token_end == r.prompt_len:
                # this group's KV is complete: its share of the prompt's
                # pages enters the link queue at iteration end
                frac = (sl.block_end - sl.block_start) / nb
                self._enqueue(sl.req_id, sl.token_end * frac, t_end)

    def export(self, req: Request, now: float) -> Migration:
        rid = req.req_id
        exp = self.src.kv.export_pages(rid)
        if rid not in self._done_t:
            # whole-prompt handoff (or a chunked scheduler that never
            # completed a partial-stack group): everything crosses now
            self._enqueue(rid, exp.length, now)
        return Migration(req=req, payload=exp, export_time=now,
                         ready_time=max(now, self._done_t.pop(rid, now)),
                         n_chunks=self._chunks.pop(rid, 0),
                         bytes_total=self._bytes.pop(rid, 0.0))

    def can_import(self, m: Migration) -> bool:
        return self.dst.kv.can_import(m.payload)

    def do_import(self, m: Migration, now: float) -> Dict[str, int]:
        imp = self.dst.kv.import_pages(m.payload)
        return {"linked_tokens": imp.linked_tokens,
                "moved_tokens": imp.moved_tokens}

    def drop(self, req_id: int) -> None:
        self._done_t.pop(req_id, None)
        self._chunks.pop(req_id, None)
        self._bytes.pop(req_id, None)

    def abort_export(self, m: Migration) -> None:
        # analytic backends hold no buffers: the exported pages were
        # already freed (move semantics), so voiding the migration only
        # needs the link bookkeeping scrubbed
        self.drop(m.req.req_id)

    def return_to_prefill(self, req: Request) -> None:
        pass                           # analytic backends hold no buffers


@dataclass
class DisaggSimResult:
    """Two-pool analytic outcome: per-pool ``SimResult`` reports plus the
    migration/link accounting.  ``decode_prefill_slices`` MUST be 0 — the
    decode pool's clock never contains prefill work, so every decode-pool
    TBT sample is prefill-stall-free by construction."""
    requests: List[Request]
    prefill: SimResult
    decode: SimResult
    sim_time: float = 0.0
    n_migrations: int = 0
    n_returns: int = 0
    handoff_bytes: float = 0.0
    link_bytes: float = 0.0
    link_energy: float = 0.0
    link_stall_time: float = 0.0
    handoff_wait_time: float = 0.0
    migration_queue_peak: int = 0
    decode_prefill_slices: int = 0
    handoff_linked_tokens: int = 0
    handoff_moved_tokens: int = 0

    @property
    def total_energy(self) -> float:
        return self.prefill.total_energy + self.decode.total_energy \
            + self.link_energy

    def decode_pool_tbts(self) -> List[float]:
        """Inter-token gaps timestamped entirely INSIDE the decode pool
        (at or after the request's last migration) — the latency the
        paper's disaggregation argument protects."""
        out: List[float] = []
        for r in self.requests:
            if r.handoff_time is None:
                continue
            ts = [r.first_token_time] + r.token_times \
                if r.first_token_time is not None else list(r.token_times)
            ts = [x for x in ts if x >= r.handoff_time]
            out.extend(b - a for a, b in zip(ts, ts[1:]))
        return out

    @property
    def decode_pool_tbt_mean(self) -> float:
        xs = self.decode_pool_tbts()
        return sum(xs) / len(xs) if xs else float("nan")


class DisaggSimulator:
    """Analytic two-pool serving: a prefill ``Simulator`` (any scheduler)
    and a decode ``Simulator`` (``DecodeOnlyScheduler``) coupled by a
    ``SimHandoff`` link under the shared ``DisaggRuntime`` loop.
    ``handoff`` picks group-granular streaming ("stream") or the
    whole-prompt baseline ("whole"); ``decode_pages`` sizes the decode
    pool's allocator (default: mirror the prefill pool);
    ``decode_watermark`` holds new admissions while the decode pool has
    fewer free pages (backpressure).  Remaining kwargs configure the
    prefill pool exactly like ``Simulator``; the decode pool inherits the
    memory/preemption/speculation settings but never admits or prefills."""

    # Simulator kwargs the decode pool inherits (scheduler-specific ones
    # like n_groups/chunk_size stay on the prefill side)
    _POOL_KEYS = ("moe_dispatch", "page_size", "preemption",
                  "preemption_mode", "host_pages", "swap_in_budget",
                  "decode_reserve", "swap_overlap", "class_headroom",
                  "prefix_cache", "prefix_lru_pages", "spec_mode", "spec_k",
                  "spec_adaptive", "spec_acceptance", "spec_seed",
                  "n_slots", "token_budget", "quantum")

    def __init__(self, cfg: ModelConfig, scheduler, hw: HardwareSpec, *,
                 handoff: str = "stream", decode_pages: Optional[int] = None,
                 decode_watermark: int = 0, **kw):
        if handoff not in ("stream", "whole"):
            raise ValueError(f"unknown handoff mode {handoff!r}")
        self.handoff = handoff
        self.decode_watermark = decode_watermark
        self.prefill = Simulator(cfg, scheduler, hw, **kw)
        dkw = {k: kw[k] for k in self._POOL_KEYS if k in kw}
        dkw["n_pages"] = self.prefill.kv.n_pages \
            if decode_pages is None else decode_pages
        self.decode = Simulator(cfg, "decode", hw, **dkw)

    def run(self, trace: List[TraceRequest],
            max_iterations: int = 2_000_000, *,
            on_token=None, clock: str = "executor",
            faults=None, retry_budget: int = 3) -> DisaggSimResult:
        xp = SimExecutor(self.prefill)
        xd = SimExecutor(self.decode)
        bridge = SimHandoff(self.prefill, self.decode, mode=self.handoff)
        runtime = DisaggRuntime(
            xp, xd, bridge, on_token=on_token, clock=clock,
            decode_watermark_pages=self.decode_watermark,
            faults=faults, retry_budget=retry_budget)
        rr = runtime.run(trace, max_iterations=max_iterations)
        pre = self.prefill._result(
            xp, rr.requests, rr.n_prefill_iterations, rr.clock, [],
            rr.n_preemptions - rr.n_returns, rr.recompute_tokens, 0, 0)
        dec = self.decode._result(
            xd, rr.requests, rr.n_decode_iterations, rr.clock,
            rr.decode_batch_sizes, rr.n_returns, 0,
            rr.n_swap_outs, rr.n_swap_ins)
        return DisaggSimResult(
            requests=rr.requests,
            prefill=pre,
            decode=dec,
            sim_time=rr.clock,
            n_migrations=rr.n_migrations,
            n_returns=rr.n_returns,
            handoff_bytes=rr.handoff_bytes,
            link_bytes=bridge.link_bytes,
            link_energy=bridge.link_energy,
            link_stall_time=rr.link_stall_time,
            handoff_wait_time=rr.handoff_wait_time,
            migration_queue_peak=rr.migration_queue_peak,
            decode_prefill_slices=rr.decode_prefill_slices,
            handoff_linked_tokens=sum(r.handoff_linked_tokens
                                      for r in rr.requests),
            handoff_moved_tokens=sum(r.handoff_moved_tokens
                                     for r in rr.requests),
        )
