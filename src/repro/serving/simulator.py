"""Discrete-event serving simulator.

Drives the SAME scheduler classes as the real-execution engine through the
analytic cost model, producing paper-scale latency/energy numbers on CPU:
iterations are events whose durations come from CostModel; arrivals are an
exogenous Poisson trace. This is the apparatus behind the Figure 3/4 SLO
sweeps, Tables 2/6/8 and Figure 5.

The functional-correctness of the schedulers is established separately by
tests/test_engine_equivalence.py on real models; here only TIME and TRAFFIC
are modelled.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.core.base import Scheduler, make_scheduler
from repro.core.plan import Request, RequestState
from repro.models.config import ModelConfig
from repro.serving.cost_model import CostModel, HardwareSpec
from repro.serving.traffic import TraceRequest


@dataclass
class SimResult:
    requests: List[Request]
    total_energy: float = 0.0
    total_expert_bytes: float = 0.0
    total_hbm_bytes: float = 0.0
    total_flops: float = 0.0
    n_iterations: int = 0
    sim_time: float = 0.0
    decode_batch_sizes: List[int] = field(default_factory=list)

    @property
    def total_tokens(self) -> int:
        """prompt + generated tokens (paper's energy/token denominator)."""
        return sum(r.prompt_len + r.n_generated for r in self.requests)

    @property
    def energy_per_token(self) -> float:
        t = self.total_tokens
        return self.total_energy / t if t else float("nan")

    @property
    def mean_decode_batch(self) -> float:
        xs = [b for b in self.decode_batch_sizes if b > 0]
        return sum(xs) / len(xs) if xs else 0.0


class Simulator:
    def __init__(self, cfg: ModelConfig, scheduler, hw: HardwareSpec,
                 moe_dispatch: str = "ragged", **sched_kw):
        self.cfg = cfg
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, cfg.n_layers, **sched_kw)
        self.scheduler: Scheduler = scheduler
        self.cost = CostModel(cfg, hw, moe_dispatch=moe_dispatch)

    def run(self, trace: List[TraceRequest],
            max_iterations: int = 2_000_000) -> SimResult:
        sched = self.scheduler
        res = SimResult(requests=[])
        pending = sorted(trace, key=lambda t: t.arrival_time)
        next_id = 0
        t = 0.0
        i_arr = 0

        def admit_arrivals(now: float):
            nonlocal i_arr, next_id
            while i_arr < len(pending) and pending[i_arr].arrival_time <= now:
                tr = pending[i_arr]
                req = Request(req_id=next_id, prompt_len=tr.prompt_len,
                              max_new_tokens=tr.output_len,
                              arrival_time=tr.arrival_time)
                res.requests.append(req)
                sched.submit(req)
                next_id += 1
                i_arr += 1

        while i_arr < len(pending) or sched.has_work():
            admit_arrivals(t)
            if not sched.has_work():
                # idle until the next arrival
                t = pending[i_arr].arrival_time
                admit_arrivals(t)
            plan = sched.next_plan(now=t)
            if plan.empty:
                # nothing runnable (shouldn't happen when has_work)
                t = pending[i_arr].arrival_time if i_arr < len(pending) else t
                continue
            cost = self.cost.iteration_cost(plan, sched.requests)
            t += cost["duration"]
            res.total_energy += cost["energy"]
            res.total_expert_bytes += cost["expert_bytes"]
            res.total_hbm_bytes += cost["hbm_bytes"]
            res.total_flops += cost["flops"]
            res.n_iterations += 1
            res.decode_batch_sizes.append(len(plan.decode_ids))

            # timestamp tokens at iteration end
            for sl in plan.prefill:
                if sl.emits_first_token:
                    r = sched.requests[sl.req_id]
                    r.first_token_time = t
                    if r.state == RequestState.DONE:
                        r.finish_time = t
            for rid in plan.decode_ids:
                r = sched.requests[rid]
                r.token_times.append(t)
                if r.state == RequestState.DONE and r.finish_time is None:
                    r.finish_time = t

            if res.n_iterations >= max_iterations:
                raise RuntimeError("simulation iteration cap hit")

        res.sim_time = t
        return res
