"""Discrete-event serving simulator.

Drives the SAME scheduler classes as the real-execution engine through the
analytic cost model, producing paper-scale latency/energy numbers on CPU:
iterations are events whose durations come from CostModel; arrivals are an
exogenous Poisson trace. This is the apparatus behind the Figure 3/4 SLO
sweeps, Tables 2/6/8 and Figure 5.

The functional-correctness of the schedulers is established separately by
tests/test_engine_equivalence.py on real models; here only TIME and TRAFFIC
are modelled.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

from repro.core.base import Scheduler, make_scheduler
from repro.core.plan import Request, RequestState
from repro.models.config import ModelConfig
from repro.serving.cost_model import CostModel, HardwareSpec, kv_pool_pages
from repro.serving.kvcache import PagedKVAllocator
from repro.serving.traffic import TraceRequest


@dataclass
class SimResult:
    requests: List[Request]
    total_energy: float = 0.0
    total_expert_bytes: float = 0.0
    total_hbm_bytes: float = 0.0
    total_flops: float = 0.0
    n_iterations: int = 0
    sim_time: float = 0.0
    decode_batch_sizes: List[int] = field(default_factory=list)
    # paged-KV memory subsystem accounting
    n_preemptions: int = 0
    recompute_tokens: int = 0      # prefill tokens re-run due to preemption
    pages_high_water: int = 0
    n_pool_pages: int = 0
    # swap-to-host accounting
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    swap_bytes: float = 0.0        # host-link traffic, both directions
    swap_stall_time: float = 0.0   # time the iteration clock spent on DMA
    host_pages_high_water: int = 0
    n_host_pages: int = 0

    @property
    def total_tokens(self) -> int:
        """prompt + generated tokens (paper's energy/token denominator).
        Folded recompute tokens are already inside prompt_len — subtract
        them so preempted requests are not counted twice."""
        return sum(r.prompt_len - r.n_folded + r.n_generated
                   for r in self.requests)

    @property
    def energy_per_token(self) -> float:
        t = self.total_tokens
        return self.total_energy / t if t else float("nan")

    @property
    def mean_decode_batch(self) -> float:
        xs = [b for b in self.decode_batch_sizes if b > 0]
        return sum(xs) / len(xs) if xs else 0.0


class Simulator:
    def __init__(self, cfg: ModelConfig, scheduler, hw: HardwareSpec,
                 moe_dispatch: str = "ragged", n_pages: Optional[int] = None,
                 page_size: int = 16, preemption: bool = True,
                 preemption_mode: str = "recompute",
                 host_pages: Optional[int] = None,
                 swap_in_budget: Optional[int] = None,
                 decode_reserve: Optional[int] = None, **sched_kw):
        """The simulator shares the scheduler's ``PagedKVAllocator`` so page
        occupancy, queueing delay, preemption counts and recompute/swap cost
        are first-class outputs of the paper-scale sweeps. ``n_pages``
        defaults to the page count the hardware's HBM can actually hold
        after model weights (see cost_model.kv_pool_pages);
        ``preemption_mode`` picks the eviction flavour ("recompute" |
        "swap" | "auto" — auto prices each victim's DMA round-trip against
        its recompute prefill on this hardware), ``host_pages`` sizes the
        host pool (default 4x the device pool) and ``swap_in_budget`` caps
        DMA-back KV tokens per iteration."""
        self.cfg = cfg
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, cfg.n_layers, **sched_kw)
        self.scheduler: Scheduler = scheduler
        self.cost = CostModel(cfg, hw, moe_dispatch=moe_dispatch)
        if n_pages is None:
            n_pages = kv_pool_pages(cfg, hw, page_size)
        if host_pages is None:
            host_pages = 4 * n_pages if preemption_mode != "recompute" else 0
        self.kv = PagedKVAllocator(n_pages, page_size,
                                   stash_factor=cfg.stash_token_factor(),
                                   n_host_pages=host_pages)
        swap_cost_fn = None
        if preemption_mode == "auto":
            swap_cost_fn = lambda r: self.cost.swap_beats_recompute(  # noqa: E731
                r.prompt_len + r.n_generated - r.n_folded)
        self.scheduler.attach_kv(self.kv, decode_reserve=decode_reserve,
                                 preemption=preemption,
                                 mode=preemption_mode,
                                 swap_in_budget=swap_in_budget,
                                 swap_cost_fn=swap_cost_fn)

    def run(self, trace: List[TraceRequest],
            max_iterations: int = 2_000_000) -> SimResult:
        sched = self.scheduler
        res = SimResult(requests=[])
        pending = sorted(trace, key=lambda t: t.arrival_time)
        next_id = 0
        t = 0.0
        i_arr = 0

        def admit_arrivals(now: float):
            nonlocal i_arr, next_id
            while i_arr < len(pending) and pending[i_arr].arrival_time <= now:
                tr = pending[i_arr]
                req = Request(req_id=next_id, prompt_len=tr.prompt_len,
                              max_new_tokens=tr.output_len,
                              arrival_time=tr.arrival_time)
                res.requests.append(req)
                sched.submit(req)
                next_id += 1
                i_arr += 1

        while i_arr < len(pending) or sched.has_work():
            admit_arrivals(t)
            if not sched.has_work():
                # idle until the next arrival
                t = pending[i_arr].arrival_time
                admit_arrivals(t)
            plan = sched.next_plan(now=t)
            res.n_preemptions += len(plan.preempted_ids)
            res.recompute_tokens += sum(
                sched.requests[rid].prompt_len for rid in plan.preempted_ids)
            # swap DMA: the host link stalls the iteration clock and burns
            # host-path energy; lengths survive the swap so both directions
            # price the victim's true filled KV
            if plan.swapped_out_ids or plan.swapped_in_ids:
                moved = sum(self.kv.length(rid) for rid in
                            plan.swapped_out_ids + plan.swapped_in_ids)
                xfer = self.cost.swap_transfer(moved)
                t += xfer["duration"]
                res.swap_stall_time += xfer["duration"]
                res.swap_bytes += xfer["bytes"]
                res.total_energy += xfer["energy"]
                res.n_swap_outs += len(plan.swapped_out_ids)
                res.n_swap_ins += len(plan.swapped_in_ids)
            if plan.empty:
                if i_arr < len(pending):
                    # nothing runnable yet — fast-forward to the arrival
                    # that will create work (t never moves backwards)
                    t = max(t, pending[i_arr].arrival_time)
                    continue
                # no runnable work, no future arrivals: advancing neither t
                # nor the iteration count would spin forever
                raise RuntimeError(
                    f"scheduler {sched.name!r} made no progress: "
                    f"{len(sched.waiting)} waiting, {sched.n_active} active, "
                    "no pending arrivals")
            cost = self.cost.iteration_cost(plan, sched.requests)
            t += cost["duration"]
            res.total_energy += cost["energy"]
            res.total_expert_bytes += cost["expert_bytes"]
            res.total_hbm_bytes += cost["hbm_bytes"]
            res.total_flops += cost["flops"]
            res.n_iterations += 1
            res.decode_batch_sizes.append(len(plan.decode_ids))

            # timestamp tokens at iteration end
            for sl in plan.prefill:
                if sl.emits_first_token:
                    r = sched.requests[sl.req_id]
                    if r.first_token_time is None:
                        r.first_token_time = t
                    else:
                        # recompute epoch: the emitting slice produces a
                        # continuation token, not a second "first token"
                        r.token_times.append(t)
                    if r.state == RequestState.DONE:
                        r.finish_time = t
            for rid in plan.decode_ids:
                r = sched.requests[rid]
                r.token_times.append(t)
                if r.state == RequestState.DONE and r.finish_time is None:
                    r.finish_time = t

            if res.n_iterations >= max_iterations:
                raise RuntimeError("simulation iteration cap hit")

        res.sim_time = t
        res.pages_high_water = self.kv.pages_high_water
        res.n_pool_pages = self.kv.n_pages
        res.host_pages_high_water = self.kv.host_pages_high_water
        res.n_host_pages = self.kv.n_host_pages
        return res
