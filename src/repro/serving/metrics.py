"""Serving metrics: TTFT / TBT statistics, per-request SLO attainment
(paper §5.1: a request attains the SLO iff its TTFT meets the TTFT SLO AND
every TBT meets the TBT SLO), per-SLO-class breakdowns for the
multi-tenant sweeps, energy-per-token accounting, and the paged-KV
memory-subsystem signals (queueing delay under memory-gated admission,
preemption rate, page high-water)."""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Sequence, Union

from repro.core.plan import Request


def percentile(xs: Sequence[float], q: float) -> float:
    """Linear-interpolation percentile (numpy's default "linear" method).
    The old nearest-rank-via-round variant biased p99 on small samples —
    on 10 points it returned the maximum for every q above ~94."""
    if not xs:
        return float("nan")
    s = sorted(xs)
    if len(s) == 1:
        return s[0]
    pos = min(max(q, 0.0), 100.0) / 100.0 * (len(s) - 1)
    lo = int(math.floor(pos))
    hi = min(lo + 1, len(s) - 1)
    return s[lo] + (s[hi] - s[lo]) * (pos - lo)


@dataclass(frozen=True)
class SLOConfig:
    ttft_slo: float            # seconds
    tbt_slo: float             # seconds

    def attained(self, req: Request) -> bool:
        t = req.ttft()
        if t is None or t > self.ttft_slo:
            return False
        return all(b <= self.tbt_slo for b in req.tbts())


def request_metrics(requests: Iterable[Request],
                    slo: Optional[SLOConfig] = None) -> Dict[str, float]:
    reqs = [r for r in requests if r.first_token_time is not None]
    ttfts = [r.ttft() for r in reqs]
    tbts: List[float] = []
    for r in reqs:
        tbts.extend(r.tbts())
    out = {
        "n_requests": float(len(reqs)),
        "ttft_mean": sum(ttfts) / len(ttfts) if ttfts else float("nan"),
        "ttft_p50": percentile(ttfts, 50),
        "ttft_p90": percentile(ttfts, 90),
        "ttft_p99": percentile(ttfts, 99),
        "tbt_mean": sum(tbts) / len(tbts) if tbts else float("nan"),
        "tbt_p50": percentile(tbts, 50),
        "tbt_p90": percentile(tbts, 90),
        "tbt_p99": percentile(tbts, 99),
    }
    e2e = [r.finish_time - r.arrival_time for r in reqs
           if r.finish_time is not None]
    out["e2e_mean"] = sum(e2e) / len(e2e) if e2e else float("nan")
    # memory-gated admission: time queued before FIRST admission
    delays = [d for d in (r.queue_delay() for r in reqs) if d is not None]
    out["queue_delay_mean"] = sum(delays) / len(delays) if delays \
        else float("nan")
    out["queue_delay_p99"] = percentile(delays, 99)
    n_pre = sum(r.n_preemptions for r in reqs)
    out["n_preemptions"] = float(n_pre)
    out["preemption_rate"] = n_pre / len(reqs) if reqs else float("nan")
    # swap-to-host eviction: swap counts and the time victims sat on host
    # (swap-out -> swap-in) — the latency cost of the DMA restore path
    n_swaps = sum(r.n_swaps for r in reqs)
    out["n_swaps"] = float(n_swaps)
    out["swap_rate"] = n_swaps / len(reqs) if reqs else float("nan")
    restores: List[float] = []
    for r in reqs:
        restores.extend(r.restore_latencies())
    out["restore_latency_mean"] = sum(restores) / len(restores) \
        if restores else float("nan")
    out["restore_latency_p99"] = percentile(restores, 99)
    # speculative decode: acceptance rate over all drafted tokens, and the
    # distribution of per-round accepted prefix lengths (0 when a round's
    # first draft already missed)
    n_drafted = sum(r.n_drafted for r in reqs)
    n_accepted = sum(r.n_draft_accepted for r in reqs)
    out["spec_drafted"] = float(n_drafted)
    out["spec_acceptance_rate"] = n_accepted / n_drafted if n_drafted \
        else float("nan")
    acc_lens: List[float] = []
    for r in reqs:
        acc_lens.extend(float(a) for a in r.accepted_lens)
    out["accepted_len_p50"] = percentile(acc_lens, 50)
    out["accepted_len_p90"] = percentile(acc_lens, 90)
    # automatic prefix caching: token-weighted hit rate (cached prompt
    # tokens over all admitted prompt tokens, recompute re-admissions
    # included) and the mean cached tokens per request
    cached = sum(r.cached_prompt_tokens for r in reqs)
    admitted = sum(r.admitted_prompt_tokens for r in reqs)
    out["prefix_hit_rate"] = cached / admitted if admitted else 0.0
    out["cached_prompt_tokens"] = cached / len(reqs) if reqs \
        else float("nan")
    # disaggregated prefill->decode handoff: migration counts, streamed
    # layer-group chunks, and the linked/moved token split (tokens linked
    # to pages already warm on the decode pool crossed the link for free
    # — the KV-locality routing win).  All zero under monolithic serving.
    n_handoffs = sum(r.n_handoffs for r in reqs)
    moved = sum(r.handoff_moved_tokens for r in reqs)
    linked = sum(r.handoff_linked_tokens for r in reqs)
    out["n_handoffs"] = float(n_handoffs)
    out["handoff_chunks_mean"] = sum(r.n_handoff_chunks for r in reqs) \
        / n_handoffs if n_handoffs else float("nan")
    out["handoff_moved_tokens"] = float(moved)
    out["handoff_linked_tokens"] = float(linked)
    out["handoff_link_ratio"] = linked / (linked + moved) \
        if linked + moved else float("nan")
    if slo is not None:
        att = [slo.attained(r) for r in reqs]
        out["slo_attainment"] = sum(att) / len(att) if att else float("nan")
        t_ok = [r.ttft() <= slo.ttft_slo for r in reqs]
        b_ok = [all(b <= slo.tbt_slo for b in r.tbts()) for r in reqs]
        out["ttft_attainment"] = sum(t_ok) / len(t_ok) if t_ok else float("nan")
        out["tbt_attainment"] = sum(b_ok) / len(b_ok) if b_ok else float("nan")
    return out


def per_class_metrics(
        requests: Iterable[Request],
        slo: Union[SLOConfig, Dict[str, SLOConfig], None] = None,
) -> Dict[str, Dict[str, float]]:
    """Split ``request_metrics`` by SLO class (the multi-tenant breakdown:
    per-class TTFT/TBT/attainment/preemption/swap).  ``slo`` may be one
    config applied to every class, a per-class dict (classes missing from
    it get no attainment columns), or None."""
    reqs = list(requests)
    out: Dict[str, Dict[str, float]] = {}
    for cls in sorted({r.slo_class for r in reqs}):
        cls_slo = slo.get(cls) if isinstance(slo, dict) else slo
        out[cls] = request_metrics(
            [r for r in reqs if r.slo_class == cls], cls_slo)
    return out


def handoff_counters(*, handoff_bytes: float = 0.0, queue_depth: int = 0,
                     link_stall_time: float = 0.0,
                     handoff_wait_time: float = 0.0,
                     n_migrations: int = 0,
                     n_returns: int = 0) -> Dict[str, float]:
    """THE canonical names for the disaggregated-handoff counters, shared
    by the live ``/metrics`` scrape (via ``prometheus_text(counters=...)``)
    and the offline benchmark reports, so the two can never disagree on
    spelling or units.  ``queue_depth`` is instantaneous (migrations
    exported but not yet imported); the rest are run totals."""
    return {
        "handoff_bytes_total": float(handoff_bytes),
        "handoff_queue_depth": float(queue_depth),
        "handoff_link_stall_seconds_total": float(link_stall_time),
        "handoff_wait_seconds_total": float(handoff_wait_time),
        "handoff_migrations_total": float(n_migrations),
        "handoff_returns_total": float(n_returns),
    }


def fault_counters(*, n_injected_faults: float = 0.0,
                   n_executor_crashes: float = 0.0,
                   n_link_drops: float = 0.0,
                   n_link_delays: float = 0.0,
                   n_swap_dma_fails: float = 0.0,
                   n_pressure_spikes: float = 0.0,
                   n_injected_disconnects: float = 0.0,
                   n_deadline_sheds: float = 0.0,
                   n_retry_sheds: float = 0.0,
                   n_disconnect_sheds: float = 0.0,
                   n_degrade_sheds: float = 0.0,
                   n_fault_retries: float = 0.0,
                   degradation_level: float = 0.0,
                   n_degradation_escalations: float = 0.0,
                   n_degradation_deescalations: float = 0.0,
                   ) -> Dict[str, float]:
    """THE canonical names for the fault-tolerance counters — shaped so
    ``fault_counters(**runtime.fault_stats())`` is the whole call.  Same
    contract as ``handoff_counters``: the live ``/metrics`` scrape and
    offline chaos reports share one spelling.  All ``*_total`` names are
    run totals; ``degradation_level`` is the instantaneous ladder rung
    index (0 = normal .. 4 = interactive_503)."""
    return {
        "faults_injected_total": float(n_injected_faults),
        "fault_executor_crashes_total": float(n_executor_crashes),
        "fault_link_drops_total": float(n_link_drops),
        "fault_link_delays_total": float(n_link_delays),
        "fault_swap_dma_fails_total": float(n_swap_dma_fails),
        "fault_pressure_spikes_total": float(n_pressure_spikes),
        "fault_injected_disconnects_total": float(n_injected_disconnects),
        "sheds_deadline_total": float(n_deadline_sheds),
        "sheds_retries_total": float(n_retry_sheds),
        "sheds_disconnect_total": float(n_disconnect_sheds),
        "sheds_degrade_total": float(n_degrade_sheds),
        "fault_retries_total": float(n_fault_retries),
        "degradation_level": float(degradation_level),
        "degradation_escalations_total": float(n_degradation_escalations),
        "degradation_deescalations_total": float(
            n_degradation_deescalations),
    }


# ---------------------------------------------------------------- exporters

def _finite(v) -> bool:
    return isinstance(v, (int, float)) and math.isfinite(v)


def prometheus_text(requests: Iterable[Request],
                    slo: Union[SLOConfig, Dict[str, SLOConfig], None] = None,
                    counters: Optional[Dict[str, float]] = None,
                    labeled: Optional[Dict[str, Dict[str, float]]] = None,
                    prefix: str = "repro") -> str:
    """Render the serving metrics in Prometheus text exposition format
    (the /metrics payload of serving/server.py).

    Emits TTFT/TBT p50/p90/p99 + means as quantile-labeled gauges,
    per-SLO-class attainment/latency/preemption breakdowns
    (``slo_class`` label), prefix-cache hit rate, and preemption/swap
    counters — all derived from the SAME ``request_metrics`` /
    ``per_class_metrics`` the offline reports print, so live scrapes and
    trace-replay summaries can never disagree on definitions.

    ``counters`` adds flat ``{prefix}_<name> value`` lines (server-level:
    http request totals, queue depth, pool occupancy); ``labeled`` adds
    one family per entry with a ``{key="..."}`` label per sample, e.g.
    ``{"http_responses_total|status": {"200": 31, "429": 4}}`` — the part
    after ``|`` names the label key.  Time-valued metrics are in the
    serving clock's unit (wall seconds under the HTTP front-end).
    NaN samples (e.g. percentiles over zero completed requests) are
    DROPPED rather than exported — scrapers choke on them and a missing
    sample is the honest statement."""
    reqs = list(requests)
    m = request_metrics(reqs, None if isinstance(slo, dict) else slo)
    per = per_class_metrics(reqs, slo)
    lines: List[str] = []

    def gauge(name: str, value, labels: str = "",
              help_text: str = "") -> None:
        if not _finite(value):
            return
        full = f"{prefix}_{name}"
        if help_text and not any(ln.startswith(f"# TYPE {full} ")
                                 for ln in lines):
            lines.append(f"# HELP {full} {help_text}")
            lines.append(f"# TYPE {full} gauge")
        lines.append(f"{full}{labels} {value:.9g}")

    gauge("requests_completed", m["n_requests"],
          help_text="requests with at least one emitted token")
    for base, help_text in (
            ("ttft", "time to first token (serving clock units)"),
            ("tbt", "time between tokens (serving clock units)")):
        for q in ("50", "90", "99"):
            gauge(base, m[f"{base}_p{q}"],
                  labels=f'{{quantile="0.{q}"}}', help_text=help_text)
        gauge(f"{base}_mean", m[f"{base}_mean"])
    gauge("queue_delay_mean", m["queue_delay_mean"],
          help_text="arrival to first admission")
    gauge("queue_delay", m["queue_delay_p99"], labels='{quantile="0.99"}')
    gauge("preemptions_total", m["n_preemptions"],
          help_text="memory-pressure evictions executed")
    gauge("swaps_total", m["n_swaps"],
          help_text="swap-to-host evictions executed")
    gauge("prefix_hit_rate", m["prefix_hit_rate"],
          help_text="cached / admitted prompt tokens")
    gauge("handoffs_total", m["n_handoffs"],
          help_text="prefill->decode pool migrations completed")
    if m["n_handoffs"]:
        gauge("handoff_moved_tokens_total", m["handoff_moved_tokens"],
              help_text="KV tokens whose payload crossed the pool link")
        gauge("handoff_linked_tokens_total", m["handoff_linked_tokens"],
              help_text="KV tokens linked to pages already warm on the "
                        "decode pool")
        gauge("handoff_link_ratio", m["handoff_link_ratio"],
              help_text="linked / (linked + moved) handoff tokens")
    if _finite(m.get("spec_acceptance_rate")):
        gauge("spec_acceptance_rate", m["spec_acceptance_rate"],
              help_text="accepted / drafted speculative tokens")
    if "slo_attainment" in m:
        gauge("slo_attainment", m["slo_attainment"],
              help_text="fraction meeting TTFT and every TBT SLO")
    for cls, cm in per.items():
        lab = f'{{slo_class="{cls}"}}'
        gauge("class_requests_completed", cm["n_requests"], lab,
              help_text="completed requests per SLO class")
        for q in ("50", "99"):
            gauge("class_ttft", cm[f"ttft_p{q}"],
                  f'{{slo_class="{cls}",quantile="0.{q}"}}',
                  help_text="per-class time to first token")
            gauge("class_tbt", cm[f"tbt_p{q}"],
                  f'{{slo_class="{cls}",quantile="0.{q}"}}',
                  help_text="per-class time between tokens")
        gauge("class_preemption_rate", cm["preemption_rate"], lab)
        gauge("class_prefix_hit_rate", cm["prefix_hit_rate"], lab)
        if "slo_attainment" in cm:
            gauge("class_slo_attainment", cm["slo_attainment"], lab,
                  help_text="per-class SLO attainment")
    for name, value in (counters or {}).items():
        gauge(name, value)
    for family, samples in (labeled or {}).items():
        name, _, key = family.partition("|")
        for label_value, value in samples.items():
            gauge(name, value, f'{{{key or "label"}="{label_value}"}}')
    return "\n".join(lines) + "\n"
