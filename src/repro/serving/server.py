"""Async HTTP/SSE serving front-end (DESIGN.md §Serving front-end).

The piece that turns trace replay into an actual service: a hand-rolled
asyncio HTTP/1.1 server (stdlib only — no web framework) ingesting
requests CONCURRENTLY with the engine iteration loop and streaming tokens
back as they are generated.

Threading / clock model
-----------------------
Two threads, one bridge:

  * The ENGINE thread runs the unmodified ``ServingRuntime`` loop in
    wall-clock mode (``EngineExecutor(wall=True)``) fed by a
    ``SubmitQueue`` — exactly the open-loop replay path, with the trace
    replaced by live arrivals.  All jax execution, scheduling and token
    timestamping happen here; the serving loop never blocks on a socket.
  * The ASYNCIO thread owns every connection.  POST handlers validate,
    rate-limit, backpressure-check, then ``SubmitQueue.put`` the frozen
    ``SubmitSpec``; the ticket's ``on_submit`` hook (which fires in the
    engine thread strictly before the request's first token) registers
    the response's token stream, so an SSE event can never race past an
    unregistered stream.  Tokens cross back via
    ``loop.call_soon_threadsafe`` onto per-request asyncio queues.

Endpoints
---------
  * ``POST /v1/generate`` — body ``{"prompt_tokens": [...],
    "max_new_tokens": N, "slo_class": "interactive", "tenant": "...",
    "stream": true}``.  With ``stream`` (default) the response is
    ``text/event-stream``: one ``token`` event per generated token in
    emission order, then one ``done`` event carrying the full token list
    and timing summary.  Without it, one JSON document at completion.
  * ``GET /metrics`` — Prometheus text exposition
    (``metrics.prometheus_text``): TTFT/TBT percentiles, per-class SLO
    attainment, prefix hit rate, preemption/swap/queue/pool/HTTP
    counters.
  * ``GET /healthz`` — liveness (503 once the engine thread has died).
  * ``GET /readyz`` — readiness: 503 while draining, while the
    degradation ladder's top rung is refusing interactive work, or once
    the engine is dead; load balancers should route on this, not
    healthz.

Backpressure
------------
Admission control answers 429 + ``Retry-After`` from two independent
gates, checked BEFORE the spec enters the queue: a per-tenant token
bucket (``serving/ratelimit.py``), and a load watermark — queue depth
(scheduler waiting + feed backlog) at or above ``queue_watermark`` while
the paged-KV pool's free fraction is at or below ``pool_watermark``.
Deep queue alone means the scheduler is draining fine; empty pool alone
means admission is about to queue briefly; both together mean real
oversubscription, and accepting more work would only grow TTFT tails.
"""

from __future__ import annotations

import asyncio
import json
import math
import threading
from typing import Dict, List, Optional, Tuple

from repro.core.plan import RequestState, SubmitSpec
from repro.serving.metrics import SLOConfig, fault_counters, prometheus_text
from repro.serving.ratelimit import TenantRateLimiter
from repro.serving.runtime import EngineExecutor, ServingRuntime, SubmitQueue

_SSE_HEADERS = (b"Content-Type: text/event-stream\r\n"
                b"Cache-Control: no-cache\r\n")


class _TokenStream:
    """Engine-thread producer -> asyncio-consumer bridge for one request's
    token events.  Items: ("token", id, t) | ("done", summary)."""

    def __init__(self, loop: asyncio.AbstractEventLoop):
        self._loop = loop
        self.queue: asyncio.Queue = asyncio.Queue()

    def push(self, item) -> None:
        self._loop.call_soon_threadsafe(self.queue.put_nowait, item)


class ServingServer:
    """The HTTP/SSE front-end over one live ``Engine``.

    ``ratelimit_rate``/``ratelimit_burst`` configure the per-tenant token
    bucket (None rate disables rate limiting); ``queue_watermark`` /
    ``pool_watermark`` the overload gate; ``slo`` an optional SLOConfig
    for live attainment in /metrics.  ``start``/``stop`` are coroutines
    (embed in an existing loop — the load generator does); ``serve_forever``
    is the blocking CLI entry."""

    def __init__(self, engine, *, host: str = "127.0.0.1", port: int = 8000,
                 ratelimit_rate: Optional[float] = None,
                 ratelimit_burst: float = 8.0,
                 queue_watermark: int = 64,
                 pool_watermark: float = 0.125,
                 retry_after: float = 1.0,
                 slo: Optional[SLOConfig] = None,
                 keepalive_timeout: float = 5.0,
                 max_iterations: int = 1_000_000_000,
                 faults=None, retry_budget: int = 3,
                 deadline_ms: Optional[float] = None,
                 drain_timeout: float = 10.0):
        self.engine = engine
        self.host = host
        self.port = port
        self.slo = slo
        self.queue_watermark = queue_watermark
        self.pool_watermark = pool_watermark
        self.retry_after = retry_after
        self.keepalive_timeout = keepalive_timeout
        self.max_iterations = max_iterations
        # default per-request completion deadline (wall ms) applied to
        # specs that do not carry their own; None disables shedding
        self.deadline_ms = deadline_ms
        self.drain_timeout = drain_timeout
        self.limiter = None if ratelimit_rate is None else \
            TenantRateLimiter(ratelimit_rate, ratelimit_burst)

        self.feed = SubmitQueue()
        self.executor = EngineExecutor(engine, wall=True)
        self.runtime = ServingRuntime(self.executor,
                                      on_token=self._on_token,
                                      clock="executor",
                                      faults=faults,
                                      retry_budget=retry_budget,
                                      on_shed=self._on_shed)
        self._thread: Optional[threading.Thread] = None
        self._engine_error: Optional[BaseException] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._loop: Optional[asyncio.AbstractEventLoop] = None

        # engine-thread-only token bookkeeping (streams registered by
        # on_submit hooks, also engine thread — no lock needed there)
        self._streams: Dict[int, _TokenStream] = {}
        self._emitted: Dict[int, int] = {}
        # the ground-truth emission order, kept for the SSE-ordering tests
        # and the load generator's offline-replay verification
        self.token_log: List[Tuple[int, int]] = []
        # asyncio-thread counters for /metrics
        self._status_counts: Dict[int, int] = {}
        self.n_dropped_streams = 0
        self.n_streams_completed = 0
        self.n_shed_streams = 0
        self._draining = False

    # ------------------------------------------------------------ lifecycle

    async def start(self) -> None:
        self._loop = asyncio.get_running_loop()
        self._thread = threading.Thread(target=self._drive,
                                        name="serving-loop", daemon=True)
        self._thread.start()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]

    async def drain(self, timeout: Optional[float] = None) -> None:
        """Graceful shutdown: flip to draining (new POSTs answer 503 and
        /readyz fails so balancers stop routing here), wait up to
        ``drain_timeout`` seconds for in-flight streams to finish, cancel
        any stragglers (the engine thread sheds them, freeing their KV
        and terminating their SSE streams), then stop the engine and the
        listener."""
        self._draining = True
        t = self.drain_timeout if timeout is None else timeout
        loop = asyncio.get_running_loop()
        deadline = loop.time() + t
        while self._streams and self._thread.is_alive() \
                and loop.time() < deadline:
            await asyncio.sleep(0.05)
        for rid in list(self._streams):
            self.runtime.cancel(rid)
        await self.stop()

    async def stop(self) -> None:
        """Close ingestion, drain resident work, join the engine thread,
        then tear the listener down."""
        self.feed.close()
        if self._thread is not None:
            await asyncio.get_running_loop().run_in_executor(
                None, self._thread.join)
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        if self._engine_error is not None:
            raise self._engine_error

    def serve_forever(self) -> None:
        async def _main():
            await self.start()
            print(f"[server] listening on http://{self.host}:{self.port} "
                  f"(POST /v1/generate, GET /metrics)")
            try:
                while self._thread.is_alive():
                    await asyncio.sleep(0.5)
                if self._engine_error is not None:
                    raise self._engine_error
            finally:
                await self.stop()
        try:
            asyncio.run(_main())
        except KeyboardInterrupt:
            print("[server] shutting down")

    def _drive(self) -> None:
        try:
            self.result = self.runtime.run(
                feed=self.feed, max_iterations=self.max_iterations)
        except BaseException as e:                  # surfaced by /healthz
            self._engine_error = e
            self.feed.close()

    # ----------------------------------------------------- engine callbacks

    def _on_token(self, rid: int, tok: Optional[int], t: float) -> None:
        """Engine thread: one call per emitted token, in emission order."""
        self.token_log.append((rid, tok))
        stream = self._streams.get(rid)
        if stream is None:
            return
        stream.push(("token", tok, t))
        req = self.engine.requests[rid]
        n = self._emitted[rid] = self._emitted.get(rid, 0) + 1
        # a speculative iteration can commit several tokens after the
        # scheduler already marked the request DONE — the stream ends only
        # once every generated token has been pushed
        if req.state is RequestState.DONE and n >= req.n_generated:
            stream.push(("done", {
                "req_id": rid,
                "n_generated": req.n_generated,
                "tokens": list(self.engine.outputs[rid]),
                "ttft": req.ttft(),
                "finish_time": req.finish_time,
                "n_preemptions": req.n_preemptions,
                "n_swaps": req.n_swaps,
            }))
            self._streams.pop(rid, None)
            self._emitted.pop(rid, None)

    def _on_shed(self, req, reason: str) -> None:
        """Engine thread: the runtime removed ``req`` without completing
        it (deadline expiry, retry exhaustion, cancel, degradation).  Its
        KV is already freed; deregister the stream here — the same thread
        that registered it — and emit the terminal event so the
        connection's consumer unblocks with the partial result."""
        rid = req.req_id
        stream = self._streams.pop(rid, None)
        self._emitted.pop(rid, None)
        self.n_shed_streams += 1
        if stream is None:
            return
        stream.push(("done", {
            "req_id": rid,
            "n_generated": req.n_generated,
            "tokens": list(self.engine.outputs.get(rid, [])),
            "ttft": req.ttft(),
            "finish_time": req.finish_time,
            "n_preemptions": req.n_preemptions,
            "n_swaps": req.n_swaps,
            "shed_reason": reason,
        }))

    # ------------------------------------------------------------- overload

    def queue_depth(self) -> int:
        return len(self.engine.scheduler.waiting) + self.feed.backlog

    def overloaded(self) -> Optional[float]:
        """Retry-after seconds when BOTH watermarks are breached, else
        None.  Reads engine state cross-thread — int/len reads are atomic
        enough for an admission heuristic."""
        depth = self.queue_depth()
        if depth < self.queue_watermark:
            return None
        alloc = self.engine.alloc
        free_frac = alloc.n_free_pages / max(alloc.n_pages, 1)
        if free_frac > self.pool_watermark:
            return None
        return min(30.0, self.retry_after *
                   max(1.0, depth / max(self.queue_watermark, 1)))

    # ------------------------------------------------------------- HTTP

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        """Serve requests off one connection.  Non-SSE requests honour
        ``Connection: keep-alive``: the handler loops, waiting up to
        ``keepalive_timeout`` seconds for the next request before closing
        the idle socket.  SSE responses always close — the event stream
        owns the connection until the generation finishes."""
        try:
            while True:
                try:
                    req = await asyncio.wait_for(
                        self._read_request(reader),
                        timeout=self.keepalive_timeout)
                except asyncio.TimeoutError:
                    break                        # idle keep-alive expired
                if req is None:
                    break
                method, path, headers, body = req
                keep = headers.get("connection", "").lower() == "keep-alive"
                if method == "POST" and path == "/v1/generate":
                    keep = await self._generate(writer, body, keep=keep)
                elif method == "GET" and path == "/metrics":
                    await self._metrics(writer, keep=keep)
                elif method == "GET" and path == "/healthz":
                    if self._engine_error is not None \
                            or not self._thread.is_alive():
                        await self._respond(writer, 503, {
                            "status": "engine dead",
                            "error": repr(self._engine_error)}, keep=keep)
                    else:
                        await self._respond(writer, 200, {"status": "ok"},
                                            keep=keep)
                elif method == "GET" and path == "/readyz":
                    dead = self._engine_error is not None \
                        or not self._thread.is_alive()
                    if dead or self._draining \
                            or self.runtime.ladder.refuse_new:
                        reason = "engine dead" if dead else (
                            "draining" if self._draining else "degraded")
                        await self._respond(
                            writer, 503,
                            {"ready": False, "reason": reason,
                             "degradation": self.runtime.ladder.level},
                            keep=keep)
                    else:
                        await self._respond(writer, 200, {"ready": True},
                                            keep=keep)
                else:
                    await self._respond(
                        writer, 404,
                        {"error": f"no route {method} {path}"}, keep=keep)
                if not keep:
                    break
        except (ConnectionResetError, BrokenPipeError, asyncio.IncompleteReadError):
            pass
        finally:
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, BrokenPipeError, OSError):
                pass

    @staticmethod
    async def _read_request(reader: asyncio.StreamReader):
        line = await reader.readline()
        if not line or line in (b"\r\n", b"\n"):
            return None
        try:
            method, path, _ = line.decode("latin-1").split(" ", 2)
        except ValueError:
            return None
        headers: Dict[str, str] = {}
        while True:
            h = await reader.readline()
            if h in (b"\r\n", b"\n", b""):
                break
            k, _, v = h.decode("latin-1").partition(":")
            headers[k.strip().lower()] = v.strip()
        n = int(headers.get("content-length", "0") or 0)
        body = await reader.readexactly(n) if n else b""
        return method, path.split("?", 1)[0], headers, body

    _REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
                429: "Too Many Requests", 500: "Internal Server Error",
                503: "Service Unavailable"}

    def _head(self, status: int, extra: bytes = b"",
              keep: bool = False) -> bytes:
        self._status_counts[status] = self._status_counts.get(status, 0) + 1
        reason = self._REASONS.get(status, "Unknown")
        conn = b"Connection: keep-alive\r\n" if keep \
            else b"Connection: close\r\n"
        return f"HTTP/1.1 {status} {reason}\r\n".encode() + conn + extra

    async def _respond(self, writer, status: int, payload,
                       retry_after: Optional[float] = None,
                       ctype: str = "application/json",
                       keep: bool = False) -> None:
        body = payload if isinstance(payload, bytes) \
            else json.dumps(payload).encode()
        extra = f"Content-Type: {ctype}\r\nContent-Length: {len(body)}\r\n"
        if retry_after is not None:
            extra += f"Retry-After: {max(1, math.ceil(retry_after))}\r\n"
        writer.write(self._head(status, extra.encode(), keep=keep)
                     + b"\r\n" + body)
        await writer.drain()

    async def _metrics(self, writer, keep: bool = False) -> None:
        alloc = self.engine.alloc
        counters = {
            "queue_depth": float(self.queue_depth()),
            "kv_pages_used": float(alloc.pages_in_use()),
            "kv_pages_total": float(alloc.n_pages),
            "active_streams": float(len(self._streams)),
            "dropped_streams_total": float(self.n_dropped_streams),
            "streams_completed_total": float(self.n_streams_completed),
            "engine_iterations_total": float(self.engine.iteration),
            "engine_dispatches_total": float(self.engine.n_dispatches),
            "engine_preempted_total": float(self.engine.n_preempted),
            "engine_swapped_out_total": float(self.engine.n_swapped_out),
            "shed_streams_total": float(self.n_shed_streams),
        }
        counters.update(fault_counters(**self.runtime.fault_stats()))
        labeled = {"http_responses_total|status":
                   {str(s): float(c)
                    for s, c in sorted(self._status_counts.items())}}
        if self.limiter is not None:
            rl = self.limiter.counters()
            labeled["ratelimit_granted_total|tenant"] = \
                {t: c["granted"] for t, c in rl.items()}
            labeled["ratelimit_rejected_total|tenant"] = \
                {t: c["rejected"] for t, c in rl.items()}
        text = prometheus_text(list(self.engine.requests.values()),
                               slo=self.slo, counters=counters,
                               labeled=labeled)
        await self._respond(writer, 200, text.encode(),
                            ctype="text/plain; version=0.0.4", keep=keep)

    async def _generate(self, writer, body: bytes,
                        keep: bool = False) -> bool:
        """Returns whether the connection may be kept open for another
        request (never after an SSE stream — it owns the socket)."""
        try:
            payload = json.loads(body or b"{}")
            dl = payload.get("deadline_ms", self.deadline_ms)
            spec = SubmitSpec(
                max_new_tokens=int(payload["max_new_tokens"]),
                prompt_tokens=tuple(int(t)
                                    for t in payload["prompt_tokens"]),
                slo_class=str(payload.get("slo_class", "interactive")),
                tenant=payload.get("tenant"),
                prefix_cache=bool(payload.get("prefix_cache", True)),
                speculative=bool(payload.get("speculative", True)),
                deadline_ms=None if dl is None else float(dl))
        except (KeyError, TypeError, ValueError, json.JSONDecodeError) as e:
            await self._respond(writer, 400, {"error": f"bad request: {e}"},
                                keep=keep)
            return keep
        if self._engine_error is not None or not self._thread.is_alive():
            await self._respond(writer, 503, {"error": "engine dead"},
                                keep=keep)
            return keep
        if self._draining:
            await self._respond(writer, 503, {"error": "draining"},
                                retry_after=self.retry_after, keep=keep)
            return keep
        if self.runtime.ladder.refuse_new \
                and spec.slo_class == "interactive":
            # top degradation rung: interactive admission is the shed class
            await self._respond(
                writer, 503,
                {"error": "degraded: interactive load shed",
                 "degradation": self.runtime.ladder.level},
                retry_after=self.retry_after, keep=keep)
            return keep
        if self.limiter is not None:
            wait = self.limiter.acquire(spec.tenant)
            if wait > 0:
                await self._respond(
                    writer, 429, {"error": "rate limited",
                                  "tenant": spec.tenant,
                                  "retry_after": wait},
                    retry_after=wait, keep=keep)
                return keep
        wait = self.overloaded()
        if wait is not None:
            await self._respond(
                writer, 429, {"error": "overloaded",
                              "queue_depth": self.queue_depth(),
                              "retry_after": wait},
                retry_after=wait, keep=keep)
            return keep

        stream = _TokenStream(self._loop)
        submitted = self._loop.create_future()

        def on_submit(req):                       # engine thread, pre-token
            self._streams[req.req_id] = stream
            self._loop.call_soon_threadsafe(
                submitted.set_result, req.req_id)

        def on_fail(exc):                         # engine thread
            self._loop.call_soon_threadsafe(_fail_safely, exc)

        def _fail_safely(exc):
            if not submitted.done():
                submitted.set_exception(exc)

        try:
            self.feed.put(spec, on_submit=on_submit, on_fail=on_fail)
        except RuntimeError:                      # queue closed: shutdown
            await self._respond(writer, 503, {"error": "shutting down"},
                                keep=keep)
            return keep
        try:
            rid = await submitted
        except ValueError as e:                   # engine rejected the spec
            await self._respond(writer, 400, {"error": str(e)}, keep=keep)
            return keep
        except Exception as e:
            await self._respond(writer, 500, {"error": repr(e)}, keep=keep)
            return keep

        if payload.get("stream", True):
            await self._stream_sse(writer, rid, stream,
                                   tag=payload.get("tag"))
            return False
        await self._block_json(writer, rid, stream,
                               tag=payload.get("tag"), keep=keep)
        return keep

    async def _stream_sse(self, writer, rid: int, stream: _TokenStream,
                          tag=None) -> None:
        writer.write(self._head(200, _SSE_HEADERS) + b"\r\n")
        try:
            await writer.drain()
            index = 0
            while True:
                item = await stream.queue.get()
                if item[0] == "token":
                    _, tok, t = item
                    data = json.dumps({"req_id": rid, "index": index,
                                       "token": tok, "t": t})
                    writer.write(f"event: token\ndata: {data}\n\n".encode())
                    await writer.drain()
                    index += 1
                else:
                    summary = dict(item[1], tag=tag)
                    data = json.dumps(summary)
                    writer.write(f"event: done\ndata: {data}\n\n".encode())
                    await writer.drain()
                    self.n_streams_completed += 1
                    return
        except (ConnectionResetError, BrokenPipeError, OSError):
            # client went away mid-stream: cancel the generation — the
            # engine thread sheds the request at the next iteration
            # boundary, freeing its KV and deregistering this stream
            self.n_dropped_streams += 1
            self.runtime.cancel(rid)

    async def _block_json(self, writer, rid: int, stream: _TokenStream,
                          tag=None, keep: bool = False) -> None:
        tokens: List[int] = []
        while True:
            item = await stream.queue.get()
            if item[0] == "token":
                tokens.append(item[1])
            else:
                summary = dict(item[1], tag=tag)
                await self._respond(writer, 200, summary, keep=keep)
                self.n_streams_completed += 1
                return
