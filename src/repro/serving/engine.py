"""The serving engine: executes IterationPlans from any scheduler against a
REAL JAX model. This is the functional-correctness half of the evaluation
(the temporal half is serving/simulator.py, which drives the same scheduler
classes through an analytic hardware model).

Execution model per iteration:

  1. admissions — allocate a KV slot; for enc-dec models run the encoder
     and install per-block cross-attention K/V into the slot.
  2. prefill slices — each slice is a (token-range × block-range) rectangle.
     Block ranges are static per jit-cache entry (the TPU analogue of the
     paper's CUDA-graph buckets); token ranges are padded to power-of-two
     buckets with a validity mask. Boundary activations between layer
     groups are stashed on the engine (this is layered prefill's carry
     state). The final slice computes the request's FIRST token.
  3. decode — ONE fixed-shape step over the whole slot pool: every slot
     decodes one token; non-decoding slots are masked (their KV writes and
     recurrent-state updates are suppressed — see models/attention._write_cache
     and the valid-masking in the recurrent mixers).

Expert-load accounting (paper §5.4): each forward returns per-block expert
activation counts from the REAL router; the engine takes, per (iteration,
block), the union of experts activated by decode and by every prefill slice
touching that block — exactly the set of expert weight loads a fused hybrid
batch would issue — and accumulates ``bytes = nnz(union) * bytes_per_expert``.

Hot-path contract (DESIGN.md §Engine hot path):

  * PACKED layer-group batches — all prefill slices of a plan sharing
    (block_start, n_blocks, emits_first_token) execute as ONE jitted call
    over a slot vector: hidden (B, P, d), per-row offsets/valid/lengths,
    cache rows gathered/scattered with a single take / ``.at[slots].set``
    per leaf (``kernels.ops.gather_slot_rows``).  ``packed=False`` keeps
    the per-slice reference path (each slice is a batch of one).
  * DONATED cache buffers — every prefill/decode executable takes the KV
    pool with ``donate_argnums``, so XLA updates it in place instead of
    allocating a fresh ``n_slots × max_len`` copy per call.
  * ONE device sync per iteration — jitted calls return device arrays
    (first tokens, per-block expert-activation masks, decode tokens, swap
    victim rows) that are fetched by a single ``jax.device_get`` at the
    end of ``execute_plan``; no per-slice ``int(token)`` stalls.
"""

from __future__ import annotations

import functools
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.base import Scheduler, make_scheduler
from repro.core.plan import (IterationPlan, PrefillSlice, Request,
                             RequestState, SubmitSpec)
from repro.kernels.ops import gather_slot_rows, scatter_slot_rows
from repro.models.config import dtype_bytes
from repro.models.model import DecoderModel
from repro.serving.kvcache import PagedKVAllocator
from repro.serving.runtime import (EngineExecutor, RunResult, ServingRuntime,
                                   TokenEvent, timestamp_events)
from repro.serving.spec import NgramDrafter, accepted_prefix, build_draft_model

Array = jax.Array

# Upper bound on live prefill executables.  Keys are (block_start, n_blocks,
# emit, B_bucket, P_bucket) — the batch/token buckets are part of the key,
# so one LRU entry == one compiled executable and the bound is real (keyed
# on the triple alone, mixed-shape traces used to retrace INSIDE an entry
# and grow live executables past the bound unobserved).
PREFILL_CACHE_SIZE = 32


def _bucket(n: int, minimum: int = 16, cap: Optional[int] = None) -> int:
    """Next power-of-two padding bucket >= n, clamped to ``cap`` (padding
    past the engine's max_len would trace shapes no request can fill)."""
    b = minimum
    while b < n:
        b *= 2
    if cap is not None:
        b = min(b, max(cap, n))
    return b


def _slice_cache(cache, slot):
    """Select one slot row (axis 1 — axis 0 is the segment-repeat stack)."""
    return jax.tree_util.tree_map(
        lambda c: jax.lax.dynamic_slice_in_dim(c, slot, 1, axis=1), cache)


def _scatter_cache(full, row, slot):
    return jax.tree_util.tree_map(
        lambda f, r: jax.lax.dynamic_update_slice_in_dim(
            f, r.astype(f.dtype), slot, axis=1), full, row)


def _chunks_cover(chunks, n_blocks: int) -> bool:
    """True iff the staged handoff chunks tile every block [0, n_blocks)
    — the export can then be assembled without touching the device."""
    nxt = 0
    for b0, b1 in sorted((b0, b1) for b0, b1, _ in chunks):
        if b0 > nxt:
            return False
        nxt = max(nxt, b1)
    return nxt >= n_blocks


class Engine:
    def __init__(self, model: DecoderModel, params, scheduler, *,
                 n_slots: int = 8, max_len: int = 512,
                 pages: Optional[int] = None, page_size: int = 16,
                 preemption: bool = True,
                 preemption_mode: str = "recompute",
                 host_pages: Optional[int] = None,
                 swap_in_budget: Optional[int] = None,
                 swap_cost_fn=None,
                 decode_reserve: Optional[int] = None,
                 class_headroom: Optional[Dict[str, int]] = None,
                 eos_token: Optional[int] = None, gmm_fn=None,
                 moe_dispatch: str = "ragged", packed: bool = True,
                 prefix_cache: bool = True,
                 prefix_lru_pages: Optional[int] = None,
                 spec_mode: str = "off", spec_k: int = 4,
                 spec_adaptive: bool = True, spec_ngram_n: int = 3,
                 draft_model: Optional[DecoderModel] = None,
                 draft_params=None, draft_config: Optional[str] = None):
        """``moe_dispatch`` selects the dropless MoE data path: "ragged"
        (default — expert-sorted tile-aligned buffer, compute/traffic scale
        with the routed work) or "dense" (worst-case (E, T, d) capacity
        buffer). Outputs are identical either way; see models/moe.py.

        ``pages``/``page_size`` size the paged KV pool shared with the
        scheduler (default: enough pages to fill every slot row — no
        pressure beyond the slot bound).  ``preemption`` enables memory-
        pressure eviction; with it off, admission still queues on pressure
        but decode growth past ``decode_reserve`` can raise
        PagedPoolExhausted.  ``preemption_mode`` picks the eviction flavour
        ("recompute" | "swap" | "auto"): under swap, victims' cache rows
        are copied to host memory and restored verbatim on swap-in (gated
        by ``swap_in_budget`` KV tokens per iteration), sized by
        ``host_pages`` (default 4x the device pool).  ``swap_cost_fn``
        prices swap vs recompute per victim for "auto"; without one, auto
        swaps whenever the victim is swappable.  ``class_headroom``
        reserves admission pages per SLO class (see
        core.base.Scheduler.attach_kv).  ``packed`` enables packed
        layer-group batches (one jitted call per (block-range, emit) group
        of the plan's prefill slices); ``packed=False`` executes every
        slice as its own batch of one — the reference path the
        equivalence tests and ``benchmarks/engine_iter_bench.py`` compare
        against.

        ``prefix_cache`` (default on) enables automatic prefix caching
        (DESIGN.md §Prefix caching): completed prompts' full KV pages are
        content-hashed into a refcounted shared index, admissions whose
        prompt matches a cached chain skip the matched tokens entirely —
        the engine restores the cached slot row and prefill starts past
        the cached boundary, with tokens bit-identical to a cold run.
        ``prefix_lru_pages`` caps the reclaimable (refcount-0) cached
        pages kept resident (None = bounded only by pool pressure).

        ``spec_mode`` enables speculative verify-k decoding ("ngram" =
        draft-free prompt/self-lookup; "draft" = a tiny stateless draft
        model — pass ``draft_model``/``draft_params`` directly or name a
        registered config via ``draft_config``).  ``spec_k`` caps the
        per-request draft budget; ``spec_adaptive`` lets a per-request
        acceptance EMA shrink the draft-model budget.  Greedy token
        streams are bit-identical to ``spec_mode="off"`` — speculation
        only changes how many tokens each dispatch commits (DESIGN.md
        §Speculative decode)."""
        self.model = model
        self.cfg = model.cfg
        self.params = params
        self.packed = packed
        if moe_dispatch not in ("dense", "ragged"):
            raise ValueError(f"unknown moe_dispatch {moe_dispatch!r}")
        self.moe_dispatch = moe_dispatch
        if isinstance(scheduler, str):
            scheduler = make_scheduler(scheduler, model.n_blocks,
                                       n_slots=n_slots)
        assert scheduler.n_slots <= n_slots, "scheduler must fit slot pool"
        self.scheduler: Scheduler = scheduler
        stash_factor = self.cfg.stash_token_factor()
        if pages is None:
            # default pool: every slot can hold a max_len request plus its
            # decode-reservation rounding and worst-case stash — admission
            # then never blocks while a slot is free (pre-paging behaviour)
            reserve = page_size if decode_reserve is None else decode_reserve
            per_slot = (-(-(max_len + reserve) // page_size)
                        + -(-int(max_len * stash_factor + 1) // page_size))
            pages = n_slots * per_slot
        if host_pages is None:
            host_pages = 4 * pages if preemption_mode != "recompute" else 0
        self.alloc = PagedKVAllocator(pages, page_size,
                                      stash_factor=stash_factor,
                                      n_host_pages=host_pages,
                                      prefix_caching=prefix_cache,
                                      prefix_lru_pages=prefix_lru_pages)
        self.prefix_cache = prefix_cache
        # digest -> (device KV row snapshot, usable tokens): the physical
        # realization of the allocator's shared-prefix index.  Rows are
        # sliced ONCE when a prompt's chains register and restored into a
        # hitting request's slot at admission; the allocator's reclaim hook
        # drops a row the moment its index entry dies.
        self._prefix_rows: Dict[bytes, Tuple[object, int]] = {}
        self.alloc.on_prefix_evict = \
            lambda digest: self._prefix_rows.pop(digest, None)
        self.scheduler.attach_kv(self.alloc, decode_reserve=decode_reserve,
                                 preemption=preemption,
                                 mode=preemption_mode,
                                 swap_in_budget=swap_in_budget,
                                 swap_cost_fn=swap_cost_fn,
                                 class_headroom=class_headroom)
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_token = eos_token
        self.gmm_fn = gmm_fn

        # speculative verify-k decoding (DESIGN.md §Speculative decode)
        if spec_mode not in ("off", "ngram", "draft"):
            raise ValueError(f"unknown spec_mode {spec_mode!r}")
        self.spec_mode = spec_mode
        self.spec_k = spec_k
        self.drafter = NgramDrafter(spec_ngram_n) \
            if spec_mode == "ngram" else None
        self.draft_model: Optional[DecoderModel] = None
        self.draft_params = None
        if spec_mode == "draft":
            if draft_model is not None:
                self.draft_model, self.draft_params = draft_model, \
                    draft_params
            elif draft_config is not None:
                self.draft_model, self.draft_params = build_draft_model(
                    draft_config, self.cfg.vocab_size)
            else:
                raise ValueError(
                    "spec_mode='draft' needs draft_model/draft_params or a "
                    "draft_config name")
            if self.draft_model.cfg.vocab_size != self.cfg.vocab_size:
                raise ValueError("draft model must share the target vocab")
        if spec_mode != "off":
            self.scheduler.configure_speculation(spec_mode, spec_k,
                                                 adaptive=spec_adaptive)
        # physical slot rows (the contiguous per-request realization of the
        # logical block tables; see DESIGN.md §Hardware adaptation)
        self._free_slots = list(range(n_slots))[::-1]
        self._slot_of: Dict[int, int] = {}

        self.cache = model.init_cache(n_slots, max_len)
        self.offsets = np.zeros(n_slots, np.int32)       # true filled length
        self.last_tok = np.zeros(n_slots, np.int32)
        self.decoding = np.zeros(n_slots, bool)

        self._next_id = 0
        self.requests: Dict[int, Request] = {}
        self.prompts: Dict[int, np.ndarray] = {}
        self.outputs: Dict[int, List[int]] = {}
        # req -> (packed boundary batch, row index, token count): cohort
        # members share ONE (B, P, d) batch array (§Engine hot path)
        self.stash: Dict[int, Tuple[Array, int, int]] = {}
        self.enc_frames: Dict[int, np.ndarray] = {}
        # swapped-out requests: req -> (host cache rows, offset, last_tok)
        self.host_kv: Dict[int, Tuple[object, int, int]] = {}
        # disaggregated handoff (DESIGN.md §Disaggregated serving): with
        # staging on (this engine is a prefill pool), every layer group
        # whose KV completes is sliced per-block and host-staged through
        # the same single end-of-iteration fetch as swap victims; at
        # export the chunks ARE the transfer — no extra device sync.
        # req -> [(block_start, block_end, host rows per block)]
        self.handoff_staging = False
        self._handoff_chunks: Dict[int, List[Tuple[int, int, list]]] = {}
        self.n_handoffs_out = 0
        self.n_handoffs_in = 0
        self.handoff_bytes = 0

        # metrics
        self.iteration = 0
        self._step_events: List[TokenEvent] = []
        self.n_preempted = 0
        self.n_swapped_out = 0
        self.n_swapped_in = 0
        self.expert_load_bytes = 0
        self.iter_log: List[dict] = []
        bytes_per_el = dtype_bytes(self.cfg.param_dtype)
        self._expert_bytes = self.cfg.expert_bytes(bytes_per_el)
        # dispatch accounting (benchmarks/engine_iter_bench.py and the
        # packed-vs-per-slice regression tests): n_dispatches counts
        # engine-level device launches (embed / prefill / decode / encode /
        # stash regather), n_prefill_* the packed-batch executions and
        # compiled executables specifically
        self.n_dispatches = 0
        self.n_prefill_dispatches = 0
        self.n_prefill_compiles = 0
        # prefix-cache accounting: restores = admissions that seeded their
        # slot row from a cached prefix (the allocator counts hits/tokens).
        # Hits are counted at plan-time reserve, so iter_log attribution
        # tracks the allocator counters seen at the last append.
        self.n_prefix_restores = 0
        self._prefix_seen = (0, 0)          # (n_prefix_hits, n_prefix_tokens)
        # speculative-decode accounting: verify/draft executables live in
        # the SAME bounded LRU as prefill executables (a growing family of
        # k buckets must not grow live executables past the bound)
        self.n_verify_dispatches = 0
        self.n_verify_compiles = 0
        self.n_draft_dispatches = 0
        self.n_spec_proposed = 0
        self.n_spec_accepted = 0
        # per-iteration record of what was ACTUALLY verified (rid -> k_eff;
        # may be smaller than plan.verify_len when a drafter found nothing)
        self.last_verify_executed: Dict[int, int] = {}

        self._jit_embed = {}
        self._jit_prefill: OrderedDict = OrderedDict()   # LRU, bounded
        # the KV pool is donated on every decode/prefill call: XLA aliases
        # the input buffers to the outputs and updates the cache in place
        self._jit_decode = jax.jit(self._decode_step_impl,
                                   donate_argnums=(1,))
        self._jit_encode = jax.jit(self._encode_impl)

    # ------------------------------------------------------------------ API

    def submit_spec(self, spec: SubmitSpec) -> Request:
        """THE ingestion door (core/plan.py): every submission path — HTTP
        front-end, trace replay, closed-loop drains — lands here with one
        frozen ``SubmitSpec``.  A spec without ``arrival_time`` is stamped
        at the engine's current iteration (live traffic on the iteration
        clock; wall-mode executors stamp before calling)."""
        if spec.prompt_tokens is None:
            raise ValueError(
                "engine submission needs real token ids — build the "
                "SubmitSpec with prompt_tokens (see "
                "traffic.attach_prompt_tokens for simulator-shaped traces)")
        rid = self._next_id
        self._next_id += 1
        prompt = np.asarray(spec.prompt_tokens, np.int32)
        if len(prompt) + spec.max_new_tokens > self.max_len:
            # the bound also caps the recompute prompt after a preemption
            # (prompt + generated-so-far never exceeds prompt + max_new)
            raise ValueError(
                f"request {rid}: prompt {len(prompt)} + max_new "
                f"{spec.max_new_tokens} exceeds max_len {self.max_len}")
        req = Request.from_spec(
            spec, rid,
            arrival_time=float(self.iteration)
            if spec.arrival_time is None else spec.arrival_time,
            prompt_tokens=prompt)
        self.requests[rid] = req
        self.prompts[rid] = prompt
        self.outputs[rid] = []
        if spec.enc_frames is not None:
            self.enc_frames[rid] = np.asarray(spec.enc_frames)
        self.scheduler.submit(req)
        return req

    def submit(self, prompt_tokens, max_new_tokens: int,
               enc_frames=None, *, slo_class: str = "interactive",
               arrival_time: Optional[float] = None) -> int:
        """Positional convenience wrapper over ``submit_spec`` (kept for
        closed-loop callers and tests); returns the request id."""
        return self.submit_spec(SubmitSpec(
            max_new_tokens=max_new_tokens, prompt_tokens=prompt_tokens,
            slo_class=slo_class, arrival_time=arrival_time,
            enc_frames=enc_frames)).req_id

    def run(self, max_iterations: int = 10_000) -> "RunResult":
        """Closed-loop drain of everything already submitted, through the
        shared ServingRuntime loop (timestamps are iteration-indexed, as
        they always were).  For open-loop timed-trace replay build a
        ``ServingRuntime(EngineExecutor(engine))`` and pass the trace."""
        runtime = ServingRuntime(EngineExecutor(self), clock="iteration")
        return runtime.run((), max_iterations=max_iterations)

    # -------------------------------------------------------------- jit fns

    def _encode_impl(self, params, frames):
        enc = self.model.encode(params, frames)
        return enc, self.model.precompute_cross_kv(params, enc)

    def _embed_impl(self, params, tokens, positions):
        return self.model.embed(params, tokens, positions=positions)

    def _decode_step_impl(self, params, cache, tokens, offsets, valid_rows):
        """tokens: (n_slots, 1). One decode token for every slot; masked
        rows are no-ops (state & KV preserved).  Returns the per-(block,
        expert) activation MASK rather than raw counts — the union
        reduction the host needs stays on device."""
        positions = offsets[:, None]
        valid = valid_rows[:, None]
        logits, cache, aux = self.model.forward(
            params, tokens, positions=positions, offset=offsets, cache=cache,
            valid=valid, gmm_fn=self.gmm_fn, dropless=True,
            moe_dispatch=self.moe_dispatch)
        next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok, cache, aux["expert_counts"] > 0

    def _prefill_impl(self, start: int, n: int, emit: bool,
                      params, cache, hidden, valid, slots, offset, length):
        """One packed layer-group batch: hidden (B, P, d) holds one row per
        prefill slice, slots/offset/length are (B,).  Static key: (start,
        n, emit, B, P).  The multi-slot cache is DONATED by the caller;
        rows are gathered/scattered with one take / one slot-vector
        scatter per leaf instead of B full-tree dynamic slices.  Padding
        rows (valid all-False, slot id == n_slots) are no-ops end to end:
        their KV/state writes are suppressed by ``valid`` and their
        writeback is dropped by the out-of-range scatter."""
        rows = gather_slot_rows(cache, slots)
        positions = offset[:, None] + jnp.arange(hidden.shape[1],
                                                 dtype=jnp.int32)[None]
        x, rows, auxes = self.model.run_blocks(
            params, hidden, start, n,
            positions=positions, offset=offset, cache=rows, valid=valid,
            gmm_fn=self.gmm_fn, dropless=True,
            moe_dispatch=self.moe_dispatch)
        cache = scatter_slot_rows(cache, rows, slots)
        # per-(block, expert) activation mask over the WHOLE batch (n, E):
        # router counts are already summed over rows, so the host-side
        # union fetch is batch-size-free
        loads = jnp.stack([a["expert_counts"] > 0 for a in auxes])
        tokens = jnp.full((hidden.shape[0],), -1, jnp.int32)
        if emit:
            h_last = jnp.take_along_axis(
                x, (length - 1)[:, None, None], axis=1)[:, 0]
            logits = self.model.logits(params, h_last)
            tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return x, cache, loads, tokens

    def _get_prefill_fn(self, start: int, n: int, emit: bool,
                        b: int, p: int):
        """One executable per (block_start, n_blocks, emit, B_bucket,
        P_bucket).  The shape buckets are part of the LRU key so the
        PREFILL_CACHE_SIZE bound counts executables, not trace families."""
        key = (start, n, emit, b, p)
        if key in self._jit_prefill:
            self._jit_prefill.move_to_end(key)
        else:
            self._jit_prefill[key] = jax.jit(
                functools.partial(self._prefill_impl, start, n, emit),
                donate_argnums=(1,))
            self.n_prefill_compiles += 1
            while len(self._jit_prefill) > PREFILL_CACHE_SIZE:
                self._jit_prefill.popitem(last=False)
        return self._jit_prefill[key]

    def _get_embed_fn(self):
        if "f" not in self._jit_embed:
            self._jit_embed["f"] = jax.jit(self._embed_impl)
        return self._jit_embed["f"]

    def _verify_impl(self, params, cache, tokens, valid, slots, offset):
        """Verify-k window for a cohort of drafting slots in ONE call:
        ``tokens`` (B, P) holds per row [last_tok, d_1..d_k, pad...] fed at
        positions offset..offset+P-1 through the FULL stack.  Row j of the
        returned argmax grid is the target's greedy token AFTER window
        position j — the host accepts the matching draft prefix.  KV for
        the whole window is written through the donated-buffer path;
        *rollback* past the first rejection is free: attention masks KV by
        the committed offset (``kv_valid = pos < offset + s``), so the
        stale tail beyond what the host commits is never read and is
        overwritten by a later window.  Padding rows (slot id == n_slots,
        valid all-False) are no-ops end to end."""
        rows = gather_slot_rows(cache, slots)
        positions = offset[:, None] + jnp.arange(tokens.shape[1],
                                                 dtype=jnp.int32)[None]
        hidden = self.model.embed(params, tokens, positions=positions)
        x, rows, auxes = self.model.run_blocks(
            params, hidden, 0, self.model.n_blocks,
            positions=positions, offset=offset, cache=rows, valid=valid,
            gmm_fn=self.gmm_fn, dropless=True,
            moe_dispatch=self.moe_dispatch)
        cache = scatter_slot_rows(cache, rows, slots)
        loads = jnp.stack([a["expert_counts"] > 0 for a in auxes])
        logits = self.model.logits(params, x)
        toks = jnp.argmax(logits, axis=-1).astype(jnp.int32)     # (B, P)
        return cache, loads, toks

    def _get_verify_fn(self, b: int, p: int):
        """Verify executables join the prefill LRU under ("verify", B, P)
        keys — bucketed k means adaptive speculation lengths reuse a small
        executable family, and the shared PREFILL_CACHE_SIZE bound counts
        them like any other live executable."""
        key = ("verify", b, p)
        if key in self._jit_prefill:
            self._jit_prefill.move_to_end(key)
        else:
            self._jit_prefill[key] = jax.jit(self._verify_impl,
                                             donate_argnums=(1,))
            self.n_verify_compiles += 1
            while len(self._jit_prefill) > PREFILL_CACHE_SIZE:
                self._jit_prefill.popitem(last=False)
        return self._jit_prefill[key]

    def _draft_impl(self, k, params, tokens, lengths):
        """Greedy k-step extension by the STATELESS draft model, one jitted
        ``lax.scan``: each step re-runs the draft over the (padded) full
        history — no draft KV cache exists, so preemption/fold/swap need
        zero draft-side bookkeeping.  Proposals stay on device (the verify
        window consumes them directly); their values ride the single
        end-of-iteration fetch."""
        n_pos = tokens.shape[1]

        def step(state, _):
            toks, lens = state
            logits, _, _ = self.draft_model.forward(params, toks)
            nxt = jnp.take_along_axis(logits, (lens - 1)[:, None, None],
                                      axis=1)[:, 0]
            tok = jnp.argmax(nxt, axis=-1).astype(jnp.int32)
            toks = jnp.where(jnp.arange(n_pos, dtype=jnp.int32)[None]
                             == lens[:, None], tok[:, None], toks)
            return (toks, lens + 1), tok

        (_, _), props = jax.lax.scan(step, (tokens, lengths), None, length=k)
        return jnp.transpose(props)                              # (B, k)

    def _get_draft_fn(self, k: int, b: int, p: int):
        """Draft executables share the bounded prefill LRU too."""
        key = ("draft", k, b, p)
        if key in self._jit_prefill:
            self._jit_prefill.move_to_end(key)
        else:
            self._jit_prefill[key] = jax.jit(
                functools.partial(self._draft_impl, k))
            self.n_verify_compiles += 1
            while len(self._jit_prefill) > PREFILL_CACHE_SIZE:
                self._jit_prefill.popitem(last=False)
        return self._jit_prefill[key]

    # -------------------------------------------------------------- stepping

    def step(self) -> IterationPlan:
        """Legacy self-driving step (plan + execute + iteration-clock
        timestamps via the runtime's shared rule). The serving loop —
        arrivals, clocks, streaming — lives in serving/runtime.py; this
        remains for tests and tools that drive iterations by hand."""
        plan = self.scheduler.next_plan(now=float(self.iteration))
        events = self.execute_plan(plan)
        # execute_plan advanced self.iteration: tokens visible at the
        # new count, exactly the runtime's iteration-clock t_end
        timestamp_events(self.scheduler, events, float(self.iteration))
        return plan

    def execute_plan(self, plan: IterationPlan) -> List[TokenEvent]:
        """Execute one scheduler-produced plan against the real model and
        return the tokens it emitted (consumed by the ServingRuntime for
        timestamping and streaming callbacks).

        The hot path is sync-free: prefill groups and the decode step are
        LAUNCHED first (device arrays only), then ONE ``jax.device_get``
        fetches everything the host needs — emitted tokens, per-block
        expert-activation masks, and this iteration's swap-out victim rows
        — and all bookkeeping (offsets, EOS, token events, expert union)
        runs on the fetched numpy values."""
        self._step_events: List[TokenEvent] = []
        dispatches0 = self.n_dispatches
        prefix_hits0, prefix_toks0 = self._prefix_seen
        block_expert_union = np.zeros(
            (self.model.n_blocks, max(self.cfg.moe.n_experts, 1)), bool)

        # memory-pressure victims first: their slot rows and stash must be
        # released before this iteration's swap-ins/admissions reuse them.
        # Swap-out rows are snapshotted as device arrays (immutable — later
        # writes build new buffers) and join the end-of-iteration fetch.
        for rid in plan.preempted_ids:
            self._preempt(rid)
        swap_pending = [self._swap_out(rid) for rid in plan.swapped_out_ids]

        for rid in plan.swapped_in_ids:
            self._swap_in(rid)
        for rid in plan.admitted_ids:
            self._admit(rid)

        groups = self._pack_slices(plan.prefill)
        launched, staged = [], []
        for g in groups:
            launched.append(self._launch_prefill_group(*g))
            if self.handoff_staging:
                # group-granular streaming: a slice whose token range ends
                # at the prompt completes its blocks' KV this iteration —
                # slice those rows NOW (before a later donated call retires
                # this cache buffer); values join the single fetch below
                for sl in g[3]:
                    if sl.token_end == self.requests[sl.req_id].prompt_len:
                        staged.append(
                            (sl.req_id, sl.block_start, sl.block_end,
                             self._slice_block_rows(sl.req_id,
                                                    sl.block_start,
                                                    sl.block_end)))
        prefill_tokens = sum(sl.n_tokens for sl in plan.prefill)

        # speculative verify-k: draft + verify are LAUNCHED here (device
        # arrays only); rows the drafter skipped fall through to the plain
        # decode step below
        spec_rows, spec_skipped, spec_fetch = [], [], None
        if plan.verify_len and self.spec_mode != "off":
            spec_rows, spec_skipped, spec_fetch = self._launch_verify(plan)
        spec_rids = {rid for rid, _, _, _, _ in spec_rows}

        decode_slot_req = decode_out = None
        plain_ids = [rid for rid in plan.decode_ids if rid not in spec_rids]
        if plain_ids:
            decode_slot_req, decode_out = self._launch_decode(plain_ids)

        # ---- the ONE host sync per iteration ----
        if launched or decode_out is not None or swap_pending \
                or spec_fetch is not None or staged:
            launched, decode_out, spec_fetch, swap_rows, staged_rows = \
                jax.device_get(
                    (launched, decode_out, spec_fetch,
                     [row for _, row in swap_pending],
                     [rows for *_, rows in staged]))
            for (rid, _), row in zip(swap_pending, swap_rows):
                self.host_kv[rid] = (row,) + self.host_kv[rid][1:]
            for (rid, b0, b1, _), rows in zip(staged, staged_rows):
                self._handoff_chunks.setdefault(rid, []).append(
                    (b0, b1, rows))
                self.handoff_bytes += sum(
                    a.nbytes for a in jax.tree_util.tree_leaves(rows))

        for (start, end, emit, slices), (loads, toks) in zip(groups,
                                                             launched):
            block_expert_union[start:end] |= loads
            for i, sl in enumerate(slices):
                self._finish_prefill_slice(sl, int(toks[i]))
        n_verify_tokens = n_spec_accepted = 0
        self.last_verify_executed = {}
        if spec_fetch is not None:
            loads, toks, props = spec_fetch
            block_expert_union |= loads
            n_verify_tokens, n_spec_accepted = self._finish_verify(
                spec_rows, toks, props)
        if decode_out is not None:
            next_tok, loads = decode_out
            block_expert_union |= loads
            for slot, rid in decode_slot_req.items():
                tok = int(next_tok[slot])
                self.offsets[slot] += 1
                self.last_tok[slot] = tok
                self._record_token(rid, tok, first=False)
                self._maybe_finish(rid, tok)
        for rid in spec_skipped:
            # a 0-proposal commit releases the scheduler's page pre-charge
            self.last_verify_executed[rid] = 0
            self.scheduler.commit_speculation(rid, proposed=0, accepted=0,
                                              extra=0)

        if self.cfg.moe.enabled:
            loaded = int(block_expert_union.sum())
            self.expert_load_bytes += loaded * self._expert_bytes
        self.iter_log.append({
            "iteration": self.iteration,
            "n_decode": len(plan.decode_ids),
            "prefill_tokens": prefill_tokens,
            "expert_load_bytes": (int(block_expert_union.sum())
                                  * self._expert_bytes),
            "pages_in_use": self.alloc.pages_in_use(),
            "host_pages_in_use": self.alloc.host_pages_in_use(),
            "n_preempted": len(plan.preempted_ids),
            "n_swapped_out": len(plan.swapped_out_ids),
            "n_swapped_in": len(plan.swapped_in_ids),
            "n_dispatches": self.n_dispatches - dispatches0,
            "n_verify_tokens": n_verify_tokens,
            "n_spec_accepted": n_spec_accepted,
            "n_spec_rows": len(spec_rows),
            "n_prefix_hits": self.alloc.n_prefix_hits - prefix_hits0,
            "prefix_cached_tokens": (self.alloc.n_prefix_tokens
                                     - prefix_toks0),
        })
        self._prefix_seen = (self.alloc.n_prefix_hits,
                             self.alloc.n_prefix_tokens)
        self.iteration += 1
        return self._step_events

    # ------------------------------------------------ disaggregated handoff

    def _slice_block_rows(self, rid: int, b0: int, b1: int) -> list:
        """Device-slice one slot's cache rows for blocks [b0, b1) — the
        group-granular handoff chunk.  Eager ops allocate fresh buffers,
        so the snapshot survives later donated calls; the VALUES ride the
        single end-of-iteration ``jax.device_get``."""
        slot = self._slot_of[rid]
        rows = []
        for b in range(b0, b1):
            s, r, p_idx = self.model.index_map[b]
            rows.append(jax.tree_util.tree_map(
                lambda c: jax.lax.dynamic_slice_in_dim(c[r], slot, 1,
                                                       axis=0),
                self.cache[s][p_idx]))
        return rows

    def _scatter_block_rows(self, slot: int, b0: int, b1: int,
                            rows: list) -> None:
        """Install imported per-block chunk rows into ``slot`` (the decode-
        side half of the streaming handoff; device ops, no host sync)."""
        for b, row in zip(range(b0, b1), rows):
            s, r, p_idx = self.model.index_map[b]
            self.cache[s][p_idx] = jax.tree_util.tree_map(
                lambda f, ch: f.at[r, slot].set(
                    jnp.asarray(ch[0]).astype(f.dtype)),
                self.cache[s][p_idx], row)

    def export_request(self, rid: int) -> dict:
        """Pull a migrating request's state off this engine (the prefill
        pool): host-staged KV chunks (or, when they do not tile the stack
        — staging off, or a preemption dropped them — a one-off full-row
        snapshot), the token buffers, and the allocator-level page export
        (shared-prefix pages stay warm in THIS pool's LRU).  The caller
        has already ``pop_request``-ed the id from the scheduler."""
        req = self.requests.pop(rid)
        slot = self._slot_of.pop(rid)
        offset = int(self.offsets[slot])
        last = int(self.last_tok[slot])
        chunks = self._handoff_chunks.pop(rid, [])
        row = None
        if not _chunks_cover(chunks, self.model.n_blocks):
            # whole-prompt fallback: the only device sync outside the
            # per-iteration fetch, taken exactly when streaming was off
            row = jax.device_get(_slice_cache(self.cache, slot))
            chunks = []
        self._free_slots.append(slot)
        self.decoding[slot] = False
        self.stash.pop(rid, None)
        self.n_handoffs_out += 1
        return {"req": req, "prompt": self.prompts.pop(rid),
                "outputs": self.outputs.pop(rid),
                "enc_frames": self.enc_frames.pop(rid, None),
                "offset": offset, "last_tok": last,
                "chunks": chunks, "row": row,
                "kv": self.alloc.export_pages(rid)}

    def import_request(self, payload: dict):
        """Install an exported request on this engine (the decode pool):
        land its pages (warm shared chains link for free), scatter the
        staged chunks — or the fallback full row — into a fresh slot, and
        resume decode exactly where the prefill pool left off.  Returns
        the allocator's ``KVImport`` (linked/moved token split).  The
        caller adopts the request into this engine's scheduler AFTER this
        lands (``Scheduler.adopt`` asserts residency)."""
        req = payload["req"]
        rid = req.req_id
        imp = self.alloc.import_pages(payload["kv"])
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        if payload["row"] is not None:
            self.cache = _scatter_cache(self.cache, payload["row"], slot)
        else:
            for b0, b1, rows in payload["chunks"]:
                self._scatter_block_rows(slot, b0, b1, rows)
        self.offsets[slot] = payload["offset"]
        self.last_tok[slot] = payload["last_tok"]
        self.decoding[slot] = True
        self.requests[rid] = req
        self.prompts[rid] = payload["prompt"]
        self.outputs[rid] = payload["outputs"]
        if payload["enc_frames"] is not None:
            self.enc_frames[rid] = payload["enc_frames"]
        self.n_handoffs_in += 1
        return imp

    def release_request(self, rid: int) -> None:
        """Drop every physical resource a SHED request still holds — slot
        row, host swap snapshot, boundary stash, staged handoff chunks —
        without touching its token buffers (the shed stream's partial
        output stays readable in ``outputs``).  The scheduler side (page
        release, queue scrub, DONE state) is ``Scheduler.shed``'s job;
        this is its executor-side mirror, callable in any pre-DONE state
        (WAITING victims hold nothing and every pop is a no-op)."""
        slot = self._slot_of.pop(rid, None)
        if slot is not None:
            self._free_slots.append(slot)
            self.decoding[slot] = False
        self.host_kv.pop(rid, None)
        self.stash.pop(rid, None)
        self._handoff_chunks.pop(rid, None)

    # -------------------------------------------------------------- helpers

    def _preempt(self, rid: int) -> None:
        """Execute a scheduler eviction: release the physical slot row and
        the boundary-activation stash, and fold the tokens generated so far
        into the recompute prompt (matching the scheduler's prompt_len
        fold in ``Scheduler.preempt``).  A demoted SWAPPED victim (the
        scheduler's swap-pin pressure valve) holds no slot — its dead
        host snapshot is dropped instead."""
        slot = self._slot_of.pop(rid, None)
        if slot is not None:
            self._free_slots.append(slot)
            self.decoding[slot] = False
        else:
            self.host_kv.pop(rid, None)
        self.stash.pop(rid, None)
        self._handoff_chunks.pop(rid, None)   # staged KV is void post-fold
        # append only the tokens generated since the last fold — a request
        # preempted twice must not duplicate the already-folded prefix
        tail = self.requests[rid].prompt_len - len(self.prompts[rid])
        if tail:
            self.prompts[rid] = np.concatenate(
                [self.prompts[rid],
                 np.asarray(self.outputs[rid][-tail:], np.int32)])
        assert len(self.prompts[rid]) == self.requests[rid].prompt_len, \
            (rid, len(self.prompts[rid]), self.requests[rid].prompt_len)
        self.n_preempted += 1

    def _swap_out(self, rid: int):
        """Execute a swap-to-host eviction: snapshot the victim's slot row
        (every per-block KV / recurrent-state entry) as ONE device slice
        and release the slot; ``execute_plan`` materialises the host copy
        in the single end-of-iteration ``jax.device_get`` (one batched
        transfer, not a per-leaf ``np.asarray`` stall each).  The snapshot
        is immutable — this iteration's compute builds new cache buffers —
        so deferring the fetch cannot observe later writes.  Until then
        ``host_kv`` holds the device snapshot (hand-stepping drivers that
        call ``_swap_out`` directly stay correct: ``_swap_in`` restores
        either representation verbatim).  The scheduler already moved the
        allocator pages to the host pool."""
        slot = self._slot_of.pop(rid)
        assert rid not in self.stash, rid       # swap victims are DECODE
        row = _slice_cache(self.cache, slot)
        self.host_kv[rid] = (row, int(self.offsets[slot]),
                             int(self.last_tok[slot]))
        self._free_slots.append(slot)
        self.decoding[slot] = False
        self.n_swapped_out += 1
        return rid, row

    def _swap_in(self, rid: int) -> None:
        """DMA-back: restore the host copy into a fresh slot row and resume
        decode exactly where the victim left off (bit-identical KV, so the
        greedy continuation matches an undisturbed run)."""
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        row, offset, last = self.host_kv.pop(rid)
        self.cache = _scatter_cache(self.cache, row, slot)
        self.offsets[slot] = offset
        self.last_tok[slot] = last
        self.decoding[slot] = True
        self.n_swapped_in += 1

    def _admit(self, rid: int) -> None:
        slot = self._free_slots.pop()
        self._slot_of[rid] = slot
        self.offsets[slot] = 0
        self.decoding[slot] = False
        hit = self.alloc.prefix_hit(rid)
        if hit.cached_tokens:
            # seed the slot row with the cached prefix KV: the snapshot
            # row holds the registering request's KV for positions
            # 0..usable-1 (usable >= cached_tokens; on a COW hit the tail
            # page's extra positions are overwritten by the re-prefilled
            # token or masked by the offset).  Same scatter machinery as
            # swap-in — a device op, no host sync.
            row, usable = self._prefix_rows[hit.leaf]
            assert usable >= hit.cached_tokens, (rid, usable, hit)
            self.cache = _scatter_cache(self.cache, row, slot)
            self.offsets[slot] = hit.cached_tokens
            self.n_prefix_restores += 1
        if rid in self.enc_frames:
            frames = jnp.asarray(self.enc_frames[rid])[None]
            _, xkv = self._jit_encode(self.params, frames)
            # install cross K/V into this slot's cache rows
            for s, seg in enumerate(xkv):
                for p_idx, kv in enumerate(seg):
                    if kv is None:
                        continue
                    cur = self.cache[s][p_idx]
                    self.cache[s][p_idx] = dict(
                        cur,
                        xk=cur["xk"].at[:, slot].set(kv["xk"][:, 0]),
                        xv=cur["xv"].at[:, slot].set(kv["xv"][:, 0]),
                    )

    def _pack_slices(self, slices: List[PrefillSlice]):
        """Group the plan's prefill slices by their layer-group rectangle:
        every (block_start, block_end, emits_first_token) group executes
        as ONE jitted call over a slot vector.  A request appears at most
        once per plan (scheduler invariant I3), so rows within a group are
        independent — distinct slots, no intra-group KV dependencies.
        With packing disabled each slice is its own group of one (the
        per-slice reference path)."""
        if not self.packed:
            return [(sl.block_start, sl.block_end, sl.emits_first_token,
                     [sl]) for sl in slices]
        grouped: OrderedDict = OrderedDict()
        for sl in slices:
            key = (sl.block_start, sl.block_end, sl.emits_first_token)
            grouped.setdefault(key, []).append(sl)
        return [(start, end, emit, sls)
                for (start, end, emit), sls in grouped.items()]

    def _launch_prefill_group(self, start: int, end: int, emit: bool,
                              slices: List[PrefillSlice]):
        """Launch one packed layer-group batch; returns DEVICE arrays
        (per-block expert-activation mask, per-row first tokens) for the
        end-of-iteration fetch.  Rows pad to a power-of-two batch bucket
        (padding rows carry the out-of-range slot id and an all-False
        valid mask) and tokens to a power-of-two token bucket."""
        b = len(slices)
        b_pad = _bucket(b, minimum=1, cap=self.n_slots)
        if start == 0:
            # fresh rectangle rows: embed every token range in ONE call
            p = _bucket(max(sl.n_tokens for sl in slices), cap=self.max_len)
            toks = np.zeros((b_pad, p), np.int32)
            pos = np.zeros((b_pad, p), np.int32)
            for i, sl in enumerate(slices):
                toks[i, :sl.n_tokens] = \
                    self.prompts[sl.req_id][sl.token_start:sl.token_end]
                pos[i] = sl.token_start + np.arange(p, dtype=np.int32)
            hidden = self._get_embed_fn()(self.params, jnp.asarray(toks),
                                          jnp.asarray(pos))
            self.n_dispatches += 1
        else:
            hidden = self._stash_hidden(slices, b_pad)
            p = hidden.shape[1]
        valid = np.zeros((b_pad, p), bool)
        slots = np.full(b_pad, self.n_slots, np.int32)  # OOB: writes dropped
        offs = np.zeros(b_pad, np.int32)
        lens = np.ones(b_pad, np.int32)
        for i, sl in enumerate(slices):
            valid[i, :sl.n_tokens] = True
            slots[i] = self._slot_of[sl.req_id]
            offs[i] = sl.token_start
            lens[i] = sl.n_tokens
        fn = self._get_prefill_fn(start, end - start, emit, b_pad, p)
        x, self.cache, loads, tokens = fn(
            self.params, self.cache, hidden, jnp.asarray(valid),
            jnp.asarray(slots), jnp.asarray(offs), jnp.asarray(lens))
        self.n_dispatches += 1
        self.n_prefill_dispatches += 1
        if end < self.model.n_blocks:
            # the whole packed boundary activation is stashed ONCE; each
            # request holds a (batch, row) reference into it
            for i, sl in enumerate(slices):
                self.stash[sl.req_id] = (x, i, sl.n_tokens)
        else:
            for sl in slices:
                self.stash.pop(sl.req_id, None)
        return loads, tokens

    def _stash_hidden(self, slices: List[PrefillSlice], b_pad: int) -> Array:
        """Boundary activations for a block_start > 0 group.  The common
        case — a layered cohort whose membership is unchanged since the
        previous group — reuses the stashed packed batch WHOLESALE (zero
        extra dispatches; this is why stash rows are stored as (batch,
        row) references).  After a mid-cohort preemption or under shape
        drift the surviving rows are regathered into a fresh batch."""
        entries = []
        for sl in slices:
            src, row, n_tok = self.stash[sl.req_id]
            assert n_tok == sl.n_tokens, "stash/token-range mismatch"
            entries.append((src, row))
        src0 = entries[0][0]
        rows = [row for _, row in entries]
        same_src = all(src is src0 for src, _ in entries)
        if same_src and rows == list(range(len(slices))) \
                and src0.shape[0] == b_pad:
            return src0
        p = max(src.shape[1] for src, _ in entries)
        if same_src:
            h = jnp.take(src0, jnp.asarray(rows, jnp.int32), axis=0)
            h = jnp.pad(h, ((0, b_pad - h.shape[0]),
                            (0, p - h.shape[1]), (0, 0)))
        else:
            parts = [jnp.pad(src[row], ((0, p - src.shape[1]), (0, 0)))
                     for src, row in entries]
            h = jnp.stack(parts)
            if h.shape[0] < b_pad:
                h = jnp.pad(h, ((0, b_pad - h.shape[0]), (0, 0), (0, 0)))
        self.n_dispatches += 1
        return h

    def _finish_prefill_slice(self, sl: PrefillSlice, tok: int) -> None:
        """Host bookkeeping for one executed slice (post-fetch): offsets,
        the emitted first token, EOS, and the decode handoff."""
        rid = sl.req_id
        slot = self._slot_of[rid]
        req = self.requests[rid]
        if sl.block_end == self.model.n_blocks:
            # tokens fully processed through the stack
            self.offsets[slot] = sl.token_end
        if sl.emits_first_token:
            if self.prefix_cache:
                # snapshot BEFORE _maybe_finish can free the allocator
                # state of an instantly-done (EOS-on-first-token) request
                self._snapshot_prefix_rows(rid, slot)
            self._record_token(rid, tok, first=True)
            self.offsets[slot] = req.prompt_len
            self.last_tok[slot] = tok
            # EOS can terminate on the very first token even when the
            # scheduler already moved the request to DECODE
            self._maybe_finish(rid, tok, after_first=True)
            if req.state == RequestState.DECODE:
                self.decoding[slot] = True

    def _snapshot_prefix_rows(self, rid: int, slot: int) -> None:
        """Slice the completed prompt's KV row once and file it under every
        shared-index chain this request's own pages serve (registration
        happened scheduler-side at plan time — ``owned_chains`` recovers
        the digests).  The slice is an immutable device snapshot (later
        donated calls build new cache buffers), so it stays valid for
        restores arbitrarily many iterations later."""
        chains = self.alloc.owned_chains(
            rid, self.requests[rid].cacheable_prompt)
        missing = [(d, depth) for d, depth in chains
                   if d not in self._prefix_rows]
        if not missing:
            return
        row = _slice_cache(self.cache, slot)
        ps = self.alloc.page_size
        for d, depth in missing:
            self._prefix_rows[d] = (row, depth * ps)

    def _history(self, rid: int) -> np.ndarray:
        """Full token sequence so far (recompute prompt + the generated
        tail not yet folded into it); its last element is last_tok and its
        length is offsets[slot] + 1."""
        req = self.requests[rid]
        tail = self.outputs[rid][req.n_folded:]
        return np.concatenate([self.prompts[rid],
                               np.asarray(tail, np.int32)])

    def _launch_verify(self, plan: IterationPlan):
        """Launch the drafting cohort's verify window (plus, in draft mode,
        the draft-model scan that feeds it); returns host row metadata
        (rid, slot, offset, k_eff), the ids that fell back to plain decode
        this iteration, and the device arrays for the one fetch.

        Window safety: per-row KV writes cover offset..offset+P-1 (the
        BUCKETED window — ``_write_cache`` drops out-of-range token writes,
        but a window that would spill past max_len has nowhere to store
        accepted tokens, so it must not launch).  Rows where the worst-case
        bucket does not fit fall back to plain decode."""
        budgets = sorted(plan.verify_len.items())
        p_worst = _bucket(self.spec_k + 1, minimum=2, cap=self.spec_k + 1)
        rows: List[Tuple[int, int, int, int, Optional[np.ndarray]]] = []
        skipped: List[int] = []
        for rid, k in budgets:
            slot = self._slot_of[rid]
            off = int(self.offsets[slot])
            if off + p_worst > self.max_len:
                skipped.append(rid)
                continue
            if self.spec_mode == "ngram":
                prop = self.drafter.propose(self._history(rid), k)
                if len(prop) == 0:
                    skipped.append(rid)
                    continue
                rows.append((rid, slot, off, len(prop),
                             prop.astype(np.int32)))
            else:
                rows.append((rid, slot, off, k, None))
        if not rows:
            return [], skipped, None

        k_max = max(k_eff for _, _, _, k_eff, _ in rows)
        p = _bucket(k_max + 1, minimum=2, cap=self.spec_k + 1)
        b_pad = _bucket(len(rows), minimum=1, cap=self.n_slots)
        tokens = np.zeros((b_pad, p), np.int32)
        valid = np.zeros((b_pad, p), bool)
        slots = np.full(b_pad, self.n_slots, np.int32)  # OOB: writes dropped
        offs = np.zeros(b_pad, np.int32)
        for i, (rid, slot, off, k_eff, prop) in enumerate(rows):
            tokens[i, 0] = self.last_tok[slot]
            if prop is not None:
                tokens[i, 1:1 + k_eff] = prop
            valid[i, :k_eff + 1] = True
            slots[i] = slot
            offs[i] = off

        props_dev = None
        if self.spec_mode == "draft":
            hists = [self._history(rid) for rid, _, _, _, _ in rows]
            p_hist = _bucket(max(len(h) for h in hists) + k_max,
                             cap=self.max_len + self.spec_k)
            hist_toks = np.zeros((b_pad, p_hist), np.int32)
            hist_lens = np.ones(b_pad, np.int32)
            for i, h in enumerate(hists):
                hist_toks[i, :len(h)] = h
                hist_lens[i] = len(h)
            draft_fn = self._get_draft_fn(k_max, b_pad, p_hist)
            props_dev = draft_fn(self.draft_params, jnp.asarray(hist_toks),
                                 jnp.asarray(hist_lens))
            self.n_dispatches += 1
            self.n_draft_dispatches += 1
            # splice the device proposals into the window without a sync
            tok_dev = jnp.asarray(tokens)
            tok_dev = jax.lax.dynamic_update_slice(
                tok_dev, props_dev.astype(jnp.int32), (0, 1))
        else:
            tok_dev = jnp.asarray(tokens)

        fn = self._get_verify_fn(b_pad, p)
        self.cache, loads, toks = fn(
            self.params, self.cache, tok_dev, jnp.asarray(valid),
            jnp.asarray(slots), jnp.asarray(offs))
        self.n_dispatches += 1
        self.n_verify_dispatches += 1
        return rows, skipped, (loads, toks, props_dev)

    def _finish_verify(self, rows, toks, props) -> Tuple[int, int]:
        """Host bookkeeping for the fetched verify grid: accept the
        matching draft prefix, emit accepted drafts plus the target's own
        next token, advance the committed offset (the rollback — stale KV
        past it is dead), and feed acceptance back to the scheduler."""
        n_proposed = n_accepted = 0
        for i, (rid, slot, off, k_eff, prop) in enumerate(rows):
            if prop is None:
                prop = np.asarray(props[i, :k_eff])
            tgt = np.asarray(toks[i])
            a = accepted_prefix(prop[:k_eff], tgt[:k_eff])
            emitted = [int(t) for t in prop[:a]] + [int(tgt[a])]
            if self.eos_token is not None:
                for j, t in enumerate(emitted):
                    if t == self.eos_token:
                        emitted = emitted[:j + 1]
                        break
            self.offsets[slot] = off + len(emitted)
            self.last_tok[slot] = emitted[-1]
            for t in emitted:
                self._record_token(rid, t, first=False)
            n_proposed += k_eff
            n_accepted += a
            self.n_spec_proposed += k_eff
            self.n_spec_accepted += a
            self.last_verify_executed[rid] = k_eff
            self.scheduler.commit_speculation(
                rid, proposed=k_eff, accepted=a, extra=len(emitted) - 1,
                committed_len=int(self.offsets[slot]))
            self._maybe_finish(rid, emitted[-1])
        return n_proposed, n_accepted

    def _launch_decode(self, decode_ids: List[int]):
        """Launch the full-pool decode step; returns the slot→request map
        and DEVICE arrays (next tokens, expert-activation mask) for the
        end-of-iteration fetch.  Slots mid-prefill this iteration carry
        stale offsets — harmless, their rows are valid-masked no-ops."""
        tokens = np.zeros((self.n_slots, 1), np.int32)
        valid = np.zeros(self.n_slots, bool)
        slot_req = {}
        for rid in decode_ids:
            slot = self._slot_of[rid]
            tokens[slot, 0] = self.last_tok[slot]
            valid[slot] = True
            slot_req[slot] = rid
        next_tok, self.cache, loads = self._jit_decode(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(self.offsets), jnp.asarray(valid))
        self.n_dispatches += 1
        return slot_req, (next_tok, loads)

    def _record_token(self, rid: int, tok: int, *, first: bool) -> None:
        """Append the token to the request's output and report it as an
        event.  TIMESTAMPS are the ServingRuntime's job (one loop, one
        clock) — the engine only knows WHAT was emitted, not when."""
        self.outputs[rid].append(tok)
        self._step_events.append(TokenEvent(rid, tok, first=first))

    def _maybe_finish(self, rid: int, tok: int,
                      after_first: bool = False) -> None:
        req = self.requests[rid]
        eos = self.eos_token is not None and tok == self.eos_token
        if eos and req.state != RequestState.DONE:
            self.scheduler.finish(rid)
        if req.state == RequestState.DONE:
            slot = self._slot_of.pop(rid)
            self._free_slots.append(slot)
            self.decoding[slot] = False
            if self.alloc.owns(rid):        # EOS path frees via scheduler
                self.alloc.free(rid)
            self.stash.pop(rid, None)
            self._handoff_chunks.pop(rid, None)


class EngineHandoff:
    """``HandoffBridge`` over two Engines sharing one model + params (the
    real-execution realization of DESIGN.md §Disaggregated serving).  With
    ``streaming=True`` the source engine host-stages each completed layer
    group through its per-iteration fetch, so exports assemble from chunks
    with zero extra device syncs; ``streaming=False`` is the whole-prompt
    baseline (one full-row snapshot per migration).  The transfer is
    host-to-host, so ``ready_time == export_time`` — on real two-device
    deployments the simulator's link model prices what this path would
    cost."""

    def __init__(self, src: "Engine", dst: "Engine", *,
                 streaming: bool = True):
        if src.cfg is not dst.cfg and src.cfg != dst.cfg:
            raise ValueError("prefill/decode engines must share the model "
                             "config (KV layouts must match)")
        src.handoff_staging = streaming
        self.src = src
        self.dst = dst

    def decode_free_pages(self) -> int:
        return self.dst.alloc.n_free_pages

    def stage(self, plan, requests, t_end, duration) -> None:
        pass            # the engine stages inside execute_plan

    def export(self, req, now):
        from repro.serving.runtime import Migration
        payload = self.src.export_request(req.req_id)
        blob = [rows for _, _, rows in payload["chunks"]] \
            if payload["row"] is None else payload["row"]
        nbytes = sum(a.nbytes for a in jax.tree_util.tree_leaves(blob))
        return Migration(req=req, payload=payload, export_time=now,
                         ready_time=now,
                         n_chunks=len(payload["chunks"]),
                         bytes_total=float(nbytes))

    def can_import(self, m) -> bool:
        return bool(self.dst._free_slots) \
            and self.dst.alloc.can_import(m.payload["kv"])

    def do_import(self, m, now) -> Dict[str, int]:
        imp = self.dst.import_request(m.payload)
        return {"linked_tokens": imp.linked_tokens,
                "moved_tokens": imp.moved_tokens}

    def drop(self, req_id: int) -> None:
        self.src._handoff_chunks.pop(req_id, None)

    def abort_export(self, m) -> None:
        """A link failure lost migration ``m``'s payload in flight.
        Reinstall its backend state on the prefill engine — the token
        buffers come back and the generated tail folds into the prompt
        array (the runtime already folded the Request itself to
        PREEMPTED) — so the whole-prompt retry re-prefills bit-
        identically.  The exported KV pages died with the link:
        ``export_pages`` already freed them from this pool, so the drop
        leaks nothing on either allocator."""
        req = m.req
        rid = req.req_id
        p = m.payload
        prompt = np.asarray(p["prompt"], np.int32)
        tail = req.prompt_len - len(prompt)
        if tail:
            prompt = np.concatenate(
                [prompt, np.asarray(p["outputs"][-tail:], np.int32)])
        assert len(prompt) == req.prompt_len, \
            (rid, len(prompt), req.prompt_len)
        self.src.requests[rid] = req
        self.src.prompts[rid] = prompt
        self.src.outputs[rid] = p["outputs"]
        if p["enc_frames"] is not None:
            self.src.enc_frames[rid] = p["enc_frames"]

    def return_to_prefill(self, req) -> None:
        rid = req.req_id
        for src_d, dst_d in ((self.dst.requests, self.src.requests),
                             (self.dst.prompts, self.src.prompts),
                             (self.dst.outputs, self.src.outputs)):
            dst_d[rid] = src_d.pop(rid)
        if rid in self.dst.enc_frames:
            self.src.enc_frames[rid] = self.dst.enc_frames.pop(rid)
