from repro.serving.engine import Engine
from repro.serving.metrics import SLOConfig, request_metrics

__all__ = ["Engine", "SLOConfig", "request_metrics"]
