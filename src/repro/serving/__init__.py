from repro.serving.engine import Engine
from repro.serving.metrics import (SLOConfig, per_class_metrics,
                                   request_metrics)
from repro.serving.runtime import EngineExecutor, ServingRuntime, SimExecutor

__all__ = ["Engine", "EngineExecutor", "SLOConfig", "ServingRuntime",
           "SimExecutor", "per_class_metrics", "request_metrics"]
