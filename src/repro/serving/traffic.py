"""Workload generation: Poisson arrivals (the paper's traffic model) with
prompt/output length distributions fitted to the paper's Table 4 dataset
statistics (ShareGPT and arXiv-Summarization).

Lengths are lognormal fitted to (mean, std) and clipped — the fitted p90s
land close to the paper's measured p90 (checked in tests/test_traffic.py).
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Tuple

import numpy as np


@dataclass(frozen=True)
class LengthModel:
    mean: float
    std: float
    lo: int = 16
    hi: int = 131072

    def _mu_sigma(self) -> Tuple[float, float]:
        # lognormal with given mean m and std s:
        # sigma^2 = ln(1 + s^2/m^2); mu = ln m - sigma^2/2
        m, s = self.mean, self.std
        sigma2 = math.log(1.0 + (s * s) / (m * m))
        mu = math.log(m) - sigma2 / 2.0
        return mu, math.sqrt(sigma2)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        mu, sigma = self._mu_sigma()
        x = rng.lognormal(mu, sigma, size=n)
        return np.clip(x, self.lo, self.hi).astype(np.int64)


@dataclass(frozen=True)
class DatasetModel:
    name: str
    input_len: LengthModel
    output_len: LengthModel


# Paper Table 4.
SHAREGPT = DatasetModel(
    name="sharegpt",
    input_len=LengthModel(mean=2340, std=2088),
    output_len=LengthModel(mean=438, std=265),
)
ARXIV = DatasetModel(
    name="arxiv",
    input_len=LengthModel(mean=9194, std=5754),
    output_len=LengthModel(mean=231, std=104),
)

DATASETS = {"sharegpt": SHAREGPT, "arxiv": ARXIV}


@dataclass(frozen=True)
class TraceRequest:
    arrival_time: float
    prompt_len: int
    output_len: int


def poisson_trace(dataset: DatasetModel, rate: float, n_requests: int,
                  seed: int = 0) -> List[TraceRequest]:
    """Exogenous Poisson arrivals at ``rate`` req/s (paper §5.1)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    ins = dataset.input_len.sample(rng, n_requests)
    outs = dataset.output_len.sample(rng, n_requests)
    return [TraceRequest(float(a), int(i), int(o))
            for a, i, o in zip(arrivals, ins, outs)]
