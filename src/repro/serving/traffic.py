"""Workload generation: Poisson arrivals (the paper's traffic model) with
prompt/output length distributions fitted to the paper's Table 4 dataset
statistics (ShareGPT and arXiv-Summarization), a bursty (on/off modulated
Poisson) arrival process for the oversubscribed sweeps, and multi-class
trace composition for the multi-tenant SLO scenarios.

Lengths are lognormal fitted to (mean, std) and clipped — the fitted p90s
land close to the paper's measured p90 (checked in tests/test_traffic.py).
Every generator is seed-deterministic.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.plan import SubmitSpec


@dataclass(frozen=True)
class LengthModel:
    mean: float
    std: float
    lo: int = 16
    hi: int = 131072

    def _mu_sigma(self) -> Tuple[float, float]:
        # lognormal with given mean m and std s:
        # sigma^2 = ln(1 + s^2/m^2); mu = ln m - sigma^2/2
        m, s = self.mean, self.std
        sigma2 = math.log(1.0 + (s * s) / (m * m))
        mu = math.log(m) - sigma2 / 2.0
        return mu, math.sqrt(sigma2)

    def sample(self, rng: np.random.Generator, n: int) -> np.ndarray:
        mu, sigma = self._mu_sigma()
        x = rng.lognormal(mu, sigma, size=n)
        return np.clip(x, self.lo, self.hi).astype(np.int64)


@dataclass(frozen=True)
class DatasetModel:
    name: str
    input_len: LengthModel
    output_len: LengthModel


# Paper Table 4.
SHAREGPT = DatasetModel(
    name="sharegpt",
    input_len=LengthModel(mean=2340, std=2088),
    output_len=LengthModel(mean=438, std=265),
)
ARXIV = DatasetModel(
    name="arxiv",
    input_len=LengthModel(mean=9194, std=5754),
    output_len=LengthModel(mean=231, std=104),
)

DATASETS = {"sharegpt": SHAREGPT, "arxiv": ARXIV}


@dataclass(frozen=True)
class TraceRequest:
    arrival_time: float
    prompt_len: int
    output_len: int
    # multi-tenant SLO class tag, carried through to the Request
    slo_class: str = "interactive"
    # actual token ids for real-engine replay (None in the simulator);
    # a tuple so the frozen dataclass stays hashable/comparable
    prompt_tokens: Optional[Tuple[int, ...]] = None

    def to_spec(self) -> SubmitSpec:
        """The one ingestion conversion: trace replay submits through the
        same ``SubmitSpec`` record as the HTTP front-end and benchmarks
        (core/plan.py) — executors never see a raw TraceRequest."""
        return SubmitSpec(max_new_tokens=self.output_len,
                          prompt_tokens=self.prompt_tokens,
                          prompt_len=self.prompt_len,
                          slo_class=self.slo_class,
                          arrival_time=self.arrival_time)


def poisson_trace(dataset: DatasetModel, rate: float, n_requests: int,
                  seed: int = 0,
                  slo_class: str = "interactive") -> List[TraceRequest]:
    """Exogenous Poisson arrivals at ``rate`` req/s (paper §5.1)."""
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n_requests)
    arrivals = np.cumsum(gaps)
    ins = dataset.input_len.sample(rng, n_requests)
    outs = dataset.output_len.sample(rng, n_requests)
    return [TraceRequest(float(a), int(i), int(o), slo_class=slo_class)
            for a, i, o in zip(arrivals, ins, outs)]


def bursty_trace(dataset: DatasetModel, rate: float, n_requests: int,
                 seed: int = 0, *, mean_on: float = 4.0,
                 mean_off: float = 8.0,
                 slo_class: str = "interactive") -> List[TraceRequest]:
    """On/off modulated Poisson arrivals: exponential ON bursts (mean
    ``mean_on`` s) alternate with silent OFF gaps (mean ``mean_off`` s).
    During a burst, arrivals come at the PEAK rate
    ``rate * (mean_on + mean_off) / mean_on`` so the long-run average rate
    matches ``rate`` — the same x-axis as ``poisson_trace`` but with the
    head-of-line pressure spikes the multi-tenant and oversubscribed
    sweeps need.  Seed-deterministic."""
    assert mean_on > 0 and mean_off >= 0
    rng = np.random.default_rng(seed)
    peak = rate * (mean_on + mean_off) / mean_on
    arrivals: List[float] = []
    t = 0.0
    while len(arrivals) < n_requests:
        on_end = t + rng.exponential(mean_on)
        while len(arrivals) < n_requests:
            t += rng.exponential(1.0 / peak)
            if t > on_end:
                break
            arrivals.append(t)
        # the overshoot past on_end is discarded (memoryless), so the OFF
        # period starts exactly at the burst boundary
        t = on_end + (rng.exponential(mean_off) if mean_off else 0.0)
    ins = dataset.input_len.sample(rng, n_requests)
    outs = dataset.output_len.sample(rng, n_requests)
    return [TraceRequest(float(a), int(i), int(o), slo_class=slo_class)
            for a, i, o in zip(arrivals, ins, outs)]


ARRIVAL_PROCESSES = {"poisson": poisson_trace, "bursty": bursty_trace}


def shared_prefix_trace(n_requests: int, *, n_prefixes: int = 4,
                        prefix_len: int = 32, suffix_len: int = 8,
                        output_len: int = 8, rate: float = 1.0,
                        zipf_alpha: float = 1.2, vocab_size: int = 251,
                        seed: int = 0,
                        slo_class: str = "interactive") -> List[TraceRequest]:
    """Prompt-reuse workload for the prefix-cache evaluation: ``n_prefixes``
    fixed "system prompts" of ``prefix_len`` tokens, each request picking
    one Zipf(``zipf_alpha``)-distributed (popular prefixes dominate, like
    production template reuse) and appending ``suffix_len`` fresh random
    tokens.  Arrivals are Poisson at ``rate``; token ids land in
    [1, vocab_size).  With the defaults, ~80% of every prompt's tokens are
    shared with earlier requests of the same prefix.  Seed-deterministic;
    prompt_tokens are always attached (the whole point is token-content
    reuse)."""
    assert n_prefixes >= 1 and prefix_len >= 1 and suffix_len >= 0
    rng = np.random.default_rng(seed)
    prefixes = [tuple(int(x) for x in
                      rng.integers(1, vocab_size, prefix_len))
                for _ in range(n_prefixes)]
    # bounded Zipf over the prefix ids: p(k) ∝ (k+1)^-alpha
    w = np.arange(1, n_prefixes + 1, dtype=np.float64) ** -zipf_alpha
    w /= w.sum()
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    out: List[TraceRequest] = []
    for a in arrivals:
        pfx = prefixes[int(rng.choice(n_prefixes, p=w))]
        sfx = tuple(int(x) for x in
                    rng.integers(1, vocab_size, suffix_len))
        toks = pfx + sfx
        out.append(TraceRequest(float(a), len(toks), output_len,
                                slo_class=slo_class, prompt_tokens=toks))
    return out


@dataclass(frozen=True)
class ClassSpec:
    """One tenant class of a mixed trace: its SLO class tag, length
    distribution, arrival rate/process and request count."""
    slo_class: str
    dataset: DatasetModel
    rate: float
    n_requests: int
    process: str = "poisson"       # "poisson" | "bursty"


def multi_class_trace(specs: Sequence[ClassSpec],
                      seed: int = 0) -> List[TraceRequest]:
    """Compose independent per-class arrival streams (each deterministic
    under ``seed`` with a distinct per-class substream) into one trace,
    merge-sorted by arrival time."""
    trace: List[TraceRequest] = []
    for i, spec in enumerate(specs):
        gen = ARRIVAL_PROCESSES[spec.process]
        trace.extend(gen(spec.dataset, spec.rate, spec.n_requests,
                         seed=seed * 1009 + i, slo_class=spec.slo_class))
    return sorted(trace, key=lambda tr: tr.arrival_time)


def attach_prompt_tokens(trace: Sequence[TraceRequest], vocab_size: int,
                         seed: int = 0) -> List[TraceRequest]:
    """Fill ``prompt_tokens`` with seed-deterministic ids in
    [1, vocab_size) so a simulator-shaped trace can replay on the real
    engine (which needs actual token values)."""
    rng = np.random.default_rng(seed)
    out = []
    for tr in trace:
        toks = tuple(int(x) for x in
                     rng.integers(1, vocab_size, tr.prompt_len))
        out.append(TraceRequest(tr.arrival_time, tr.prompt_len,
                                tr.output_len, slo_class=tr.slo_class,
                                prompt_tokens=toks))
    return out
