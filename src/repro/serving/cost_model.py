"""Analytic hardware cost model for the discrete-event simulator.

Roofline-style: each iteration's duration is
    max(flops / (eff_c * FLOPS), hbm_bytes / (eff_m * BW)) + fixed overhead
and its energy is
    P_static * duration + hbm_bytes * e_hbm + flops * e_flop.

Two calibrations ship: ``H100X2`` approximates the paper's testbed (2×H100
NVLink, TP=2) so the reproduction can be compared against the paper's
absolute numbers; ``TPU_V5E_POD`` uses the roofline constants mandated for
this repo (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s ICI per link).

MoE expert-touch modelling uses the uniform-routing coverage expectation
    E[unique experts | n tokens] = E * (1 - (1 - k/E)^n)
which reproduces the paper's measured Table 1 within a few percent (see
benchmarks/table1_coverage.py, where it is validated against the REAL
router in the engine).
"""

from __future__ import annotations

import numpy as np

from dataclasses import dataclass
from typing import Dict, Optional

from repro.core.plan import IterationPlan, PrefillSlice, Request
from repro.models.config import FFN_MOE, ModelConfig


@dataclass(frozen=True)
class HardwareSpec:
    name: str
    n_chips: int
    flops_per_chip: float          # bf16 FLOP/s
    hbm_bw_per_chip: float         # bytes/s
    link_bw: float                 # bytes/s per link (ICI / NVLink)
    hbm_capacity_per_chip: float   # bytes
    static_power_w: float          # per chip, idle+base
    e_hbm_pj_per_byte: float
    e_flop_pj: float
    compute_eff: float = 0.65      # achievable fraction of peak
    mem_eff: float = 0.75
    iter_overhead_s: float = 250e-6
    # fixed per-block cost (kernel sequence / MoE dispatch machinery);
    # dominates small-batch decode iterations on the paper's GPU testbed.
    block_overhead_s: float = 30e-6
    # host <-> HBM DMA path (PCIe / DMA engines), aggregate across the
    # chips that share the host link — what swap-to-host preemption pays
    # per direction.  e_host covers the end-to-end byte move (PCIe PHY +
    # host DRAM touch), an order of magnitude above the on-package HBM
    # path.  host_dma_latency_s is the fixed per-transfer setup cost
    # (descriptor build, driver submission, completion interrupt) paid
    # once per direction regardless of size — the term that makes tiny
    # swaps more expensive than their byte count suggests.
    host_bw: float = 50e9          # bytes/s
    e_host_pj_per_byte: float = 60.0
    host_dma_latency_s: float = 50e-6
    # inter-pool link (the disaggregated prefill→decode KV handoff path):
    # ``link_bw`` above is the raw per-link bandwidth; e_link covers the
    # end-to-end byte move over the serdes + remote HBM touch, and
    # link_latency_s the fixed per-transfer setup cost (one RDMA/ICI
    # descriptor per handoff chunk).
    e_link_pj_per_byte: float = 20.0
    link_latency_s: float = 5e-6

    @property
    def flops(self) -> float:
        return self.n_chips * self.flops_per_chip * self.compute_eff

    @property
    def hbm_bw(self) -> float:
        return self.n_chips * self.hbm_bw_per_chip * self.mem_eff

    @property
    def ridge_op_per_byte(self) -> float:
        return self.flops_per_chip / self.hbm_bw_per_chip


# The paper's testbed: 2 × H100-80GB SXM, NVLink, tensor parallel.
# compute_eff / mem_eff calibrated against the paper's microbenchmarks
# (Fig. 2: chunk-512 hybrid iteration ≈ 30 ms on Qwen3-30B-A3B; Table 6:
# decode-batch ≈ 16–32 iterations ≈ 21–33 ms) — grouped-GEMM at ~64 tokens
# per expert plus per-layer TP all-reduce lands well under peak HBM bw.
# Energy constants are WHOLE-GPU (NVML-style, as the paper measures):
# e_hbm is the system-level energy per byte moved through the memory path
# (~150 W incremental per chip at full stream ≈ 100 pJ/B), not bare HBM
# cell energy; static covers idle+clocking.
H100X2 = HardwareSpec(
    name="h100x2", n_chips=2,
    flops_per_chip=989e12, hbm_bw_per_chip=3.35e12, link_bw=450e9,
    hbm_capacity_per_chip=80e9, static_power_w=150.0,
    e_hbm_pj_per_byte=100.0, e_flop_pj=0.4,
    compute_eff=0.55, mem_eff=0.50, iter_overhead_s=300e-6,
    block_overhead_s=300e-6,
    # 2 x PCIe gen5 x16 (~55 GB/s usable each) to host DRAM
    host_bw=110e9, e_host_pj_per_byte=60.0, host_dma_latency_s=50e-6,
    # NVLink pool-to-pool: cheap per byte, microsecond-class setup
    e_link_pj_per_byte=15.0, link_latency_s=3e-6,
)

# This repo's target: TPU v5e (constants mandated by the brief).
TPU_V5E = HardwareSpec(
    name="tpu_v5e", n_chips=1,
    flops_per_chip=197e12, hbm_bw_per_chip=819e9, link_bw=50e9,
    hbm_capacity_per_chip=16e9, static_power_w=90.0,
    e_hbm_pj_per_byte=6.0, e_flop_pj=0.45,
    # PCIe gen3-class host attach on v5e boards; runtime-mediated DMA
    # submission carries a higher fixed latency than the GPU driver path
    host_bw=16e9, e_host_pj_per_byte=80.0, host_dma_latency_s=100e-6,
    # ICI pool-to-pool: lower bandwidth than NVLink, runtime-mediated
    # descriptor submission carries a higher fixed latency
    e_link_pj_per_byte=25.0, link_latency_s=10e-6,
)


def kv_pool_pages(cfg: ModelConfig, hw: HardwareSpec, page_size: int = 16,
                  bytes_per_param: int = 2, bytes_per_act: int = 2,
                  util: float = 0.9) -> int:
    """Pages the hardware's HBM can dedicate to the paged KV pool: total
    capacity × ``util`` minus model weights, divided by the per-page KV
    footprint.  This is how the simulator (and a TPU deployment) sizes
    ``PagedKVAllocator`` so the paper-scale sweeps run under the SAME
    memory bound the engine would face."""
    cap = hw.n_chips * hw.hbm_capacity_per_chip * util
    weights = cfg.param_count() * bytes_per_param
    kv_per_page = max(cfg.kv_bytes_per_token(bytes_per_act), 1) * page_size
    pages = int((cap - weights) // kv_per_page)
    # models bigger than the modeled chip count would be sharded wider in
    # reality — keep the analytic pool at a 5%-of-capacity floor instead
    # of refusing to simulate
    floor = max(1, int(cap * 0.05 // kv_per_page))
    return max(pages, floor)


# Real routing is CORRELATED (tokens in a batch favour similar experts), so
# the uniform model overestimates mid-range coverage. We model this with an
# effective-token exponent n_eff = n^alpha; alpha = 0.785 is the minimax fit
# to the paper's measured Table 1 (Qwen3-30B-A3B on ShareGPT, <19% rel err
# at every batch size, exact at n=1). alpha=1.0 recovers uniform routing.
COVERAGE_CORRELATION_ALPHA = 0.785


def expected_coverage(n_experts: int, top_k: int, n_tokens: float,
                      alpha: float = COVERAGE_CORRELATION_ALPHA) -> float:
    """E[#unique experts] activated by n tokens routed top-k, under the
    Table-1-calibrated correlated-routing model."""
    if n_experts <= 0:
        return 0.0
    if n_tokens <= 0:
        return 0.0
    n_eff = n_tokens ** alpha
    return n_experts * (1.0 - (1.0 - top_k / n_experts) ** n_eff)


@dataclass
class BlockCost:
    flops: float = 0.0
    weight_bytes: float = 0.0
    kv_bytes: float = 0.0
    expert_bytes: float = 0.0      # subset of weight_bytes, tracked separately

    def add(self, o: "BlockCost") -> None:
        self.flops += o.flops
        self.weight_bytes += o.weight_bytes
        self.kv_bytes += o.kv_bytes
        self.expert_bytes += o.expert_bytes


class CostModel:
    """``moe_dispatch`` mirrors the engine's MoE data path:

    - "ragged" (default): expert GMM rows scale with the routed work
      (top_k per token) and expert weight traffic with the coverage
      expectation — the analytic twin of the ragged dropless pipeline
      (models/moe.py + kernels/moe_gmm_ragged.py).
    - "dense": the worst-case dropless capacity buffer — every expert
      computes a full (T, d) slab, so GMM flops carry an E/top_k
      amplification and ALL E experts' weights stream each pass.
    """

    def __init__(self, cfg: ModelConfig, hw: HardwareSpec,
                 bytes_per_param: int = 2, bytes_per_act: int = 2,
                 moe_dispatch: str = "ragged"):
        self.cfg = cfg
        self.hw = hw
        self.bp = bytes_per_param
        self.ba = bytes_per_act
        if moe_dispatch not in ("dense", "ragged"):
            raise ValueError(f"unknown moe_dispatch {moe_dispatch!r}")
        self.moe_dispatch = moe_dispatch
        self.specs = cfg.block_specs()
        # per-block static sizes
        self._attn_params = [cfg.attn_param_count(s) for s in self.specs]
        self._ffn_params = [cfg.ffn_param_count(s) for s in self.specs]
        self._expert_bytes = cfg.expert_bytes(bytes_per_param)
        e = cfg.moe
        # "dense" FFN traffic per block: full MLP for dense blocks; for MoE
        # blocks only the always-touched parts (router + shared experts).
        self._dense_ffn_bytes = []
        for i, s in enumerate(self.specs):
            if s.ffn == FFN_MOE:
                shared = e.n_shared_experts * 3 * cfg.d_model * e.shared_d_ff
                router = cfg.d_model * e.n_experts
                self._dense_ffn_bytes.append((shared + router) * bytes_per_param)
            else:
                self._dense_ffn_bytes.append(self._ffn_params[i] * bytes_per_param)
        self._kv_per_tok_block = (cfg.kv_bytes_per_token(bytes_per_act)
                                  / max(cfg.n_layers, 1))
        self._embed_bytes = cfg.vocab_size * cfg.d_model * bytes_per_param
        self._swap_cmp_cache: Dict[int, bool] = {}

        # -- vectorized per-block tables (iteration_cost hot path) ----------
        L = len(self.specs)
        self._np_attn_params = np.array(self._attn_params, float)
        self._np_dense_ffn_bytes = np.array(self._dense_ffn_bytes, float)
        self._np_is_moe = np.array([s.ffn == FFN_MOE for s in self.specs])
        # experts computed per routed token: top_k for the ragged pipeline,
        # ALL E for the dense dropless capacity buffer (empty slab rows are
        # still GEMMed)
        self._experts_per_tok = (e.top_k if moe_dispatch == "ragged"
                                 else e.n_experts)
        lin = np.zeros(L)
        for b, s_ in enumerate(self.specs):
            lin[b] = 2.0 * self._attn_params[b]
            if s_.ffn == FFN_MOE:
                lin[b] += 2.0 * (self._experts_per_tok * 3 * cfg.d_model
                                 * e.expert_d_ff
                                 + e.n_shared_experts * 3 * cfg.d_model
                                 * e.shared_d_ff + cfg.d_model * e.n_experts)
            else:
                lin[b] += 2.0 * self._ffn_params[b]
        self._np_lin_flops = lin                  # per-token matmul flops
        self._np_lin_cum = np.concatenate([[0.0], np.cumsum(lin)])
        # attention blocks grouped by window; prefix counts per group
        self._attn_groups = []                    # (window_or_None, prefix)
        wins = {}
        for b, s_ in enumerate(self.specs):
            if s_.is_attention():
                wins.setdefault(s_.window, []).append(b)
        for w, blks in wins.items():
            member = np.zeros(L)
            member[blks] = 1.0
            prefix = np.concatenate([[0.0], np.cumsum(member)])
            self._attn_groups.append((w, prefix))

    def block_prefill_costs(self, n_tokens: int = 512):
        """Per-block prefill weight-bytes at a reference token count — the
        weights for LayeredPrefillScheduler(block_costs=...) adaptive
        grouping (paper §7 future work)."""
        return [self.block_weight_bytes(b, n_tokens).weight_bytes
                for b in range(len(self.specs))]

    # -- per-block cost pieces ---------------------------------------------------

    def block_flops(self, b: int, n_tokens: float, ctx_len: float) -> float:
        """Matmul + attention flops for n_tokens new tokens attending over
        ctx_len context in block b."""
        cfg = self.cfg
        s = self.specs[b]
        f = 2.0 * n_tokens * self._attn_params[b]
        if s.ffn == FFN_MOE:
            e = cfg.moe
            active = (self._experts_per_tok * 3 * cfg.d_model * e.expert_d_ff
                      + e.n_shared_experts * 3 * cfg.d_model * e.shared_d_ff
                      + cfg.d_model * e.n_experts)
            f += 2.0 * n_tokens * active
        else:
            f += 2.0 * n_tokens * self._ffn_params[b]
        if s.is_attention():
            win = s.window
            eff_ctx = min(ctx_len, win) if win else ctx_len
            hd = cfg.head_dim_
            f += 4.0 * n_tokens * eff_ctx * cfg.n_heads * hd
        return f

    def block_weight_bytes(self, b: int, n_tokens: float) -> BlockCost:
        """Weight traffic for a block processing n_tokens (>=1 real tokens
        => all dense weights stream once; MoE experts by coverage)."""
        c = BlockCost()
        if n_tokens <= 0:
            return c
        cfg = self.cfg
        s = self.specs[b]
        c.weight_bytes += self._attn_params[b] * self.bp
        if s.ffn == FFN_MOE:
            e = cfg.moe
            cov = (expected_coverage(e.n_experts, e.top_k, n_tokens)
                   if self.moe_dispatch == "ragged" else float(e.n_experts))
            c.expert_bytes = cov * self._expert_bytes
            c.weight_bytes += c.expert_bytes + self._dense_ffn_bytes[b]
        else:
            c.weight_bytes += self._dense_ffn_bytes[b]
        return c

    def moe_gmm_cost(self, n_tokens: float, dispatch: Optional[str] = None,
                     m_blk: Optional[int] = None) -> Dict[str, float]:
        """Modeled cost of ONE MoE block's routed-expert GMM at n_tokens.

        ragged: rows = routed assignments (top_k per token) plus expected
        tile-alignment padding (~half an m_blk row tile per active expert),
        with m_blk defaulting to the tile size the runtime dispatch would
        pick (models.moe.ragged_tile_rows — small tiles at decode scale);
        weight traffic = active_experts × bytes_per_expert — exactly the
        engine's ``expert_load_bytes`` counter and what the scalar-prefetch
        kernel streams. dense: the dropless worst-case capacity buffer —
        E × n_tokens rows, all E experts' weights.

        act_bytes covers the GMM row buffer (read + write) plus the
        dispatch gather / weighted combine on the (T·k, d) assignments."""
        dispatch = dispatch or self.moe_dispatch
        cfg = self.cfg
        e = cfg.moe
        if not e.enabled or n_tokens <= 0:
            return {"rows": 0.0, "flops": 0.0, "weight_bytes": 0.0,
                    "act_bytes": 0.0, "active_experts": 0.0}
        routed = n_tokens * e.top_k
        if m_blk is None:
            # lazy import: models.moe pulls jax, which the analytic model
            # otherwise never needs
            from repro.models.moe import ragged_tile_rows
            m_blk, _ = ragged_tile_rows(int(routed), e.n_experts)
        cov = expected_coverage(e.n_experts, e.top_k, n_tokens)
        if dispatch == "ragged":
            rows = routed + cov * (m_blk - 1) / 2.0
            weight_bytes = cov * self._expert_bytes
        else:
            rows = float(e.n_experts) * n_tokens
            weight_bytes = float(e.n_experts) * self._expert_bytes
        flops = 2.0 * rows * 3.0 * cfg.d_model * e.expert_d_ff
        act_bytes = (2.0 * rows + 2.0 * routed) * cfg.d_model * self.ba
        return {"rows": rows, "flops": flops, "weight_bytes": weight_bytes,
                "act_bytes": act_bytes, "active_experts": cov}

    Q_TILE = 256  # flash-attention query tile: K/V streams once per tile

    def block_kv_bytes(self, b: int, n_new: float, ctx_len: float) -> float:
        """KV-cache read traffic for attention over ``ctx_len`` context.
        FlashAttention streams the block's K/V once per query TILE, not per
        query token (decode: n_new=1 -> one pass over the context)."""
        s = self.specs[b]
        if not s.is_attention():
            return 0.0
        eff = min(ctx_len, s.window) if s.window else ctx_len
        passes = max(1.0, n_new / self.Q_TILE)
        return passes * eff * self._kv_per_tok_block

    # -- swap-vs-recompute pricing ---------------------------------------------

    def kv_swap_bytes(self, n_tokens: float) -> float:
        """Bytes moved over the host link to swap ``n_tokens`` of KV one
        direction (the block-table metadata is noise at page granularity)."""
        return n_tokens * self.cfg.kv_bytes_per_token(self.ba)

    def swap_transfer(self, n_tokens: float) -> Dict[str, float]:
        """Time/energy to move ``n_tokens`` of KV across the host link in
        ONE direction (swap-out and swap-in each pay this once): a fixed
        DMA setup latency plus the byte stream."""
        b = self.kv_swap_bytes(n_tokens)
        return {"bytes": b,
                "duration": self.hw.host_dma_latency_s + b / self.hw.host_bw,
                "energy": b * self.hw.e_host_pj_per_byte * 1e-12}

    def link_transfer(self, n_tokens: float) -> Dict[str, float]:
        """Time/energy to stream ``n_tokens`` of KV over the inter-pool
        link (the disaggregated prefill→decode handoff): a fixed setup
        latency per transfer plus the byte stream at ``link_bw``.  The
        simulator overlaps this against remaining prefill compute and only
        charges ``stall = max(0, transfer − remaining_compute)``."""
        b = self.kv_swap_bytes(n_tokens)
        return {"bytes": b,
                "duration": self.hw.link_latency_s + b / self.hw.link_bw,
                "energy": b * self.hw.e_link_pj_per_byte * 1e-12}

    def recompute_cost(self, n_tokens: int) -> Dict[str, float]:
        """Cost of re-running a full-stack prefill over ``n_tokens`` — what
        a recompute-restored victim pays instead of the DMA-back.  Priced
        as a dedicated iteration (fixed overheads + full weight stream):
        the worst case, but the one the oversubscribed regime approaches
        as recompute epochs stop overlapping with other work."""
        plan = IterationPlan(prefill=[PrefillSlice(
            req_id=-1, token_start=0, token_end=int(n_tokens),
            block_start=0, block_end=len(self.specs),
            emits_first_token=True)])
        return self.iteration_cost(plan, {})

    def swap_beats_recompute(self, n_tokens: int) -> bool:
        """True iff the swap round-trip (DMA out + back, each paying the
        fixed setup latency) is cheaper in time than recomputing the
        victim's prefill — the per-victim crossover the "auto" preemption
        mode evaluates.  Both sides carry a fixed term (2x DMA setup vs
        iteration + per-block overheads) and a linear term (KV bytes over
        the host link vs prefill flops + weight re-stream).  On the
        shipped calibrations the recompute side's fixed cost and — for
        MoE models — the expert re-stream dominate, so swap wins from the
        smallest contexts up; the hook earns its keep on calibrations
        with fatter recompute batches or thinner host links (memoized:
        the pressure pass may evaluate it per victim per iteration)."""
        if n_tokens <= 0:
            return False
        hit = self._swap_cmp_cache.get(n_tokens)
        if hit is None:
            swap = 2.0 * self.swap_transfer(n_tokens)["duration"]
            hit = swap < self.recompute_cost(n_tokens)["duration"]
            if len(self._swap_cmp_cache) < 65536:
                self._swap_cmp_cache[n_tokens] = hit
        return hit

    # -- iteration-level costs ------------------------------------------------------

    def iteration_cost(self, plan: IterationPlan,
                       requests: Dict[int, Request]) -> Dict[str, float]:
        """Aggregate flops/bytes for one iteration. Per block, weight traffic
        is charged ONCE for the union of work touching it (fused hybrid
        batch semantics — same union rule as the engine's real counter).
        Fully vectorized over blocks (the simulator calls this per
        iteration for hundreds of thousands of iterations)."""
        cfg = self.cfg
        L = len(self.specs)
        hd4 = 4.0 * cfg.n_heads * cfg.head_dim_
        tokens_per_block = np.zeros(L)
        flops = 0.0
        kv_bytes = 0.0

        n_dec = len(plan.decode_ids)
        q_tokens = 0.0
        if n_dec:
            # speculative verify-k widens the decode query per request to
            # w_i = 1 + k_i tokens (plan.verify_len).  The acceptance
            # amortization is structural: the extra query tokens join
            # tokens_per_block, so each touched block's weight stream —
            # and, for MoE, its expert coverage — is charged ONCE for the
            # whole window instead of once per committed token.
            ws = np.array([1.0 + plan.verify_len.get(r, 0)
                           for r in plan.decode_ids], float)
            q_tokens = float(ws.sum())
            tokens_per_block += q_tokens
            flops += q_tokens * self._np_lin_cum[L]
            # true KV length: the recompute prompt already contains the
            # n_folded generated tokens of any earlier preemption
            ctxs = np.array([requests[r].prompt_len + requests[r].n_generated
                             - requests[r].n_folded
                             for r in plan.decode_ids], float)
            for w, prefix in self._attn_groups:
                cnt = prefix[L]
                eff = np.minimum(ctxs, w) if w else ctxs
                # one KV pass per row regardless of window width (w_i <=
                # k+1 << Q_TILE); attention flops scale with the width
                total_eff = float(eff.sum())
                kv_bytes += cnt * total_eff * self._kv_per_tok_block
                flops += cnt * hd4 * float((ws * eff).sum())

        act_bytes = 0.0
        for sl in plan.prefill:
            b0, b1, n = sl.block_start, sl.block_end, sl.n_tokens
            ctx0 = sl.token_start
            tokens_per_block[b0:b1] += n
            flops += n * (self._np_lin_cum[b1] - self._np_lin_cum[b0])
            for w, prefix in self._attn_groups:
                cnt = prefix[b1] - prefix[b0]
                if not cnt:
                    continue
                ctx_f = ctx0 + n / 2.0          # avg ctx for flops
                ctx_kv = ctx0 + n               # full ctx for kv stream
                eff_f = min(ctx_f, w) if w else ctx_f
                eff_kv = min(ctx_kv, w) if w else ctx_kv
                flops += cnt * hd4 * n * eff_f
                passes = max(1.0, n / self.Q_TILE)
                kv_bytes += cnt * passes * eff_kv * self._kv_per_tok_block
            # boundary activation stash write+read (layered-specific traffic)
            if b0 > 0:
                act_bytes += n * cfg.d_model * self.ba
            if b1 < L:
                act_bytes += n * cfg.d_model * self.ba

        touched = tokens_per_block > 0
        weight_bytes = float(
            ((self._np_attn_params * self.bp + self._np_dense_ffn_bytes)
             * touched).sum())
        e = cfg.moe
        if e.enabled:
            if self.moe_dispatch == "ragged":
                n_eff = np.where(self._np_is_moe & touched,
                                 np.maximum(tokens_per_block, 1e-9), 0.0) \
                    ** COVERAGE_CORRELATION_ALPHA
                cov = e.n_experts * (1.0
                                     - (1.0 - e.top_k / e.n_experts) ** n_eff)
                cov = np.where(self._np_is_moe & touched, cov, 0.0)
            else:
                # dense dropless buffer GEMMs (and streams) every expert
                cov = np.where(self._np_is_moe & touched,
                               float(e.n_experts), 0.0)
            expert_bytes = float(cov.sum()) * self._expert_bytes
        else:
            expert_bytes = 0.0
        weight_bytes += expert_bytes

        emits = sum(1 for s_ in plan.prefill if s_.emits_first_token)
        if n_dec + emits > 0:
            weight_bytes += self._embed_bytes          # unembedding stream
            # every verify-window position is argmaxed, not just the last
            flops += 2.0 * (q_tokens + emits) * self._embed_bytes / self.bp

        total_bytes = weight_bytes + kv_bytes + act_bytes
        t_compute = flops / self.hw.flops
        t_memory = total_bytes / self.hw.hbm_bw
        blocks_touched = int(touched.sum())
        duration = (max(t_compute, t_memory) + self.hw.iter_overhead_s
                    + blocks_touched * self.hw.block_overhead_s)
        energy = (duration * self.hw.static_power_w * self.hw.n_chips
                  + total_bytes * self.hw.e_hbm_pj_per_byte * 1e-12
                  + flops * self.hw.e_flop_pj * 1e-12)
        return {
            "duration": duration,
            "flops": flops,
            "hbm_bytes": total_bytes,
            "weight_bytes": weight_bytes,
            "expert_bytes": expert_bytes,
            "kv_bytes": kv_bytes,
            "energy": energy,
            "bound": "compute" if t_compute >= t_memory else "memory",
        }
