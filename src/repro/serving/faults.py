"""Deterministic fault injection + graceful degradation for the serving
stack (DESIGN.md §Fault tolerance).

A ``FaultPlan`` is a seeded, replayable schedule of failure events — the
chaos-side mirror of the seeded traffic generators in
``serving/traffic.py``: same plan, same trace, same tokens.  The
``FaultInjector`` consumes the plan one iteration at a time; the serving
runtimes poll it at well-defined points in the loop and translate each
event into the recovery machinery that already exists (eviction +
recompute, swap demotion, migration re-routing), so every injected fault
exercises a path a real fault would take.

Fault taxonomy (``FaultEvent.kind``):

  executor_crash    — the executor step raises: every PREFILL/DECODE
                      resident is evicted and recovered by recompute
                      (SWAPPED victims keep their host copy).  In the
                      disaggregated runtime ``target`` picks the pool
                      (0 = prefill, 1 = decode).
  link_drop         — a queued inter-pool migration's payload is lost;
                      the victim is folded and re-queued on the prefill
                      pool (whole-prompt retry) — never lost.
  link_delay        — a latency spike: ``magnitude`` is added to every
                      queued migration's ready_time.
  swap_dma_fail     — this iteration's swap-out DMA fails; the victims
                      demote to recompute evictions
                      (``Scheduler.fail_swap_out``).
  pressure_spike    — ``magnitude`` pages are phantom-reserved for
                      ``duration`` iterations, forcing the allocator
                      pressure/eviction path under an otherwise-fitting
                      load.
  client_disconnect — the ``target``-th lowest live request id is
                      cancelled mid-stream (the runtime sheds it and
                      frees all its KV).

Events whose preconditions are absent (no swap activity, empty link
queue, no residents) stay ARMED: they fire at the first iteration >= the
scheduled one where the precondition holds, so a seeded plan composes
deterministically with any trace.

The ``DegradationLadder`` turns sustained fault/overload pressure into
staged capability shedding — shrink spec-k, disable speculation, shed
batch-class work, refuse interactive admissions — and restores rungs in
reverse once pressure clears.  Every rung only toggles knobs that are
token-identical by construction (speculation is bit-identical to greedy;
shedding removes streams but never alters surviving ones).
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional

import numpy as np

FAULT_KINDS = ("executor_crash", "link_drop", "link_delay",
               "swap_dma_fail", "pressure_spike", "client_disconnect")


class InjectedFault(RuntimeError):
    """Base of every injector-raised failure (lets supervision code tell
    a scheduled chaos event from an organic bug)."""


class ExecutorCrash(InjectedFault):
    """Injected executor-step exception; the runtime recovers by evicting
    residents into the recompute path."""


@dataclass(frozen=True)
class FaultEvent:
    iteration: int          # earliest iteration this event may fire
    kind: str
    magnitude: float = 1.0  # link_delay: clock units; pressure_spike: pages
    duration: int = 0       # pressure_spike: iterations the phantom holds
    target: int = 0         # pool index (executor_crash) / k-th live rid

    def __post_init__(self):
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"known: {list(FAULT_KINDS)}")
        if self.iteration < 0:
            raise ValueError(f"iteration must be >= 0, got {self.iteration}")


@dataclass
class FaultPlan:
    """A replayable fault schedule.  ``events`` is kept sorted by
    (iteration, kind, target) so plans built from sets/dicts/JSON all
    inject identically."""

    events: List[FaultEvent] = field(default_factory=list)
    seed: Optional[int] = None

    def __post_init__(self):
        self.events = sorted(self.events,
                             key=lambda e: (e.iteration, e.kind, e.target))

    # -- construction -------------------------------------------------------

    @classmethod
    def from_seed(cls, seed: int, *, horizon: int = 200,
                  n_events: int = 8,
                  kinds: Optional[List[str]] = None) -> "FaultPlan":
        """Draw ``n_events`` events uniformly over ``[1, horizon)`` from a
        seeded rng — the chaos analogue of the seeded traffic traces."""
        rng = np.random.default_rng(seed)
        kinds = list(kinds or FAULT_KINDS)
        events = []
        for _ in range(n_events):
            kind = kinds[int(rng.integers(0, len(kinds)))]
            events.append(FaultEvent(
                iteration=int(rng.integers(1, max(horizon, 2))),
                kind=kind,
                magnitude=float(rng.integers(1, 4)),
                duration=int(rng.integers(1, 6)),
                target=int(rng.integers(0, 3))))
        return cls(events=events, seed=seed)

    # -- (de)serialization --------------------------------------------------

    def to_json(self) -> str:
        return json.dumps({"seed": self.seed,
                           "events": [asdict(e) for e in self.events]},
                          indent=2, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        data = json.loads(text)
        known = {"seed", "events"}
        extra = set(data) - known
        if extra:
            raise ValueError(f"unknown FaultPlan fields: {sorted(extra)}")
        return cls(events=[FaultEvent(**e) for e in data.get("events", [])],
                   seed=data.get("seed"))

    @classmethod
    def load(cls, spec: str) -> "FaultPlan":
        """Resolve a CLI-style plan spec: ``@path`` reads a JSON file,
        ``seed:<n>`` draws a seeded plan, anything else parses as inline
        JSON."""
        spec = spec.strip()
        if spec.startswith("@"):
            with open(spec[1:], "r", encoding="utf-8") as f:
                return cls.from_json(f.read())
        if spec.startswith("seed:"):
            return cls.from_seed(int(spec[len("seed:"):]))
        return cls.from_json(spec)


class FaultInjector:
    """Consumes a ``FaultPlan`` against a runtime's iteration counter.

    The runtimes poll ``due(kind, iteration)`` at the loop point where
    that kind can be acted on; undrawn events stay armed, so an event
    scheduled for a quiet iteration fires at the next opportunity.
    ``counters`` accumulates per-kind injection counts for metrics."""

    def __init__(self, plan: FaultPlan):
        self.plan = plan
        self._pending: List[FaultEvent] = list(plan.events)
        self.counters: Dict[str, int] = {f"n_{k}": 0 for k in FAULT_KINDS}
        # live phantom pressure reservations: (release_iteration, rid)
        self._pressure: List[tuple] = []
        self._next_phantom = -1

    def exhausted(self) -> bool:
        return not self._pending and not self._pressure

    def armed(self, kind: str) -> int:
        return sum(1 for e in self._pending if e.kind == kind)

    def due(self, kind: str, iteration: int) -> List[FaultEvent]:
        """Pop (and count) every armed ``kind`` event scheduled at or
        before ``iteration``."""
        fired = [e for e in self._pending
                 if e.kind == kind and e.iteration <= iteration]
        if fired:
            self._pending = [e for e in self._pending if e not in fired]
            self.counters[f"n_{kind}"] += len(fired)
        return fired

    def maybe_crash(self, iteration: int, *, pool: Optional[int] = None,
                    active: bool = True) -> None:
        """Raise ``ExecutorCrash`` when an executor_crash event is due for
        this pool (``target`` 0 = prefill, >0 = decode; ``pool=None``
        matches any) AND the pool has residents to fail — otherwise the
        event stays armed for the next opportunity.  Raised BEFORE the
        scheduler plans, so recovery is exactly an eviction: no plan's
        bookkeeping has run against state that never executed."""
        if not active:
            return
        for e in self._pending:
            if e.kind != "executor_crash" or e.iteration > iteration:
                continue
            if pool is not None and min(e.target, 1) != pool:
                continue
            self._pending.remove(e)
            self.counters["n_executor_crash"] += 1
            raise ExecutorCrash(
                f"injected executor crash (scheduled it={e.iteration}, "
                f"fired it={iteration})")

    # -- allocator pressure spikes -----------------------------------------

    def apply_pressure(self, kvs, iteration: int) -> None:
        """Fire due pressure_spike events: phantom-reserve up to
        ``magnitude`` free pages (on the ``target``-th allocator of
        ``kvs``) under a synthetic negative request id, released after
        ``duration`` iterations — and unconditionally by
        ``release_pressure(None)`` at run end, so the zero-leak invariant
        is preserved by construction."""
        kvs = [kv for kv in kvs if kv is not None]
        if not kvs:
            return
        for ev in self.due("pressure_spike", iteration):
            kv = kvs[ev.target % len(kvs)]
            pages = min(int(ev.magnitude), kv.n_free_pages)
            if pages <= 0:
                continue
            rid = self._next_phantom
            self._next_phantom -= 1
            kv.reserve(rid, pages * kv.page_size)
            self._pressure.append((iteration + max(ev.duration, 1), rid, kv))

    def release_pressure(self, iteration: Optional[int]) -> None:
        """Release phantom reservations due by ``iteration`` (None = all,
        the end-of-run sweep)."""
        keep = []
        for rel_it, rid, kv in self._pressure:
            if iteration is None or rel_it <= iteration:
                if kv.owns(rid):
                    kv.free(rid)
            else:
                keep.append((rel_it, rid, kv))
        self._pressure = keep


# -- graceful degradation ----------------------------------------------------

DEGRADATION_LEVELS = ("normal", "spec_shrunk", "spec_off",
                      "shed_batch", "interactive_503")


class DegradationLadder:
    """Staged capability shedding under sustained fault/overload pressure.

    Callers ``record_pressure()`` on every recovery action (fault
    eviction, link drop, swap-DMA failure, deadline shed) and ``step()``
    once per iteration.  When >= ``trip`` pressure events land within
    ``window`` iterations the ladder climbs one rung; after ``cool``
    quiet iterations it descends one.  Rungs:

      normal          — full service.
      spec_shrunk     — speculative k halved on every attached scheduler
                        (fewer wasted verify tokens under churn).
      spec_off        — speculation disabled outright.
      shed_batch      — batch-class requests are shed on sight (the
                        runtime consults ``shed_class``).
      interactive_503 — the front-end refuses new work
                        (``refuse_new`` -> HTTP 503 / not ready).

    Speculation toggles are bit-identity-safe: spec decode emits the same
    greedy stream regardless of k (DESIGN.md §Speculative decode)."""

    def __init__(self, schedulers=(), *, trip: int = 3, window: int = 8,
                 cool: int = 16):
        self.schedulers = list(schedulers)
        self.trip = trip
        self.window = window
        self.cool = cool
        self.level_index = 0
        self.n_escalations = 0
        self.n_deescalations = 0
        self._events: List[int] = []     # pressure iterations (recent)
        self._last_pressure = -1
        self._last_change = -1
        self._saved = [(s.spec_mode, s.spec_k, s.spec_adaptive)
                       for s in self.schedulers]

    @property
    def level(self) -> str:
        return DEGRADATION_LEVELS[self.level_index]

    @property
    def shed_batch(self) -> bool:
        return self.level_index >= DEGRADATION_LEVELS.index("shed_batch")

    @property
    def refuse_new(self) -> bool:
        return self.level_index >= DEGRADATION_LEVELS.index("interactive_503")

    def shed_class(self, slo_class: str) -> bool:
        return self.shed_batch and slo_class == "batch"

    def record_pressure(self, iteration: int) -> None:
        self._events.append(iteration)
        self._last_pressure = max(self._last_pressure, iteration)

    def step(self, iteration: int) -> None:
        """Advance the ladder: escalate when the recent-pressure window
        trips, de-escalate after a quiet cool-down.  At most one rung per
        call, and never twice for the same window (``_last_change``)."""
        self._events = [t for t in self._events
                        if t > iteration - self.window]
        if (len(self._events) >= self.trip
                and self.level_index < len(DEGRADATION_LEVELS) - 1
                and iteration > self._last_change):
            self.level_index += 1
            self.n_escalations += 1
            self._last_change = iteration
            self._events.clear()
            self._apply()
        elif (self.level_index > 0
                and iteration - max(self._last_pressure,
                                    self._last_change) >= self.cool):
            self.level_index -= 1
            self.n_deescalations += 1
            self._last_change = iteration
            self._apply()

    def _apply(self) -> None:
        """Impose the current rung's speculation posture on every attached
        scheduler; descending below spec_shrunk restores the saved
        configuration verbatim."""
        for s, (mode, k, adaptive) in zip(self.schedulers, self._saved):
            if mode == "off":
                continue
            if self.level == "normal":
                s.configure_speculation(mode, k, adaptive)
            elif self.level == "spec_shrunk":
                s.configure_speculation(mode, max(1, k // 2), adaptive)
            else:                         # spec_off and every rung above
                s.configure_speculation("off")
