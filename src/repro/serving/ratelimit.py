"""Per-tenant admission rate limiting for the HTTP front-end.

Classic token bucket: a tenant's bucket holds up to ``burst`` tokens and
refills continuously at ``rate`` tokens/second; each accepted request
spends ``cost`` tokens.  An empty bucket answers with the EXACT number of
seconds until the requested cost will have refilled — the server forwards
that as the 429 ``Retry-After`` header, so well-behaved clients back off
precisely instead of hammering.

The clock is injectable (``clock=lambda: t``) so the refill law is
property-testable deterministically: over ANY acquire sequence spanning
``T`` seconds, a bucket can never grant more than ``burst + rate * T``
tokens — the conservation invariant tests/test_server.py sweeps.

Thread-safety: buckets are mutated under one lock per limiter.  The HTTP
server calls ``acquire`` from asyncio callbacks while metric scrapes read
counters from other threads; everything stays consistent without the
serving loop ever blocking on the limiter.
"""

from __future__ import annotations

import threading
import time
from typing import Callable, Dict, Optional


class TokenBucket:
    """One tenant's bucket.  Not locked — ``TenantRateLimiter`` serializes
    access; standalone use from a single thread is fine."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        if rate <= 0 or burst <= 0:
            raise ValueError(f"rate and burst must be positive "
                             f"(got rate={rate}, burst={burst})")
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._tokens = float(burst)          # start full: bursts up front
        self._t_last = clock()
        self.n_granted = 0
        self.n_rejected = 0

    def _refill(self, now: float) -> None:
        # monotonic clocks can still tie; never move backwards
        dt = max(0.0, now - self._t_last)
        self._t_last = now
        self._tokens = min(self.burst, self._tokens + dt * self.rate)

    def acquire(self, cost: float = 1.0) -> float:
        """Try to spend ``cost`` tokens NOW.  Returns 0.0 on success, else
        the seconds until the deficit will have refilled (retry-after)."""
        if cost > self.burst:
            raise ValueError(
                f"cost {cost} can never fit burst {self.burst}")
        self._refill(self._clock())
        if self._tokens >= cost:
            self._tokens -= cost
            self.n_granted += 1
            return 0.0
        self.n_rejected += 1
        return (cost - self._tokens) / self.rate

    @property
    def available(self) -> float:
        self._refill(self._clock())
        return self._tokens


class TenantRateLimiter:
    """Lazy per-tenant bucket map with one shared (rate, burst) policy.
    ``acquire(tenant)`` returns 0.0 (admitted) or retry-after seconds;
    unknown tenants get a fresh full bucket on first sight, so the limiter
    needs no tenant pre-registration."""

    def __init__(self, rate: float, burst: float,
                 clock: Callable[[], float] = time.monotonic):
        self.rate = float(rate)
        self.burst = float(burst)
        self._clock = clock
        self._lock = threading.Lock()
        self._buckets: Dict[str, TokenBucket] = {}

    def acquire(self, tenant: str, cost: float = 1.0) -> float:
        with self._lock:
            bucket = self._buckets.get(tenant)
            if bucket is None:
                bucket = self._buckets[tenant] = TokenBucket(
                    self.rate, self.burst, self._clock)
            return bucket.acquire(cost)

    def bucket(self, tenant: str) -> Optional[TokenBucket]:
        with self._lock:
            return self._buckets.get(tenant)

    def counters(self) -> Dict[str, Dict[str, float]]:
        """Per-tenant grant/reject counters for the /metrics exporter."""
        with self._lock:
            return {t: {"granted": float(b.n_granted),
                        "rejected": float(b.n_rejected)}
                    for t, b in self._buckets.items()}
