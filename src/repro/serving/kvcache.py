"""Paged KV-cache memory subsystem.

A single global pool of fixed-size *pages* (``page_size`` KV tokens each)
backs every resident request.  Each request owns a *block table* — the
ordered list of physical page ids holding its KV — which grows
page-granularly as decode appends tokens.  The same allocator instance is
shared by the scheduler (admission / preemption decisions), the execution
engine (physical placement + the paged Pallas decode kernel's block
tables) and the discrete-event simulator (page occupancy, preemption and
recompute accounting in the paper-scale sweeps).  See DESIGN.md
§Hardware adaptation for how the logical page pool maps onto TPU-friendly
physical layouts.

Memory charged against the pool:

  * KV reservations — admission reserves ``prompt_len + decode_reserve``
    tokens up front (the scheduler admits only when this fits), so prefill
    never fails mid-flight; decode growth past the reservation allocates
    pages on demand and is what creates *pressure*.
  * Layered-prefill stash — boundary activations carried between layer
    groups are charged as ``stash_factor`` KV-token-equivalents per
    stashed token (``stash_factor ≈ d_model·bytes_act /
    kv_bytes_per_token``) and released when the request's prefill
    completes.

A second, host-side page pool (``n_host_pages``) backs **swap-to-host
preemption**: ``swap_out`` moves a resident request's KV pages to host
pages wholesale (the block table is remembered on the host side, in
logical order), freeing HBM; ``swap_in`` is the DMA-back — it claims fresh
HBM pages and releases the host copy, after which decode resumes with the
KV intact (no recompute epoch).  Host pages are accounted exactly like HBM
pages: a swapped request owns its host pages until swap-in or ``free``.

The allocator never decides WHO to evict — victim selection
(latest-arrival-first) lives in ``core.base.Scheduler``; the allocator
only enforces that nobody allocates pages it does not have.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List


class PagedPoolExhausted(RuntimeError):
    """Raised when an allocation is attempted against an empty pool.

    Under pressure-aware admission + preemption this never surfaces: the
    scheduler checks ``can_admit``/``growth_deficit`` (and preempts) before
    any page is claimed.  It CAN surface when preemption is disabled and
    decode growth outruns the reservation."""


@dataclass
class PagedKVAllocator:
    n_pages: int
    page_size: int = 16
    # KV-token-equivalents charged per stashed boundary-activation token
    # (layered prefill's carry state); callers derive it from the model's
    # d_model / kv_bytes_per_token ratio.
    stash_factor: float = 1.0
    # host-side page pool for swap-to-host preemption (0 = swap disabled)
    n_host_pages: int = 0
    _free: List[int] = field(default_factory=list)
    _tables: Dict[int, List[int]] = field(default_factory=dict)  # req -> pages
    _lengths: Dict[int, int] = field(default_factory=dict)       # req -> toks
    _stash: Dict[int, List[int]] = field(default_factory=dict)   # req -> pages
    _host_free: List[int] = field(default_factory=list)
    _host_tables: Dict[int, List[int]] = field(default_factory=dict)
    # speculative pre-charge: req -> table size before reserve_spec
    _spec_base: Dict[int, int] = field(default_factory=dict)
    pages_high_water: int = 0
    host_pages_high_water: int = 0
    n_grow_allocs: int = 0
    # swap traffic accounting (cumulative, in KV tokens moved per direction)
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    swapped_out_tokens: int = 0
    swapped_in_tokens: int = 0

    def __post_init__(self):
        assert self.n_pages > 0 and self.page_size > 0
        assert self.n_host_pages >= 0
        self._free = list(range(self.n_pages))[::-1]
        self._host_free = list(range(self.n_host_pages))[::-1]

    # -- sizing --------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(max(n_tokens, 0) / self.page_size)

    def stash_pages_for(self, n_tokens: int) -> int:
        return self.pages_for(math.ceil(n_tokens * self.stash_factor))

    @property
    def n_free_pages(self) -> int:
        return len(self._free)

    def pages_in_use(self) -> int:
        return self.n_pages - len(self._free)

    @property
    def n_free_host_pages(self) -> int:
        return len(self._host_free)

    def host_pages_in_use(self) -> int:
        return self.n_host_pages - len(self._host_free)

    # -- admission queries ---------------------------------------------------

    def can_admit(self, n_tokens: int, stash_tokens: int = 0,
                  headroom_pages: int = 0) -> bool:
        """True iff a reservation for ``n_tokens`` of KV plus the stash
        charge fits the pool RIGHT NOW, leaving ``headroom_pages`` free
        (the scheduler's per-SLO-class admission reserve)."""
        need = self.pages_for(n_tokens) + self.stash_pages_for(stash_tokens)
        return need + headroom_pages <= len(self._free)

    def fits_pool(self, n_tokens: int, stash_tokens: int = 0,
                  headroom_pages: int = 0) -> bool:
        """True iff the request could EVER fit (empty pool minus the
        caller's headroom reserve)."""
        need = self.pages_for(n_tokens) + self.stash_pages_for(stash_tokens)
        return need + headroom_pages <= self.n_pages

    # -- request lifecycle ---------------------------------------------------

    def owns(self, req_id: int) -> bool:
        """True iff ``req_id`` holds pages in EITHER pool (resident or
        swapped) — i.e. ``free`` has something to release."""
        return req_id in self._tables or req_id in self._host_tables

    def is_resident(self, req_id: int) -> bool:
        return req_id in self._tables

    def is_swapped(self, req_id: int) -> bool:
        return req_id in self._host_tables

    def reserve(self, req_id: int, n_tokens: int,
                stash_tokens: int = 0) -> None:
        """Admission-time reservation: claims pages for ``n_tokens`` of KV
        (prompt + decode reservation) and the stash charge."""
        assert req_id not in self._tables, req_id
        need_kv = self.pages_for(n_tokens)
        need_stash = self.stash_pages_for(stash_tokens)
        if need_kv + need_stash > len(self._free):
            raise PagedPoolExhausted(
                f"reserve({req_id}): need {need_kv + need_stash} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        self._tables[req_id] = [self._free.pop() for _ in range(need_kv)]
        self._stash[req_id] = [self._free.pop() for _ in range(need_stash)]
        self._lengths[req_id] = 0
        self._bump_high_water()

    def set_length(self, req_id: int, n_tokens: int) -> None:
        """Record the filled KV length (monotone); never allocates."""
        assert n_tokens <= len(self._tables[req_id]) * self.page_size, \
            (req_id, n_tokens)
        self._lengths[req_id] = max(self._lengths[req_id], n_tokens)

    def growth_deficit(self, req_id: int, n_tokens: int) -> int:
        """Pages that must be newly allocated for the block table to cover
        ``n_tokens`` (0 when the reservation already covers it)."""
        return max(0, self.pages_for(n_tokens) - len(self._tables[req_id]))

    def grow_to(self, req_id: int, n_tokens: int) -> None:
        """Page-granular grow-on-write: extend the block table to cover
        ``n_tokens``.  Raises PagedPoolExhausted when the pool is dry — the
        scheduler's pressure pass preempts before letting that happen."""
        deficit = self.growth_deficit(req_id, n_tokens)
        if deficit > len(self._free):
            raise PagedPoolExhausted(
                f"grow_to({req_id}, {n_tokens}): need {deficit} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        for _ in range(deficit):
            self._tables[req_id].append(self._free.pop())
            self.n_grow_allocs += 1
        self._lengths[req_id] = max(self._lengths[req_id], n_tokens)
        if deficit:
            self._bump_high_water()

    def release_stash(self, req_id: int) -> None:
        self._free.extend(reversed(self._stash.pop(req_id, [])))
        self._stash[req_id] = []

    # -- speculative decode reservations --------------------------------------
    #
    # Verify-k decoding may commit anywhere from 1 to 1+k tokens per
    # iteration.  The scheduler pre-charges pages for the FULL window
    # (``reserve_spec``) without recording a filled length — the length is
    # only known post-verification — and the executor's commit trims the
    # table back to the accepted length (``release_spec``), so a rejected
    # draft leaves no page behind.  Speculation is opportunistic: it never
    # evicts anybody, it just shrinks k when the pool is dry.

    def reserve_spec(self, req_id: int, n_tokens: int) -> None:
        """Pre-charge pages so the block table covers ``n_tokens`` WITHOUT
        recording a filled length.  Remembers the pre-speculation table
        size so ``release_spec`` can trim back exactly."""
        assert req_id in self._tables, req_id
        if req_id not in self._spec_base:
            self._spec_base[req_id] = len(self._tables[req_id])
        deficit = self.growth_deficit(req_id, n_tokens)
        if deficit > len(self._free):
            raise PagedPoolExhausted(
                f"reserve_spec({req_id}, {n_tokens}): need {deficit} pages, "
                f"{len(self._free)} free of {self.n_pages}")
        for _ in range(deficit):
            self._tables[req_id].append(self._free.pop())
        if deficit:
            self._bump_high_water()

    def release_spec(self, req_id: int) -> None:
        """Trim the speculative pre-charge back to what the committed
        length (set via ``grow_to``/``set_length`` since) actually needs —
        never below the pre-speculation table size.  No-op for requests
        without an outstanding ``reserve_spec``."""
        base = self._spec_base.pop(req_id, None)
        if base is None or req_id not in self._tables:
            return
        keep = max(base, self.pages_for(self._lengths[req_id]))
        table = self._tables[req_id]
        while len(table) > keep:
            self._free.append(table.pop())

    def has_spec_reservation(self, req_id: int) -> bool:
        return req_id in self._spec_base

    def free(self, req_id: int) -> None:
        """Return every page (KV + stash, HBM or host) of ``req_id``."""
        assert self.owns(req_id), req_id
        self._free.extend(reversed(self._tables.pop(req_id, [])))
        self._free.extend(reversed(self._stash.pop(req_id, [])))
        self._host_free.extend(reversed(self._host_tables.pop(req_id, [])))
        self._lengths.pop(req_id, None)
        self._spec_base.pop(req_id, None)

    # -- swap-to-host ---------------------------------------------------------

    def can_swap_out(self, req_id: int) -> bool:
        """True iff the host pool can hold ``req_id``'s KV pages right now.
        A mid-prefill request (live stash) is never swappable — boundary
        activations are execution state, not KV; such victims fold to
        recompute instead."""
        if not self.is_resident(req_id) or self._stash.get(req_id):
            return False
        return len(self._tables[req_id]) <= len(self._host_free)

    def swap_out(self, req_id: int) -> int:
        """Move every KV page of ``req_id`` to the host pool; the block
        table is remembered host-side in logical order.  Returns the number
        of KV tokens moved (the DMA traffic the executor must price)."""
        assert self.can_swap_out(req_id), req_id
        n_pages = len(self._tables[req_id])
        self._free.extend(reversed(self._tables.pop(req_id)))
        self._stash.pop(req_id, None)       # empty by the can_swap_out guard
        self._host_tables[req_id] = [self._host_free.pop()
                                     for _ in range(n_pages)]
        self.host_pages_high_water = max(self.host_pages_high_water,
                                         self.host_pages_in_use())
        moved = self._lengths[req_id]
        self.n_swap_outs += 1
        self.swapped_out_tokens += moved
        return moved

    def swapped_pages(self, req_id: int) -> int:
        return len(self._host_tables[req_id])

    def can_swap_in(self, req_id: int) -> bool:
        return (self.is_swapped(req_id)
                and len(self._host_tables[req_id]) <= len(self._free))

    def swap_in(self, req_id: int) -> int:
        """DMA-back: claim fresh HBM pages for the swapped KV and release
        the host copy.  Returns the number of KV tokens moved."""
        assert self.can_swap_in(req_id), req_id
        n_pages = len(self._host_tables[req_id])
        self._host_free.extend(reversed(self._host_tables.pop(req_id)))
        self._tables[req_id] = [self._free.pop() for _ in range(n_pages)]
        self._stash[req_id] = []
        self._bump_high_water()
        moved = self._lengths[req_id]
        self.n_swap_ins += 1
        self.swapped_in_tokens += moved
        return moved

    # -- physical mapping ----------------------------------------------------

    def block_table(self, req_id: int) -> List[int]:
        """Physical page ids backing ``req_id``'s KV, in logical order —
        what the paged decode-attention kernel walks."""
        return list(self._tables[req_id])

    def length(self, req_id: int) -> int:
        return self._lengths[req_id]

    # -- internals -----------------------------------------------------------

    def _bump_high_water(self) -> None:
        self.pages_high_water = max(self.pages_high_water,
                                    self.pages_in_use())
