"""KV-cache slot management.

The engine uses a fixed pool of per-request *slots* (contiguous per-slot
layout — friendlier to TPU DMA than vLLM's scattered pages; see DESIGN.md
§Hardware adaptation). Page-granular *accounting* is kept alongside so
memory-pressure metrics match a paged allocator's: a slot logically
occupies ceil(len / page_size) pages and the high-water page mark is
reported in the engine metrics.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class SlotAllocator:
    n_slots: int
    max_len: int
    page_size: int = 16
    _free: List[int] = field(default_factory=list)
    _owner: Dict[int, int] = field(default_factory=dict)   # slot -> req
    _slot_of: Dict[int, int] = field(default_factory=dict)  # req -> slot
    _lengths: Dict[int, int] = field(default_factory=dict)  # slot -> tokens
    pages_high_water: int = 0

    def __post_init__(self):
        self._free = list(range(self.n_slots))[::-1]

    @property
    def n_free(self) -> int:
        return len(self._free)

    def slot_of(self, req_id: int) -> int:
        return self._slot_of[req_id]

    def owns(self, req_id: int) -> bool:
        return req_id in self._slot_of

    def alloc(self, req_id: int) -> int:
        if not self._free:
            raise RuntimeError("KV slot pool exhausted")
        slot = self._free.pop()
        self._owner[slot] = req_id
        self._slot_of[req_id] = slot
        self._lengths[slot] = 0
        return slot

    def free(self, req_id: int) -> None:
        slot = self._slot_of.pop(req_id)
        del self._owner[slot]
        del self._lengths[slot]
        self._free.append(slot)

    def set_length(self, req_id: int, n_tokens: int) -> None:
        assert n_tokens <= self.max_len, (n_tokens, self.max_len)
        self._lengths[self._slot_of[req_id]] = n_tokens
        self.pages_high_water = max(self.pages_high_water, self.pages_in_use())

    def pages_in_use(self) -> int:
        return sum(math.ceil(n / self.page_size) for n in self._lengths.values())
