"""Paged KV-cache memory subsystem.

A single global pool of fixed-size *pages* (``page_size`` KV tokens each)
backs every resident request.  Each request owns a *block table* — the
ordered list of physical page ids holding its KV — which grows
page-granularly as decode appends tokens.  The same allocator instance is
shared by the scheduler (admission / preemption decisions), the execution
engine (physical placement + the paged Pallas decode kernel's block
tables) and the discrete-event simulator (page occupancy, preemption and
recompute accounting in the paper-scale sweeps).  See DESIGN.md
§Hardware adaptation for how the logical page pool maps onto TPU-friendly
physical layouts.

Memory charged against the pool:

  * KV reservations — admission reserves ``prompt_len + decode_reserve``
    tokens up front (the scheduler admits only when this fits), so prefill
    never fails mid-flight; decode growth past the reservation allocates
    pages on demand and is what creates *pressure*.
  * Layered-prefill stash — boundary activations carried between layer
    groups are charged as ``stash_factor`` KV-token-equivalents per
    stashed token (``stash_factor ≈ d_model·bytes_act /
    kv_bytes_per_token``) and released when the request's prefill
    completes.

A second, host-side page pool (``n_host_pages``) backs **swap-to-host
preemption**: ``swap_out`` moves a resident request's KV pages to host
pages wholesale (the block table is remembered on the host side, in
logical order), freeing HBM; ``swap_in`` is the DMA-back — it claims fresh
HBM pages and releases the host copy, after which decode resumes with the
KV intact (no recompute epoch).  Host pages are accounted exactly like HBM
pages: a swapped request owns its host pages until swap-in or ``free``.

**Automatic prefix caching** (``prefix_caching=True``, DESIGN.md §Prefix
caching): every FULL page of a completed prompt can be registered in a
content-addressed index keyed by a *chain digest* — the hash of the
page's tokens folded together with the parent page's digest, so a page's
identity includes its entire prefix.  A later admission whose prompt
matches a chain links the shared pages into its block table (refcounted,
zero new pages charged) and starts prefill past the cached boundary.
Shared pages are read-only: the partial tail page of a prompt is never
shared, and when a whole prompt is covered by cached full pages the last
matched page is *copy-on-write* — it is dropped from the hit, the new
request re-prefills its tokens into a private page (so it still computes
its first logits), and the hit references only pages it links refcounted.
Refcount-0 shared pages park in an LRU and count as free: they are
reclaimed (oldest first, evicting their index entry) whenever the free
list runs dry, so cached prefixes never block a cold admission.  Shared
pages are excluded from swap: ``swap_out`` moves only a victim's private
pages to host and pins the shared prefix in HBM.

Every device-page release (free / evict / swap-out / spec trim / stash)
funnels through ONE helper, ``_release_pages`` — the single choke point
that makes refcount double-decrements structurally impossible —
and ``check_invariants`` asserts that every physical page is in exactly
one of {free list, LRU, a block table, a stash, pinned-shared} and that
every refcount equals the number of referencing table positions.

The allocator never decides WHO to evict — victim selection
(latest-arrival-first) lives in ``core.base.Scheduler``; the allocator
only enforces that nobody allocates pages it does not have.
"""

from __future__ import annotations

import hashlib
import math
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class PagedPoolExhausted(RuntimeError):
    """Raised when an allocation is attempted against an empty pool.

    Under pressure-aware admission + preemption this never surfaces: the
    scheduler checks ``can_admit``/``growth_deficit`` (and preempts) before
    any page is claimed.  It CAN surface when preemption is disabled and
    decode growth outruns the reservation."""


def _page_digest(parent: bytes, tokens: Tuple[int, ...]) -> bytes:
    """Chain digest of one full page: folds the PARENT page's digest into
    the hash, so a block's identity includes its whole prefix."""
    h = hashlib.blake2b(parent, digest_size=16)
    h.update(repr(tokens).encode())
    return h.digest()


@dataclass(frozen=True)
class PrefixHit:
    """Result of matching a prompt against the shared-prefix index.

    ``pages`` are the physical page ids to link (read-only, refcounted);
    ``leaf`` is the digest of the deepest LINKED page (``pages[-1]``) —
    always a chain the engine holds a KV row snapshot for, and always
    refcount-protected once the hit is reserved.  ``cow`` marks a
    fully-covered prompt whose last matched page was dropped from the hit
    (its tokens re-prefill into a private copy-on-write page)."""
    cached_tokens: int = 0
    pages: Tuple[int, ...] = ()
    leaf: Optional[bytes] = None
    cow: bool = False


_NO_HIT = PrefixHit()


@dataclass(frozen=True)
class KVExport:
    """Serializable manifest of one request's KV residency, produced by
    ``export_pages`` for transfer to ANOTHER allocator (the disaggregated
    prefill→decode handoff, DESIGN.md §Disaggregated serving).

    ``chain`` is the leading shared-prefix run in block-table order —
    ``(digest, page_tokens)`` per full shared page — carried so the
    importing side can LINK pages its own index already holds (zero link
    bytes) and register the rest, keeping the chain warm on both pools.
    ``private_tokens`` are the KV tokens whose payload must actually cross
    the inter-pool link if no page of the chain links on import."""
    req_id: int
    length: int
    chain: Tuple[Tuple[bytes, Tuple[int, ...]], ...] = ()
    n_private_pages: int = 0
    private_tokens: int = 0
    host_resident: bool = False


@dataclass(frozen=True)
class KVImport:
    """Outcome of ``import_pages``: how many tokens were served by pages
    already warm on the importing pool (``linked_tokens`` — zero bytes on
    the link) vs. materialized from the transferred payload
    (``moved_tokens``)."""
    linked_tokens: int = 0
    moved_tokens: int = 0
    n_pages: int = 0


@dataclass
class PagedKVAllocator:
    n_pages: int
    page_size: int = 16
    # KV-token-equivalents charged per stashed boundary-activation token
    # (layered prefill's carry state); callers derive it from the model's
    # d_model / kv_bytes_per_token ratio.
    stash_factor: float = 1.0
    # host-side page pool for swap-to-host preemption (0 = swap disabled)
    n_host_pages: int = 0
    # automatic prefix caching: content-hash full prompt pages into a
    # refcounted read-only index (off by default — the raw allocator is
    # also the substrate for caches that must not alias)
    prefix_caching: bool = False
    # cap on refcount-0 shared pages retained in the LRU (None = bounded
    # only by pool pressure)
    prefix_lru_pages: Optional[int] = None
    _free: List[int] = field(default_factory=list)
    _tables: Dict[int, List[int]] = field(default_factory=dict)  # req -> pages
    _lengths: Dict[int, int] = field(default_factory=dict)       # req -> toks
    _stash: Dict[int, List[int]] = field(default_factory=dict)   # req -> pages
    _host_free: List[int] = field(default_factory=list)
    _host_tables: Dict[int, List[int]] = field(default_factory=dict)
    # speculative pre-charge: req -> table size before reserve_spec
    _spec_base: Dict[int, int] = field(default_factory=dict)
    # -- prefix-cache state --------------------------------------------------
    _index: Dict[bytes, int] = field(default_factory=dict)    # digest -> page
    _page_digests: Dict[int, bytes] = field(default_factory=dict)
    _page_tokens: Dict[int, Tuple[int, ...]] = field(default_factory=dict)
    _refs: Dict[int, int] = field(default_factory=dict)       # page -> refcount
    _lru: "OrderedDict[int, None]" = field(default_factory=OrderedDict)
    _hits: Dict[int, PrefixHit] = field(default_factory=dict)  # req -> hit
    # shared prefix pages pinned in HBM while their owner is swapped out
    _swapped_shared: Dict[int, List[int]] = field(default_factory=dict)
    # tokens that crossed the host link in the request's LAST swap (shared
    # pages stay pinned, so this can be less than length)
    _swap_moved: Dict[int, int] = field(default_factory=dict)
    # engine hook: called with the chain digest of every page evicted from
    # the shared index, so cached KV row snapshots can be dropped with it
    on_prefix_evict: Optional[Callable[[bytes], None]] = None
    pages_high_water: int = 0
    host_pages_high_water: int = 0
    n_grow_allocs: int = 0
    # swap traffic accounting (cumulative, in KV tokens moved per direction)
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    swapped_out_tokens: int = 0
    swapped_in_tokens: int = 0
    # prefix-cache accounting (cumulative)
    n_prefix_hits: int = 0
    n_prefix_tokens: int = 0
    n_prefix_cow: int = 0
    n_prefix_evictions: int = 0
    # inter-pool handoff accounting (cumulative, in KV tokens)
    n_exports: int = 0
    n_imports: int = 0
    exported_tokens: int = 0
    import_linked_tokens: int = 0
    import_moved_tokens: int = 0

    def __post_init__(self):
        assert self.n_pages > 0 and self.page_size > 0
        assert self.n_host_pages >= 0
        self._free = list(range(self.n_pages))[::-1]
        self._host_free = list(range(self.n_host_pages))[::-1]

    # -- sizing --------------------------------------------------------------

    def pages_for(self, n_tokens: int) -> int:
        return math.ceil(max(n_tokens, 0) / self.page_size)

    def stash_pages_for(self, n_tokens: int) -> int:
        return self.pages_for(math.ceil(n_tokens * self.stash_factor))

    @property
    def n_free_pages(self) -> int:
        # refcount-0 shared pages are reclaimable on demand: they count as
        # free so cached prefixes never shrink the pool's usable capacity
        return len(self._free) + len(self._lru)

    def pages_in_use(self) -> int:
        return self.n_pages - self.n_free_pages

    @property
    def n_free_host_pages(self) -> int:
        return len(self._host_free)

    def host_pages_in_use(self) -> int:
        return self.n_host_pages - len(self._host_free)

    @property
    def n_shared_pages(self) -> int:
        """Pages currently registered in the shared-prefix index."""
        return len(self._page_digests)

    # -- prefix matching -----------------------------------------------------

    def lookup_prefix(self, prompt_tokens: Optional[Sequence[int]]) \
            -> PrefixHit:
        """Walk the shared index along the prompt's full pages (chain
        digests, token content verified page-by-page against collisions).
        Non-mutating — safe for admissibility probes.  A fully-covered
        prompt drops the LAST matched page from the hit (copy-on-write):
        its tokens are re-prefilled into a private page so the request
        still computes final logits, and — the real point — the hit then
        only ever references pages it will LINK refcounted at reserve, so
        no unpinned page can be LRU-reclaimed between the admission
        decision and the engine's row restore."""
        if not self.prefix_caching or prompt_tokens is None \
                or len(prompt_tokens) == 0:
            return _NO_HIT
        ps = self.page_size
        n = len(prompt_tokens)
        pages: List[int] = []
        digests: List[bytes] = []
        parent = b""
        for i in range(n // ps):
            toks = tuple(int(t) for t in prompt_tokens[i * ps:(i + 1) * ps])
            d = _page_digest(parent, toks)
            pid = self._index.get(d)
            if pid is None or self._page_tokens.get(pid) != toks:
                break
            pages.append(pid)
            digests.append(d)
            parent = d
        cow = len(pages) * ps >= n
        if cow:
            pages, digests = pages[:-1], digests[:-1]
        if not pages:
            return _NO_HIT
        return PrefixHit(cached_tokens=len(pages) * ps, pages=tuple(pages),
                         leaf=digests[-1], cow=cow)

    def prefix_hit(self, req_id: int) -> PrefixHit:
        """The hit recorded when ``req_id`` was reserved (no-hit default)."""
        return self._hits.get(req_id, _NO_HIT)

    def register_prefix(self, req_id: int,
                        prompt_tokens: Optional[Sequence[int]]) \
            -> List[Tuple[bytes, int]]:
        """Publish the FULL pages of a completed prompt into the shared
        index (idempotent — pages already registered under the same chain
        are skipped).  Registration stops at the first page whose chain
        digest is already served by a DIFFERENT physical page (a cohort
        mate won the race) — the remainder stays private and is released
        normally.  Returns the newly registered ``(digest, depth)`` pairs
        so the engine can snapshot KV rows for exactly those chains."""
        if not self.prefix_caching or prompt_tokens is None \
                or len(prompt_tokens) == 0:
            return []
        table = self._tables.get(req_id)
        if table is None:
            return []
        ps = self.page_size
        parent, new = b"", []
        for i in range(min(len(prompt_tokens) // ps, len(table))):
            toks = tuple(int(t) for t in prompt_tokens[i * ps:(i + 1) * ps])
            d = _page_digest(parent, toks)
            pid = table[i]
            cur = self._index.get(d)
            if cur == pid:
                parent = d
                continue
            if cur is not None or pid in self._page_digests:
                break
            self._index[d] = pid
            self._page_digests[pid] = d
            self._page_tokens[pid] = toks
            self._refs[pid] = 1          # the owner's table reference
            new.append((d, i + 1))
            parent = d
        return new

    def owned_chains(self, req_id: int,
                     prompt_tokens: Optional[Sequence[int]]) \
            -> List[Tuple[bytes, int]]:
        """(digest, depth) pairs in the shared index currently served by
        ``req_id``'s OWN block-table pages.  The engine snapshots its KV
        row under exactly these digests after the prompt completes —
        registration itself happens scheduler-side at plan time, before
        the prefill has executed, so its return value cannot drive the
        snapshot."""
        table = self._tables.get(req_id)
        if not self.prefix_caching or prompt_tokens is None \
                or len(prompt_tokens) == 0 or table is None:
            return []
        ps = self.page_size
        parent, out = b"", []
        for i in range(min(len(prompt_tokens) // ps, len(table))):
            toks = tuple(int(t) for t in prompt_tokens[i * ps:(i + 1) * ps])
            d = _page_digest(parent, toks)
            if self._index.get(d) != table[i]:
                break
            out.append((d, i + 1))
            parent = d
        return out

    # -- admission queries ---------------------------------------------------

    def _avail_for(self, hit: PrefixHit) -> int:
        """Pages claimable for NEW allocations once ``hit``'s shared pages
        are linked: the free list plus the reclaimable LRU, minus matched
        pages currently parked in the LRU (linking revives, not consumes,
        them — but they stop being reclaimable)."""
        parked = sum(1 for p in hit.pages if p in self._lru)
        return len(self._free) + len(self._lru) - parked

    def can_admit(self, n_tokens: int, stash_tokens: int = 0,
                  headroom_pages: int = 0,
                  prompt_tokens: Optional[Sequence[int]] = None) -> bool:
        """True iff a reservation for ``n_tokens`` of KV plus the stash
        charge fits the pool RIGHT NOW, leaving ``headroom_pages`` free
        (the scheduler's per-SLO-class admission reserve).  With
        ``prompt_tokens`` the query is prefix-aware: matched shared pages
        are charged zero new pages."""
        hit = self.lookup_prefix(prompt_tokens)
        need = (max(0, self.pages_for(n_tokens) - len(hit.pages))
                + self.stash_pages_for(stash_tokens))
        return need + headroom_pages <= self._avail_for(hit)

    def fits_pool(self, n_tokens: int, stash_tokens: int = 0,
                  headroom_pages: int = 0) -> bool:
        """True iff the request could EVER fit (empty pool minus the
        caller's headroom reserve).  Deliberately NOT prefix-aware: shared
        pages can be evicted under pressure, so the worst case must fit
        without cache credit."""
        need = self.pages_for(n_tokens) + self.stash_pages_for(stash_tokens)
        return need + headroom_pages <= self.n_pages

    # -- request lifecycle ---------------------------------------------------

    def owns(self, req_id: int) -> bool:
        """True iff ``req_id`` holds pages in EITHER pool (resident or
        swapped) — i.e. ``free`` has something to release."""
        return req_id in self._tables or req_id in self._host_tables

    def is_resident(self, req_id: int) -> bool:
        return req_id in self._tables

    def is_swapped(self, req_id: int) -> bool:
        return req_id in self._host_tables

    def reserve(self, req_id: int, n_tokens: int, stash_tokens: int = 0,
                prompt_tokens: Optional[Sequence[int]] = None) -> PrefixHit:
        """Admission-time reservation: claims pages for ``n_tokens`` of KV
        (prompt + decode reservation) and the stash charge.  With
        ``prompt_tokens``, matched shared prefix pages are LINKED at the
        head of the block table (refcount bumped, revived from the LRU)
        and only the uncached remainder allocates new pages.  Records the
        filled length as the cached token count and returns the hit."""
        assert req_id not in self._tables, req_id
        hit = self.lookup_prefix(prompt_tokens)
        need_kv = self.pages_for(n_tokens)
        assert len(hit.pages) <= need_kv, (req_id, hit, n_tokens)
        need_new = need_kv - len(hit.pages)
        need_stash = self.stash_pages_for(stash_tokens)
        if need_new + need_stash > self._avail_for(hit):
            raise PagedPoolExhausted(
                f"reserve({req_id}): need {need_new + need_stash} pages, "
                f"{self.n_free_pages} free of {self.n_pages}")
        table = []
        for pid in hit.pages:
            self._refs[pid] += 1
            self._lru.pop(pid, None)
            table.append(pid)
        for _ in range(need_new):
            table.append(self._take_page())
        self._tables[req_id] = table
        self._stash[req_id] = [self._take_page() for _ in range(need_stash)]
        self._lengths[req_id] = hit.cached_tokens
        if hit.cached_tokens:
            self._hits[req_id] = hit
            self.n_prefix_hits += 1
            self.n_prefix_tokens += hit.cached_tokens
            self.n_prefix_cow += int(hit.cow)
        self._bump_high_water()
        return hit

    def set_length(self, req_id: int, n_tokens: int) -> None:
        """Record the filled KV length (monotone); never allocates."""
        assert n_tokens <= len(self._tables[req_id]) * self.page_size, \
            (req_id, n_tokens)
        self._lengths[req_id] = max(self._lengths[req_id], n_tokens)

    def growth_deficit(self, req_id: int, n_tokens: int) -> int:
        """Pages that must be newly allocated for the block table to cover
        ``n_tokens`` (0 when the reservation already covers it)."""
        return max(0, self.pages_for(n_tokens) - len(self._tables[req_id]))

    def grow_to(self, req_id: int, n_tokens: int) -> None:
        """Page-granular grow-on-write: extend the block table to cover
        ``n_tokens``.  Raises PagedPoolExhausted when the pool is dry — the
        scheduler's pressure pass preempts before letting that happen."""
        deficit = self.growth_deficit(req_id, n_tokens)
        if deficit > self.n_free_pages:
            raise PagedPoolExhausted(
                f"grow_to({req_id}, {n_tokens}): need {deficit} pages, "
                f"{self.n_free_pages} free of {self.n_pages}")
        for _ in range(deficit):
            self._tables[req_id].append(self._take_page())
            self.n_grow_allocs += 1
        self._lengths[req_id] = max(self._lengths[req_id], n_tokens)
        if deficit:
            self._bump_high_water()

    def release_stash(self, req_id: int) -> None:
        self._release_pages(self._stash.pop(req_id, []))
        self._stash[req_id] = []

    # -- speculative decode reservations --------------------------------------
    #
    # Verify-k decoding may commit anywhere from 1 to 1+k tokens per
    # iteration.  The scheduler pre-charges pages for the FULL window
    # (``reserve_spec``) without recording a filled length — the length is
    # only known post-verification — and the executor's commit trims the
    # table back to the accepted length (``release_spec``), so a rejected
    # draft leaves no page behind.  Speculation is opportunistic: it never
    # evicts anybody, it just shrinks k when the pool is dry.

    def reserve_spec(self, req_id: int, n_tokens: int) -> None:
        """Pre-charge pages so the block table covers ``n_tokens`` WITHOUT
        recording a filled length.  Remembers the pre-speculation table
        size so ``release_spec`` can trim back exactly."""
        assert req_id in self._tables, req_id
        if req_id not in self._spec_base:
            self._spec_base[req_id] = len(self._tables[req_id])
        deficit = self.growth_deficit(req_id, n_tokens)
        if deficit > self.n_free_pages:
            raise PagedPoolExhausted(
                f"reserve_spec({req_id}, {n_tokens}): need {deficit} pages, "
                f"{self.n_free_pages} free of {self.n_pages}")
        for _ in range(deficit):
            self._tables[req_id].append(self._take_page())
        if deficit:
            self._bump_high_water()

    def release_spec(self, req_id: int) -> None:
        """Trim the speculative pre-charge back to what the committed
        length (set via ``grow_to``/``set_length`` since) actually needs —
        never below the pre-speculation table size.  No-op for requests
        without an outstanding ``reserve_spec``.  Trimmed pages are always
        the private tail (the base covers the whole prompt, so shared
        prefix pages sit strictly below it)."""
        base = self._spec_base.pop(req_id, None)
        if base is None or req_id not in self._tables:
            return
        keep = max(base, self.pages_for(self._lengths[req_id]))
        table = self._tables[req_id]
        while len(table) > keep:
            self._release_pages([table.pop()])

    def has_spec_reservation(self, req_id: int) -> bool:
        return req_id in self._spec_base

    def free(self, req_id: int) -> None:
        """Return every page (KV + stash, HBM or host) of ``req_id``.
        Shared prefix pages are decref'd, not freed — at refcount 0 they
        park in the reclaimable LRU with their cached content intact."""
        assert self.owns(req_id), req_id
        self._release_pages(self._tables.pop(req_id, []))
        self._release_pages(self._stash.pop(req_id, []))
        self._release_pages(self._swapped_shared.pop(req_id, []))
        self._host_free.extend(reversed(self._host_tables.pop(req_id, [])))
        self._lengths.pop(req_id, None)
        self._spec_base.pop(req_id, None)
        self._hits.pop(req_id, None)
        self._swap_moved.pop(req_id, None)

    # -- swap-to-host ---------------------------------------------------------

    def _split_shared(self, table: List[int]) -> Tuple[List[int], List[int]]:
        """Partition a block table into (shared, private) pages, order
        preserved.  Shared pages always occupy a leading run (linked at
        reserve or registered over the prompt's leading full pages)."""
        shared = [p for p in table if p in self._page_digests]
        private = [p for p in table if p not in self._page_digests]
        return shared, private

    def can_swap_out(self, req_id: int) -> bool:
        """True iff the host pool can hold ``req_id``'s PRIVATE KV pages
        right now (shared prefix pages stay pinned in HBM — they are
        read-only and other requests may be attached to them).  A
        mid-prefill request (live stash) is never swappable — boundary
        activations are execution state, not KV; such victims fold to
        recompute instead."""
        if not self.is_resident(req_id) or self._stash.get(req_id):
            return False
        _, private = self._split_shared(self._tables[req_id])
        if not private:
            # a fully-shared victim would be a zero-progress swap (nothing
            # leaves HBM); recompute-eviction at least parks its shared
            # pages in the reclaimable LRU
            return False
        return len(private) <= len(self._host_free)

    def swap_out(self, req_id: int) -> int:
        """Move the PRIVATE KV pages of ``req_id`` to the host pool (the
        block table is remembered host-side in logical order); shared
        prefix pages keep their refcount and stay pinned in HBM.  Returns
        the number of KV tokens that actually cross the host link."""
        assert self.can_swap_out(req_id), req_id
        shared, private = self._split_shared(self._tables.pop(req_id))
        self._release_pages(private)
        self._stash.pop(req_id, None)       # empty by the can_swap_out guard
        self._swapped_shared[req_id] = shared
        self._host_tables[req_id] = [self._host_free.pop()
                                     for _ in range(len(private))]
        self.host_pages_high_water = max(self.host_pages_high_water,
                                         self.host_pages_in_use())
        moved = max(0, self._lengths[req_id] - len(shared) * self.page_size)
        self._swap_moved[req_id] = moved
        self.n_swap_outs += 1
        self.swapped_out_tokens += moved
        return moved

    def swapped_pages(self, req_id: int) -> int:
        return len(self._host_tables[req_id])

    def last_swap_tokens(self, req_id: int) -> int:
        """KV tokens moved by ``req_id``'s most recent swap (either
        direction) — the DMA traffic an executor prices.  Shared prefix
        pages never move, so this can be less than ``length``."""
        return self._swap_moved.get(req_id, 0)

    def can_swap_in(self, req_id: int) -> bool:
        return (self.is_swapped(req_id)
                and len(self._host_tables[req_id]) <= self.n_free_pages)

    def swap_in(self, req_id: int) -> int:
        """DMA-back: claim fresh HBM pages for the swapped private KV,
        re-attach the pinned shared prefix, and release the host copy.
        Returns the number of KV tokens moved."""
        assert self.can_swap_in(req_id), req_id
        n_private = len(self._host_tables[req_id])
        self._host_free.extend(reversed(self._host_tables.pop(req_id)))
        shared = self._swapped_shared.pop(req_id, [])
        self._tables[req_id] = shared + [self._take_page()
                                         for _ in range(n_private)]
        self._stash[req_id] = []
        self._bump_high_water()
        moved = max(0, self._lengths[req_id] - len(shared) * self.page_size)
        self._swap_moved[req_id] = moved
        self.n_swap_ins += 1
        self.swapped_in_tokens += moved
        return moved

    # -- inter-pool export / import (disaggregated handoff) -------------------
    #
    # ``export_pages`` serializes a request's residency into a ``KVExport``
    # manifest and releases its pages HERE (move semantics): shared prefix
    # pages decref and park in this pool's LRU — the source stays warm for
    # later prompts — while the manifest carries the chain digests so the
    # importing pool can link pages it already holds instead of receiving
    # their payload.  ``import_pages`` is the mirror: it lands the request
    # as owned (private) + pinned-shared (linked/registered chain) pages,
    # with ``check_invariants`` holding on both allocators at every step.

    def export_pages(self, req_id: int) -> KVExport:
        """Serialize ``req_id``'s KV residency (resident OR swapped) for
        transfer to another allocator, then release every page it holds on
        this side.  Returns the manifest the destination imports from."""
        assert self.owns(req_id), req_id
        length = self._lengths[req_id]
        if self.is_resident(req_id):
            shared, private = self._split_shared(self._tables[req_id])
            host_resident = False
        else:
            shared = list(self._swapped_shared.get(req_id, []))
            private = self._host_tables[req_id]
            host_resident = True
        # the chain only ever covers FULL pages of the filled length
        shared = shared[:length // self.page_size]
        chain = tuple((self._page_digests[p], self._page_tokens[p])
                      for p in shared)
        export = KVExport(
            req_id=req_id, length=length, chain=chain,
            n_private_pages=len(private),
            private_tokens=max(0, length - len(chain) * self.page_size),
            host_resident=host_resident)
        self.free(req_id)
        self.n_exports += 1
        self.exported_tokens += length
        return export

    def _match_chain(self, export: KVExport) -> List[int]:
        """Leading run of ``export.chain`` already served by THIS pool's
        index (content-verified, like ``lookup_prefix``).  Non-mutating."""
        linked: List[int] = []
        for digest, toks in export.chain:
            pid = self._index.get(digest)
            if pid is None or self._page_tokens.get(pid) != toks:
                break
            linked.append(pid)
        return linked

    def can_import(self, export: KVExport, n_tokens: Optional[int] = None,
                   headroom_pages: int = 0) -> bool:
        """True iff ``import_pages`` would succeed right now (prefix-aware:
        chain pages warm on this side are charged zero new pages)."""
        n_tokens = max(n_tokens or 0, export.length)
        linked = self._match_chain(export)
        hit = PrefixHit(cached_tokens=len(linked) * self.page_size,
                        pages=tuple(linked))
        need = max(0, self.pages_for(n_tokens) - len(linked))
        return need + headroom_pages <= self._avail_for(hit)

    def import_pages(self, export: KVExport,
                     n_tokens: Optional[int] = None) -> KVImport:
        """Materialize an exported request on THIS allocator: chain pages
        already warm here are linked refcounted (zero link bytes), the
        rest of the chain allocates fresh pages and registers into the
        index (the transferred payload makes this pool warm too), and the
        private remainder allocates owned pages.  ``n_tokens`` reserves
        decode growth past the filled length (default: exactly the filled
        length).  Raises ``PagedPoolExhausted`` when the pool cannot hold
        the request — probe ``can_import`` first."""
        req_id = export.req_id
        assert req_id not in self._tables, req_id
        n_tokens = max(n_tokens or 0, export.length)
        linked = self._match_chain(export)
        hit = PrefixHit(cached_tokens=len(linked) * self.page_size,
                        pages=tuple(linked))
        need_new = max(0, self.pages_for(n_tokens) - len(linked))
        if need_new > self._avail_for(hit):
            raise PagedPoolExhausted(
                f"import_pages({req_id}): need {need_new} pages, "
                f"{self.n_free_pages} free of {self.n_pages}")
        table: List[int] = []
        for pid in linked:
            self._refs[pid] += 1
            self._lru.pop(pid, None)
            table.append(pid)
        # cold chain pages: allocate + register so the chain is warm here
        # for the NEXT import/admission sharing this prefix
        for digest, toks in export.chain[len(linked):]:
            pid = self._take_page()
            table.append(pid)
            if digest not in self._index and self.prefix_caching:
                self._index[digest] = pid
                self._page_digests[pid] = digest
                self._page_tokens[pid] = toks
                self._refs[pid] = 1
        while len(table) < self.pages_for(n_tokens):
            table.append(self._take_page())
        self._tables[req_id] = table
        self._stash[req_id] = []
        self._lengths[req_id] = export.length
        self._bump_high_water()
        linked_tokens = min(hit.cached_tokens, export.length)
        self.n_imports += 1
        self.import_linked_tokens += linked_tokens
        self.import_moved_tokens += export.length - linked_tokens
        return KVImport(linked_tokens=linked_tokens,
                        moved_tokens=export.length - linked_tokens,
                        n_pages=len(table))

    # -- physical mapping ----------------------------------------------------

    def block_table(self, req_id: int) -> List[int]:
        """Physical page ids backing ``req_id``'s KV, in logical order —
        what the paged decode-attention kernel walks."""
        return list(self._tables[req_id])

    def length(self, req_id: int) -> int:
        return self._lengths[req_id]

    # -- internals -----------------------------------------------------------

    def _take_page(self) -> int:
        """Claim one physical page: the free list first, then reclaim the
        oldest refcount-0 shared page (evicting its index entry)."""
        if self._free:
            return self._free.pop()
        pid, _ = self._lru.popitem(last=False)
        self._unregister(pid)
        return pid

    def _release_pages(self, pages: List[int]) -> None:
        """THE single release choke point for device pages (free / evict /
        swap-out / spec trim / stash all funnel here): shared pages decref
        and park in the reclaimable LRU at refcount 0 with content intact;
        private pages return to the free list."""
        for pid in reversed(pages):
            if pid not in self._page_digests:
                self._free.append(pid)
                continue
            self._refs[pid] -= 1
            assert self._refs[pid] >= 0, pid
            if self._refs[pid] == 0:
                self._lru[pid] = None
                self._enforce_lru_cap()

    def _enforce_lru_cap(self) -> None:
        cap = self.prefix_lru_pages
        while cap is not None and len(self._lru) > cap:
            pid, _ = self._lru.popitem(last=False)
            self._unregister(pid)
            self._free.append(pid)

    def _unregister(self, pid: int) -> None:
        """Drop one page from the shared index (LRU reclaim), notifying
        the engine so its cached KV row snapshots die with the entry."""
        d = self._page_digests.pop(pid)
        self._index.pop(d, None)
        self._page_tokens.pop(pid, None)
        self._refs.pop(pid, None)
        self.n_prefix_evictions += 1
        if self.on_prefix_evict is not None:
            self.on_prefix_evict(d)

    def _bump_high_water(self) -> None:
        self.pages_high_water = max(self.pages_high_water,
                                    self.pages_in_use())

    # -- debug invariant ------------------------------------------------------

    def check_invariants(self) -> None:
        """Assert global page conservation: every device page is in exactly
        one of {free list, LRU, a block table, a stash, pinned-shared},
        shared refcounts equal the number of referencing positions, and
        every host page is in exactly one of {host free list, host table}.
        O(pool) — for tests and debugging, never on the serving path."""
        free = set(self._free)
        assert len(free) == len(self._free), "duplicate page in free list"
        lru = set(self._lru)
        assert not free & lru, "page both free and LRU-parked"
        refs: Dict[int, int] = {}
        private_placed: List[int] = []
        holders = (list(self._tables.values()) + list(self._stash.values())
                   + list(self._swapped_shared.values()))
        for t in holders:
            for p in t:
                if p in self._page_digests:
                    refs[p] = refs.get(p, 0) + 1
                else:
                    private_placed.append(p)
        assert len(private_placed) == len(set(private_placed)), \
            "private page referenced by two tables"
        assert not set(private_placed) & (free | lru), \
            "placed private page also free/LRU"
        for pid, d in self._page_digests.items():
            assert self._index.get(d) == pid, (pid, "index out of sync")
            assert self._refs[pid] == refs.get(pid, 0), \
                (pid, self._refs[pid], refs.get(pid, 0))
            assert (pid in lru) == (self._refs[pid] == 0), (pid, "LRU sync")
            assert pid not in free, (pid, "shared page on free list")
        for pid in lru:
            assert pid in self._page_digests, (pid, "LRU page unregistered")
        pinned = sum(1 for p in self._page_digests if self._refs[p] > 0)
        assert (len(free) + len(lru) + len(private_placed) + pinned
                == self.n_pages), "device page conservation violated"
        host = list(self._host_free) + [p for t in self._host_tables.values()
                                        for p in t]
        assert sorted(host) == list(range(self.n_host_pages)), \
            "host page conservation violated"
