"""The executor-agnostic serving loop (DESIGN.md §Serving runtime).

``ServingRuntime`` owns everything the real-execution engine and the
discrete-event simulator used to reimplement privately: timed arrival
injection (open-loop trace replay), idling to the next arrival instead of
raising when the pool drains, per-iteration stepping via the scheduler's
``next_plan``, token timestamping (TTFT pinning across recompute epochs),
preemption/swap accounting, per-token streaming callbacks, and the
no-progress / iteration-cap guards.  ``Engine.run`` and ``Simulator.run``
both delegate here, so the two loops cannot drift and the equivalence
tests compare one loop driving two backends, not two reimplementations.

An ``Executor`` is the backend behind the loop:

  * ``EngineExecutor`` — wraps ``serving.engine.Engine``: plans execute on
    a REAL jax model, token events carry actual token ids, and the clock
    is either the iteration index (deterministic replay — the default) or
    real wall time (``wall=True``: arrivals in seconds, the runtime sleeps
    through idle gaps — open-loop serving).
  * ``SimExecutor`` — wraps ``serving.simulator.Simulator``: plans are
    priced by the analytic cost model, token events carry ``None`` (there
    is no model), and the clock advances by modeled iteration durations.

Arrival clock semantics: the runtime keeps ONE clock ``t``.  With
``clock="executor"`` (simulator default, engine wall mode) ``t`` advances
by each step's modeled/measured duration and arrival times are in the
executor's time unit (seconds).  With ``clock="iteration"`` (engine
default) ``t`` advances 1.0 per executed iteration and arrival times are
iteration indices — identical across backends by construction, which is
what makes cross-backend trace-replay equivalence exactly testable.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Protocol,
                    Sequence, Union)

import numpy as np

from repro.core.base import fold_for_recompute
from repro.core.plan import IterationPlan, Request, RequestState, SubmitSpec
from repro.serving.faults import (FAULT_KINDS, DegradationLadder,
                                  ExecutorCrash, FaultInjector)

if TYPE_CHECKING:  # typing only — runtime must not import its backends
    from repro.core.base import Scheduler
    from repro.serving.traffic import TraceRequest

# on_token(req_id, token_or_None, t) — called once per emitted token, in
# emission order, timestamped at the end of the iteration that produced it
TokenCallback = Callable[[int, Optional[int], float], None]


@dataclass(frozen=True)
class TokenEvent:
    """One token emitted by an executor step. ``token`` is the real id on
    the engine, None on the simulator. ``first`` marks tokens produced by
    an emitting prefill slice — the runtime decides whether that is the
    request's TRUE first token or a recompute-epoch continuation."""
    req_id: int
    token: Optional[int]
    first: bool = False


@dataclass
class StepOutcome:
    """What one executed iteration reports back to the loop."""
    duration: float
    events: List[TokenEvent] = field(default_factory=list)
    # engine-level device launches this iteration (embed + packed prefill
    # batches + decode); 0 for analytic backends.  Surfaced so serving
    # harnesses can track dispatch pressure without poking the engine.
    n_dispatches: int = 0


def timestamp_events(sched, events: List[TokenEvent], t_end: float,
                     on_token: Optional[TokenCallback] = None) -> None:
    """THE timestamping rule, shared by the runtime loop and the engine's
    legacy hand-stepping path: tokens become visible at iteration end;
    the first token of a recompute epoch is a CONTINUATION — TTFT stays
    pinned to the original first emission; finish times stamp when the
    scheduler bookkeeping (or an engine-side EOS) has moved the request
    to DONE."""
    for ev in events:
        r = sched.requests[ev.req_id]
        if ev.first and r.first_token_time is None:
            r.first_token_time = t_end
        else:
            r.token_times.append(t_end)
        if r.state == RequestState.DONE and r.finish_time is None:
            r.finish_time = t_end
        if on_token is not None:
            on_token(ev.req_id, ev.token, t_end)


def diagnose_stall(reason: str, pools, *, pending: int = 0, held: int = 0,
                   migrations: int = 0, max_rows: int = 12) -> str:
    """Build the no-progress / failed-drain diagnostic: per-pool queue
    depths, allocator free/in-use/high-water, a per-state census, and a
    bounded per-request table — raised instead of a bare "no progress"
    message so a hang is debuggable from the exception text alone.
    ``pools`` is a sequence of (tag, scheduler) pairs."""
    lines = [reason,
             f"pending_arrivals={pending} held={held} "
             f"migrations_in_flight={migrations}"]
    for tag, s in pools:
        states: Dict[str, int] = {}
        for r in s.requests.values():
            states[r.state.name] = states.get(r.state.name, 0) + 1
        kv = s.kv
        kv_line = "kv=unbounded"
        if kv is not None:
            kv_line = (f"kv free={kv.n_free_pages}/{kv.n_pages} "
                       f"in_use={kv.pages_in_use()} "
                       f"hwm={kv.pages_high_water} "
                       f"host={kv.host_pages_in_use()}/{kv.n_host_pages}")
        lines.append(f"[{tag}] sched={s.name!r} waiting={len(s.waiting)} "
                     f"active={s.n_active} states={states or '{}'} "
                     f"{kv_line}")
        live = [r for r in sorted(s.requests.values(),
                                  key=lambda r: r.req_id)
                if r.state != RequestState.DONE]
        for r in live[:max_rows]:
            lines.append(f"  r{r.req_id} {r.state.name} "
                         f"class={r.slo_class} prompt={r.prompt_len} "
                         f"tokens_done={r.tokens_done} "
                         f"gen={r.n_generated} "
                         f"preempts={r.n_preemptions} swaps={r.n_swaps}")
        if len(live) > max_rows:
            lines.append(f"  ... and {len(live) - max_rows} more")
    return "\n".join(lines)


class Executor(Protocol):
    """Backend protocol: the runtime never touches jax or the cost model
    directly — it schedules, clocks and timestamps; the executor runs."""
    scheduler: "Scheduler"

    def submit(self, spec: SubmitSpec, now: float) -> Request:
        """Create + submit the request for an arriving SubmitSpec (the
        unified ingestion record — trace items convert via
        ``TraceRequest.to_spec``).  A spec without an arrival time is
        stamped at ``now`` in the executor's clock unit."""
        ...

    def execute(self, plan: IterationPlan, now: float) -> StepOutcome:
        """Run one iteration plan; return its duration and token events."""
        ...

    def idle(self, t: float, until: float) -> float:
        """Advance the executor clock from ``t`` to ``until`` with no work
        resident (wall executors sleep); returns the new clock value."""
        ...

    def poll_clock(self, t: float) -> float:
        """The executor's CURRENT clock reading given the loop's last value
        ``t`` — wall executors re-read the monotonic clock (live-feed
        idling advances time without an ``idle`` target), virtual clocks
        return ``t`` unchanged."""
        ...

    def initial_clock(self) -> float:
        """Where this run's clock starts.  The engine's iteration clock
        resumes from its persistent iteration counter so a second run()
        cannot stamp tokens EARLIER than requests submitted after the
        first (TTFT stays positive across incremental submit/run
        cycles); fresh backends start at 0."""
        ...

    def evict(self, req_id: int) -> None:
        """Release the backend's physical state for a resident the
        SCHEDULER just preempted outside a plan (fault recovery): the
        executor-side half of ``Scheduler.preempt``, normally run by
        ``execute`` for ``plan.preempted_ids``.  No-op for analytic
        backends."""
        ...

    def release(self, req_id: int) -> None:
        """Release the backend's physical state for a SHED request (any
        pre-DONE state) — the executor-side mirror of
        ``Scheduler.shed``.  No-op for analytic backends."""
        ...


class SubmitTicket:
    """One live submission in flight through a ``SubmitQueue``: the serving
    loop resolves it (engine thread) when the spec is actually submitted,
    after which ``request`` is the backend's live Request.  ``on_submit``
    fires synchronously IN the serving-loop thread right after submission
    and strictly before any of the request's tokens are emitted — the HTTP
    front-end registers its per-request token stream there, so no token
    can race past an unregistered stream."""

    __slots__ = ("spec", "on_submit", "on_fail", "request", "error", "_done")

    def __init__(self, spec: SubmitSpec,
                 on_submit: Optional[Callable[[Request], None]] = None,
                 on_fail: Optional[Callable[[BaseException], None]] = None):
        self.spec = spec
        self.on_submit = on_submit
        self.on_fail = on_fail
        self.request: Optional[Request] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def _resolve(self, request: Request) -> None:
        self.request = request
        if self.on_submit is not None:
            self.on_submit(request)
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self.error = exc
        if self.on_fail is not None:
            self.on_fail(exc)
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> Request:
        """Block until the serving loop picked this spec up; re-raise its
        submission error (bad request) in the waiting thread."""
        if not self._done.wait(timeout):
            raise TimeoutError("submission not picked up by serving loop")
        if self.error is not None:
            raise self.error
        return self.request


class SubmitQueue:
    """Thread-safe live-ingestion channel bridging concurrent producers
    (HTTP handler threads / asyncio callbacks) into the single-threaded
    serving loop: producers ``put`` SubmitSpecs, the loop drains them at
    every iteration boundary and blocks on ``wait`` while idle instead of
    spinning.  ``close`` ends the stream — the loop finishes whatever is
    already queued or resident, then returns."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: deque = deque()
        self._wake = threading.Event()
        self._closed = False

    def put(self, spec: SubmitSpec,
            on_submit: Optional[Callable[[Request], None]] = None,
            on_fail: Optional[Callable[[BaseException], None]] = None) \
            -> SubmitTicket:
        ticket = SubmitTicket(spec, on_submit, on_fail)
        with self._lock:
            if self._closed:
                raise RuntimeError("submit queue is closed")
            self._items.append(ticket)
            self._wake.set()
        return ticket

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._wake.set()

    @property
    def backlog(self) -> int:
        return len(self._items)

    @property
    def exhausted(self) -> bool:
        """True once closed AND fully drained — the loop's stop signal."""
        with self._lock:
            return self._closed and not self._items

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until an item arrives or the queue closes (the serving
        loop's idle wakeup).  Returns True if something may be pending."""
        return self._wake.wait(timeout)

    def drain(self) -> List[SubmitTicket]:
        with self._lock:
            items = list(self._items)
            self._items.clear()
            if not self._closed:
                self._wake.clear()
            return items


@dataclass
class RunResult:
    """Backend-agnostic outcome of one ``ServingRuntime.run``. Executors
    layer their own accounting on top (see ``simulator.SimResult``)."""
    requests: List[Request] = field(default_factory=list)
    n_iterations: int = 0
    clock: float = 0.0             # final clock value (sim_time / iterations)
    decode_batch_sizes: List[int] = field(default_factory=list)
    n_preemptions: int = 0
    recompute_tokens: int = 0      # prefill tokens re-run due to preemption
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    n_dispatches: int = 0          # total device launches (engine backends)


class _Supervised:
    """Fault supervision shared by ``ServingRuntime`` and
    ``DisaggRuntime`` (DESIGN.md §Fault tolerance): per-request deadline
    shedding, bounded retry through the existing PREEMPTED/recompute
    machinery (a failed step is just an eviction with a retry budget),
    thread-safe client cancellation, and the graceful-degradation
    ladder.  Every recovery path reuses machinery the equivalence tests
    already pin down, which is why surviving requests' token streams
    stay bit-identical to a fault-free run."""

    # shed reason -> counter attribute (unknown reasons count as
    # disconnects — the catch-all for operator-initiated cancels)
    _SHED_COUNTERS = {"deadline": "n_deadline_sheds",
                      "retries": "n_retry_sheds",
                      "disconnect": "n_disconnect_sheds",
                      "degrade": "n_degrade_sheds"}

    def _init_supervision(self, schedulers, *,
                          faults: Optional[FaultInjector],
                          retry_budget: int,
                          ladder: Optional[DegradationLadder],
                          on_shed) -> None:
        self.faults = faults
        self.retry_budget = retry_budget
        self.ladder = ladder if ladder is not None \
            else DegradationLadder(schedulers)
        self.on_shed = on_shed
        self._cancel_lock = threading.Lock()
        self._cancels: deque = deque()
        self.n_deadline_sheds = 0
        self.n_retry_sheds = 0
        self.n_disconnect_sheds = 0
        self.n_degrade_sheds = 0
        self.n_fault_retries = 0

    # -- client cancellation (any thread) -----------------------------------

    def cancel(self, req_id: int, reason: str = "disconnect") -> None:
        """Request cancellation from ANY thread (the HTTP front-end's
        disconnect handler): queued here, applied at the next iteration
        boundary IN the serving-loop thread — the only place scheduler
        and executor state may be touched.  Unknown or already-finished
        ids are ignored."""
        with self._cancel_lock:
            self._cancels.append((req_id, reason))

    def _drain_cancel_queue(self) -> List:
        if not self._cancels:
            return []
        with self._cancel_lock:
            items = list(self._cancels)
            self._cancels.clear()
        return items

    # -- shedding -----------------------------------------------------------

    def _count_shed(self, reason: str) -> None:
        attr = self._SHED_COUNTERS.get(reason, "n_disconnect_sheds")
        setattr(self, attr, getattr(self, attr) + 1)

    def _shed_request(self, sched, x, rid: int, reason: str,
                      iteration: int) -> None:
        """Shed one request end to end: scheduler side (pages freed, queue
        scrubbed, DONE + shed_reason) plus the executor's physical state
        (slot/stash/host snapshot), then notify ``on_shed`` so front-end
        streams can terminate."""
        r = sched.requests[rid]
        sched.shed(rid, reason)
        release = getattr(x, "release", None)
        if release is not None:
            release(rid)
        self._count_shed(reason)
        if reason in ("deadline", "retries"):
            self.ladder.record_pressure(iteration)
        if self.on_shed is not None:
            self.on_shed(r, reason)

    def _shed_batch_class(self, pools, iteration: int) -> None:
        if not self.ladder.shed_batch:
            return
        for sched, x in pools:
            for rid in [rid for rid, r in sorted(sched.requests.items())
                        if r.state != RequestState.DONE
                        and self.ladder.shed_class(r.slo_class)]:
                self._shed_request(sched, x, rid, "degrade", iteration)

    # -- deadlines ----------------------------------------------------------

    def _deadline_scale(self, x) -> float:
        # wall executors clock in seconds, so deadline_ms really is
        # milliseconds; virtual clocks read it in their own units
        # (iterations on the deterministic clock, modeled seconds on the
        # simulator) — deterministic replay stays deterministic
        return 1e-3 if getattr(x, "wall", False) else 1.0

    @staticmethod
    def _expired(r: Request, now: float, scale: float) -> bool:
        return (r.deadline_ms is not None
                and r.state != RequestState.DONE
                and now >= r.arrival_time + r.deadline_ms * scale)

    def _check_deadlines(self, sched, x, now: float,
                         iteration: int) -> bool:
        scale = self._deadline_scale(x)
        expired = [rid for rid, r in sorted(sched.requests.items())
                   if self._expired(r, now, scale)]
        for rid in expired:
            self._shed_request(sched, x, rid, "deadline", iteration)
        return bool(expired)

    # -- injected faults ----------------------------------------------------

    def _recover_crash(self, sched, x, res, iteration: int) -> None:
        """Executor-step failure: every PREFILL/DECODE resident is evicted
        (latest-arrival-first, so head-requeueing leaves the earliest in
        front) and recovered through the recompute path; a victim over
        its retry budget is shed instead.  SWAPPED residents keep their
        intact host copy — a crash does not touch host memory."""
        victims = sorted((r for r in sched.requests.values()
                          if r.state in (RequestState.PREFILL,
                                         RequestState.DECODE)),
                         key=lambda r: (r.arrival_time, r.req_id),
                         reverse=True)
        evict = getattr(x, "evict", None)
        for r in victims:
            rid = r.req_id
            if r.n_fault_retries >= self.retry_budget:
                self._shed_request(sched, x, rid, "retries", iteration)
                continue
            r.n_fault_retries += 1
            self.n_fault_retries += 1
            sched.preempt(rid)
            if evict is not None:
                evict(rid)
            res.n_preemptions += 1
            res.recompute_tokens += r.prompt_len
        self.ladder.record_pressure(iteration)

    def _fail_swap_dma(self, sched, plan: IterationPlan,
                       iteration: int) -> None:
        """swap_dma_fail: this iteration's swap-out DMA batch failed —
        demote the victims to recompute evictions BEFORE the executor
        runs, so the engine releases their slots via the preempt path
        instead of snapshotting dead data to host.  Armed until an
        iteration with swap activity."""
        if self.faults is None or not plan.swapped_out_ids:
            return
        if not self.faults.due("swap_dma_fail", iteration):
            return
        for rid in list(plan.swapped_out_ids):
            sched.fail_swap_out(rid)
            plan.preempted_ids.append(rid)
        plan.swapped_out_ids.clear()
        self.ladder.record_pressure(iteration)

    def _inject_disconnects(self, pools, iteration: int) -> bool:
        """client_disconnect: shed the ``target``-th live request (rid
        order) as if its SSE peer vanished mid-stream."""
        if self.faults is None:
            return False
        live = [(sched, x, rid)
                for sched, x in pools
                for rid, r in sorted(sched.requests.items())
                if r.state != RequestState.DONE]
        if not live:
            return False
        acted = False
        for ev in self.faults.due("client_disconnect", iteration):
            if not live:
                break
            sched, x, rid = live.pop(ev.target % len(live))
            self._shed_request(sched, x, rid, "disconnect", iteration)
            acted = True
        return acted

    # -- metrics ------------------------------------------------------------

    def fault_stats(self) -> Dict[str, float]:
        """Counter snapshot shaped as ``metrics.fault_counters`` kwargs —
        the one schema the /metrics endpoint, offline reports, and the CI
        chaos gate all read."""
        c = dict(self.faults.counters) if self.faults is not None \
            else {f"n_{k}": 0 for k in FAULT_KINDS}
        return {
            "n_injected_faults": float(sum(c.values())),
            "n_executor_crashes": c["n_executor_crash"],
            "n_link_drops": c["n_link_drop"],
            "n_link_delays": c["n_link_delay"],
            "n_swap_dma_fails": c["n_swap_dma_fail"],
            "n_pressure_spikes": c["n_pressure_spike"],
            "n_injected_disconnects": c["n_client_disconnect"],
            "n_deadline_sheds": self.n_deadline_sheds,
            "n_retry_sheds": self.n_retry_sheds,
            "n_disconnect_sheds": self.n_disconnect_sheds,
            "n_degrade_sheds": self.n_degrade_sheds,
            "n_fault_retries": self.n_fault_retries,
            "degradation_level": self.ladder.level_index,
            "n_degradation_escalations": self.ladder.n_escalations,
            "n_degradation_deescalations": self.ladder.n_deescalations,
        }


class ServingRuntime(_Supervised):
    def __init__(self, executor: Executor, *,
                 on_token: Optional[TokenCallback] = None,
                 clock: str = "executor",
                 record_plans: bool = False,
                 faults: Optional[FaultInjector] = None,
                 retry_budget: int = 3,
                 ladder: Optional[DegradationLadder] = None,
                 on_shed: Optional[Callable[[Request, str], None]] = None):
        """``faults`` attaches a deterministic fault injector (see
        serving/faults.py); ``retry_budget`` bounds per-request crash
        recoveries before the victim is shed; ``on_shed(req, reason)``
        fires in the serving-loop thread whenever a request is removed
        without completing (deadline, retries, disconnect, degrade)."""
        if clock not in ("executor", "iteration"):
            raise ValueError(f"unknown clock {clock!r}")
        self.executor = executor
        self.on_token = on_token
        self.clock = clock
        self.record_plans = record_plans
        self.plans: List[IterationPlan] = []
        self._init_supervision([executor.scheduler], faults=faults,
                               retry_budget=retry_budget, ladder=ladder,
                               on_shed=on_shed)

    def _supervise(self, sched, x, res, t: float, it: int) -> bool:
        """One pre-plan supervision pass: queued cancels, deadline sheds,
        injected faults (allocator pressure, executor crash, client
        disconnects), then the degradation ladder.  Runs BEFORE
        ``next_plan`` so every recovery is a plain eviction — no plan
        bookkeeping has advanced against state that never executed.
        Returns True when the pass consumed all resident work."""
        for rid, reason in self._drain_cancel_queue():
            r = sched.requests.get(rid)
            if r is not None and r.state != RequestState.DONE:
                self._shed_request(sched, x, rid, reason, it)
        self._check_deadlines(sched, x, t, it)
        f = self.faults
        if f is not None:
            f.release_pressure(it)
            f.apply_pressure([sched.kv], it)
            try:
                f.maybe_crash(it, active=sched.n_active > 0)
            except ExecutorCrash:
                self._recover_crash(sched, x, res, it)
            self._inject_disconnects([(sched, x)], it)
        self.ladder.step(it)
        self._shed_batch_class([(sched, x)], it)
        return not sched.has_work()

    def run(self, trace: Sequence[Union["TraceRequest", SubmitSpec]] = (),
            max_iterations: int = 10_000, *,
            feed: Optional[SubmitQueue] = None,
            idle_poll: float = 0.05) -> RunResult:
        """Replay ``trace`` open-loop (requests injected at their arrival
        times; the loop idles to the next arrival when the pool drains)
        and drain everything already submitted to the scheduler.  An empty
        trace is the closed-loop drain the engine's legacy ``run`` was.

        ``feed`` attaches a live ``SubmitQueue``: specs arriving from
        other threads are injected at every iteration boundary (arrival
        stamped at the current clock when the spec carries none), and when
        the pool drains the loop BLOCKS on the queue (granularity
        ``idle_poll`` seconds) instead of exiting — the serving loop of
        the HTTP front-end.  The run returns once the feed is closed and
        drained and no work remains."""
        x = self.executor
        sched = x.scheduler
        res = RunResult(
            # closed-loop requests submitted before run() — id order
            requests=[sched.requests[k] for k in sorted(sched.requests)])
        pending = sorted(trace, key=lambda tr: tr.arrival_time)
        i_arr = 0
        t = float(x.initial_clock())

        def inject(now: float) -> None:
            nonlocal i_arr
            while i_arr < len(pending) \
                    and pending[i_arr].arrival_time <= now:
                tr = pending[i_arr]
                spec = tr.to_spec() if hasattr(tr, "to_spec") else tr
                res.requests.append(x.submit(spec, now))
                i_arr += 1
            if feed is not None:
                for ticket in feed.drain():
                    try:
                        req = x.submit(ticket.spec, now)
                    except Exception as e:     # bad spec: report, keep going
                        ticket._fail(e)
                        continue
                    res.requests.append(req)
                    ticket._resolve(req)

        def live() -> bool:
            return feed is not None and not feed.exhausted

        while i_arr < len(pending) or sched.has_work() or live():
            inject(t)
            if not sched.has_work():
                if live():
                    # live idle: block on the feed (bounded so wall clocks
                    # stay responsive to close/shutdown), then re-read the
                    # executor clock — arrivals are stamped at real idle
                    # time, not at the last iteration's end
                    feed.wait(idle_poll)
                    t = max(t, x.poll_clock(t))
                    continue
                if i_arr >= len(pending):
                    break          # feed closed + drained, nothing pending
                # open-loop idle: fast-forward (or, on a wall clock, sleep)
                # to the next arrival instead of raising "did not drain"
                nxt = pending[i_arr].arrival_time
                t = nxt if self.clock == "iteration" else x.idle(t, nxt)
                inject(t)
            if res.n_iterations >= max_iterations:
                raise RuntimeError(diagnose_stall(
                    f"did not drain within {max_iterations} iterations; "
                    "scheduler stuck?", [("pool", sched)],
                    pending=len(pending) - i_arr))
            if self._supervise(sched, x, res, t, res.n_iterations):
                continue       # supervision consumed all resident work
            plan = sched.next_plan(now=t)
            self._fail_swap_dma(sched, plan, res.n_iterations)
            if self.record_plans:
                self.plans.append(plan)
            res.n_preemptions += len(plan.preempted_ids)
            res.recompute_tokens += sum(
                sched.requests[rid].prompt_len
                for rid in plan.preempted_ids)
            res.n_swap_outs += len(plan.swapped_out_ids)
            res.n_swap_ins += len(plan.swapped_in_ids)
            if plan.empty:
                if i_arr < len(pending):
                    # nothing runnable yet — fast-forward to the arrival
                    # that will create work (t never moves backwards)
                    t = max(t, pending[i_arr].arrival_time)
                    continue
                # no runnable work, no future arrivals: advancing neither
                # t nor the iteration count would spin forever
                raise RuntimeError(diagnose_stall(
                    f"scheduler {sched.name!r} made no progress at t={t}: "
                    "no pending arrivals and the next plan is empty",
                    [("pool", sched)]))
            outcome = x.execute(plan, t)
            res.n_iterations += 1
            res.n_dispatches += outcome.n_dispatches
            res.decode_batch_sizes.append(len(plan.decode_ids))
            t_end = t + (1.0 if self.clock == "iteration"
                         else outcome.duration)
            timestamp_events(sched, outcome.events, t_end, self.on_token)
            t = t_end

        if self.faults is not None:
            self.faults.release_pressure(None)   # zero-leak: no phantom
        res.clock = t                            # reservation survives a run
        return res


@dataclass
class Migration:
    """One prefill→decode handoff in flight: the migrating Request, a
    backend-opaque payload (KV export + physical state), and the link
    timeline — ``ready_time`` is when the last KV byte lands on the decode
    side (== ``export_time`` plus the residual transfer the remaining
    prefill compute could not hide; equal to ``export_time`` on the engine,
    whose chunks were host-staged through the per-iteration fetch)."""
    req: Request
    payload: object
    export_time: float
    ready_time: float
    n_chunks: int = 0
    bytes_total: float = 0.0


class HandoffBridge(Protocol):
    """Backend-specific mechanics of the prefill→decode KV handoff; the
    ``DisaggRuntime`` decides WHEN to stage/export/import, the bridge knows
    HOW (engine: host-staged cache rows; simulator: priced link FIFO)."""

    def decode_free_pages(self) -> int:
        """Free pages on the decode pool's allocator (watermark signal)."""
        ...

    def stage(self, plan: IterationPlan, requests: Dict[int, Request],
              t_end: float, duration: float) -> None:
        """Observe one executed prefill-pool plan: layer groups whose KV
        completed this iteration enter the per-request handoff stream
        (simulator link model; the engine stages inside execute_plan)."""
        ...

    def export(self, req: Request, now: float) -> Migration:
        """Pull the migrating request's KV/state off the prefill backend
        (the scheduler has already ``pop_request``-ed it)."""
        ...

    def can_import(self, m: Migration) -> bool:
        """True iff the decode backend can take the payload right now."""
        ...

    def do_import(self, m: Migration, now: float) -> Dict[str, int]:
        """Install the payload on the decode backend; returns the
        ``{"linked_tokens", "moved_tokens"}`` split (pages already warm on
        the decode pool link for free — KV-locality routing's win)."""
        ...

    def drop(self, req_id: int) -> None:
        """A prefill-pool preemption voided any staged chunks."""
        ...

    def abort_export(self, m: Migration) -> None:
        """A link failure lost migration ``m`` in flight: reinstall the
        victim's backend state on the prefill side so a whole-prompt
        recompute retry can run (the KV payload itself died with the
        link — export's move semantics already freed it, nothing
        leaks)."""
        ...

    def return_to_prefill(self, req: Request) -> None:
        """Move a decode-pool recompute victim's backend state (prompt /
        output buffers) back to the prefill backend before readmission."""
        ...


@dataclass
class DisaggRunResult(RunResult):
    """``RunResult`` plus the two-pool accounting: per-pool iteration
    counts, migration/handoff traffic, and the link-stall totals.
    ``decode_prefill_slices`` MUST stay 0 — the decode pool's iteration
    clock never contains prefill work (its TBT is prefill-free by
    construction; the CI gate asserts the counter)."""
    n_prefill_iterations: int = 0
    n_decode_iterations: int = 0
    n_migrations: int = 0
    n_returns: int = 0             # recompute victims routed back to prefill
    handoff_bytes: float = 0.0     # payload bytes that crossed the link
    link_stall_time: float = 0.0   # export→ready residual (unhidden) time
    handoff_wait_time: float = 0.0  # export→import total (stall + capacity)
    migration_queue_peak: int = 0
    held_peak: int = 0             # watermark-backpressured arrivals
    decode_prefill_slices: int = 0


class DisaggRuntime(_Supervised):
    """Two-pool disaggregated serving loop (DESIGN.md §Disaggregated
    serving): a prefill executor and a decode executor advance under ONE
    runtime clock.  Requests are admitted and prefilled on the prefill
    pool; as each layer group's KV completes it streams toward the decode
    pool (bridge-managed), and when the final group emits the first token
    the request is exported, crosses the link, and is ``adopt``-ed by the
    decode pool, which runs decode-only iterations forever after.  Decode-
    pool recompute victims fold and route BACK to the prefill pool (the
    decode pool cannot prefill); swap victims restore locally.

    Clock semantics mirror ``ServingRuntime``: ``clock="iteration"``
    advances both pools in lockstep 1.0 per iteration (deterministic
    engine replay — token streams bit-identical to monolithic serving);
    ``clock="executor"`` gives each pool its own event-driven ready time,
    so decode-pool timestamps contain ONLY decode durations — the
    prefill-free-TBT property the paper's disaggregation argument needs.

    ``decode_watermark_pages`` backpressures admission: new arrivals are
    HELD (not submitted to the prefill pool) while the decode pool's free
    pages sit below the watermark, so prefill work whose handoff would
    have nowhere to land is never started."""

    def __init__(self, prefill: Executor, decode: Executor,
                 bridge: HandoffBridge, *,
                 on_token: Optional[TokenCallback] = None,
                 clock: str = "executor",
                 decode_watermark_pages: int = 0,
                 record_plans: bool = False,
                 faults: Optional[FaultInjector] = None,
                 retry_budget: int = 3,
                 ladder: Optional[DegradationLadder] = None,
                 on_shed: Optional[Callable[[Request, str], None]] = None):
        if clock not in ("executor", "iteration"):
            raise ValueError(f"unknown clock {clock!r}")
        self.prefill = prefill
        self.decode = decode
        self.bridge = bridge
        self.on_token = on_token
        self.clock = clock
        self.decode_watermark_pages = decode_watermark_pages
        self.record_plans = record_plans
        self.plans: List = []          # (pool_tag, IterationPlan)
        self._init_supervision([prefill.scheduler, decode.scheduler],
                               faults=faults, retry_budget=retry_budget,
                               ladder=ladder, on_shed=on_shed)

    # -- disagg-specific supervision ----------------------------------------

    def _shed_request(self, sched, x, rid: int, reason: str,
                      iteration: int) -> None:
        _Supervised._shed_request(self, sched, x, rid, reason, iteration)
        self.bridge.drop(rid)      # staged handoff chunks die with it

    def _shed_migration(self, m: Migration, reason: str,
                        iteration: int) -> None:
        """Shed a request caught mid-migration: its KV pages were already
        freed from the prefill pool by the export's move semantics and
        never landed on the decode pool, so discarding the payload leaks
        nothing — only the control record needs retiring."""
        r = m.req
        r.state = RequestState.DONE
        r.shed_reason = reason
        self._count_shed(reason)
        if reason in ("deadline", "retries"):
            self.ladder.record_pressure(iteration)
        if self.on_shed is not None:
            self.on_shed(r, reason)

    def _drop_migration(self, m: Migration, res, iteration: int) -> None:
        """link_drop recovery: the payload is lost in flight, but the
        request is NEVER lost — it folds for recompute and re-enters the
        prefill pool's queue at the head (whole-prompt retry).  Victims
        over their retry budget are shed instead."""
        req = m.req
        if req.n_fault_retries >= self.retry_budget:
            self._shed_migration(m, "retries", iteration)
            return
        req.n_fault_retries += 1
        self.n_fault_retries += 1
        fold_for_recompute(req)
        abort = getattr(self.bridge, "abort_export", None)
        if abort is not None:
            abort(m)
        sp = self.prefill.scheduler
        sp.readmit(req)
        sp.n_preemptions += 1
        res.n_preemptions += 1
        res.recompute_tokens += req.prompt_len
        self.ladder.record_pressure(iteration)

    def _recover_decode_crash(self, res, iteration: int) -> None:
        """Decode-pool executor crash: recompute victims cannot re-prefill
        locally (the decode pool never plans prefill), so each one folds
        and routes BACK to the prefill pool — exactly the plan-level
        recompute-victim return path.  SWAPPED residents keep their host
        copy and restore locally."""
        sp, sd = self.prefill.scheduler, self.decode.scheduler
        xd, bridge = self.decode, self.bridge
        victims = sorted((r for r in sd.requests.values()
                          if r.state == RequestState.DECODE),
                         key=lambda r: (r.arrival_time, r.req_id),
                         reverse=True)
        evict = getattr(xd, "evict", None)
        for r in victims:
            rid = r.req_id
            if r.n_fault_retries >= self.retry_budget:
                self._shed_request(sd, xd, rid, "retries", iteration)
                continue
            r.n_fault_retries += 1
            self.n_fault_retries += 1
            sd.preempt(rid)
            if evict is not None:
                evict(rid)
            req = sd.pop_request(rid)
            bridge.return_to_prefill(req)
            sp.readmit(req)
            res.n_returns += 1
            res.n_preemptions += 1
            res.recompute_tokens += req.prompt_len
        self.ladder.record_pressure(iteration)

    def _supervise(self, migr: deque, held: deque, res, t: float,
                   it: int) -> bool:
        """Pre-step supervision over BOTH pools, the link queue, and the
        backpressure-held arrivals.  Returns True when it changed pool
        state (the caller resets the stall latches)."""
        sp, sd = self.prefill.scheduler, self.decode.scheduler
        xp, xd = self.prefill, self.decode
        acted = False
        # queued client cancels: the victim may live on either pool or be
        # mid-migration on the link
        for rid, reason in self._drain_cancel_queue():
            shed = False
            for sched, x in ((sp, xp), (sd, xd)):
                r = sched.requests.get(rid)
                if r is not None and r.state != RequestState.DONE:
                    self._shed_request(sched, x, rid, reason, it)
                    shed = acted = True
                    break
            if not shed:
                for m in list(migr):
                    if m.req.req_id == rid:
                        migr.remove(m)
                        self._shed_migration(m, reason, it)
                        acted = True
                        break
        # deadlines: both pools, in-flight migrations, held arrivals
        for sched, x in ((sp, xp), (sd, xd)):
            acted |= self._check_deadlines(sched, x, t, it)
        scale = self._deadline_scale(xp)
        for m in [m for m in migr if self._expired(m.req, t, scale)]:
            migr.remove(m)
            self._shed_migration(m, "deadline", it)
            acted = True
        for item in [h for h in held
                     if getattr(h[0], "deadline_ms", None) is not None
                     and getattr(h[0], "arrival_time", None) is not None
                     and t >= h[0].arrival_time
                     + h[0].deadline_ms * scale]:
            held.remove(item)
            _, ticket = item
            self.n_deadline_sheds += 1
            self.ladder.record_pressure(it)
            if ticket is not None:
                ticket._fail(TimeoutError(
                    "deadline expired before admission"))
            acted = True
        f = self.faults
        if f is not None:
            f.release_pressure(it)
            f.apply_pressure([sp.kv, sd.kv], it)
            if migr:
                # latency spike: queued payloads land late — the import
                # gate re-reads ready_time, token values never change
                for ev in f.due("link_delay", it):
                    for m in migr:
                        m.ready_time += ev.magnitude
                for ev in f.due("link_drop", it):
                    if not migr:
                        break
                    m = migr[ev.target % len(migr)]
                    migr.remove(m)
                    self._drop_migration(m, res, it)
                    acted = True
            try:
                f.maybe_crash(it, pool=0, active=sp.n_active > 0)
            except ExecutorCrash:
                self._recover_crash(sp, xp, res, it)
                for rid, r in sp.requests.items():
                    if r.state == RequestState.PREEMPTED:
                        self.bridge.drop(rid)   # staged KV is void
                acted = True
            try:
                f.maybe_crash(it, pool=1, active=sd.n_active > 0)
            except ExecutorCrash:
                self._recover_decode_crash(res, it)
                acted = True
            acted |= self._inject_disconnects([(sp, xp), (sd, xd)], it)
        self.ladder.step(it)
        before = self.n_degrade_sheds
        self._shed_batch_class([(sp, xp), (sd, xd)], it)
        acted |= self.n_degrade_sheds != before
        return acted

    def run(self, trace: Sequence[Union["TraceRequest", SubmitSpec]] = (),
            max_iterations: int = 10_000, *,
            feed: Optional[SubmitQueue] = None,
            idle_poll: float = 0.05) -> DisaggRunResult:
        xp, xd, bridge = self.prefill, self.decode, self.bridge
        sp, sd = xp.scheduler, xd.scheduler
        step = self.clock == "iteration"
        res = DisaggRunResult(
            requests=[sp.requests[k] for k in sorted(sp.requests)])
        pending = sorted(trace, key=lambda tr: tr.arrival_time)
        i_arr = 0
        t = max(float(xp.initial_clock()), float(xd.initial_clock()))
        rp = rd = t                    # per-pool next-ready clocks
        held: deque = deque()          # (spec, ticket|None) backpressured
        migr: deque = deque()          # Migration FIFO (link order)
        # a pool whose last attempt produced an empty plan is stalled until
        # some OTHER event (arrival, import, return, other-pool iteration)
        # can change its state — re-planning the same state would spin
        stall_p = stall_d = False

        def live() -> bool:
            return feed is not None and not feed.exhausted

        def inject(now: float) -> bool:
            nonlocal i_arr
            n0 = len(held)
            while i_arr < len(pending) \
                    and pending[i_arr].arrival_time <= now:
                held.append((pending[i_arr], None))
                i_arr += 1
            if feed is not None:
                for ticket in feed.drain():
                    held.append((ticket.spec, ticket))
            res.held_peak = max(res.held_peak, len(held))
            return len(held) > n0

        def admit_held(now: float) -> bool:
            n = 0
            while held:
                if self.decode_watermark_pages > 0 \
                        and bridge.decode_free_pages() \
                        < self.decode_watermark_pages:
                    break              # decode pool must drain first
                item, ticket = held.popleft()
                spec = item.to_spec() if hasattr(item, "to_spec") else item
                try:
                    req = xp.submit(spec, now)
                except Exception as e:
                    if ticket is None:
                        raise
                    ticket._fail(e)
                    continue
                res.requests.append(req)
                if ticket is not None:
                    ticket._resolve(req)
                n += 1
            return n > 0

        def attempt_imports(now: float) -> bool:
            n = 0
            while migr and migr[0].ready_time <= now:
                m = migr[0]
                if not (sd.can_adopt(m.req) and bridge.can_import(m)):
                    if not sd.has_work():
                        raise RuntimeError(diagnose_stall(
                            f"decode pool can never import request "
                            f"{m.req.req_id} — enlarge the decode pool",
                            [("prefill", sp), ("decode", sd)],
                            pending=len(pending) - i_arr,
                            held=len(held), migrations=len(migr)))
                    break              # FIFO: wait for the decode pool
                migr.popleft()
                info = bridge.do_import(m, now)
                sd.adopt(m.req)
                m.req.n_handoffs += 1
                m.req.handoff_linked_tokens += info.get("linked_tokens", 0)
                m.req.handoff_moved_tokens += info.get("moved_tokens", 0)
                m.req.handoff_time = now
                res.handoff_wait_time += now - m.export_time
                res.n_migrations += 1
                n += 1
            return n > 0

        while i_arr < len(pending) or held or migr \
                or sp.has_work() or sd.has_work() or live():
            acted = inject(t)
            acted |= admit_held(t)
            acted |= attempt_imports(t)
            acted |= self._supervise(migr, held, res, t, res.n_iterations)
            if acted:
                stall_p = stall_d = False

            executed = False
            if sp.has_work() and rp <= t and not stall_p:
                plan = sp.next_plan(now=t)
                self._fail_swap_dma(sp, plan, res.n_iterations)
                if plan.empty:
                    stall_p = True
                else:
                    if self.record_plans:
                        self.plans.append(("prefill", plan))
                    for rid in plan.preempted_ids:
                        bridge.drop(rid)
                    res.n_preemptions += len(plan.preempted_ids)
                    res.recompute_tokens += sum(
                        sp.requests[rid].prompt_len
                        for rid in plan.preempted_ids)
                    res.n_swap_outs += len(plan.swapped_out_ids)
                    res.n_swap_ins += len(plan.swapped_in_ids)
                    outcome = xp.execute(plan, t)
                    dur = 1.0 if step else outcome.duration
                    t_end = t + dur
                    bridge.stage(plan, sp.requests, t_end, dur)
                    timestamp_events(sp, outcome.events, t_end,
                                     self.on_token)
                    res.n_iterations += 1
                    res.n_prefill_iterations += 1
                    res.n_dispatches += outcome.n_dispatches
                    rp = t_end
                    # completed prefills migrate NOW: the pool is pure
                    # prefill — first-token emitters leave for the decode
                    # pool the moment their last layer group finishes
                    for rid in sorted(
                            r.req_id for r in sp.requests.values()
                            if r.state == RequestState.DECODE):
                        req = sp.pop_request(rid)
                        m = bridge.export(req, t_end)
                        req.n_handoff_chunks += m.n_chunks
                        res.handoff_bytes += m.bytes_total
                        res.link_stall_time += max(
                            0.0, m.ready_time - m.export_time)
                        migr.append(m)
                    res.migration_queue_peak = max(
                        res.migration_queue_peak, len(migr))
                    executed = True
                    stall_d = False

            if sd.has_work() and rd <= t and not stall_d:
                plan = sd.next_plan(now=t)
                self._fail_swap_dma(sd, plan, res.n_iterations)
                if plan.empty:
                    stall_d = True
                else:
                    if self.record_plans:
                        self.plans.append(("decode", plan))
                    res.decode_prefill_slices += len(plan.prefill)
                    res.n_swap_outs += len(plan.swapped_out_ids)
                    res.n_swap_ins += len(plan.swapped_in_ids)
                    outcome = xd.execute(plan, t)
                    dur = 1.0 if step else outcome.duration
                    t_end = t + dur
                    timestamp_events(sd, outcome.events, t_end,
                                     self.on_token)
                    res.n_iterations += 1
                    res.n_decode_iterations += 1
                    res.n_dispatches += outcome.n_dispatches
                    res.decode_batch_sizes.append(len(plan.decode_ids))
                    rd = t_end
                    # fold-to-recompute victims route back to the prefill
                    # pool (this pool cannot prefill); swap victims stay —
                    # they restore locally via _readmit_swapped
                    for rid in plan.preempted_ids:
                        req = sd.pop_request(rid)
                        bridge.return_to_prefill(req)
                        sp.readmit(req)
                        res.n_returns += 1
                        res.n_preemptions += 1
                        res.recompute_tokens += req.prompt_len
                    executed = True
                    stall_p = False

            if res.n_iterations > max_iterations:
                raise RuntimeError(diagnose_stall(
                    f"did not drain within {max_iterations} iterations; "
                    "scheduler stuck?", [("prefill", sp), ("decode", sd)],
                    pending=len(pending) - i_arr, held=len(held),
                    migrations=len(migr)))
            if executed or acted:
                continue
            # nothing ran at t: advance to the next event
            if live():
                feed.wait(idle_poll)
                t = max(t, xp.poll_clock(t))
                continue
            nxt = []
            if sp.has_work() and not stall_p:
                nxt.append(rp)
            if sd.has_work() and not stall_d:
                nxt.append(rd)
            if i_arr < len(pending):
                nxt.append(pending[i_arr].arrival_time)
            if migr:
                nxt.append(migr[0].ready_time)
            nxt = [x for x in nxt if x > t]
            if not nxt:
                raise RuntimeError(diagnose_stall(
                    f"disaggregated loop made no progress at t={t}: "
                    "no pool can step and no future event exists",
                    [("prefill", sp), ("decode", sd)],
                    pending=len(pending) - i_arr, held=len(held),
                    migrations=len(migr)))
            t = min(nxt)

        if self.faults is not None:
            self.faults.release_pressure(None)   # zero-leak at drain
        res.clock = max(t, rp, rd)
        return res


class EngineExecutor:
    """Real-execution backend: wraps ``serving.engine.Engine``.

    ``wall=False`` (default): each iteration advances the clock by 1.0 —
    pair with ``ServingRuntime(clock="iteration")`` for deterministic
    replay where trace arrival times are iteration indices.  ``wall=True``:
    durations are measured wall seconds and idle really sleeps — pair with
    ``clock="executor"`` for open-loop serving against wall-clock arrival
    times."""

    def __init__(self, engine, *, wall: bool = False):
        self.engine = engine
        self.scheduler = engine.scheduler
        self.wall = wall
        self._t0 = time.monotonic()      # re-anchored by initial_clock()

    def submit(self, spec: SubmitSpec, now: float) -> Request:
        if spec.arrival_time is None:
            spec = dataclasses.replace(spec, arrival_time=now)
        return self.engine.submit_spec(spec)

    def execute(self, plan: IterationPlan, now: float) -> StepOutcome:
        before = self.engine.n_dispatches
        events = self.engine.execute_plan(plan)
        # wall durations are ABSOLUTE elapsed minus the loop clock, so
        # scheduling/streaming overhead between steps is charged too and
        # the pacing cannot drift behind the trace's real-second schedule
        dur = max(0.0, time.monotonic() - self._t0 - now) if self.wall \
            else 1.0
        return StepOutcome(duration=dur, events=events,
                           n_dispatches=self.engine.n_dispatches - before)

    def idle(self, t: float, until: float) -> float:
        if not self.wall:
            return until
        # wall clock: wait until the ABSOLUTE arrival deadline (chunked
        # so huge gaps in a mis-scaled trace stay interruptible); if the
        # loop is already past it, no sleep happens at all
        deadline = self._t0 + until
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            time.sleep(min(remaining, 0.05))
        return time.monotonic() - self._t0

    def evict(self, req_id: int) -> None:
        # fault recovery: the scheduler already ran its preempt fold; this
        # is the engine-side half a plan's preempted_ids would have run
        self.engine._preempt(req_id)

    def release(self, req_id: int) -> None:
        self.engine.release_request(req_id)

    def poll_clock(self, t: float) -> float:
        return time.monotonic() - self._t0 if self.wall else t

    def initial_clock(self) -> float:
        # the iteration clock resumes from the engine's persistent
        # counter, matching requests' iteration-stamped arrival times
        # across incremental submit/run cycles; wall runs re-anchor to
        # now (arrival times are seconds since run start)
        if self.wall:
            self._t0 = time.monotonic()
            return 0.0
        return float(self.engine.iteration)


class SimExecutor:
    """Analytic backend: wraps ``serving.simulator.Simulator``. Iteration
    durations come from the cost model; swap DMA is charged as overlappable
    with the iteration's compute (``stall = max(0, dma - compute)``) unless
    the simulator was built with ``swap_overlap=False`` (the PR-3 serial
    model, kept for comparison).  Accumulates the energy/traffic totals
    that ``Simulator.run`` folds into its ``SimResult``."""

    def __init__(self, sim):
        self.sim = sim
        self.scheduler = sim.scheduler
        self._next_id = 0
        self.total_energy = 0.0
        self.total_expert_bytes = 0.0
        self.total_hbm_bytes = 0.0
        self.total_flops = 0.0
        self.swap_bytes = 0.0
        self.swap_dma_time = 0.0       # host-link busy time, both directions
        self.swap_stall_time = 0.0     # the part compute could not hide
        self.total_drafted = 0         # speculative decode accounting
        self.total_accepted = 0

    def submit(self, spec: SubmitSpec, now: float) -> Request:
        # prompt_tokens (when the spec carries them) make the analytic
        # backend prefix-cache-aware: the shared scheduler code hashes and
        # matches exactly as it does under the engine, so cross-backend
        # plan streams stay identical with caching enabled
        req = Request.from_spec(
            spec, self._next_id,
            arrival_time=now if spec.arrival_time is None
            else spec.arrival_time,
            prompt_tokens=None if spec.prompt_tokens is None
            else np.asarray(spec.prompt_tokens, np.int32))
        self._next_id += 1
        self.scheduler.submit(req)
        return req

    def execute(self, plan: IterationPlan, now: float) -> StepOutcome:
        sim = self.sim
        dma = 0.0
        if plan.swapped_out_ids or plan.swapped_in_ids:
            # swap DMA: tokens that actually crossed the host link (shared
            # prefix pages stay pinned in HBM and move in neither direction)
            moved = sum(sim.kv.last_swap_tokens(rid) for rid in
                        plan.swapped_out_ids + plan.swapped_in_ids)
            xfer = sim.cost.swap_transfer(moved)
            dma = xfer["duration"]
            self.swap_dma_time += dma
            self.swap_bytes += xfer["bytes"]
            self.total_energy += xfer["energy"]
        cost = sim.cost.iteration_cost(plan, self.scheduler.requests)
        self.total_energy += cost["energy"]
        self.total_expert_bytes += cost["expert_bytes"]
        self.total_hbm_bytes += cost["hbm_bytes"]
        self.total_flops += cost["flops"]
        # the DMA engines run asynchronously to compute: only the excess
        # past the iteration's compute stalls the clock (serial flag
        # charges the whole transfer, the PR-3 model)
        stall = dma if not sim.swap_overlap \
            else max(0.0, dma - cost["duration"])
        self.swap_stall_time += stall
        events = [TokenEvent(sl.req_id, None, first=True)
                  for sl in plan.prefill if sl.emits_first_token]
        events += [TokenEvent(rid, None) for rid in plan.decode_ids]
        # speculative verify-k: analytic acceptance — a run of consecutive
        # Bernoulli(spec_acceptance) successes capped at the budget (the
        # simulator has no tokens to verify); deterministic given the
        # simulator's seed and the sorted commit order.  Priced above via
        # plan.verify_len; committed here AFTER pricing so the cost sees
        # pre-commit context lengths, like the engine.
        for rid in sorted(plan.verify_len):
            k = plan.verify_len[rid]
            a = sim.draw_accepted(k)
            self.total_drafted += k
            self.total_accepted += a
            self.scheduler.commit_speculation(rid, proposed=k, accepted=a,
                                              extra=a)
            events += [TokenEvent(rid, None)] * a
        return StepOutcome(duration=cost["duration"] + stall, events=events)

    def idle(self, t: float, until: float) -> float:
        return until

    def evict(self, req_id: int) -> None:
        pass    # analytic backend: no per-request physical state to drop

    def release(self, req_id: int) -> None:
        pass

    def poll_clock(self, t: float) -> float:
        return t

    def initial_clock(self) -> float:
        return 0.0
