"""The executor-agnostic serving loop (DESIGN.md §Serving runtime).

``ServingRuntime`` owns everything the real-execution engine and the
discrete-event simulator used to reimplement privately: timed arrival
injection (open-loop trace replay), idling to the next arrival instead of
raising when the pool drains, per-iteration stepping via the scheduler's
``next_plan``, token timestamping (TTFT pinning across recompute epochs),
preemption/swap accounting, per-token streaming callbacks, and the
no-progress / iteration-cap guards.  ``Engine.run`` and ``Simulator.run``
both delegate here, so the two loops cannot drift and the equivalence
tests compare one loop driving two backends, not two reimplementations.

An ``Executor`` is the backend behind the loop:

  * ``EngineExecutor`` — wraps ``serving.engine.Engine``: plans execute on
    a REAL jax model, token events carry actual token ids, and the clock
    is either the iteration index (deterministic replay — the default) or
    real wall time (``wall=True``: arrivals in seconds, the runtime sleeps
    through idle gaps — open-loop serving).
  * ``SimExecutor`` — wraps ``serving.simulator.Simulator``: plans are
    priced by the analytic cost model, token events carry ``None`` (there
    is no model), and the clock advances by modeled iteration durations.

Arrival clock semantics: the runtime keeps ONE clock ``t``.  With
``clock="executor"`` (simulator default, engine wall mode) ``t`` advances
by each step's modeled/measured duration and arrival times are in the
executor's time unit (seconds).  With ``clock="iteration"`` (engine
default) ``t`` advances 1.0 per executed iteration and arrival times are
iteration indices — identical across backends by construction, which is
what makes cross-backend trace-replay equivalence exactly testable.
"""

from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from dataclasses import dataclass, field
from typing import (TYPE_CHECKING, Callable, Dict, List, Optional, Protocol,
                    Sequence, Union)

import numpy as np

from repro.core.plan import IterationPlan, Request, RequestState, SubmitSpec

if TYPE_CHECKING:  # typing only — runtime must not import its backends
    from repro.core.base import Scheduler
    from repro.serving.traffic import TraceRequest

# on_token(req_id, token_or_None, t) — called once per emitted token, in
# emission order, timestamped at the end of the iteration that produced it
TokenCallback = Callable[[int, Optional[int], float], None]


@dataclass(frozen=True)
class TokenEvent:
    """One token emitted by an executor step. ``token`` is the real id on
    the engine, None on the simulator. ``first`` marks tokens produced by
    an emitting prefill slice — the runtime decides whether that is the
    request's TRUE first token or a recompute-epoch continuation."""
    req_id: int
    token: Optional[int]
    first: bool = False


@dataclass
class StepOutcome:
    """What one executed iteration reports back to the loop."""
    duration: float
    events: List[TokenEvent] = field(default_factory=list)
    # engine-level device launches this iteration (embed + packed prefill
    # batches + decode); 0 for analytic backends.  Surfaced so serving
    # harnesses can track dispatch pressure without poking the engine.
    n_dispatches: int = 0


def timestamp_events(sched, events: List[TokenEvent], t_end: float,
                     on_token: Optional[TokenCallback] = None) -> None:
    """THE timestamping rule, shared by the runtime loop and the engine's
    legacy hand-stepping path: tokens become visible at iteration end;
    the first token of a recompute epoch is a CONTINUATION — TTFT stays
    pinned to the original first emission; finish times stamp when the
    scheduler bookkeeping (or an engine-side EOS) has moved the request
    to DONE."""
    for ev in events:
        r = sched.requests[ev.req_id]
        if ev.first and r.first_token_time is None:
            r.first_token_time = t_end
        else:
            r.token_times.append(t_end)
        if r.state == RequestState.DONE and r.finish_time is None:
            r.finish_time = t_end
        if on_token is not None:
            on_token(ev.req_id, ev.token, t_end)


class Executor(Protocol):
    """Backend protocol: the runtime never touches jax or the cost model
    directly — it schedules, clocks and timestamps; the executor runs."""
    scheduler: "Scheduler"

    def submit(self, spec: SubmitSpec, now: float) -> Request:
        """Create + submit the request for an arriving SubmitSpec (the
        unified ingestion record — trace items convert via
        ``TraceRequest.to_spec``).  A spec without an arrival time is
        stamped at ``now`` in the executor's clock unit."""
        ...

    def execute(self, plan: IterationPlan, now: float) -> StepOutcome:
        """Run one iteration plan; return its duration and token events."""
        ...

    def idle(self, t: float, until: float) -> float:
        """Advance the executor clock from ``t`` to ``until`` with no work
        resident (wall executors sleep); returns the new clock value."""
        ...

    def poll_clock(self, t: float) -> float:
        """The executor's CURRENT clock reading given the loop's last value
        ``t`` — wall executors re-read the monotonic clock (live-feed
        idling advances time without an ``idle`` target), virtual clocks
        return ``t`` unchanged."""
        ...

    def initial_clock(self) -> float:
        """Where this run's clock starts.  The engine's iteration clock
        resumes from its persistent iteration counter so a second run()
        cannot stamp tokens EARLIER than requests submitted after the
        first (TTFT stays positive across incremental submit/run
        cycles); fresh backends start at 0."""
        ...


class SubmitTicket:
    """One live submission in flight through a ``SubmitQueue``: the serving
    loop resolves it (engine thread) when the spec is actually submitted,
    after which ``request`` is the backend's live Request.  ``on_submit``
    fires synchronously IN the serving-loop thread right after submission
    and strictly before any of the request's tokens are emitted — the HTTP
    front-end registers its per-request token stream there, so no token
    can race past an unregistered stream."""

    __slots__ = ("spec", "on_submit", "on_fail", "request", "error", "_done")

    def __init__(self, spec: SubmitSpec,
                 on_submit: Optional[Callable[[Request], None]] = None,
                 on_fail: Optional[Callable[[BaseException], None]] = None):
        self.spec = spec
        self.on_submit = on_submit
        self.on_fail = on_fail
        self.request: Optional[Request] = None
        self.error: Optional[BaseException] = None
        self._done = threading.Event()

    def _resolve(self, request: Request) -> None:
        self.request = request
        if self.on_submit is not None:
            self.on_submit(request)
        self._done.set()

    def _fail(self, exc: BaseException) -> None:
        self.error = exc
        if self.on_fail is not None:
            self.on_fail(exc)
        self._done.set()

    def wait(self, timeout: Optional[float] = None) -> Request:
        """Block until the serving loop picked this spec up; re-raise its
        submission error (bad request) in the waiting thread."""
        if not self._done.wait(timeout):
            raise TimeoutError("submission not picked up by serving loop")
        if self.error is not None:
            raise self.error
        return self.request


class SubmitQueue:
    """Thread-safe live-ingestion channel bridging concurrent producers
    (HTTP handler threads / asyncio callbacks) into the single-threaded
    serving loop: producers ``put`` SubmitSpecs, the loop drains them at
    every iteration boundary and blocks on ``wait`` while idle instead of
    spinning.  ``close`` ends the stream — the loop finishes whatever is
    already queued or resident, then returns."""

    def __init__(self):
        self._lock = threading.Lock()
        self._items: deque = deque()
        self._wake = threading.Event()
        self._closed = False

    def put(self, spec: SubmitSpec,
            on_submit: Optional[Callable[[Request], None]] = None,
            on_fail: Optional[Callable[[BaseException], None]] = None) \
            -> SubmitTicket:
        ticket = SubmitTicket(spec, on_submit, on_fail)
        with self._lock:
            if self._closed:
                raise RuntimeError("submit queue is closed")
            self._items.append(ticket)
            self._wake.set()
        return ticket

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._wake.set()

    @property
    def backlog(self) -> int:
        return len(self._items)

    @property
    def exhausted(self) -> bool:
        """True once closed AND fully drained — the loop's stop signal."""
        with self._lock:
            return self._closed and not self._items

    def wait(self, timeout: Optional[float] = None) -> bool:
        """Block until an item arrives or the queue closes (the serving
        loop's idle wakeup).  Returns True if something may be pending."""
        return self._wake.wait(timeout)

    def drain(self) -> List[SubmitTicket]:
        with self._lock:
            items = list(self._items)
            self._items.clear()
            if not self._closed:
                self._wake.clear()
            return items


@dataclass
class RunResult:
    """Backend-agnostic outcome of one ``ServingRuntime.run``. Executors
    layer their own accounting on top (see ``simulator.SimResult``)."""
    requests: List[Request] = field(default_factory=list)
    n_iterations: int = 0
    clock: float = 0.0             # final clock value (sim_time / iterations)
    decode_batch_sizes: List[int] = field(default_factory=list)
    n_preemptions: int = 0
    recompute_tokens: int = 0      # prefill tokens re-run due to preemption
    n_swap_outs: int = 0
    n_swap_ins: int = 0
    n_dispatches: int = 0          # total device launches (engine backends)


class ServingRuntime:
    def __init__(self, executor: Executor, *,
                 on_token: Optional[TokenCallback] = None,
                 clock: str = "executor",
                 record_plans: bool = False):
        if clock not in ("executor", "iteration"):
            raise ValueError(f"unknown clock {clock!r}")
        self.executor = executor
        self.on_token = on_token
        self.clock = clock
        self.record_plans = record_plans
        self.plans: List[IterationPlan] = []

    def run(self, trace: Sequence[Union["TraceRequest", SubmitSpec]] = (),
            max_iterations: int = 10_000, *,
            feed: Optional[SubmitQueue] = None,
            idle_poll: float = 0.05) -> RunResult:
        """Replay ``trace`` open-loop (requests injected at their arrival
        times; the loop idles to the next arrival when the pool drains)
        and drain everything already submitted to the scheduler.  An empty
        trace is the closed-loop drain the engine's legacy ``run`` was.

        ``feed`` attaches a live ``SubmitQueue``: specs arriving from
        other threads are injected at every iteration boundary (arrival
        stamped at the current clock when the spec carries none), and when
        the pool drains the loop BLOCKS on the queue (granularity
        ``idle_poll`` seconds) instead of exiting — the serving loop of
        the HTTP front-end.  The run returns once the feed is closed and
        drained and no work remains."""
        x = self.executor
        sched = x.scheduler
        res = RunResult(
            # closed-loop requests submitted before run() — id order
            requests=[sched.requests[k] for k in sorted(sched.requests)])
        pending = sorted(trace, key=lambda tr: tr.arrival_time)
        i_arr = 0
        t = float(x.initial_clock())

        def inject(now: float) -> None:
            nonlocal i_arr
            while i_arr < len(pending) \
                    and pending[i_arr].arrival_time <= now:
                tr = pending[i_arr]
                spec = tr.to_spec() if hasattr(tr, "to_spec") else tr
                res.requests.append(x.submit(spec, now))
                i_arr += 1
            if feed is not None:
                for ticket in feed.drain():
                    try:
                        req = x.submit(ticket.spec, now)
                    except Exception as e:     # bad spec: report, keep going
                        ticket._fail(e)
                        continue
                    res.requests.append(req)
                    ticket._resolve(req)

        def live() -> bool:
            return feed is not None and not feed.exhausted

        while i_arr < len(pending) or sched.has_work() or live():
            inject(t)
            if not sched.has_work():
                if live():
                    # live idle: block on the feed (bounded so wall clocks
                    # stay responsive to close/shutdown), then re-read the
                    # executor clock — arrivals are stamped at real idle
                    # time, not at the last iteration's end
                    feed.wait(idle_poll)
                    t = max(t, x.poll_clock(t))
                    continue
                if i_arr >= len(pending):
                    break          # feed closed + drained, nothing pending
                # open-loop idle: fast-forward (or, on a wall clock, sleep)
                # to the next arrival instead of raising "did not drain"
                nxt = pending[i_arr].arrival_time
                t = nxt if self.clock == "iteration" else x.idle(t, nxt)
                inject(t)
            if res.n_iterations >= max_iterations:
                raise RuntimeError(
                    f"did not drain within {max_iterations} iterations; "
                    "scheduler stuck?")
            plan = sched.next_plan(now=t)
            if self.record_plans:
                self.plans.append(plan)
            res.n_preemptions += len(plan.preempted_ids)
            res.recompute_tokens += sum(
                sched.requests[rid].prompt_len
                for rid in plan.preempted_ids)
            res.n_swap_outs += len(plan.swapped_out_ids)
            res.n_swap_ins += len(plan.swapped_in_ids)
            if plan.empty:
                if i_arr < len(pending):
                    # nothing runnable yet — fast-forward to the arrival
                    # that will create work (t never moves backwards)
                    t = max(t, pending[i_arr].arrival_time)
                    continue
                # no runnable work, no future arrivals: advancing neither
                # t nor the iteration count would spin forever
                raise RuntimeError(
                    f"scheduler {sched.name!r} made no progress: "
                    f"{len(sched.waiting)} waiting, {sched.n_active} "
                    "active, no pending arrivals")
            outcome = x.execute(plan, t)
            res.n_iterations += 1
            res.n_dispatches += outcome.n_dispatches
            res.decode_batch_sizes.append(len(plan.decode_ids))
            t_end = t + (1.0 if self.clock == "iteration"
                         else outcome.duration)
            timestamp_events(sched, outcome.events, t_end, self.on_token)
            t = t_end

        res.clock = t
        return res


@dataclass
class Migration:
    """One prefill→decode handoff in flight: the migrating Request, a
    backend-opaque payload (KV export + physical state), and the link
    timeline — ``ready_time`` is when the last KV byte lands on the decode
    side (== ``export_time`` plus the residual transfer the remaining
    prefill compute could not hide; equal to ``export_time`` on the engine,
    whose chunks were host-staged through the per-iteration fetch)."""
    req: Request
    payload: object
    export_time: float
    ready_time: float
    n_chunks: int = 0
    bytes_total: float = 0.0


class HandoffBridge(Protocol):
    """Backend-specific mechanics of the prefill→decode KV handoff; the
    ``DisaggRuntime`` decides WHEN to stage/export/import, the bridge knows
    HOW (engine: host-staged cache rows; simulator: priced link FIFO)."""

    def decode_free_pages(self) -> int:
        """Free pages on the decode pool's allocator (watermark signal)."""
        ...

    def stage(self, plan: IterationPlan, requests: Dict[int, Request],
              t_end: float, duration: float) -> None:
        """Observe one executed prefill-pool plan: layer groups whose KV
        completed this iteration enter the per-request handoff stream
        (simulator link model; the engine stages inside execute_plan)."""
        ...

    def export(self, req: Request, now: float) -> Migration:
        """Pull the migrating request's KV/state off the prefill backend
        (the scheduler has already ``pop_request``-ed it)."""
        ...

    def can_import(self, m: Migration) -> bool:
        """True iff the decode backend can take the payload right now."""
        ...

    def do_import(self, m: Migration, now: float) -> Dict[str, int]:
        """Install the payload on the decode backend; returns the
        ``{"linked_tokens", "moved_tokens"}`` split (pages already warm on
        the decode pool link for free — KV-locality routing's win)."""
        ...

    def drop(self, req_id: int) -> None:
        """A prefill-pool preemption voided any staged chunks."""
        ...

    def return_to_prefill(self, req: Request) -> None:
        """Move a decode-pool recompute victim's backend state (prompt /
        output buffers) back to the prefill backend before readmission."""
        ...


@dataclass
class DisaggRunResult(RunResult):
    """``RunResult`` plus the two-pool accounting: per-pool iteration
    counts, migration/handoff traffic, and the link-stall totals.
    ``decode_prefill_slices`` MUST stay 0 — the decode pool's iteration
    clock never contains prefill work (its TBT is prefill-free by
    construction; the CI gate asserts the counter)."""
    n_prefill_iterations: int = 0
    n_decode_iterations: int = 0
    n_migrations: int = 0
    n_returns: int = 0             # recompute victims routed back to prefill
    handoff_bytes: float = 0.0     # payload bytes that crossed the link
    link_stall_time: float = 0.0   # export→ready residual (unhidden) time
    handoff_wait_time: float = 0.0  # export→import total (stall + capacity)
    migration_queue_peak: int = 0
    held_peak: int = 0             # watermark-backpressured arrivals
    decode_prefill_slices: int = 0


class DisaggRuntime:
    """Two-pool disaggregated serving loop (DESIGN.md §Disaggregated
    serving): a prefill executor and a decode executor advance under ONE
    runtime clock.  Requests are admitted and prefilled on the prefill
    pool; as each layer group's KV completes it streams toward the decode
    pool (bridge-managed), and when the final group emits the first token
    the request is exported, crosses the link, and is ``adopt``-ed by the
    decode pool, which runs decode-only iterations forever after.  Decode-
    pool recompute victims fold and route BACK to the prefill pool (the
    decode pool cannot prefill); swap victims restore locally.

    Clock semantics mirror ``ServingRuntime``: ``clock="iteration"``
    advances both pools in lockstep 1.0 per iteration (deterministic
    engine replay — token streams bit-identical to monolithic serving);
    ``clock="executor"`` gives each pool its own event-driven ready time,
    so decode-pool timestamps contain ONLY decode durations — the
    prefill-free-TBT property the paper's disaggregation argument needs.

    ``decode_watermark_pages`` backpressures admission: new arrivals are
    HELD (not submitted to the prefill pool) while the decode pool's free
    pages sit below the watermark, so prefill work whose handoff would
    have nowhere to land is never started."""

    def __init__(self, prefill: Executor, decode: Executor,
                 bridge: HandoffBridge, *,
                 on_token: Optional[TokenCallback] = None,
                 clock: str = "executor",
                 decode_watermark_pages: int = 0,
                 record_plans: bool = False):
        if clock not in ("executor", "iteration"):
            raise ValueError(f"unknown clock {clock!r}")
        self.prefill = prefill
        self.decode = decode
        self.bridge = bridge
        self.on_token = on_token
        self.clock = clock
        self.decode_watermark_pages = decode_watermark_pages
        self.record_plans = record_plans
        self.plans: List = []          # (pool_tag, IterationPlan)

    def run(self, trace: Sequence[Union["TraceRequest", SubmitSpec]] = (),
            max_iterations: int = 10_000, *,
            feed: Optional[SubmitQueue] = None,
            idle_poll: float = 0.05) -> DisaggRunResult:
        xp, xd, bridge = self.prefill, self.decode, self.bridge
        sp, sd = xp.scheduler, xd.scheduler
        step = self.clock == "iteration"
        res = DisaggRunResult(
            requests=[sp.requests[k] for k in sorted(sp.requests)])
        pending = sorted(trace, key=lambda tr: tr.arrival_time)
        i_arr = 0
        t = max(float(xp.initial_clock()), float(xd.initial_clock()))
        rp = rd = t                    # per-pool next-ready clocks
        held: deque = deque()          # (spec, ticket|None) backpressured
        migr: deque = deque()          # Migration FIFO (link order)
        # a pool whose last attempt produced an empty plan is stalled until
        # some OTHER event (arrival, import, return, other-pool iteration)
        # can change its state — re-planning the same state would spin
        stall_p = stall_d = False

        def live() -> bool:
            return feed is not None and not feed.exhausted

        def inject(now: float) -> bool:
            nonlocal i_arr
            n0 = len(held)
            while i_arr < len(pending) \
                    and pending[i_arr].arrival_time <= now:
                held.append((pending[i_arr], None))
                i_arr += 1
            if feed is not None:
                for ticket in feed.drain():
                    held.append((ticket.spec, ticket))
            res.held_peak = max(res.held_peak, len(held))
            return len(held) > n0

        def admit_held(now: float) -> bool:
            n = 0
            while held:
                if self.decode_watermark_pages > 0 \
                        and bridge.decode_free_pages() \
                        < self.decode_watermark_pages:
                    break              # decode pool must drain first
                item, ticket = held.popleft()
                spec = item.to_spec() if hasattr(item, "to_spec") else item
                try:
                    req = xp.submit(spec, now)
                except Exception as e:
                    if ticket is None:
                        raise
                    ticket._fail(e)
                    continue
                res.requests.append(req)
                if ticket is not None:
                    ticket._resolve(req)
                n += 1
            return n > 0

        def attempt_imports(now: float) -> bool:
            n = 0
            while migr and migr[0].ready_time <= now:
                m = migr[0]
                if not (sd.can_adopt(m.req) and bridge.can_import(m)):
                    if not sd.has_work():
                        raise RuntimeError(
                            f"decode pool can never import request "
                            f"{m.req.req_id} — enlarge the decode pool")
                    break              # FIFO: wait for the decode pool
                migr.popleft()
                info = bridge.do_import(m, now)
                sd.adopt(m.req)
                m.req.n_handoffs += 1
                m.req.handoff_linked_tokens += info.get("linked_tokens", 0)
                m.req.handoff_moved_tokens += info.get("moved_tokens", 0)
                m.req.handoff_time = now
                res.handoff_wait_time += now - m.export_time
                res.n_migrations += 1
                n += 1
            return n > 0

        while i_arr < len(pending) or held or migr \
                or sp.has_work() or sd.has_work() or live():
            acted = inject(t)
            acted |= admit_held(t)
            acted |= attempt_imports(t)
            if acted:
                stall_p = stall_d = False

            executed = False
            if sp.has_work() and rp <= t and not stall_p:
                plan = sp.next_plan(now=t)
                if plan.empty:
                    stall_p = True
                else:
                    if self.record_plans:
                        self.plans.append(("prefill", plan))
                    for rid in plan.preempted_ids:
                        bridge.drop(rid)
                    res.n_preemptions += len(plan.preempted_ids)
                    res.recompute_tokens += sum(
                        sp.requests[rid].prompt_len
                        for rid in plan.preempted_ids)
                    res.n_swap_outs += len(plan.swapped_out_ids)
                    res.n_swap_ins += len(plan.swapped_in_ids)
                    outcome = xp.execute(plan, t)
                    dur = 1.0 if step else outcome.duration
                    t_end = t + dur
                    bridge.stage(plan, sp.requests, t_end, dur)
                    timestamp_events(sp, outcome.events, t_end,
                                     self.on_token)
                    res.n_iterations += 1
                    res.n_prefill_iterations += 1
                    res.n_dispatches += outcome.n_dispatches
                    rp = t_end
                    # completed prefills migrate NOW: the pool is pure
                    # prefill — first-token emitters leave for the decode
                    # pool the moment their last layer group finishes
                    for rid in sorted(
                            r.req_id for r in sp.requests.values()
                            if r.state == RequestState.DECODE):
                        req = sp.pop_request(rid)
                        m = bridge.export(req, t_end)
                        req.n_handoff_chunks += m.n_chunks
                        res.handoff_bytes += m.bytes_total
                        res.link_stall_time += max(
                            0.0, m.ready_time - m.export_time)
                        migr.append(m)
                    res.migration_queue_peak = max(
                        res.migration_queue_peak, len(migr))
                    executed = True
                    stall_d = False

            if sd.has_work() and rd <= t and not stall_d:
                plan = sd.next_plan(now=t)
                if plan.empty:
                    stall_d = True
                else:
                    if self.record_plans:
                        self.plans.append(("decode", plan))
                    res.decode_prefill_slices += len(plan.prefill)
                    res.n_swap_outs += len(plan.swapped_out_ids)
                    res.n_swap_ins += len(plan.swapped_in_ids)
                    outcome = xd.execute(plan, t)
                    dur = 1.0 if step else outcome.duration
                    t_end = t + dur
                    timestamp_events(sd, outcome.events, t_end,
                                     self.on_token)
                    res.n_iterations += 1
                    res.n_decode_iterations += 1
                    res.n_dispatches += outcome.n_dispatches
                    res.decode_batch_sizes.append(len(plan.decode_ids))
                    rd = t_end
                    # fold-to-recompute victims route back to the prefill
                    # pool (this pool cannot prefill); swap victims stay —
                    # they restore locally via _readmit_swapped
                    for rid in plan.preempted_ids:
                        req = sd.pop_request(rid)
                        bridge.return_to_prefill(req)
                        sp.readmit(req)
                        res.n_returns += 1
                        res.n_preemptions += 1
                        res.recompute_tokens += req.prompt_len
                    executed = True
                    stall_p = False

            if res.n_iterations > max_iterations:
                raise RuntimeError(
                    f"did not drain within {max_iterations} iterations; "
                    "scheduler stuck?")
            if executed or acted:
                continue
            # nothing ran at t: advance to the next event
            if live():
                feed.wait(idle_poll)
                t = max(t, xp.poll_clock(t))
                continue
            nxt = []
            if sp.has_work() and not stall_p:
                nxt.append(rp)
            if sd.has_work() and not stall_d:
                nxt.append(rd)
            if i_arr < len(pending):
                nxt.append(pending[i_arr].arrival_time)
            if migr:
                nxt.append(migr[0].ready_time)
            nxt = [x for x in nxt if x > t]
            if not nxt:
                raise RuntimeError(
                    f"disaggregated loop made no progress at t={t}: "
                    f"{len(sp.waiting)} prefill-waiting, "
                    f"{sp.n_active}/{sd.n_active} active, "
                    f"{len(migr)} migrations, {len(held)} held")
            t = min(nxt)

        res.clock = max(t, rp, rd)
        return res


class EngineExecutor:
    """Real-execution backend: wraps ``serving.engine.Engine``.

    ``wall=False`` (default): each iteration advances the clock by 1.0 —
    pair with ``ServingRuntime(clock="iteration")`` for deterministic
    replay where trace arrival times are iteration indices.  ``wall=True``:
    durations are measured wall seconds and idle really sleeps — pair with
    ``clock="executor"`` for open-loop serving against wall-clock arrival
    times."""

    def __init__(self, engine, *, wall: bool = False):
        self.engine = engine
        self.scheduler = engine.scheduler
        self.wall = wall
        self._t0 = time.monotonic()      # re-anchored by initial_clock()

    def submit(self, spec: SubmitSpec, now: float) -> Request:
        if spec.arrival_time is None:
            spec = dataclasses.replace(spec, arrival_time=now)
        return self.engine.submit_spec(spec)

    def execute(self, plan: IterationPlan, now: float) -> StepOutcome:
        before = self.engine.n_dispatches
        events = self.engine.execute_plan(plan)
        # wall durations are ABSOLUTE elapsed minus the loop clock, so
        # scheduling/streaming overhead between steps is charged too and
        # the pacing cannot drift behind the trace's real-second schedule
        dur = max(0.0, time.monotonic() - self._t0 - now) if self.wall \
            else 1.0
        return StepOutcome(duration=dur, events=events,
                           n_dispatches=self.engine.n_dispatches - before)

    def idle(self, t: float, until: float) -> float:
        if not self.wall:
            return until
        # wall clock: wait until the ABSOLUTE arrival deadline (chunked
        # so huge gaps in a mis-scaled trace stay interruptible); if the
        # loop is already past it, no sleep happens at all
        deadline = self._t0 + until
        while True:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                break
            time.sleep(min(remaining, 0.05))
        return time.monotonic() - self._t0

    def poll_clock(self, t: float) -> float:
        return time.monotonic() - self._t0 if self.wall else t

    def initial_clock(self) -> float:
        # the iteration clock resumes from the engine's persistent
        # counter, matching requests' iteration-stamped arrival times
        # across incremental submit/run cycles; wall runs re-anchor to
        # now (arrival times are seconds since run start)
        if self.wall:
            self._t0 = time.monotonic()
            return 0.0
        return float(self.engine.iteration)


class SimExecutor:
    """Analytic backend: wraps ``serving.simulator.Simulator``. Iteration
    durations come from the cost model; swap DMA is charged as overlappable
    with the iteration's compute (``stall = max(0, dma - compute)``) unless
    the simulator was built with ``swap_overlap=False`` (the PR-3 serial
    model, kept for comparison).  Accumulates the energy/traffic totals
    that ``Simulator.run`` folds into its ``SimResult``."""

    def __init__(self, sim):
        self.sim = sim
        self.scheduler = sim.scheduler
        self._next_id = 0
        self.total_energy = 0.0
        self.total_expert_bytes = 0.0
        self.total_hbm_bytes = 0.0
        self.total_flops = 0.0
        self.swap_bytes = 0.0
        self.swap_dma_time = 0.0       # host-link busy time, both directions
        self.swap_stall_time = 0.0     # the part compute could not hide
        self.total_drafted = 0         # speculative decode accounting
        self.total_accepted = 0

    def submit(self, spec: SubmitSpec, now: float) -> Request:
        # prompt_tokens (when the spec carries them) make the analytic
        # backend prefix-cache-aware: the shared scheduler code hashes and
        # matches exactly as it does under the engine, so cross-backend
        # plan streams stay identical with caching enabled
        req = Request.from_spec(
            spec, self._next_id,
            arrival_time=now if spec.arrival_time is None
            else spec.arrival_time,
            prompt_tokens=None if spec.prompt_tokens is None
            else np.asarray(spec.prompt_tokens, np.int32))
        self._next_id += 1
        self.scheduler.submit(req)
        return req

    def execute(self, plan: IterationPlan, now: float) -> StepOutcome:
        sim = self.sim
        dma = 0.0
        if plan.swapped_out_ids or plan.swapped_in_ids:
            # swap DMA: tokens that actually crossed the host link (shared
            # prefix pages stay pinned in HBM and move in neither direction)
            moved = sum(sim.kv.last_swap_tokens(rid) for rid in
                        plan.swapped_out_ids + plan.swapped_in_ids)
            xfer = sim.cost.swap_transfer(moved)
            dma = xfer["duration"]
            self.swap_dma_time += dma
            self.swap_bytes += xfer["bytes"]
            self.total_energy += xfer["energy"]
        cost = sim.cost.iteration_cost(plan, self.scheduler.requests)
        self.total_energy += cost["energy"]
        self.total_expert_bytes += cost["expert_bytes"]
        self.total_hbm_bytes += cost["hbm_bytes"]
        self.total_flops += cost["flops"]
        # the DMA engines run asynchronously to compute: only the excess
        # past the iteration's compute stalls the clock (serial flag
        # charges the whole transfer, the PR-3 model)
        stall = dma if not sim.swap_overlap \
            else max(0.0, dma - cost["duration"])
        self.swap_stall_time += stall
        events = [TokenEvent(sl.req_id, None, first=True)
                  for sl in plan.prefill if sl.emits_first_token]
        events += [TokenEvent(rid, None) for rid in plan.decode_ids]
        # speculative verify-k: analytic acceptance — a run of consecutive
        # Bernoulli(spec_acceptance) successes capped at the budget (the
        # simulator has no tokens to verify); deterministic given the
        # simulator's seed and the sorted commit order.  Priced above via
        # plan.verify_len; committed here AFTER pricing so the cost sees
        # pre-commit context lengths, like the engine.
        for rid in sorted(plan.verify_len):
            k = plan.verify_len[rid]
            a = sim.draw_accepted(k)
            self.total_drafted += k
            self.total_accepted += a
            self.scheduler.commit_speculation(rid, proposed=k, accepted=a,
                                              extra=a)
            events += [TokenEvent(rid, None)] * a
        return StepOutcome(duration=cost["duration"] + stall, events=events)

    def idle(self, t: float, until: float) -> float:
        return until

    def poll_clock(self, t: float) -> float:
        return t

    def initial_clock(self) -> float:
        return 0.0
