"""Layer-group partitioning — §4.4 of the paper.

``num_groups`` implements G(L) = max(1, ceil(L / work_quantum)) with the
paper's work_quantum = 512 ("an arbitrary value... chosen to match chunked
prefill with chunk size 512"), capped at the number of blocks. ``partition``
splits the stack into G contiguous groups whose sizes differ by at most one
(the paper's future-work case of L % G != 0 is handled here)."""

from __future__ import annotations

import math
from typing import List, Tuple

DEFAULT_QUANTUM = 512


def num_groups(prompt_len: int, n_blocks: int,
               quantum: int = DEFAULT_QUANTUM) -> int:
    g = max(1, math.ceil(prompt_len / quantum))
    return min(g, n_blocks)


def partition(n_blocks: int, g: int) -> List[Tuple[int, int]]:
    """G contiguous (start, end) groups covering [0, n_blocks), balanced to
    within one block."""
    assert 1 <= g <= n_blocks, (g, n_blocks)
    base, rem = divmod(n_blocks, g)
    groups = []
    start = 0
    for i in range(g):
        size = base + (1 if i < rem else 0)
        groups.append((start, start + size))
        start += size
    assert start == n_blocks
    return groups

def partition_weighted(costs, g: int):
    """Adaptive layer grouping (the paper's §7 future work): split the
    stack into g contiguous groups balancing per-group COST rather than
    block count. ``costs`` is one non-negative weight per block — the
    scheduler uses per-block prefill weight-bytes from the cost model, so
    heterogeneous stacks (RecurrentGemma's 2:1 RG-LRU:attention pattern,
    DeepSeek's dense block 0, MoE-vs-dense depth profiles) get groups with
    near-equal per-iteration work, tightening the TBT envelope that the
    one-group-per-iteration rule produces.

    Greedy prefix-quantile split with a contiguity constraint; exact
    balance is NP-ish, but prefix splitting is optimal-in-class for the
    contiguous-group requirement and is what pipeline-parallel stage
    balancing uses."""
    n = len(costs)
    assert 1 <= g <= n, (g, n)
    total = float(sum(costs)) or 1.0
    bounds = [0]
    acc = 0.0
    target_idx = 1
    for i, c in enumerate(costs):
        acc += float(c)
        # close the current group once its share reaches the target
        # quantile, leaving at least one block per remaining group
        while (target_idx < g and acc >= total * target_idx / g
               and i + 1 - bounds[-1] >= 1
               and n - (i + 1) >= g - target_idx):
            bounds.append(i + 1)
            target_idx += 1
    while len(bounds) < g:
        bounds.append(n - (g - len(bounds)))
    bounds.append(n)
    groups = [(bounds[i], bounds[i + 1]) for i in range(g)]
    assert groups[0][0] == 0 and groups[-1][1] == n
    assert all(b > a for a, b in groups)
    return groups
