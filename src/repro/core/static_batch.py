"""FasterTransformer-style static batching — baseline.

Requests are processed in fixed batches from start to finish: a batch is
admitted only when the previous one fully drains. Stall-free within a batch
(decode-only iterations) but TTFT for queued requests includes the whole
residency time of the batch ahead of them."""

from __future__ import annotations

from repro.core.base import Scheduler, register
from repro.core.plan import IterationPlan, PrefillSlice


@register
class StaticBatchScheduler(Scheduler):
    name = "static"

    def __init__(self, n_blocks: int, *, batch_size: int = 8, **kw):
        super().__init__(n_blocks, **kw)
        self.batch_size = min(batch_size, self.n_slots)

    def _plan(self, now: float = 0.0) -> IterationPlan:
        plan = IterationPlan()
        if self.n_active == 0 and self.waiting:
            plan.admitted_ids = self.admit(now, limit=self.batch_size)
            for rid in plan.admitted_ids:
                r = self.requests[rid]
                plan.prefill.append(PrefillSlice(
                    req_id=rid, token_start=r.tokens_done,
                    token_end=r.prompt_len,
                    block_start=0, block_end=self.n_blocks,
                    emits_first_token=True))
                r.tokens_done = r.prompt_len
        else:
            plan.decode_ids = self.decode_ids()
        self._finish_decode_bookkeeping(plan)
        return plan
