"""Orca-style continuous batching (OSDI'22) — baseline.

Iteration-level scheduling: newly admitted requests run their FULL prefill
(all tokens × all blocks) in one iteration, co-scheduled with decode. No
stall-free guarantee: a long prefill inflates that iteration's duration and
every concurrent decode's TBT — the failure mode chunked/layered prefill
were designed to fix."""

from __future__ import annotations

from repro.core.base import Scheduler, register
from repro.core.plan import IterationPlan, PrefillSlice


@register
class ContinuousBatchingScheduler(Scheduler):
    name = "continuous"

    def _plan(self, now: float = 0.0) -> IterationPlan:
        plan = IterationPlan()
        plan.decode_ids = self.decode_ids()
        plan.admitted_ids = self.admit(now)
        for rid in plan.admitted_ids:
            r = self.requests[rid]
            plan.prefill.append(PrefillSlice(
                req_id=rid, token_start=r.tokens_done,
                token_end=r.prompt_len,
                block_start=0, block_end=self.n_blocks,
                emits_first_token=True))
            r.tokens_done = r.prompt_len
        self._finish_decode_bookkeeping(plan)
        return plan
