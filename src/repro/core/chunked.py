"""Chunked prefill (Sarathi-Serve, OSDI'24) — the paper's baseline.

Token-axis scheduling: each iteration forms a hybrid batch of all decode
tokens plus a prefill chunk filling the remaining token budget; the chunk
traverses ALL blocks. Short waiting requests are coalesced into one chunk.
This is the scheduler whose MoE expert-reload amplification (#chunks ×
expert loads) the paper eliminates.
"""

from __future__ import annotations

from repro.core.base import Scheduler, register
from repro.core.plan import IterationPlan, PrefillSlice, RequestState


@register
class ChunkedPrefillScheduler(Scheduler):
    name = "chunked"

    def _plan(self, now: float = 0.0) -> IterationPlan:
        plan = IterationPlan()
        plan.decode_ids = self.decode_ids()

        # Sarathi: decode tokens count against the iteration token budget.
        budget = max(self.token_budget - len(plan.decode_ids), 0)

        # serve in-flight prefills first (FCFS by admit order = req_id order),
        # then admit more while budget remains.
        while budget > 0:
            pending = [r for r in self.active
                       if r.state == RequestState.PREFILL and r.remaining_prompt > 0
                       and all(s.req_id != r.req_id for s in plan.prefill)]
            pending.sort(key=lambda r: (r.admit_time, r.req_id))
            if not pending:
                newly = self.admit(now, limit=1)
                if not newly:
                    break
                plan.admitted_ids.extend(newly)
                continue
            r = pending[0]
            take = min(budget, r.remaining_prompt)
            sl = PrefillSlice(
                req_id=r.req_id,
                token_start=r.tokens_done,
                token_end=r.tokens_done + take,
                block_start=0,
                block_end=self.n_blocks,
                emits_first_token=(r.tokens_done + take == r.prompt_len),
            )
            plan.prefill.append(sl)
            r.tokens_done += take
            budget -= take

        self._finish_decode_bookkeeping(plan)
        return plan
