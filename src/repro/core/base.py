"""Scheduler base: request table, memory-pressure-aware admission, decode
bookkeeping, and preemption.

Schedulers are *pure control logic* — no jax, no timing. The same scheduler
instance drives either the real-execution engine (serving/engine.py) or the
discrete-event simulator (serving/simulator.py); that the two share this
code is what makes the functional-equivalence tests meaningful.

Invariants enforced here and asserted by tests/test_scheduler_invariants.py:
  I1 (stall-free): every iteration's plan decodes EVERY request in DECODE
      state — decode work is never preempted by prefill.  (A memory-pressure
      eviction moves its victim OUT of DECODE before the plan is built, so
      I1 is stated over the post-eviction decode set.)
  I2 (coverage): over a prefill *epoch* (admission → completion or
      preemption) a request's slices tile the rectangle
      [0, prompt_len) x [0, n_blocks) at most once, and the final epoch
      tiles it exactly once — each layer sees each prompt token exactly
      once per epoch (the paper's anti-amplification property is I2 plus
      the per-iteration shape of the slices).
  I3 (order): slices of a request are emitted in block-major/token-major
      order consistent with causal dependencies (restarting at (0, 0) on a
      new epoch).

Memory model (DESIGN.md §Paged KV memory): when a ``PagedKVAllocator`` is
attached, admission reserves ``prompt_len + decode_reserve`` tokens of KV
plus the scheduler's worst-case boundary-activation stash up front, so
prefill never runs out of pages mid-flight; decode growth past the
reservation is charged page-by-page at the top of ``next_plan`` and, when
the pool is dry, evicts victims latest-arrival-first.  Without an
allocator the schedulers behave exactly as before (slot-bound admission
only).

Eviction is mode-aware (DESIGN.md §Swap-to-host preemption):

  * "recompute" — free the victim's pages and fold its generated tokens
    into the recompute prompt; the request re-enters PREFILL at the head
    of the queue (the PR-2 behaviour, always available as a fallback).
  * "swap" — move the victim's KV pages to the allocator's host pool
    intact (``RequestState.SWAPPED``).  Re-admission is a DMA-back gated
    on free HBM pages AND the per-iteration swap-in token budget
    (``swap_in_budget``); the request then resumes DECODE directly.
    Only complete-KV victims (DECODE state, no live stash) are swappable;
    mid-prefill victims and host-pool overflow fall back to recompute.
  * "auto" — per victim, swap iff ``swap_cost_fn`` (wired by the executor
    from the hardware cost model) says the DMA round-trip is cheaper than
    re-running the recompute prefill; without a cost hook, auto prefers
    swap whenever the victim is swappable.

Multi-tenant SLO classes (DESIGN.md §Serving runtime): every request
carries a ``slo_class`` ("interactive" | "batch" | operator-defined).
The eviction victim walk is class-aware — candidates are ranked by
``CLASS_EVICT_RANK`` FIRST (batch victims go before interactive ones) and
latest-arrival within a class — and admission can reserve per-class
headroom pages: with ``class_headroom={"interactive": k}``, a request of
any OTHER class must leave k pages free, so a batch burst cannot starve
interactive admissions.
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Callable, Dict, List, Optional

from repro.core.plan import IterationPlan, Request, RequestState

if TYPE_CHECKING:  # avoid core <-> serving import cycle at runtime
    from repro.serving.kvcache import PagedKVAllocator

# Eviction priority per SLO class: HIGHER rank is evicted first.  Unknown
# classes rank with "interactive" (never evicted ahead of batch work).
CLASS_EVICT_RANK: Dict[str, int] = {"interactive": 0, "batch": 1}


def fold_for_recompute(r: Request) -> None:
    """Fold ``r``'s generated tokens into its recompute prompt and reset
    the prefill counters for a fresh epoch (PREEMPTED state).  Recompute
    prefill covers prompt + everything generated so far; its final slice
    then emits generation token n_generated + 1 (greedy decode of token
    g+1 given the g-token prefix is the same function whether reached by
    a decode step or by prefill over the prefix).  Only the NOT-yet-
    folded tail is appended — a second fold must not duplicate tokens
    folded by the first.  Module-level because the disaggregated runtime
    folds in-flight migrations that belong to NO scheduler (a dropped
    link's victim); callers queue/route the request themselves."""
    if r.orig_prompt_len is None:
        r.orig_prompt_len = r.prompt_len
    r.prompt_len += r.n_generated - r.n_folded
    r.n_folded = r.n_generated
    r.tokens_done = 0
    r.blocks_done = 0
    r.n_preemptions += 1
    r.state = RequestState.PREEMPTED


class Scheduler:
    name = "base"

    def __init__(self, n_blocks: int, *, n_slots: int = 16,
                 token_budget: int = 512, quantum: int = 512):
        self.n_blocks = n_blocks
        self.n_slots = n_slots
        self.token_budget = token_budget
        self.quantum = quantum
        self.requests: Dict[int, Request] = {}
        self.waiting: deque = deque()
        self.iteration = 0
        # paged KV memory (optional — None means unbounded memory)
        self.kv: Optional["PagedKVAllocator"] = None
        self.decode_reserve = 0
        self.preemption_enabled = True
        self.preemption_mode = "recompute"
        self.swap_in_budget: Optional[int] = None
        self.swap_cost_fn: Optional[Callable[[Request], bool]] = None
        self.class_headroom: Dict[str, int] = {}
        self.n_preemptions = 0
        self.n_swap_outs = 0
        # speculative verify-k decoding (configure_speculation): budgets are
        # planned here, executed by the engine/simulator, and fed back via
        # commit_speculation
        self.spec_mode = "off"
        self.spec_k = 0
        self.spec_adaptive = True
        self._spec_ema: Dict[int, float] = {}

    # -- memory subsystem ------------------------------------------------------

    def attach_kv(self, kv: "PagedKVAllocator", *,
                  decode_reserve: Optional[int] = None,
                  preemption: bool = True, mode: str = "recompute",
                  swap_in_budget: Optional[int] = None,
                  swap_cost_fn=None,
                  class_headroom: Optional[Dict[str, int]] = None) -> None:
        """Share a paged allocator with this scheduler. ``decode_reserve``
        is the per-request decode KV reservation in tokens (default: one
        page); growth beyond it triggers the preemption path.  ``mode``
        selects the eviction flavour ("recompute" | "swap" | "auto");
        ``swap_in_budget`` caps the KV tokens DMA'd back from host per
        iteration (None = unlimited); ``swap_cost_fn(req) -> bool`` prices
        swap vs recompute per victim for "auto" (True = swap is cheaper).
        ``class_headroom`` maps an SLO class to pages reserved for it:
        admission of any OTHER class must leave that many pages free."""
        if mode not in ("recompute", "swap", "auto"):
            raise ValueError(f"unknown preemption mode {mode!r}")
        if mode != "recompute" and kv.n_host_pages <= 0:
            raise ValueError(
                f"preemption mode {mode!r} needs a host pool; construct "
                "PagedKVAllocator with n_host_pages > 0")
        self.kv = kv
        self.decode_reserve = kv.page_size if decode_reserve is None \
            else decode_reserve
        self.preemption_enabled = preemption
        self.preemption_mode = mode
        self.swap_in_budget = swap_in_budget
        self.swap_cost_fn = swap_cost_fn
        self.class_headroom = dict(class_headroom or {})

    def configure_speculation(self, mode: str = "off", k: int = 4,
                              adaptive: bool = True) -> None:
        """Enable speculative verify-k decoding.  ``mode`` selects the
        drafter the executor runs ("ngram" | "draft"; "off" disables);
        ``k`` is the per-request draft budget ceiling; ``adaptive`` scales
        the draft-model budget by a per-request acceptance EMA (n-gram
        proposals are already self-limiting, so the EMA only gates the
        draft-model path)."""
        if mode not in ("off", "ngram", "draft"):
            raise ValueError(f"unknown speculation mode {mode!r}")
        if mode != "off" and k < 1:
            raise ValueError("speculation needs k >= 1")
        self.spec_mode = mode
        self.spec_k = k if mode != "off" else 0
        self.spec_adaptive = adaptive

    def _spec_budget(self, r: Request) -> int:
        """Draft budget for ``r`` this iteration: the configured k, shrunk
        by the acceptance EMA (draft mode), and capped so the base token
        plus every accepted draft can never exceed max_new_tokens."""
        cap = r.max_new_tokens - r.n_generated - 1
        if cap <= 0:
            return 0
        k = self.spec_k
        if self.spec_adaptive and self.spec_mode == "draft":
            ema = self._spec_ema.get(r.req_id, 1.0)
            k = max(1, int(round(ema * self.spec_k)))
        return min(k, cap)

    def _spec_budgets(self) -> Dict[int, int]:
        """Per-request draft budgets for this iteration's decode set, with
        the verify window's worst-case KV pre-charged (``reserve_spec``).
        Speculation is opportunistic: it never evicts — when the pool
        cannot cover the full window the budget shrinks (possibly to 0)
        instead, so spec on/off admission and eviction decisions are
        identical."""
        if self.spec_mode == "off":
            return {}
        budgets: Dict[int, int] = {}
        decodes = sorted((r for r in self.requests.values()
                          if r.state == RequestState.DECODE
                          and r.use_speculation),
                         key=lambda r: r.req_id)
        for r in decodes:
            k = self._spec_budget(r)
            if k <= 0:
                continue
            if self.kv is not None:
                base = r.prompt_len + r.n_generated - r.n_folded
                while k > 0 and self.kv.growth_deficit(r.req_id, base + k) \
                        > self.kv.n_free_pages:
                    k -= 1
                if k <= 0:
                    continue
                self.kv.reserve_spec(r.req_id, base + k)
            budgets[r.req_id] = k
        return budgets

    def commit_speculation(self, req_id: int, *, proposed: int,
                           accepted: int, extra: int,
                           committed_len: Optional[int] = None) -> None:
        """Executor feedback after verifying ``req_id``'s drafts:
        ``proposed`` tokens were drafted, ``accepted`` matched the target
        argmax, and ``extra`` tokens were emitted BEYOND the base decode
        token (normally == accepted; EOS truncation can make it smaller).
        Updates generation counters, the acceptance EMA, and trims the
        speculative page reservation back to ``committed_len`` (the filled
        KV length; inferred from the allocator record when omitted).  MUST
        be called for every id in ``plan.verify_len`` — a 0-proposal call
        is how the page pre-charge of a skipped row is released."""
        r = self.requests[req_id]
        if proposed > 0:
            r.n_spec_rounds += 1
            r.n_drafted += proposed
            r.n_draft_accepted += accepted
            r.accepted_lens.append(accepted)
            ema = self._spec_ema.get(req_id, 1.0)
            self._spec_ema[req_id] = 0.5 * ema + 0.5 * (accepted / proposed)
        if extra > 0:
            r.n_generated += extra
            assert r.n_generated <= r.max_new_tokens, req_id
            if r.n_generated >= r.max_new_tokens \
                    and r.state == RequestState.DECODE:
                r.state = RequestState.DONE
        if self.kv is not None and self.kv.is_resident(req_id):
            if r.state == RequestState.DONE:
                self.kv.free(req_id)
            else:
                if committed_len is None:
                    committed_len = self.kv.length(req_id) + extra
                self.kv.grow_to(req_id, committed_len)
                self.kv.release_spec(req_id)
        if r.state == RequestState.DONE:
            self._spec_ema.pop(req_id, None)

    def _headroom_for(self, slo_class: str) -> int:
        """Pages a request of ``slo_class`` must leave free at admission:
        the headroom reserved for every OTHER class."""
        return sum(pages for cls, pages in self.class_headroom.items()
                   if cls != slo_class)

    def max_stash_tokens(self, req: Request,
                         prompt_len: Optional[int] = None) -> int:
        """Worst-case boundary-activation stash (in prompt tokens) this
        scheduler will hold live for ``req`` — charged against the page
        pool at admission. Token-axis schedulers carry no stash.
        ``prompt_len`` overrides the request's current value (used to
        evaluate eligibility at the POST-fold recompute length)."""
        return 0

    # -- lifecycle -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert req.req_id not in self.requests
        req.state = RequestState.WAITING
        self.requests[req.req_id] = req
        self.waiting.append(req.req_id)

    def finish(self, req_id: int) -> None:
        """Executor signals EOS / client cancel before max_new_tokens."""
        self.requests[req_id].state = RequestState.DONE
        self._spec_ema.pop(req_id, None)
        if self.kv is not None and self.kv.owns(req_id):
            self.kv.free(req_id)

    # -- inter-pool migration (disaggregated serving) --------------------------

    def can_adopt(self, req: Request) -> bool:
        """True iff an imported request (KV already landed on this
        scheduler's allocator) can join the resident set right now."""
        return self.n_active < self.n_slots

    def adopt(self, req: Request) -> None:
        """Place a migrated request directly into DECODE.  The caller has
        already materialized its KV on this scheduler's allocator
        (``import_pages``) — adoption is pure bookkeeping; the next plan
        decodes it under invariant I1 like any other resident."""
        assert req.req_id not in self.requests, req.req_id
        assert self.kv is None or self.kv.is_resident(req.req_id), req.req_id
        req.state = RequestState.DECODE
        self.requests[req.req_id] = req

    def pop_request(self, req_id: int) -> Request:
        """Remove a request from this scheduler entirely (migration out).
        Its KV, if any, must already have been exported/freed — this drops
        only control state.  The mirror of ``adopt``/``submit``."""
        r = self.requests.pop(req_id)
        try:
            self.waiting.remove(req_id)
        except ValueError:
            pass
        self._spec_ema.pop(req_id, None)
        return r

    def readmit(self, req: Request) -> None:
        """Accept a recompute victim routed back from another pool (the
        decode pool cannot prefill, so its fold-to-recompute victims return
        here).  The fold already ran on the evicting scheduler; requeue at
        the HEAD exactly like a local preemption so the victim is not
        starved behind never-admitted arrivals."""
        assert req.req_id not in self.requests, req.req_id
        assert req.state == RequestState.PREEMPTED, req.state
        self.requests[req.req_id] = req
        self.waiting.appendleft(req.req_id)

    @property
    def active(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.state in (RequestState.PREFILL, RequestState.DECODE)]

    @property
    def n_active(self) -> int:
        return len(self.active)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_active > 0

    def decode_ids(self) -> List[int]:
        return sorted(r.req_id for r in self.requests.values()
                      if r.state == RequestState.DECODE)

    # -- admission ------------------------------------------------------------

    def _kv_admissible(self, r: Request) -> bool:
        if self.kv is None:
            return True
        need = r.prompt_len + self.decode_reserve
        stash = self.max_stash_tokens(r)
        headroom = self._headroom_for(r.slo_class)
        # a request that cannot fit even an EMPTY pool (minus the headroom
        # reserved for other classes) would wait forever — surface it
        # instead of deadlocking the queue (queued requests have
        # n_generated == n_folded, so prompt_len + remaining generation is
        # the true final sequence length).  The worst case is deliberately
        # NOT prefix-aware: shared pages can be reclaimed under pressure.
        worst = r.prompt_len + (r.max_new_tokens - r.n_folded)
        if not self.kv.fits_pool(worst, stash, headroom_pages=headroom):
            reserved = f" minus {headroom} headroom pages" if headroom else ""
            raise RuntimeError(
                f"request {r.req_id} needs {worst} KV tokens "
                f"(+{stash} stash) but the pool holds only "
                f"{self.kv.n_pages * self.kv.page_size} tokens{reserved}; "
                f"enlarge --pages or shard the request")
        # prefix-aware admission: matched prefix tokens are charged zero new
        # pages (can_admit links, not allocates, shared pages) and the stash
        # only ever carries the UNCACHED tail of the prompt
        hit = self.kv.lookup_prefix(r.cacheable_prompt)
        stash = self.max_stash_tokens(
            r, prompt_len=r.prompt_len - hit.cached_tokens)
        return self.kv.can_admit(need, stash, headroom_pages=headroom,
                                 prompt_tokens=r.cacheable_prompt)

    def admit(self, now: float, limit: Optional[int] = None) -> List[int]:
        """FCFS admission, gated on BOTH a free slot and the page pool
        holding the request's prompt KV + decode reservation + stash.
        Head-of-line blocking is deliberate: bypassing a big request with
        later small ones would starve it under sustained load."""
        admitted = []
        while self.waiting and self.n_active < self.n_slots:
            if limit is not None and len(admitted) >= limit:
                break
            rid = self.waiting[0]
            r = self.requests[rid]
            if r.state == RequestState.SWAPPED:
                # swapped requests re-enter ONLY via the swap-in pass at the
                # top of next_plan (HBM pages + bandwidth budget gates);
                # bypassing this head-of-line victim would starve it
                break
            if not self._kv_admissible(r):
                break
            self.waiting.popleft()
            if self.kv is not None:
                hit = self.kv.lookup_prefix(r.cacheable_prompt)
                stash = self.max_stash_tokens(
                    r, prompt_len=r.prompt_len - hit.cached_tokens)
                hit = self.kv.reserve(rid, r.prompt_len + self.decode_reserve,
                                      stash, prompt_tokens=r.cacheable_prompt)
                # matched prefix tokens are already computed: this prefill
                # epoch starts past the cached boundary (every layer group
                # skips them uniformly — per-group KV is complete for
                # cached blocks)
                r.tokens_done = hit.cached_tokens
                r.cached_prompt_tokens += hit.cached_tokens
            r.admitted_prompt_tokens += r.prompt_len
            r.state = RequestState.PREFILL
            if r.admit_time is None:        # queueing delay = FIRST admission
                r.admit_time = now
            admitted.append(rid)
        return admitted

    # -- preemption ------------------------------------------------------------

    def _evictable(self, r: Request) -> bool:
        """True iff ``r`` would still fit an EMPTY pool after the
        restore-by-recompute fold (prompt + generated-so-far, with the
        stash re-evaluated at the folded length, and the same per-class
        headroom its re-admission will be gated on)."""
        folded = r.prompt_len + (r.n_generated - r.n_folded)
        worst = folded + (r.max_new_tokens - r.n_generated)
        return self.kv.fits_pool(worst,
                                 self.max_stash_tokens(r, prompt_len=folded),
                                 headroom_pages=self._headroom_for(r.slo_class))

    def _on_preempt(self, req_id: int) -> None:
        """Scheduler-specific cleanup (drop the victim from in-flight cohort
        / chunk-run state). Base schedulers keep no such state."""

    def swap_out(self, req_id: int, now: float = 0.0) -> None:
        """Evict ``req_id`` by swapping its KV pages to the host pool
        (``SWAPPED`` state): no pages are lost, no tokens are folded, and
        re-admission resumes DECODE directly after the DMA-back.  Requeued
        at the head like a recompute victim."""
        r = self.requests[req_id]
        assert r.state == RequestState.DECODE, r.state
        self._on_preempt(req_id)
        self.kv.swap_out(req_id)
        r.state = RequestState.SWAPPED
        r.n_swaps += 1
        r.swap_out_times.append(now)
        self.waiting.appendleft(req_id)
        self.n_swap_outs += 1

    def preempt(self, req_id: int) -> None:
        """Evict ``req_id`` (restore-by-recompute): free its pages, fold the
        tokens it already generated into the recompute prompt, and requeue
        it ahead of never-admitted arrivals (earliest-arrival first)."""
        r = self.requests[req_id]
        assert r.state in (RequestState.PREFILL, RequestState.DECODE), r.state
        self._on_preempt(req_id)
        if self.kv is not None and self.kv.owns(req_id):
            self.kv.free(req_id)
        fold_for_recompute(r)
        self.waiting.appendleft(req_id)
        self.n_preemptions += 1

    def shed(self, req_id: int, reason: str = "deadline") -> None:
        """Remove ``req_id`` from service without completing it (deadline
        expiry, retry exhaustion, client disconnect, load shedding):
        release every page it holds — resident, swapped, or stash — drop
        it from the waiting queue, and mark it DONE with ``shed_reason``
        so metrics can tell a shed stream from a finished one.  Unlike
        ``finish`` this handles any pre-DONE state and scrubs the waiting
        deque (a DONE rid left at the head would corrupt ``admit``)."""
        r = self.requests[req_id]
        assert r.state != RequestState.DONE, req_id
        self._on_preempt(req_id)
        try:
            self.waiting.remove(req_id)
        except ValueError:
            pass
        self._spec_ema.pop(req_id, None)
        if self.kv is not None and self.kv.owns(req_id):
            self.kv.free(req_id)
        r.state = RequestState.DONE
        r.shed_reason = reason

    def fail_swap_out(self, req_id: int) -> None:
        """A swap-out DMA failed mid-flight: the host copy is void, so the
        victim cannot be restored by swap-in.  Demote it to a recompute
        eviction — free its pages (dropping the dead host copy), un-record
        the swap, and fold for a fresh prefill epoch.  The request is
        already queued at the head from ``swap_out``; only the state and
        the pages change, exactly like ``_demote_swapped``."""
        r = self.requests[req_id]
        assert r.state == RequestState.SWAPPED, r.state
        self.kv.free(req_id)
        r.n_swaps -= 1
        if r.swap_out_times:
            r.swap_out_times.pop()
        self.n_swap_outs -= 1
        fold_for_recompute(r)
        self.n_preemptions += 1

    def _evict_route(self, r: Request) -> Optional[str]:
        """Eviction flavour available for victim ``r``: "swap" (KV pages to
        host, no work lost), "recompute" (fold + re-prefill), or None when
        neither leaves the request restorable.  Swap requires a complete KV
        (DECODE state — mid-prefill boundary stashes are execution state,
        not KV) and host-pool room; "auto" additionally asks the executor's
        cost hook whether the DMA round-trip beats the recompute prefill."""
        swappable = (self.preemption_mode != "recompute"
                     and r.state == RequestState.DECODE
                     and self.kv.can_swap_out(r.req_id))
        recomputable = self._evictable(r)
        if swappable:
            if self.preemption_mode == "swap":
                return "swap"
            if (self.swap_cost_fn is None or self.swap_cost_fn(r)
                    or not recomputable):
                return "swap"
        return "recompute" if recomputable else None

    def _reserve_decode_growth(self, now: float):
        """Pre-charge this iteration's decode KV growth (one token per
        DECODE request), evicting victims latest-arrival-first while the
        pool cannot cover the deficit. Runs BEFORE the plan is built so I1
        is stated over the surviving decode set.  Returns the recompute
        and swap victim id lists."""
        if self.kv is None:
            return [], []
        preempted: List[int] = []
        swapped: List[int] = []
        decodes: List[Request] = []
        while True:
            decodes = [r for r in self.requests.values()
                       if r.state == RequestState.DECODE]
            # KV after this iteration's write: recompute prompt plus the
            # tokens generated SINCE the last fold (folded ones are already
            # inside prompt_len)
            deficit = sum(
                self.kv.growth_deficit(
                    r.req_id,
                    r.prompt_len + r.n_generated - r.n_folded)
                for r in decodes)
            if deficit <= self.kv.n_free_pages:
                break
            if not self.preemption_enabled:
                # let grow_to below surface PagedPoolExhausted — the
                # operator chose queueing-only (--preemption off)
                break
            # eligible victims: eviction must leave the request restorable
            # (swap: host-pool room; recompute: the post-fold footprint
            # still fits an empty pool).  The earliest-arrival resident is
            # never evicted: admission guarantees a lone request always
            # fits, so keeping it guarantees forward progress.  The guard
            # is CLASS-AWARE: protect the earliest within the highest-
            # priority class present — a batch-class earliest resident
            # must not shield itself while interactive requests starve.
            residents = self.active
            best_rank = min(CLASS_EVICT_RANK.get(r.slo_class, 0)
                            for r in residents)
            earliest = min((r for r in residents
                            if CLASS_EVICT_RANK.get(r.slo_class, 0)
                            == best_rank),
                           key=lambda r: (r.arrival_time, r.req_id))
            # walk candidates class-rank-first (batch victims before
            # interactive — CLASS_EVICT_RANK), latest-arrival within a
            # class, and take the FIRST with an eviction route — identical
            # victim to scoring them all, but the route (and the auto-mode
            # cost hook behind it) is evaluated only until a victim is
            # found, not per resident
            victim = route = None
            for r in sorted((r for r in self.active if r is not earliest),
                            key=lambda r: (CLASS_EVICT_RANK.get(r.slo_class, 0),
                                           r.arrival_time, r.req_id),
                            reverse=True):
                route = self._evict_route(r)
                if route:
                    victim = r
                    break
            if victim is None:
                # every resident is shielded: the pool must be pinned by
                # SWAPPED requests' shared prefix pages.  Demote one to a
                # recompute victim (releasing its pin + host copy) before
                # declaring the pool undersized.
                demoted = self._demote_swapped(exclude=swapped)
                if demoted is not None:
                    preempted.append(demoted)
                    continue
                raise RuntimeError(
                    "paged KV pool cannot cover decode growth and no "
                    "evictable resident remains — enlarge the pool")
            if route == "swap":
                self.swap_out(victim.req_id, now)
                swapped.append(victim.req_id)
            else:
                self.preempt(victim.req_id)
                preempted.append(victim.req_id)
        for r in decodes:
            self.kv.grow_to(r.req_id,
                            r.prompt_len + r.n_generated - r.n_folded)
        return preempted, swapped

    def _demote_swapped(self, exclude: List[int] = ()) -> Optional[int]:
        """Pressure valve for the swap-pin deadlock: a SWAPPED request's
        shared prefix pages stay pinned in HBM, so enough swapped victims
        can starve the lone protected resident's decode growth with no
        resident evictable (acute on a disaggregated decode pool, whose
        imports register every prompt page as shared).  Fold the lowest-
        priority latest-arrival swapped request to a recompute victim —
        the only transition that unpins without a swap-in.  It is already
        queued at the head from its swap-out; only the state and the
        pages change.  ``exclude`` holds THIS iteration's swap victims
        (demoting one would undo the swap it just paid for).  Returns
        the demoted id, or None if no swapped request qualifies."""
        cands = [r for r in self.requests.values()
                 if r.state == RequestState.SWAPPED
                 and r.req_id not in exclude and self._evictable(r)]
        if not cands:
            return None
        victim = max(cands,
                     key=lambda r: (CLASS_EVICT_RANK.get(r.slo_class, 0),
                                    r.arrival_time, r.req_id))
        rid = victim.req_id
        self.kv.free(rid)
        fold_for_recompute(victim)
        self.n_preemptions += 1
        return rid

    def _readmit_swapped(self, now: float,
                         exclude: List[int] = ()) -> List[int]:
        """DMA-back pass: restore SWAPPED requests from the head of the
        queue while (a) a slot is free, (b) the HBM pool holds their pages,
        and (c) the per-iteration swap-in token budget allows.  At least
        one restore is always allowed once pages fit — a budget smaller
        than the smallest request must throttle, not deadlock.  Restored
        requests resume DECODE directly (their KV is intact).  ``exclude``
        holds THIS iteration's swap victims: restoring one of them would
        be a zero-progress DMA round trip (it would retake the very pages
        it just vacated and be evicted again next iteration), so the pass
        stops at them until at least one iteration has elapsed."""
        if self.kv is None:
            return []
        budget = self.swap_in_budget
        swapped_in: List[int] = []
        while self.waiting and self.n_active < self.n_slots:
            rid = self.waiting[0]
            r = self.requests[rid]
            if r.state != RequestState.SWAPPED or rid in exclude:
                break
            if not self.kv.can_swap_in(rid):
                break
            # the DMA-back is a re-admission: it must leave the same
            # per-class headroom free that queue admission enforces, or a
            # swapped batch request would retake the interactive reserve
            if self.kv.n_free_pages - self.kv.swapped_pages(rid) \
                    < self._headroom_for(r.slo_class):
                break
            need = self.kv.length(rid)
            if budget is not None and need > budget and swapped_in:
                break
            self.waiting.popleft()
            r.swap_in_times.append(now)
            self.kv.swap_in(rid)
            r.state = RequestState.DECODE
            swapped_in.append(rid)
            if budget is not None:
                budget -= need
                if budget <= 0:
                    break
        return swapped_in

    # -- per-iteration hooks ----------------------------------------------------

    def next_plan(self, now: float = 0.0) -> IterationPlan:
        """Template method: resolve memory pressure (possibly evicting via
        recompute-fold or swap-to-host), restore swapped requests within
        the DMA budget, then delegate iteration planning to ``_plan``."""
        preempted, swapped_out = self._reserve_decode_growth(now)
        # draft budgets over the post-eviction decode set; requests swapped
        # IN below decode plainly their first iteration back (their budget
        # pass already ran)
        spec = self._spec_budgets()
        swapped_in = self._readmit_swapped(now, exclude=swapped_out)
        plan = self._plan(now)
        plan.preempted_ids = preempted
        plan.swapped_out_ids = swapped_out
        plan.swapped_in_ids = swapped_in
        if spec:
            in_plan = set(plan.decode_ids)
            plan.verify_len = {rid: k for rid, k in spec.items()
                               if rid in in_plan}
            if self.kv is not None:      # defensive: never strand a charge
                for rid in spec:
                    if rid not in in_plan:
                        self.kv.release_spec(rid)
        return plan

    def _plan(self, now: float) -> IterationPlan:
        raise NotImplementedError

    def _finish_decode_bookkeeping(self, plan: IterationPlan) -> None:
        """Advance decode counters; retire requests that hit max_new_tokens.
        The first token of a prefill epoch is produced by its final prefill
        slice, so a fresh request entering DECODE has n_generated == 1 (a
        recompute-restored one continues from its pre-eviction count)."""
        for rid in plan.decode_ids:
            r = self.requests[rid]
            r.n_generated += 1
            if r.n_generated >= r.max_new_tokens:
                r.state = RequestState.DONE
                if self.kv is not None and self.kv.owns(rid):
                    self.kv.free(rid)
        for sl in plan.prefill:
            if sl.emits_first_token:
                r = self.requests[sl.req_id]
                if self.kv is not None and self.kv.owns(sl.req_id):
                    self.kv.set_length(sl.req_id, r.prompt_len)
                    self.kv.release_stash(sl.req_id)
                    # publish the completed prompt's full pages into the
                    # shared-prefix index (idempotent — the engine may have
                    # registered already when snapshotting its KV row) so
                    # later admissions can link them refcounted
                    self.kv.register_prefix(sl.req_id, r.cacheable_prompt)
                r.state = RequestState.DECODE
                r.n_generated += 1
                if r.n_generated >= r.max_new_tokens:
                    r.state = RequestState.DONE
                    if self.kv is not None and self.kv.owns(sl.req_id):
                        self.kv.free(sl.req_id)
        self.iteration += 1


SCHEDULERS: Dict[str, type] = {}

# Schedulers resolvable by make_scheduler but absent from the public
# SCHEDULERS enumeration (CLI choices, invariant sweeps): pool-internal
# roles that are not standalone serving policies.
_INTERNAL_SCHEDULERS: Dict[str, type] = {}


def register(cls):
    SCHEDULERS[cls.name] = cls
    return cls


def register_internal(cls):
    _INTERNAL_SCHEDULERS[cls.name] = cls
    return cls


def make_scheduler(name: str, n_blocks: int, **kw) -> Scheduler:
    cls = SCHEDULERS.get(name) or _INTERNAL_SCHEDULERS.get(name)
    if cls is None:
        raise KeyError(f"unknown scheduler {name!r}; known: {list(SCHEDULERS)}")
    return cls(n_blocks, **kw)


@register_internal
class DecodeOnlyScheduler(Scheduler):
    """The decode pool's scheduler in disaggregated serving: residents
    arrive exclusively via ``adopt`` (KV imported from the prefill pool),
    so ``_plan`` never admits and never emits prefill slices — the pool's
    iteration clock contains ONLY decode work, which is what makes the
    decode pool's TBT provably prefill-free.  Memory pressure still runs:
    decode growth can evict (swap victims restore locally through
    ``_readmit_swapped``; recompute victims fold and are routed BACK to
    the prefill pool by the disaggregated runtime)."""

    name = "decode"

    def _plan(self, now: float = 0.0) -> IterationPlan:
        plan = IterationPlan()
        plan.decode_ids = self.decode_ids()
        self._finish_decode_bookkeeping(plan)
        return plan
