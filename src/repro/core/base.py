"""Scheduler base: request table, memory-pressure-aware admission, decode
bookkeeping, and preemption.

Schedulers are *pure control logic* — no jax, no timing. The same scheduler
instance drives either the real-execution engine (serving/engine.py) or the
discrete-event simulator (serving/simulator.py); that the two share this
code is what makes the functional-equivalence tests meaningful.

Invariants enforced here and asserted by tests/test_scheduler_invariants.py:
  I1 (stall-free): every iteration's plan decodes EVERY request in DECODE
      state — decode work is never preempted by prefill.  (A memory-pressure
      eviction moves its victim OUT of DECODE before the plan is built, so
      I1 is stated over the post-eviction decode set.)
  I2 (coverage): over a prefill *epoch* (admission → completion or
      preemption) a request's slices tile the rectangle
      [0, prompt_len) x [0, n_blocks) at most once, and the final epoch
      tiles it exactly once — each layer sees each prompt token exactly
      once per epoch (the paper's anti-amplification property is I2 plus
      the per-iteration shape of the slices).
  I3 (order): slices of a request are emitted in block-major/token-major
      order consistent with causal dependencies (restarting at (0, 0) on a
      new epoch).

Memory model (DESIGN.md §Paged KV memory): when a ``PagedKVAllocator`` is
attached, admission reserves ``prompt_len + decode_reserve`` tokens of KV
plus the scheduler's worst-case boundary-activation stash up front, so
prefill never runs out of pages mid-flight; decode growth past the
reservation is charged page-by-page at the top of ``next_plan`` and, when
the pool is dry, evicts victims latest-arrival-first (restore-by-recompute:
generated tokens fold into the recompute prompt and the request re-enters
the queue ahead of never-admitted arrivals).  Without an allocator the
schedulers behave exactly as before (slot-bound admission only).
"""

from __future__ import annotations

from collections import deque
from typing import TYPE_CHECKING, Dict, List, Optional

from repro.core.plan import IterationPlan, PrefillSlice, Request, RequestState

if TYPE_CHECKING:  # avoid core <-> serving import cycle at runtime
    from repro.serving.kvcache import PagedKVAllocator


class Scheduler:
    name = "base"

    def __init__(self, n_blocks: int, *, n_slots: int = 16,
                 token_budget: int = 512, quantum: int = 512):
        self.n_blocks = n_blocks
        self.n_slots = n_slots
        self.token_budget = token_budget
        self.quantum = quantum
        self.requests: Dict[int, Request] = {}
        self.waiting: deque = deque()
        self.iteration = 0
        # paged KV memory (optional — None means unbounded memory)
        self.kv: Optional["PagedKVAllocator"] = None
        self.decode_reserve = 0
        self.preemption_enabled = True
        self.n_preemptions = 0

    # -- memory subsystem ------------------------------------------------------

    def attach_kv(self, kv: "PagedKVAllocator", *,
                  decode_reserve: Optional[int] = None,
                  preemption: bool = True) -> None:
        """Share a paged allocator with this scheduler. ``decode_reserve``
        is the per-request decode KV reservation in tokens (default: one
        page); growth beyond it triggers the preemption path."""
        self.kv = kv
        self.decode_reserve = kv.page_size if decode_reserve is None \
            else decode_reserve
        self.preemption_enabled = preemption

    def max_stash_tokens(self, req: Request,
                         prompt_len: Optional[int] = None) -> int:
        """Worst-case boundary-activation stash (in prompt tokens) this
        scheduler will hold live for ``req`` — charged against the page
        pool at admission. Token-axis schedulers carry no stash.
        ``prompt_len`` overrides the request's current value (used to
        evaluate eligibility at the POST-fold recompute length)."""
        return 0

    # -- lifecycle -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert req.req_id not in self.requests
        req.state = RequestState.WAITING
        self.requests[req.req_id] = req
        self.waiting.append(req.req_id)

    def finish(self, req_id: int) -> None:
        """Executor signals EOS / client cancel before max_new_tokens."""
        self.requests[req_id].state = RequestState.DONE
        if self.kv is not None and self.kv.owns(req_id):
            self.kv.free(req_id)

    @property
    def active(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.state in (RequestState.PREFILL, RequestState.DECODE)]

    @property
    def n_active(self) -> int:
        return len(self.active)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_active > 0

    def decode_ids(self) -> List[int]:
        return sorted(r.req_id for r in self.requests.values()
                      if r.state == RequestState.DECODE)

    # -- admission ------------------------------------------------------------

    def _kv_admissible(self, r: Request) -> bool:
        if self.kv is None:
            return True
        need = r.prompt_len + self.decode_reserve
        stash = self.max_stash_tokens(r)
        # a request that cannot fit even an EMPTY pool would wait forever —
        # surface it instead of deadlocking the queue (queued requests have
        # n_generated == n_folded, so prompt_len + remaining generation is
        # the true final sequence length)
        worst = r.prompt_len + (r.max_new_tokens - r.n_folded)
        if not self.kv.fits_pool(worst, stash):
            raise RuntimeError(
                f"request {r.req_id} needs {worst} KV tokens "
                f"(+{stash} stash) but the pool holds only "
                f"{self.kv.n_pages * self.kv.page_size} tokens; "
                f"enlarge --pages or shard the request")
        return self.kv.can_admit(need, stash)

    def admit(self, now: float, limit: Optional[int] = None) -> List[int]:
        """FCFS admission, gated on BOTH a free slot and the page pool
        holding the request's prompt KV + decode reservation + stash.
        Head-of-line blocking is deliberate: bypassing a big request with
        later small ones would starve it under sustained load."""
        admitted = []
        while self.waiting and self.n_active < self.n_slots:
            if limit is not None and len(admitted) >= limit:
                break
            rid = self.waiting[0]
            r = self.requests[rid]
            if not self._kv_admissible(r):
                break
            self.waiting.popleft()
            if self.kv is not None:
                self.kv.reserve(rid, r.prompt_len + self.decode_reserve,
                                self.max_stash_tokens(r))
            r.state = RequestState.PREFILL
            if r.admit_time is None:        # queueing delay = FIRST admission
                r.admit_time = now
            admitted.append(rid)
        return admitted

    # -- preemption ------------------------------------------------------------

    def _evictable(self, r: Request) -> bool:
        """True iff ``r`` would still fit an EMPTY pool after the
        restore-by-recompute fold (prompt + generated-so-far, with the
        stash re-evaluated at the folded length)."""
        folded = r.prompt_len + (r.n_generated - r.n_folded)
        worst = folded + (r.max_new_tokens - r.n_generated)
        return self.kv.fits_pool(worst,
                                 self.max_stash_tokens(r, prompt_len=folded))

    def _on_preempt(self, req_id: int) -> None:
        """Scheduler-specific cleanup (drop the victim from in-flight cohort
        / chunk-run state). Base schedulers keep no such state."""

    def preempt(self, req_id: int) -> None:
        """Evict ``req_id`` (restore-by-recompute): free its pages, fold the
        tokens it already generated into the recompute prompt, and requeue
        it ahead of never-admitted arrivals (earliest-arrival first)."""
        r = self.requests[req_id]
        assert r.state in (RequestState.PREFILL, RequestState.DECODE), r.state
        self._on_preempt(req_id)
        if self.kv is not None and self.kv.owns(req_id):
            self.kv.free(req_id)
        if r.orig_prompt_len is None:
            r.orig_prompt_len = r.prompt_len
        # recompute prefill covers prompt + everything generated so far; its
        # final slice then emits generation token n_generated + 1 (greedy
        # decode of token g+1 given the g-token prefix is the same function
        # whether reached by a decode step or by prefill over the prefix).
        # Only the NOT-yet-folded tail is appended — a second preemption
        # must not re-fold tokens folded by the first.
        r.prompt_len += r.n_generated - r.n_folded
        r.n_folded = r.n_generated
        r.tokens_done = 0
        r.blocks_done = 0
        r.n_preemptions += 1
        r.state = RequestState.PREEMPTED
        self.waiting.appendleft(req_id)
        self.n_preemptions += 1

    def _reserve_decode_growth(self, now: float) -> List[int]:
        """Pre-charge this iteration's decode KV growth (one token per
        DECODE request), evicting victims latest-arrival-first while the
        pool cannot cover the deficit. Runs BEFORE the plan is built so I1
        is stated over the surviving decode set."""
        if self.kv is None:
            return []
        preempted: List[int] = []
        while True:
            decodes = [r for r in self.requests.values()
                       if r.state == RequestState.DECODE]
            # KV after this iteration's write: recompute prompt plus the
            # tokens generated SINCE the last fold (folded ones are already
            # inside prompt_len)
            deficit = sum(
                self.kv.growth_deficit(
                    r.req_id,
                    r.prompt_len + r.n_generated - r.n_folded)
                for r in decodes)
            if deficit <= self.kv.n_free_pages:
                break
            if not self.preemption_enabled:
                # let grow_to below surface PagedPoolExhausted — the
                # operator chose queueing-only (--preemption off)
                break
            # eligible victims: evicting must leave the request re-
            # admittable — folding generated tokens into the recompute
            # prompt grows the worst-case stash charge, so a request can
            # be resident yet too big to ever come back.  The earliest-
            # arrival resident is never evicted: admission guarantees a
            # lone request always fits, so keeping it guarantees forward
            # progress.
            earliest = min(self.active,
                           key=lambda r: (r.arrival_time, r.req_id))
            victims = [r for r in self.active
                       if r is not earliest and self._evictable(r)]
            if not victims:
                raise RuntimeError(
                    "paged KV pool cannot cover decode growth and no "
                    "evictable resident remains — enlarge the pool")
            victim = max(victims,
                         key=lambda r: (r.arrival_time, r.req_id))
            self.preempt(victim.req_id)
            preempted.append(victim.req_id)
        for r in decodes:
            self.kv.grow_to(r.req_id,
                            r.prompt_len + r.n_generated - r.n_folded)
        return preempted

    # -- per-iteration hooks ----------------------------------------------------

    def next_plan(self, now: float = 0.0) -> IterationPlan:
        """Template method: resolve memory pressure (possibly preempting),
        then delegate iteration planning to the scheduler's ``_plan``."""
        preempted = self._reserve_decode_growth(now)
        plan = self._plan(now)
        plan.preempted_ids = preempted
        return plan

    def _plan(self, now: float) -> IterationPlan:
        raise NotImplementedError

    def _finish_decode_bookkeeping(self, plan: IterationPlan) -> None:
        """Advance decode counters; retire requests that hit max_new_tokens.
        The first token of a prefill epoch is produced by its final prefill
        slice, so a fresh request entering DECODE has n_generated == 1 (a
        recompute-restored one continues from its pre-eviction count)."""
        for rid in plan.decode_ids:
            r = self.requests[rid]
            r.n_generated += 1
            if r.n_generated >= r.max_new_tokens:
                r.state = RequestState.DONE
                if self.kv is not None and self.kv.owns(rid):
                    self.kv.free(rid)
        for sl in plan.prefill:
            if sl.emits_first_token:
                r = self.requests[sl.req_id]
                if self.kv is not None and self.kv.owns(sl.req_id):
                    self.kv.set_length(sl.req_id, r.prompt_len)
                    self.kv.release_stash(sl.req_id)
                r.state = RequestState.DECODE
                r.n_generated += 1
                if r.n_generated >= r.max_new_tokens:
                    r.state = RequestState.DONE
                    if self.kv is not None and self.kv.owns(sl.req_id):
                        self.kv.free(sl.req_id)
        self.iteration += 1


SCHEDULERS: Dict[str, type] = {}


def register(cls):
    SCHEDULERS[cls.name] = cls
    return cls


def make_scheduler(name: str, n_blocks: int, **kw) -> Scheduler:
    if name not in SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; known: {list(SCHEDULERS)}")
    return SCHEDULERS[name](n_blocks, **kw)
