"""Scheduler base: request table, admission, decode bookkeeping.

Schedulers are *pure control logic* — no jax, no timing. The same scheduler
instance drives either the real-execution engine (serving/engine.py) or the
discrete-event simulator (serving/simulator.py); that the two share this
code is what makes the functional-equivalence tests meaningful.

Invariants enforced here and asserted by tests/test_scheduler_invariants.py:
  I1 (stall-free): every iteration's plan decodes EVERY request in DECODE
      state — decode work is never preempted by prefill.
  I2 (coverage): over a request's lifetime its prefill slices tile the
      rectangle [0, prompt_len) x [0, n_blocks) exactly once — each layer
      sees each prompt token exactly once (the paper's anti-amplification
      property is I2 plus the per-iteration shape of the slices).
  I3 (order): slices of a request are emitted in block-major/token-major
      order consistent with causal dependencies.
"""

from __future__ import annotations

from collections import deque
from typing import Dict, List, Optional

from repro.core.plan import IterationPlan, PrefillSlice, Request, RequestState


class Scheduler:
    name = "base"

    def __init__(self, n_blocks: int, *, n_slots: int = 16,
                 token_budget: int = 512, quantum: int = 512):
        self.n_blocks = n_blocks
        self.n_slots = n_slots
        self.token_budget = token_budget
        self.quantum = quantum
        self.requests: Dict[int, Request] = {}
        self.waiting: deque = deque()
        self.iteration = 0

    # -- lifecycle -----------------------------------------------------------

    def submit(self, req: Request) -> None:
        assert req.req_id not in self.requests
        req.state = RequestState.WAITING
        self.requests[req.req_id] = req
        self.waiting.append(req.req_id)

    def finish(self, req_id: int) -> None:
        """Executor signals EOS / client cancel before max_new_tokens."""
        self.requests[req_id].state = RequestState.DONE

    @property
    def active(self) -> List[Request]:
        return [r for r in self.requests.values()
                if r.state in (RequestState.PREFILL, RequestState.DECODE)]

    @property
    def n_active(self) -> int:
        return len(self.active)

    def has_work(self) -> bool:
        return bool(self.waiting) or self.n_active > 0

    def decode_ids(self) -> List[int]:
        return sorted(r.req_id for r in self.requests.values()
                      if r.state == RequestState.DECODE)

    # -- admission ------------------------------------------------------------

    def admit(self, now: float, limit: Optional[int] = None) -> List[int]:
        admitted = []
        while self.waiting and self.n_active < self.n_slots:
            if limit is not None and len(admitted) >= limit:
                break
            rid = self.waiting.popleft()
            r = self.requests[rid]
            r.state = RequestState.PREFILL
            r.admit_time = now
            admitted.append(rid)
        return admitted

    # -- per-iteration hooks ----------------------------------------------------

    def next_plan(self, now: float = 0.0) -> IterationPlan:
        raise NotImplementedError

    def _finish_decode_bookkeeping(self, plan: IterationPlan) -> None:
        """Advance decode counters; retire requests that hit max_new_tokens.
        The first token of a request is produced by its final prefill slice,
        so a request entering DECODE already has n_generated == 1."""
        for rid in plan.decode_ids:
            r = self.requests[rid]
            r.n_generated += 1
            if r.n_generated >= r.max_new_tokens:
                r.state = RequestState.DONE
        for sl in plan.prefill:
            if sl.emits_first_token:
                r = self.requests[sl.req_id]
                r.state = RequestState.DECODE
                r.n_generated = 1
                if r.max_new_tokens <= 1:
                    r.state = RequestState.DONE
        self.iteration += 1


SCHEDULERS: Dict[str, type] = {}


def register(cls):
    SCHEDULERS[cls.name] = cls
    return cls


def make_scheduler(name: str, n_blocks: int, **kw) -> Scheduler:
    if name not in SCHEDULERS:
        raise KeyError(f"unknown scheduler {name!r}; known: {list(SCHEDULERS)}")
    return SCHEDULERS[name](n_blocks, **kw)
