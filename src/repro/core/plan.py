"""Scheduling primitives shared by every scheduler, the engine and the
discrete-event simulator.

The central abstraction generalizing chunked *and* layered prefill is the
2-D **PrefillSlice** — a rectangle (token range × block range) of one
request's prefill work:

  - chunked prefill  : (chunk_i tokens,            ALL blocks)
  - layered prefill  : (ALL tokens,                group_g blocks)
  - hybrid (§4.3)    : (chunk_i tokens,            group_g blocks)
  - continuous (Orca): (ALL tokens,                ALL blocks)

An ``IterationPlan`` is what a scheduler emits per engine iteration: the
decode batch (every request in DECODE state — stall-freeness is precisely
the property that this list is never preempted) plus the prefill slices
co-scheduled with it.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    # victim of a memory-pressure eviction, queued for restore-by-recompute:
    # its pages are freed, its generated tokens are folded into the
    # recompute prompt, and it re-enters PREFILL at the head of the queue
    PREEMPTED = "preempted"
    # victim of a memory-pressure eviction under swap mode: its KV pages
    # moved to the host pool intact; re-admission DMAs them back (gated on
    # free HBM pages AND the per-iteration swap-in bandwidth budget) and the
    # request resumes DECODE directly — no recompute epoch
    SWAPPED = "swapped"
    DONE = "done"


@dataclass
class Request:
    req_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float = 0.0
    # multi-tenant SLO class ("interactive" | "batch" | operator-defined):
    # drives class-aware eviction ordering (batch victims go first) and the
    # optional per-class admission headroom — see core/base.py
    slo_class: str = "interactive"
    # engine-only: actual token ids (None in the simulator)
    prompt_tokens: Optional[object] = None
    state: RequestState = RequestState.WAITING
    # prefill progress. After a preemption, prompt_len is the RECOMPUTE
    # length (original prompt + tokens generated before eviction) and these
    # counters restart from zero for the new prefill epoch.
    tokens_done: int = 0            # prompt tokens fully processed (all blocks)
    blocks_done: int = 0            # blocks processed for the current chunk
    n_generated: int = 0
    n_preemptions: int = 0
    n_folded: int = 0               # generated tokens folded into prompt_len
    orig_prompt_len: Optional[int] = None   # set on first preemption
    # swap-to-host eviction bookkeeping (paired out/in timestamps; a request
    # still swapped out has one more out than in)
    n_swaps: int = 0
    swap_out_times: List[float] = field(default_factory=list)
    swap_in_times: List[float] = field(default_factory=list)
    # speculative decoding bookkeeping (commit_speculation): rounds in which
    # the executor actually proposed draft tokens, totals over proposed /
    # accepted drafts, and the per-round accepted lengths (for p50/p90)
    n_spec_rounds: int = 0
    n_drafted: int = 0
    n_draft_accepted: int = 0
    accepted_lens: List[int] = field(default_factory=list)
    # automatic prefix caching (cumulative over all admissions, including
    # recompute epochs): prompt tokens served from shared KV pages vs
    # prompt tokens this request would have prefilled cold
    cached_prompt_tokens: int = 0
    admitted_prompt_tokens: int = 0
    # metrics (filled by engine/simulator)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    @property
    def remaining_prompt(self) -> int:
        return self.prompt_len - self.tokens_done

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of this request's admitted prompt tokens served from
        the shared prefix cache (0.0 before first admission)."""
        return self.cached_prompt_tokens / max(self.admitted_prompt_tokens, 1)

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def queue_delay(self) -> Optional[float]:
        """Time spent queued before FIRST admission (memory-gated admission
        makes this a first-class serving metric)."""
        if self.admit_time is None:
            return None
        return self.admit_time - self.arrival_time

    def tbts(self) -> List[float]:
        ts = [self.first_token_time] + self.token_times \
            if self.first_token_time is not None else self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    def restore_latencies(self) -> List[float]:
        """Per completed swap cycle: time spent swapped out on host (swap-out
        to swap-in).  An in-flight swap (out without in yet) is excluded."""
        return [b - a for a, b in zip(self.swap_out_times,
                                      self.swap_in_times)]


@dataclass(frozen=True)
class PrefillSlice:
    req_id: int
    token_start: int
    token_end: int
    block_start: int
    block_end: int
    emits_first_token: bool = False   # last slice of the request's prefill

    @property
    def n_tokens(self) -> int:
        return self.token_end - self.token_start

    @property
    def n_blocks(self) -> int:
        return self.block_end - self.block_start


@dataclass
class IterationPlan:
    decode_ids: List[int] = field(default_factory=list)
    prefill: List[PrefillSlice] = field(default_factory=list)
    admitted_ids: List[int] = field(default_factory=list)
    # memory-pressure victims evicted THIS iteration (latest-arrival-first);
    # the executor frees their slot/stash state before running the plan.
    # preempted_ids = fold-to-recompute victims; swapped_out_ids = victims
    # whose KV moved to the host pool intact (SWAPPED state)
    preempted_ids: List[int] = field(default_factory=list)
    swapped_out_ids: List[int] = field(default_factory=list)
    # swapped requests restored THIS iteration (DMA-back); they are already
    # in DECODE state and appear in decode_ids — the executor must copy
    # their host KV back into device cache before the decode step
    swapped_in_ids: List[int] = field(default_factory=list)
    # speculative decoding: req_id -> draft budget k for this iteration.
    # The executor verifies up to k proposed tokens per listed request in
    # one dispatch and MUST call scheduler.commit_speculation for every
    # listed id afterwards (even with 0 proposals) so the speculative page
    # reservation is released.  Requests absent from this dict decode one
    # token exactly as before — an empty dict is the non-speculative plan.
    verify_len: Dict[int, int] = field(default_factory=dict)

    @property
    def empty(self) -> bool:
        return not self.decode_ids and not self.prefill
