"""Scheduling primitives shared by every scheduler, the engine and the
discrete-event simulator.

The central abstraction generalizing chunked *and* layered prefill is the
2-D **PrefillSlice** — a rectangle (token range × block range) of one
request's prefill work:

  - chunked prefill  : (chunk_i tokens,            ALL blocks)
  - layered prefill  : (ALL tokens,                group_g blocks)
  - hybrid (§4.3)    : (chunk_i tokens,            group_g blocks)
  - continuous (Orca): (ALL tokens,                ALL blocks)

An ``IterationPlan`` is what a scheduler emits per engine iteration: the
decode batch (every request in DECODE state — stall-freeness is precisely
the property that this list is never preempted) plus the prefill slices
co-scheduled with it.
"""

from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


class RequestState(enum.Enum):
    WAITING = "waiting"
    PREFILL = "prefill"
    DECODE = "decode"
    # victim of a memory-pressure eviction, queued for restore-by-recompute:
    # its pages are freed, its generated tokens are folded into the
    # recompute prompt, and it re-enters PREFILL at the head of the queue
    PREEMPTED = "preempted"
    # victim of a memory-pressure eviction under swap mode: its KV pages
    # moved to the host pool intact; re-admission DMAs them back (gated on
    # free HBM pages AND the per-iteration swap-in bandwidth budget) and the
    # request resumes DECODE directly — no recompute epoch
    SWAPPED = "swapped"
    DONE = "done"


@dataclass(frozen=True)
class SubmitSpec:
    """THE request-ingestion record.  Every path that creates a serving
    request — HTTP POST /v1/generate, open-loop trace replay, closed-loop
    benchmark drains, the load generator — builds one of these and hands
    it to ``Executor.submit`` / ``Engine.submit_spec``; there is no other
    door.  Frozen so a spec can sit in a cross-thread queue, be retried
    after a 429, or be replayed offline without aliasing surprises.

    ``prompt_tokens`` carries real token ids (required by the engine;
    analytic backends may run from ``prompt_len`` alone).  ``arrival_time``
    is the trace timestamp for replay; None means "stamp me when the
    serving loop first sees me" — the live-traffic case.  ``tenant`` is
    the rate-limiting identity used by the HTTP front-end (per-tenant
    token buckets); it defaults to the SLO class when unset so single-
    tenant setups need no extra field."""
    max_new_tokens: int
    prompt_tokens: Optional[Tuple[int, ...]] = None
    prompt_len: Optional[int] = None
    slo_class: str = "interactive"
    arrival_time: Optional[float] = None
    tenant: Optional[str] = None
    # engine-only extras: encoder frames for enc-dec models (kept opaque
    # here — the engine validates shape), opt-outs for the shared-prefix
    # cache and speculative decoding on a per-request basis
    enc_frames: Optional[object] = None
    prefix_cache: bool = True
    speculative: bool = True
    # per-request completion deadline: the serving runtime sheds the
    # request (freeing ALL its KV) once arrival_time + deadline elapses.
    # Interpreted against the runtime's clock — wall-clock executors read
    # it as milliseconds, the deterministic iteration clock as iterations.
    # None disables shedding for this request.
    deadline_ms: Optional[float] = None

    def __post_init__(self):
        if self.prompt_tokens is None and self.prompt_len is None:
            raise ValueError(
                "SubmitSpec needs prompt_tokens (engine) or prompt_len "
                "(analytic backends)")
        if self.prompt_tokens is not None:
            toks = tuple(int(t) for t in self.prompt_tokens)
            object.__setattr__(self, "prompt_tokens", toks)
            if self.prompt_len is None:
                object.__setattr__(self, "prompt_len", len(toks))
            elif self.prompt_len != len(toks):
                raise ValueError(
                    f"prompt_len {self.prompt_len} != "
                    f"len(prompt_tokens) {len(toks)}")
        if self.prompt_len <= 0:
            raise ValueError(f"prompt_len must be positive, "
                             f"got {self.prompt_len}")
        if self.max_new_tokens <= 0:
            raise ValueError(f"max_new_tokens must be positive, "
                             f"got {self.max_new_tokens}")
        if self.tenant is None:
            object.__setattr__(self, "tenant", self.slo_class)
        if self.deadline_ms is not None and self.deadline_ms <= 0:
            raise ValueError(f"deadline_ms must be positive or None, "
                             f"got {self.deadline_ms}")


@dataclass
class Request:
    req_id: int
    prompt_len: int
    max_new_tokens: int
    arrival_time: float = 0.0
    # multi-tenant SLO class ("interactive" | "batch" | operator-defined):
    # drives class-aware eviction ordering (batch victims go first) and the
    # optional per-class admission headroom — see core/base.py
    slo_class: str = "interactive"
    # engine-only: actual token ids (None in the simulator)
    prompt_tokens: Optional[object] = None
    # rate-limiting identity (SubmitSpec.tenant); per-request opt-outs for
    # the shared-prefix cache (neither match nor publish) and speculative
    # decoding (never drafted for) — SubmitSpec carries both end to end
    tenant: str = "interactive"
    use_prefix_cache: bool = True
    use_speculation: bool = True
    # completion deadline relative to arrival (SubmitSpec.deadline_ms);
    # enforced by the serving runtime's shed scan, None = no deadline
    deadline_ms: Optional[float] = None
    state: RequestState = RequestState.WAITING
    # prefill progress. After a preemption, prompt_len is the RECOMPUTE
    # length (original prompt + tokens generated before eviction) and these
    # counters restart from zero for the new prefill epoch.
    tokens_done: int = 0            # prompt tokens fully processed (all blocks)
    blocks_done: int = 0            # blocks processed for the current chunk
    n_generated: int = 0
    n_preemptions: int = 0
    n_folded: int = 0               # generated tokens folded into prompt_len
    orig_prompt_len: Optional[int] = None   # set on first preemption
    # swap-to-host eviction bookkeeping (paired out/in timestamps; a request
    # still swapped out has one more out than in)
    n_swaps: int = 0
    swap_out_times: List[float] = field(default_factory=list)
    swap_in_times: List[float] = field(default_factory=list)
    # speculative decoding bookkeeping (commit_speculation): rounds in which
    # the executor actually proposed draft tokens, totals over proposed /
    # accepted drafts, and the per-round accepted lengths (for p50/p90)
    n_spec_rounds: int = 0
    n_drafted: int = 0
    n_draft_accepted: int = 0
    accepted_lens: List[int] = field(default_factory=list)
    # automatic prefix caching (cumulative over all admissions, including
    # recompute epochs): prompt tokens served from shared KV pages vs
    # prompt tokens this request would have prefilled cold
    cached_prompt_tokens: int = 0
    admitted_prompt_tokens: int = 0
    # disaggregated prefill→decode handoff bookkeeping (cumulative over
    # migrations — a recompute victim routed back to the prefill pool
    # migrates again): streamed layer-group chunks, tokens whose payload
    # crossed the inter-pool link vs tokens linked to pages already warm
    # on the decode pool, and the migration completion timestamp
    n_handoffs: int = 0
    n_handoff_chunks: int = 0
    handoff_moved_tokens: int = 0
    handoff_linked_tokens: int = 0
    handoff_time: Optional[float] = None
    # fault-tolerance bookkeeping (serving/faults.py): recoveries consumed
    # from the runtime's retry budget, and — for requests removed without
    # completing — why ("deadline" | "retries" | "disconnect" | "degrade");
    # shed_reason None on a DONE request means it finished normally
    n_fault_retries: int = 0
    shed_reason: Optional[str] = None
    # metrics (filled by engine/simulator)
    admit_time: Optional[float] = None
    first_token_time: Optional[float] = None
    finish_time: Optional[float] = None
    token_times: List[float] = field(default_factory=list)

    @property
    def remaining_prompt(self) -> int:
        return self.prompt_len - self.tokens_done

    @property
    def cacheable_prompt(self) -> Optional[object]:
        """Prompt tokens as seen by the shared-prefix machinery: None when
        this request opted out, so every lookup/register site uniformly
        sees a miss without sprinkling flag checks."""
        return self.prompt_tokens if self.use_prefix_cache else None

    @property
    def prefix_hit_rate(self) -> float:
        """Fraction of this request's admitted prompt tokens served from
        the shared prefix cache (0.0 before first admission)."""
        return self.cached_prompt_tokens / max(self.admitted_prompt_tokens, 1)

    @classmethod
    def from_spec(cls, spec: "SubmitSpec", req_id: int, *,
                  arrival_time: float,
                  prompt_tokens: Optional[object] = None) -> "Request":
        """Build the mutable serving Request from an ingestion spec — the
        one place spec fields map onto request fields, shared by the
        engine and the analytic backends.  ``prompt_tokens`` lets the
        caller pass its backend-native array form (the engine's int32
        ndarray); defaults to the spec's tuple."""
        return cls(req_id=req_id, prompt_len=spec.prompt_len,
                   max_new_tokens=spec.max_new_tokens,
                   arrival_time=arrival_time,
                   slo_class=spec.slo_class,
                   prompt_tokens=spec.prompt_tokens
                   if prompt_tokens is None else prompt_tokens,
                   tenant=spec.tenant,
                   use_prefix_cache=spec.prefix_cache,
                   use_speculation=spec.speculative,
                   deadline_ms=spec.deadline_ms)

    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival_time

    def queue_delay(self) -> Optional[float]:
        """Time spent queued before FIRST admission (memory-gated admission
        makes this a first-class serving metric)."""
        if self.admit_time is None:
            return None
        return self.admit_time - self.arrival_time

    def tbts(self) -> List[float]:
        ts = [self.first_token_time] + self.token_times \
            if self.first_token_time is not None else self.token_times
        return [b - a for a, b in zip(ts, ts[1:])]

    def restore_latencies(self) -> List[float]:
        """Per completed swap cycle: time spent swapped out on host (swap-out
        to swap-in).  An in-flight swap (out without in yet) is excluded."""
        return [b - a for a, b in zip(self.swap_out_times,
                                      self.swap_in_times)]


@dataclass(frozen=True)
class PrefillSlice:
    req_id: int
    token_start: int
    token_end: int
    block_start: int
    block_end: int
    emits_first_token: bool = False   # last slice of the request's prefill

    @property
    def n_tokens(self) -> int:
        return self.token_end - self.token_start

    @property
    def n_blocks(self) -> int:
        return self.block_end - self.block_start


@dataclass
class IterationPlan:
    decode_ids: List[int] = field(default_factory=list)
    prefill: List[PrefillSlice] = field(default_factory=list)
    admitted_ids: List[int] = field(default_factory=list)
    # memory-pressure victims evicted THIS iteration (latest-arrival-first);
    # the executor frees their slot/stash state before running the plan.
    # preempted_ids = fold-to-recompute victims; swapped_out_ids = victims
    # whose KV moved to the host pool intact (SWAPPED state)
    preempted_ids: List[int] = field(default_factory=list)
    swapped_out_ids: List[int] = field(default_factory=list)
    # swapped requests restored THIS iteration (DMA-back); they are already
    # in DECODE state and appear in decode_ids — the executor must copy
    # their host KV back into device cache before the decode step
    swapped_in_ids: List[int] = field(default_factory=list)
    # speculative decoding: req_id -> draft budget k for this iteration.
    # The executor verifies up to k proposed tokens per listed request in
    # one dispatch and MUST call scheduler.commit_speculation for every
    # listed id afterwards (even with 0 proposals) so the speculative page
    # reservation is released.  Requests absent from this dict decode one
    # token exactly as before — an empty dict is the non-speculative plan.
    verify_len: Dict[int, int] = field(default_factory=dict)

    @property
    def empty(self) -> bool:
        return not self.decode_ids and not self.prefill
