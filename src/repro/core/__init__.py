# The paper's primary contribution: prefill scheduling with the layer axis
# as a first-class scheduling unit (plus the token-axis baselines it is
# evaluated against, and the §4.3 hybrid generalization).
from repro.core.base import SCHEDULERS, Scheduler, make_scheduler
from repro.core.chunked import ChunkedPrefillScheduler
from repro.core.continuous import ContinuousBatchingScheduler
from repro.core.hybrid import HybridPrefillScheduler
from repro.core.layered import LayeredPrefillScheduler
from repro.core.plan import (IterationPlan, PrefillSlice, Request,
                             RequestState)
from repro.core.static_batch import StaticBatchScheduler

__all__ = [
    "Scheduler", "SCHEDULERS", "make_scheduler",
    "IterationPlan", "PrefillSlice", "Request", "RequestState",
    "ChunkedPrefillScheduler", "LayeredPrefillScheduler",
    "ContinuousBatchingScheduler", "StaticBatchScheduler",
    "HybridPrefillScheduler",
]
