"""Layered prefill — THE PAPER'S CONTRIBUTION (§4).

Layer-axis scheduling: the decoder stack is partitioned into G contiguous
layer groups (G = max(1, ceil(L/512)), capped at n_blocks — §4.4). Each
iteration, exactly ONE designated group runs prefill (co-scheduled with the
always-running decode batch); the other groups run decode only. A request's
prefill therefore finishes in exactly G iterations, each layer sees the
prompt exactly once, and no chunk-induced expert reloads occur.

Concurrent small arrivals admitted in the same iteration are merged into a
*cohort* that advances through the groups together (§4.4 "when multiple
small inputs arrive concurrently, we merge them into a single batch").
Cohorts are strictly serial — one-group-per-iteration is a global rule, so
a new cohort starts only after the previous finished its last group.
"""

from __future__ import annotations

from typing import List, Optional, Tuple

from repro.core import layer_groups
from repro.core.base import Scheduler, register
from repro.core.plan import IterationPlan, PrefillSlice


@register
class LayeredPrefillScheduler(Scheduler):
    name = "layered"

    def __init__(self, n_blocks: int, *, merge_cohort: bool = True,
                 block_costs=None, **kw):
        super().__init__(n_blocks, **kw)
        self.merge_cohort = merge_cohort
        # adaptive grouping (paper §7 future work): per-block cost weights
        # (e.g. prefill weight-bytes from the cost model) balance per-group
        # WORK instead of block count on heterogeneous stacks
        self.block_costs = list(block_costs) if block_costs is not None \
            else None
        # active cohort: (request ids, group boundaries, next group index)
        self._cohort: Optional[Tuple[List[int], List[Tuple[int, int]], int]] = None

    def max_stash_tokens(self, req, prompt_len=None) -> int:
        # layered prefill stashes the FULL prompt's boundary activations
        # between layer groups
        return req.prompt_len if prompt_len is None else prompt_len

    def _on_preempt(self, req_id: int) -> None:
        """Drop an evicted request from the in-flight cohort; the survivors
        keep advancing through the remaining groups."""
        if self._cohort is None:
            return
        rids, groups, gi = self._cohort
        if req_id not in rids:
            return
        rids = [r for r in rids if r != req_id]
        self._cohort = (rids, groups, gi) if rids else None

    def _start_cohort(self, now: float) -> None:
        limit = None if self.merge_cohort else 1
        admitted = self.admit(now, limit=limit)
        if not admitted:
            return
        # prefix-cached tokens (tokens_done > 0 straight out of admit) are
        # never prefilled, so they don't count toward the group-count budget
        total_tokens = sum(self.requests[rid].remaining_prompt
                           for rid in admitted)
        g = layer_groups.num_groups(total_tokens, self.n_blocks, self.quantum)
        if self.block_costs is not None:
            groups = layer_groups.partition_weighted(self.block_costs, g)
        else:
            groups = layer_groups.partition(self.n_blocks, g)
        self._cohort = (admitted, groups, 0)

    def _plan(self, now: float = 0.0) -> IterationPlan:
        plan = IterationPlan()
        plan.decode_ids = self.decode_ids()

        if self._cohort is None:
            self._start_cohort(now)
            if self._cohort is not None:
                plan.admitted_ids = list(self._cohort[0])

        if self._cohort is not None:
            rids, groups, gi = self._cohort
            b0, b1 = groups[gi]
            last = gi == len(groups) - 1
            for rid in rids:
                r = self.requests[rid]
                # start past the cached block boundary: per-layer-group KV is
                # complete for prefix-cache-hit blocks, so every group skips
                # the same leading token range uniformly
                plan.prefill.append(PrefillSlice(
                    req_id=rid, token_start=r.tokens_done,
                    token_end=r.prompt_len,
                    block_start=b0, block_end=b1, emits_first_token=last))
                if last:
                    r.tokens_done = r.prompt_len
                r.blocks_done = b1
            self._cohort = None if last else (rids, groups, gi + 1)

        self._finish_decode_bookkeeping(plan)
        return plan
