"""Hybrid layered × chunked prefill — the paper's §4.3 generalization.

The two axes are orthogonal: split the prompt into large chunks (large
enough that per-expert batch clears the accelerator's ridge point, making
MoE compute-bound) AND spread each chunk across layer groups to stay within
the per-iteration stall-free budget. Work per iteration is one
(chunk × group) rectangle.

With chunk_size >= prompt length this degenerates to pure layered prefill;
with group count 1 it degenerates to chunked prefill — both covered by the
property tests. The default chunk_size = quantum * n_blocks is the largest
chunk whose per-group work still matches a 512-token chunked iteration.
"""

from __future__ import annotations

import math
from typing import List, Optional, Tuple

from repro.core import layer_groups
from repro.core.base import Scheduler, register
from repro.core.plan import IterationPlan, PrefillSlice


@register
class HybridPrefillScheduler(Scheduler):
    name = "hybrid"

    def __init__(self, n_blocks: int, *, chunk_size: Optional[int] = None,
                 **kw):
        super().__init__(n_blocks, **kw)
        self.chunk_size = chunk_size or self.quantum * n_blocks
        # (req id, chunk boundaries, chunk idx, group boundaries, group idx)
        self._run: Optional[Tuple[int, List[Tuple[int, int]], int,
                                  List[Tuple[int, int]], int]] = None

    def max_stash_tokens(self, req, prompt_len=None) -> int:
        # hybrid stashes one chunk's boundary activations at a time
        return min(self.chunk_size,
                   req.prompt_len if prompt_len is None else prompt_len)

    def _on_preempt(self, req_id: int) -> None:
        if self._run is not None and self._run[0] == req_id:
            self._run = None

    def _chunks(self, prompt_len: int, start: int = 0) -> List[Tuple[int, int]]:
        n = max(1, math.ceil((prompt_len - start) / self.chunk_size))
        out = []
        for i in range(n):
            end = min(start + self.chunk_size, prompt_len)
            out.append((start, end))
            start = end
        return out

    def _start_run(self, now: float) -> None:
        admitted = self.admit(now, limit=1)
        if not admitted:
            return
        rid = admitted[0]
        r = self.requests[rid]
        # chunking starts past the prefix-cached boundary (tokens_done set by
        # admit on a cache hit) — cached tokens are never prefilled
        chunks = self._chunks(r.prompt_len, start=r.tokens_done)
        g = layer_groups.num_groups(chunks[0][1] - chunks[0][0],
                                    self.n_blocks, self.quantum)
        groups = layer_groups.partition(self.n_blocks, g)
        self._run = (rid, chunks, 0, groups, 0)

    def _plan(self, now: float = 0.0) -> IterationPlan:
        plan = IterationPlan()
        plan.decode_ids = self.decode_ids()

        if self._run is None:
            self._start_run(now)
            if self._run is not None:
                plan.admitted_ids = [self._run[0]]

        if self._run is not None:
            rid, chunks, ci, groups, gi = self._run
            r = self.requests[rid]
            t0, t1 = chunks[ci]
            b0, b1 = groups[gi]
            last_group = gi == len(groups) - 1
            last_chunk = ci == len(chunks) - 1
            plan.prefill.append(PrefillSlice(
                req_id=rid, token_start=t0, token_end=t1,
                block_start=b0, block_end=b1,
                emits_first_token=last_group and last_chunk))
            if last_group:
                r.tokens_done = t1
                if last_chunk:
                    self._run = None
                else:
                    nxt = chunks[ci + 1]
                    g = layer_groups.num_groups(nxt[1] - nxt[0],
                                                self.n_blocks, self.quantum)
                    self._run = (rid, chunks, ci + 1,
                                 layer_groups.partition(self.n_blocks, g), 0)
            else:
                self._run = (rid, chunks, ci, groups, gi + 1)

        self._finish_decode_bookkeeping(plan)
        return plan


# ensure registry side-effects when importing repro.core
from repro.core import chunked as _chunked          # noqa: E402,F401
from repro.core import continuous as _continuous    # noqa: E402,F401
from repro.core import layered as _layered          # noqa: E402,F401
from repro.core import static_batch as _static      # noqa: E402,F401
