"""Sharding rules: logical-role activation hints + per-parameter specs.

Two pieces:

1. ``shard_hint(x, *roles)`` — inside model code we annotate activations
   with *logical roles* ("batch", "expert", "tp", ...). When a
   ``sharding_context`` is active (the launcher/dry-run installs one around
   tracing), roles resolve to mesh axes and become
   ``with_sharding_constraint``s; outside any context they are no-ops, so
   unit tests and the CPU engine never touch device state.

2. ``param_specs(cfg, params)`` — map a parameter pytree to PartitionSpecs
   by leaf path: Megatron-style tensor parallelism for dense blocks
   (column-split w_q/w_k/w_v/w_up/w_gate, row-split w_o/w_down), expert
   parallelism for MoE stacks (experts split over the ``model`` axis),
   vocab-parallel embedding/unembedding. Scan-stacked leading axes (layer
   repeats) are automatically skipped.
"""

from __future__ import annotations

import re
import threading
from contextlib import contextmanager
from typing import Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_TLS = threading.local()


def default_rules(mesh: Mesh) -> dict:
    axes = mesh.axis_names
    batch = tuple(a for a in ("pod", "data") if a in axes)
    tp = ("model",) if "model" in axes else ()
    return {
        "batch": batch or None,
        "data": ("data",) if "data" in axes else None,
        "expert": tp or None,
        "expert_inner": ("data",) if "data" in axes else None,
        # capacity dim of the (E, C, d) dispatch buffer: co-shard over the
        # batch axes so the buffer is never materialized unsharded (the
        # all-gather that otherwise dominates MoE prefill/train collectives)
        "expert_cap": batch or None,
        "tp": tp or None,
        "vocab": tp or None,
        "seq": tp or None,      # sequence-sharded KV cache / seq parallelism
        None: None,
    }


@contextmanager
def sharding_context(mesh: Mesh, rules: Optional[dict] = None):
    prev = getattr(_TLS, "ctx", None)
    _TLS.ctx = (mesh, rules or default_rules(mesh))
    try:
        yield
    finally:
        _TLS.ctx = prev


def active_context():
    return getattr(_TLS, "ctx", None)


def shard_hint(x, *roles):
    ctx = active_context()
    if ctx is None:
        return x
    mesh, rules = ctx
    spec = P(*[rules.get(r) for r in roles])
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def shard_seq_hint(x):
    """Residual-stream constraint between blocks: Megatron-style sequence
    parallelism — the (B, S, D) activation is sharded over batch AND, when S
    divides the model axis, over sequence, so remat-saved residuals fit HBM
    at 4k-seq training shapes. No-op outside a sharding context."""
    ctx = active_context()
    if ctx is None or x.ndim != 3:
        return x
    mesh, rules = ctx
    tp = rules.get("tp")
    tp_n = _axes_size(mesh, tp)
    bspec = rules.get("batch")
    if x.shape[0] % max(_axes_size(mesh, bspec), 1) != 0:
        bspec = None
    if tp_n > 1 and x.shape[1] % tp_n == 0:
        spec = P(bspec, tp, None)
    else:
        spec = P(bspec, None, None)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


# ---------------------------------------------------------------------------
# Parameter partition specs
# ---------------------------------------------------------------------------

# (path-regex, spec-builder(ndim -> trailing dims spec)) — matched against the
# '/'-joined leaf path; first match wins. Trailing-dim specs are right-aligned
# so scan-stacked leading axes stay unsharded.
_PARAM_RULES = [
    # MoE expert stacks: (E, d, f) / (E, f, d) — expert parallel over the
    # model axis PLUS tensor-parallel d_ff over the data axis ("expert-TP"):
    # a 235B-class MoE does not fit 16-way sharding on 16 GB chips, so the
    # expert FFN dimension is co-sharded and all-gathered/reduced per use.
    (r"moe/w_(gate|up)$", ("expert", None, "expert_inner")),
    (r"moe/w_down$", ("expert", "expert_inner", None)),
    (r"moe/router$", (None, None)),
    (r"moe/shared/w_(gate|up)$", (None, "tp")),
    (r"moe/shared/w_down$", ("tp", None)),
    # Dense MLP: column/row parallel.
    (r"mlp/w_(gate|up)$", (None, "tp")),
    (r"mlp/w_down$", ("tp", None)),
    # Attention projections.
    (r"attn/w_(q|k|v)$", (None, "tp")),
    (r"attn/w_o$", ("tp", None)),
    (r"attn/x_(q|k|v)$", (None, "tp")),
    (r"attn/x_o$", ("tp", None)),
    # MLA: keep compressions replicated, decompressions TP.
    (r"attn/w_(dq|dkv|kr)$", (None, None)),
    (r"attn/w_(uq|uk|uv)$", (None, "tp")),
    # RG-LRU / xLSTM inner projections.
    (r"(rglru|lstm)/w_(in|gate|x|qkv|up)\w*$", (None, "tp")),
    (r"(rglru|lstm)/w_(out|down|o)\w*$", ("tp", None)),
    (r"(rglru|lstm)/(a_param|conv_w|conv_b|gates\w*)$", None),
    # Embedding / unembedding: vocab parallel.
    (r"embed/tok$", ("vocab", None)),
    (r"embed/lm_head$", (None, "vocab")),
]


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


def spec_for_path(path_str: str, ndim: int, rules: dict) -> P:
    for pat, trailing in _PARAM_RULES:
        if re.search(pat, path_str):
            if trailing is None:
                return P()
            tdims = [rules.get(r) for r in trailing]
            n_trail = len(tdims)
            if ndim < n_trail:
                tdims = tdims[-ndim:]
                n_trail = ndim
            return P(*([None] * (ndim - n_trail) + tdims))
    return P()  # replicated by default (norms, biases, scalars)


def param_specs(params, mesh: Mesh, rules: Optional[dict] = None):
    rules = rules or default_rules(mesh)

    def leaf(path, x):
        spec = spec_for_path(_path_str(path), getattr(x, "ndim", 0), rules)
        shape = getattr(x, "shape", ())
        # Divisibility guard: a dim whose global size does not divide its
        # assigned axes replicates instead (e.g. whisper vocab 51865 or
        # minicpm 122753 on a 16-way vocab-parallel axis).
        fixed = [
            s if (s is None or i >= len(shape)
                  or shape[i] % _axes_size(mesh, s) == 0) else None
            for i, s in enumerate(spec)
        ]
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(leaf, params)


def param_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    specs = param_specs(params, mesh, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Cache partition specs (serving dry-run)
# ---------------------------------------------------------------------------


def _axes_size(mesh: Mesh, roles) -> int:
    if roles is None:
        return 1
    if isinstance(roles, str):
        roles = (roles,)
    n = 1
    for r in roles:
        if r in mesh.shape:
            n *= mesh.shape[r]
    return n


def cache_specs(cache, mesh: Mesh, batch: int,
                rules: Optional[dict] = None):
    """PartitionSpecs for a segment-stacked cache pytree.

    Leaf layouts (axis 0 is the segment-repeat stack, axis 1 the slot/batch):
      k/v/xk/xv  (R, B, S, kvH, hd) — shard batch; shard kv heads over TP
                 only when divisible (GQA with few kv heads replicates K/V,
                 Megatron-style).
      ckv/kr     (R, B, S, r)       — MLA compressed cache: batch only.
      conv       (R, B, cw-1, W)    — recurrent conv tail: W over TP if divisible.
      h          (R, B, W)          — LRU state: W over TP if divisible.
      C/n/m/c    (R, B, ...)        — xLSTM states: batch only.
    """
    rules = rules or default_rules(mesh)
    bspec = rules.get("batch")
    if batch % max(_axes_size(mesh, bspec), 1) != 0:
        bspec = None           # e.g. long_500k batch=1: replicate
    tp = rules.get("tp")
    tp_n = _axes_size(mesh, tp)

    def leaf(path, x):
        name = _path_str(path).rsplit("/", 1)[-1]
        nd = x.ndim
        spec = [None] * nd
        if nd >= 2:
            spec[1] = bspec
        if name in ("k", "v", "xk", "xv") and nd == 5:
            if tp_n > 1 and x.shape[3] % tp_n == 0:
                spec[3] = tp            # shard kv heads (MHA-ish archs)
            elif tp_n > 1 and x.shape[2] % tp_n == 0:
                spec[2] = tp            # GQA few-kv-heads: shard sequence
        elif name in ("ckv", "kr") and nd == 4:
            if tp_n > 1 and x.shape[2] % tp_n == 0:
                spec[2] = tp            # MLA compressed cache: shard sequence
        elif name == "conv" and nd == 4:
            if tp_n > 1 and x.shape[3] % tp_n == 0:
                spec[3] = tp
        elif name == "h" and nd == 3:
            if tp_n > 1 and x.shape[2] % tp_n == 0:
                spec[2] = tp
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, cache)


def cache_shardings(cache, mesh: Mesh, batch: int,
                    rules: Optional[dict] = None):
    specs = cache_specs(cache, mesh, batch, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda s: isinstance(s, P))


# ---------------------------------------------------------------------------
# Optimizer-state partition specs (ZeRO-1)
# ---------------------------------------------------------------------------


def moment_specs(params, mesh: Mesh, rules: Optional[dict] = None):
    """Adam moments: start from the parameter spec, then shard the largest
    still-replicated dim over the data axis when divisible (ZeRO-1 — the
    f32 moments of a 34B+ model do not fit replicated on 16 GB chips)."""
    rules = rules or default_rules(mesh)
    data_axes = rules.get("data")
    data_n = _axes_size(mesh, data_axes)

    def leaf(path, x):
        base = spec_for_path(_path_str(path), getattr(x, "ndim", 0), rules)
        shape = getattr(x, "shape", ())
        base = P(*[s if (s is None or i >= len(shape)
                         or shape[i] % _axes_size(mesh, s) == 0) else None
                   for i, s in enumerate(base)])
        if data_n <= 1 or getattr(x, "ndim", 0) == 0:
            return base
        spec = list(base) + [None] * (x.ndim - len(base))
        used = set()
        for s_ in spec:
            for a in ((s_,) if isinstance(s_, str) else (s_ or ())):
                used.add(a)
        if any(a in used for a in (data_axes or ())):
            return P(*spec)
        # largest replicated dim divisible by the data axis
        cand = [i for i in range(x.ndim)
                if spec[i] is None and x.shape[i] % data_n == 0]
        if cand:
            i = max(cand, key=lambda j: x.shape[j])
            spec[i] = data_axes
        return P(*spec)

    return jax.tree_util.tree_map_with_path(leaf, params)


def moment_shardings(params, mesh: Mesh, rules: Optional[dict] = None):
    specs = moment_specs(params, mesh, rules)
    return jax.tree_util.tree_map(lambda s: NamedSharding(mesh, s), specs,
                                  is_leaf=lambda s: isinstance(s, P))
