"""Production mesh construction.

Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod:  (2, 16, 16) = 512 chips, axes (pod, data, model) — the ``pod``
axis is pure data parallelism (per-step gradient / metric reductions are
the only cross-pod collectives; DCN-friendly).

A FUNCTION, not a module constant: importing this module must never touch
jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the same axis names (tests / CPU smoke)."""
    return jax.make_mesh((1, 1), ("data", "model"))
