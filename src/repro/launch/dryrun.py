import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
against the production mesh — 16×16 single-pod and 2×16×16 multi-pod — and
extract memory/cost/collective analyses for the roofline report.

THE FIRST TWO LINES of this file set XLA_FLAGS before any other import
(jax locks the device count at first init). Do not reorder.

Cost-extraction strategy (single CPU core, exact numbers):
  1. the FULL model is lowered+compiled with segment scans (compact HLO) —
     this is the feasibility proof and the source of memory_analysis();
  2. XLA's cost_analysis counts while-loop bodies ONCE, so flops/bytes/
     collective-bytes come from two small UNROLLED variants with
     L1 = remainder + period and L2 = remainder + 2·period layers: per-layer
     cost is affine in the repeat count, so
        F(L) = F(L1) + (k-1) · (F(L2) - F(L1)),  k = (L - r) / p
     is exact for the homogeneous segment structure of every config.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch yi-34b --shape decode_32k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--out DIR]
"""

import argparse      # noqa: E402
import dataclasses   # noqa: E402
import json          # noqa: E402
import time          # noqa: E402
import traceback     # noqa: E402

import jax           # noqa: E402

from repro.configs import ASSIGNED, get_config          # noqa: E402
from repro.configs.shapes import (SHAPES, applicable, cache_len_for,  # noqa: E402
                                  input_specs)
from repro.launch import analysis            # noqa: E402
from repro.launch.mesh import make_production_mesh      # noqa: E402
from repro.launch.steps import make_step_fn  # noqa: E402
from repro.models.config import FFN_MOE, dtype_bytes    # noqa: E402
from repro.models.model import DecoderModel  # noqa: E402
from repro.serving.cost_model import TPU_V5E, CostModel  # noqa: E402
from repro.sharding.partition import (cache_shardings, default_rules,  # noqa: E402
                                      moment_shardings, param_shardings,
                                      sharding_context)
from repro.training.optimizer import adamw   # noqa: E402


def _compile_step(cfg, shape, mesh, rules, *, unroll: bool):
    """Lower + compile one step function for (cfg, shape) on mesh."""
    remat = shape.kind == "train"
    model = DecoderModel(cfg, unroll=unroll, remat=remat)
    params_struct = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_shard = param_shardings(params_struct, mesh, rules)
    in_specs = input_specs(cfg, shape, model)

    def batch_shardings():
        bspec = rules.get("batch")
        n = 1
        for a in (bspec or ()):
            n *= mesh.shape[a]
        if shape.global_batch % max(n, 1) != 0:
            bspec = None
        return {
            k: jax.sharding.NamedSharding(
                mesh, jax.sharding.PartitionSpec(
                    bspec, *([None] * (len(v.shape) - 1))))
            for k, v in in_specs.items()}

    if shape.kind == "train":
        opt = adamw()
        step = make_step_fn(model, shape, opt)
        opt_struct = jax.eval_shape(opt.init, params_struct)
        m_shard = moment_shardings(params_struct, mesh, rules)
        o_shard = type(opt_struct)(
            step=jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec()),
            mu=m_shard, nu=m_shard)
        args = [params_struct, opt_struct, in_specs]
        shardings = [p_shard, o_shard, batch_shardings()]
        donate = (0, 1)
    else:
        step = make_step_fn(model, shape)
        cache_struct = jax.eval_shape(
            lambda: model.init_cache(shape.global_batch,
                                     cache_len_for(cfg, shape)))
        c_shard = cache_shardings(cache_struct, mesh, shape.global_batch,
                                  rules)
        args = [params_struct, cache_struct, in_specs]
        shardings = [p_shard, c_shard, batch_shardings()]
        donate = (1,)

    with mesh, sharding_context(mesh, rules):
        lowered = jax.jit(step, in_shardings=shardings,
                          donate_argnums=donate).lower(*args)
        compiled = lowered.compile()
    return lowered, compiled


def _layer_split(cfg):
    """(period, repeats, remainder) of the dominant segment structure."""
    segs = cfg.scan_segments()
    main = max(segs, key=lambda s: len(s[0]) * s[1])
    p = len(main[0])
    k = main[1]
    r = cfg.n_layers - p * k
    return p, k, r


def _moe_dispatch_analysis(cfg, shape):
    """Analytic ragged-vs-dense expert-GMM cost for this (arch, shape) —
    the roofline-report twin of the engine's ragged dropless pipeline
    (models/moe.py). Per MoE block at the shape's token count."""
    if not cfg.moe.enabled:
        return None
    n_tok = (shape.global_batch if shape.kind == "decode"
             else shape.global_batch * shape.seq_len)
    cm = CostModel(cfg, TPU_V5E,
                   bytes_per_param=dtype_bytes(cfg.param_dtype))
    ragged = cm.moe_gmm_cost(n_tok, "ragged")
    dense = cm.moe_gmm_cost(n_tok, "dense")
    return {
        "n_tokens": n_tok,
        "n_moe_blocks": sum(1 for s in cfg.block_specs()
                            if s.ffn == FFN_MOE),
        "ragged": ragged, "dense": dense,
        "flops_ratio": ragged["flops"] / max(dense["flops"], 1.0),
        "weight_bytes_ratio": (ragged["weight_bytes"]
                               / max(dense["weight_bytes"], 1.0)),
    }


def _measure(compiled) -> dict:
    cost = analysis.extract_cost(compiled)
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    coll = analysis.collective_bytes(hlo)
    return {"flops": cost["flops"], "bytes": cost["bytes"],
            "coll": dict(coll)}


def dryrun_one(arch: str, shape_name: str, *, multi_pod: bool = False,
               analyze: bool = True, verbose: bool = True) -> dict:
    shape = SHAPES[shape_name]
    cfg = get_config(arch)
    ok, reason = applicable(cfg, shape)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": reason}

    t0 = time.time()
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = default_rules(mesh)

    # 1. feasibility proof + memory: full model, scanned segments
    _, compiled_full = _compile_step(cfg, shape, mesh, rules, unroll=False)
    mem = analysis.extract_memory(compiled_full)
    compile_s = time.time() - t0

    out = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "compile_s": compile_s,
           "peak_memory_per_device": mem}
    moe_rep = _moe_dispatch_analysis(cfg, shape)
    if moe_rep is not None:
        out["moe_dispatch"] = moe_rep

    if analyze:
        p, k, r = _layer_split(cfg)
        if k >= 3:
            l1, l2 = r + p, r + 2 * p
            cfg1 = dataclasses.replace(cfg, n_layers=l1)
            cfg2 = dataclasses.replace(cfg, n_layers=l2)
            _, c1 = _compile_step(cfg1, shape, mesh, rules, unroll=True)
            _, c2 = _compile_step(cfg2, shape, mesh, rules, unroll=True)
            m1, m2 = _measure(c1), _measure(c2)
            scale = k - 1
            flops = m1["flops"] + scale * (m2["flops"] - m1["flops"])
            bytes_ = m1["bytes"] + scale * (m2["bytes"] - m1["bytes"])
            coll = {kk: m1["coll"].get(kk, 0)
                    + scale * (m2["coll"].get(kk, 0) - m1["coll"].get(kk, 0))
                    for kk in m2["coll"]}
            out["extrapolation"] = {"L1": l1, "L2": l2, "period": p,
                                    "repeats": k, "remainder": r,
                                    "m1": m1, "m2": m2}
        else:
            _, c_direct = _compile_step(cfg, shape, mesh, rules, unroll=True)
            m = _measure(c_direct)
            flops, bytes_, coll = m["flops"], m["bytes"], m["coll"]

        rep = analysis.RooflineReport(
            arch=arch, shape=shape_name, mesh=mesh_name,
            n_chips=512 if multi_pod else 256,
            hlo_flops=flops, hlo_bytes=bytes_,
            coll_bytes=coll.get("total", 0), coll_by_kind=coll,
            model_flops=analysis.model_flops_estimate(cfg, shape),
            peak_memory_per_device=mem)
        out.update(rep.to_dict())
        out["status"] = "ok"

    out["total_s"] = time.time() - t0
    if verbose:
        msg = (f"[dryrun] {arch} × {shape_name} × {mesh_name}: ok "
               f"compile={compile_s:.0f}s total={out['total_s']:.0f}s")
        if analyze:
            msg += (f" flops/dev={out['hlo_flops']:.3e}"
                    f" bytes/dev={out['hlo_bytes']:.3e}"
                    f" coll/dev={out['collective_bytes']:.3e}"
                    f" bottleneck={out['bottleneck']}")
        if mem is not None:
            msg += f" mem/dev={mem/1e9:.2f}GB"
        print(msg, flush=True)
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    archs = ASSIGNED if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = [False, True] if args.all else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
                path = os.path.join(args.out, tag + ".json")
                if os.path.exists(path):
                    print(f"[dryrun] {tag}: cached", flush=True)
                    continue
                try:
                    # roofline numbers only needed on the single-pod mesh
                    rec = dryrun_one(arch, shape, multi_pod=mp,
                                     analyze=not mp)
                except Exception as e:
                    traceback.print_exc()
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": str(e)}
                    failures.append(tag)
                with open(path, "w") as f:
                    json.dump(rec, f, indent=2)
    if failures:
        raise SystemExit(f"dry-run failures: {failures}")
    print("[dryrun] all requested combinations compiled", flush=True)


if __name__ == "__main__":
    main()
