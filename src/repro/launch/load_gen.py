"""Closed-loop load generator for the HTTP/SSE front-end
(serving/server.py) — and the acceptance harness proving the live server
returns EXACTLY the tokens an offline trace replay would.

``--clients N`` concurrent clients pull requests off one shared trace
(built by the SAME ``ServeConfig.engine_trace`` generator serve.py
replays), POST them to ``/v1/generate``, parse the SSE stream, honor 429
``Retry-After`` backoff, and record client-side latency.  With no
``--url`` the generator boots an IN-PROCESS server on an OS-assigned port
from the same ServeConfig — the mode the CI smoke lane and the tests run.

Verification (``--verify``, default in in-process mode): after the load
run, a FRESH engine with identical params (``jax.random.PRNGKey(0)`` —
engine init is deterministic) replays the same trace through the offline
``ServingRuntime`` under the iteration clock, and every request's live
token stream must be bit-identical to its offline twin.  Greedy token
identity is scheduling-invariant (the PR-2/PR-6 invariant), so this holds
even though the live server admits requests in wall-clock arrival order
under whatever interleaving the OS produced — any mismatch means the
serving path corrupted state, and the generator exits nonzero.

Usage:
  # in-process smoke: 8 clients, 64 requests, verify token identity
  PYTHONPATH=src python -m repro.launch.load_gen --smoke \
      --clients 8 --requests 64

  # against a running server (launched with serve.py --http :8000)
  PYTHONPATH=src python -m repro.launch.load_gen --smoke \
      --url http://127.0.0.1:8000 --clients 16 --requests 200 --no-verify
"""

from __future__ import annotations

import argparse
import asyncio
import json
import sys
import time
from dataclasses import dataclass, field
from typing import Dict, List, Tuple

from repro.launch.config import ServeConfig
from repro.serving.metrics import percentile


@dataclass
class ClientResult:
    index: int                      # position in the trace
    tokens: List[int] = field(default_factory=list)
    latency: float = 0.0            # POST to done event, client wall clock
    ttfb: float = 0.0               # POST to first token event
    n_retries: int = 0
    status: int = 0


@dataclass
class LoadReport:
    results: List[ClientResult]
    elapsed: float
    n_429: int
    n_errors: int

    def summary(self) -> Dict[str, float]:
        ok = [r for r in self.results if r.status == 200]
        lat = [r.latency for r in ok]
        ttfb = [r.ttfb for r in ok]
        return {
            "n_requests": float(len(self.results)),
            "n_ok": float(len(ok)),
            "n_429_retries": float(self.n_429),
            "n_errors": float(self.n_errors),
            "elapsed_s": self.elapsed,
            "throughput_rps": len(ok) / self.elapsed if self.elapsed
            else 0.0,
            "latency_p50": percentile(lat, 50),
            "latency_p99": percentile(lat, 99),
            "ttfb_p50": percentile(ttfb, 50),
            "ttfb_p99": percentile(ttfb, 99),
        }


async def _post_generate(host: str, port: int, payload: dict,
                         timeout: float = 300.0,
                         on_first_byte=None) -> Tuple[int, dict, list]:
    """One POST /v1/generate over a fresh connection (the server always
    answers Connection: close).  Returns (status, headers, sse_events);
    non-SSE bodies come back as one synthetic ("json", payload) event.
    ``on_first_byte`` fires when the first body chunk past the headers
    lands — the client-side time-to-first-byte mark."""
    reader, writer = await asyncio.open_connection(host, port)
    try:
        body = json.dumps(payload).encode()
        writer.write((f"POST /v1/generate HTTP/1.1\r\nHost: {host}\r\n"
                      f"Content-Type: application/json\r\n"
                      f"Content-Length: {len(body)}\r\n\r\n").encode()
                     + body)
        await writer.drain()
        raw = await asyncio.wait_for(
            reader.readuntil(b"\r\n\r\n"), timeout)
        first = await asyncio.wait_for(reader.read(4096), timeout)
        if first and on_first_byte is not None:
            on_first_byte()
        raw += first
        while first:
            first = await asyncio.wait_for(reader.read(1 << 16), timeout)
            raw += first
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass
    head, _, rest = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split(" ", 2)[1])
    headers = {}
    for ln in lines[1:]:
        k, _, v = ln.partition(":")
        headers[k.strip().lower()] = v.strip()
    if "text/event-stream" in headers.get("content-type", ""):
        events = []
        for block in rest.decode().strip().split("\n\n"):
            ev: Dict[str, str] = {}
            for ln in block.split("\n"):
                k, _, v = ln.partition(": ")
                ev[k] = v
            if "event" in ev:
                events.append((ev["event"], json.loads(ev["data"])))
        return status, headers, events
    payload = json.loads(rest) if rest else {}
    return status, headers, [("json", payload)]


async def _fetch(host: str, port: int, path: str) -> Tuple[int, bytes]:
    reader, writer = await asyncio.open_connection(host, port)
    try:
        writer.write(f"GET {path} HTTP/1.1\r\nHost: {host}\r\n\r\n"
                     .encode())
        await writer.drain()
        raw = await reader.read()
    finally:
        writer.close()
        try:
            await writer.wait_closed()
        except (ConnectionResetError, BrokenPipeError, OSError):
            pass
    head, _, body = raw.partition(b"\r\n\r\n")
    return int(head.split(b" ", 2)[1]), body


async def run_load(host: str, port: int, trace, n_clients: int,
                   max_retries: int = 100) -> LoadReport:
    """Closed loop: ``n_clients`` workers drain the shared trace; each
    request retries on 429 after the server's Retry-After."""
    work = list(enumerate(trace))
    queue: asyncio.Queue = asyncio.Queue()
    for item in work:
        queue.put_nowait(item)
    results: List[ClientResult] = []
    n_429 = 0
    n_errors = 0
    t0 = time.monotonic()

    async def worker():
        nonlocal n_429, n_errors
        while True:
            try:
                index, tr = queue.get_nowait()
            except asyncio.QueueEmpty:
                return
            res = ClientResult(index=index)
            payload = {
                "prompt_tokens": list(tr.prompt_tokens),
                "max_new_tokens": tr.output_len,
                "slo_class": tr.slo_class,
                "tag": index,
            }
            for _ in range(max_retries):
                t_post = time.monotonic()

                def mark_ttfb():
                    res.ttfb = time.monotonic() - t_post
                try:
                    status, headers, events = await _post_generate(
                        host, port, payload, on_first_byte=mark_ttfb)
                except (ConnectionError, OSError, asyncio.TimeoutError):
                    n_errors += 1
                    res.status = -1
                    break
                if status == 429:
                    n_429 += 1
                    res.n_retries += 1
                    await asyncio.sleep(
                        float(headers.get("retry-after", 1)))
                    continue
                res.status = status
                if status != 200:
                    n_errors += 1
                    break
                for kind, data in events:
                    if kind == "token":
                        res.tokens.append(data["token"])
                    elif kind == "done":
                        res.latency = time.monotonic() - t_post
                        assert res.tokens == data["tokens"], \
                            (index, res.tokens, data["tokens"])
                break
            else:
                n_errors += 1
                res.status = 429
            results.append(res)

    await asyncio.gather(*[worker() for _ in range(n_clients)])
    return LoadReport(results=results, elapsed=time.monotonic() - t0,
                      n_429=n_429, n_errors=n_errors)


def offline_tokens(sc: ServeConfig, trace) -> List[List[int]]:
    """The ground truth: a fresh identically-seeded engine replays the
    same trace through the offline runtime (iteration clock, no HTTP,
    no threads); returns per-trace-index token lists."""
    from repro.launch.serve import build_engine
    from repro.serving.runtime import EngineExecutor, ServingRuntime

    eng = build_engine(sc)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    res = rt.run(trace, max_iterations=1_000_000)
    return [list(eng.outputs[r.req_id]) for r in res.requests]


def verify_identity(report: LoadReport, offline: List[List[int]]) -> int:
    """Compare every live stream with its offline twin; returns the
    number of mismatched requests (0 = bit-identical)."""
    bad = 0
    for r in report.results:
        if r.status != 200:
            bad += 1
            continue
        if r.tokens != offline[r.index]:
            bad += 1
            print(f"[load-gen] MISMATCH index={r.index}: "
                  f"live={r.tokens} offline={offline[r.index]}",
                  file=sys.stderr)
    return bad


async def _amain(sc: ServeConfig, args) -> int:
    if args.url:
        host, _, port = args.url.rstrip("/").rpartition("//")[-1] \
            .partition(":")
        host, port = host or "127.0.0.1", int(port or 80)
        server = None
        vocab = args.vocab_size
    else:
        from repro.launch.serve import build_engine
        from repro.serving.server import ServingServer
        eng = build_engine(sc)
        if sc.http is None:
            sc.http = ":0"            # in-process: OS-assigned port
        server = ServingServer(eng, **sc.server_kwargs())
        await server.start()
        host, port = server.host, server.port
        vocab = eng.cfg.vocab_size
        print(f"[load-gen] in-process server on {host}:{port}")

    trace = sc.engine_trace(vocab)
    print(f"[load-gen] {args.clients} clients x {len(trace)} requests "
          f"-> {host}:{port}")
    report = await run_load(host, port, trace, args.clients)

    status, metrics_body = await _fetch(host, port, "/metrics")
    if server is not None:
        await server.stop()
    s = report.summary()
    print(f"[load-gen] {s['n_ok']:.0f}/{s['n_requests']:.0f} ok in "
          f"{s['elapsed_s']:.1f}s ({s['throughput_rps']:.1f} req/s); "
          f"{s['n_429_retries']:.0f} rate-limit retries, "
          f"{s['n_errors']:.0f} errors")
    print(f"[load-gen] client latency p50={s['latency_p50']:.3f}s "
          f"p99={s['latency_p99']:.3f}s; "
          f"ttfb p50={s['ttfb_p50']:.3f}s p99={s['ttfb_p99']:.3f}s")
    flat: Dict[str, float] = {}
    if status == 200:
        for ln in metrics_body.decode().splitlines():
            if ln.startswith("#") or not ln.strip():
                continue
            name, _, val = ln.rpartition(" ")
            flat[name] = float(val)
    out = {"summary": s, "config": json.loads(sc.to_json()),
           "metrics_scrape_ok": status == 200, "metrics": flat}
    if args.verify:
        offline = offline_tokens(sc, trace)
        bad = verify_identity(report, offline)
        out["n_mismatched"] = bad
        if bad:
            print(f"[load-gen] FAIL: {bad} stream(s) diverged from "
                  f"offline replay", file=sys.stderr)
        else:
            print(f"[load-gen] verified: all {len(trace)} live token "
                  f"streams bit-identical to offline replay")
    if args.out:
        with open(args.out, "w") as f:
            json.dump(out, f, indent=2, default=float)
        print(f"[load-gen] report -> {args.out}")
    if s["n_errors"] or out.get("n_mismatched"):
        return 1
    return 0


def main() -> None:
    ap = argparse.ArgumentParser()
    ServeConfig.add_arguments(ap)
    ap.add_argument("--clients", type=int, default=8,
                    help="concurrent closed-loop clients")
    ap.add_argument("--url", default=None,
                    help="target server (default: boot an in-process "
                         "server from this ServeConfig)")
    ap.add_argument("--vocab-size", type=int, default=1024,
                    help="token id range for generated prompts when "
                         "--url is remote (in-process mode reads the "
                         "engine's config)")
    ap.add_argument("--verify", default=None,
                    action=argparse.BooleanOptionalAction,
                    help="replay the trace offline and require "
                         "bit-identical token streams (default: on "
                         "in-process, off against --url)")
    ap.add_argument("--out", default=None,
                    help="write the JSON report here")
    args = ap.parse_args()
    sc = ServeConfig.from_args(args)
    if not sc.simulate and not sc.smoke:
        sc.smoke = True
    sc.slots = min(sc.slots, 8)
    if args.verify is None:
        args.verify = args.url is None
    sys.exit(asyncio.run(_amain(sc, args)))


if __name__ == "__main__":
    main()
