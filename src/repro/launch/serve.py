"""Production serving launcher: real-execution engine (smoke-sized models
on CPU; the same engine code path runs under a device mesh on TPU) or the
discrete-event simulator at full model scale.

Usage:
  # real engine, reduced model, layered prefill:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
      --smoke --scheduler layered --requests 8

  # full-scale simulation of the paper's serving scenario:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-30b-a3b \
      --simulate --dataset arxiv --rate 1.3 --requests 100
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, list_configs
from repro.core.base import SCHEDULERS, make_scheduler
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2, TPU_V5E
from repro.serving.engine import Engine
from repro.serving.metrics import SLOConfig, request_metrics
from repro.serving.simulator import Simulator
from repro.serving.traffic import DATASETS, poisson_trace


def preemption_opts(args):
    """Map --preemption {on,off,recompute,swap,auto} onto the scheduler's
    (enabled, mode) pair: "on" is a legacy alias for "recompute"; "off"
    disables eviction entirely (queueing-only admission)."""
    enabled = args.preemption != "off"
    mode = args.preemption if args.preemption in ("swap", "auto") \
        else "recompute"
    return enabled, mode


def serve_real(args) -> None:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(args.scheduler, model.n_blocks,
                           n_slots=args.slots, quantum=args.quantum,
                           token_budget=args.token_budget)
    enabled, mode = preemption_opts(args)
    eng = Engine(model, params, sched, n_slots=args.slots,
                 max_len=args.max_len, moe_dispatch=args.moe_dispatch,
                 pages=args.pages, page_size=args.page_size,
                 preemption=enabled, preemption_mode=mode,
                 host_pages=args.host_pages,
                 swap_in_budget=args.swap_in_budget,
                 decode_reserve=args.decode_reserve)
    rng = np.random.default_rng(args.seed)
    for _ in range(args.requests):
        n = int(rng.integers(16, args.max_len // 2))
        enc = None
        if cfg.encoder.enabled:
            enc = np.zeros((cfg.encoder.n_frames, cfg.d_model), np.float32)
        eng.submit(rng.integers(1, cfg.vocab_size, n).tolist(),
                   max_new_tokens=int(rng.integers(4, 16)), enc_frames=enc)
    eng.run()
    m = request_metrics(eng.requests.values())
    print(f"[serve] {cfg.name} x {args.scheduler}: "
          f"{args.requests} requests in {eng.iteration} iterations")
    print(f"[serve] ttft(iters) mean={m['ttft_mean']:.1f} "
          f"p99={m['ttft_p99']:.1f}; expert-load "
          f"{eng.expert_load_bytes / 1e6:.1f} MB")
    print(f"[serve] kv pages high-water {eng.alloc.pages_high_water}"
          f"/{eng.alloc.n_pages}; queue delay mean "
          f"{m['queue_delay_mean']:.1f} iters; "
          f"preemptions {eng.n_preempted} "
          f"(rate {m['preemption_rate']:.2f}/req)")
    if eng.alloc.n_host_pages:
        print(f"[serve] swap: {eng.n_swapped_out} out / "
              f"{eng.n_swapped_in} in; host pages high-water "
              f"{eng.alloc.host_pages_high_water}/{eng.alloc.n_host_pages}; "
              f"restore latency mean {m['restore_latency_mean']:.1f} iters")


def serve_sim(args) -> None:
    cfg = get_config(args.arch)
    hw = H100X2 if args.hw == "h100x2" else TPU_V5E
    if args.host_bw is not None:
        hw = dataclasses.replace(hw, host_bw=args.host_bw * 1e9)
    trace = poisson_trace(DATASETS[args.dataset], args.rate, args.requests,
                          seed=args.seed)
    enabled, mode = preemption_opts(args)
    sim = Simulator(cfg, args.scheduler, hw, n_slots=args.slots,
                    quantum=args.quantum, token_budget=args.token_budget,
                    moe_dispatch=args.moe_dispatch, n_pages=args.pages,
                    page_size=args.page_size,
                    preemption=enabled, preemption_mode=mode,
                    host_pages=args.host_pages,
                    swap_in_budget=args.swap_in_budget,
                    decode_reserve=args.decode_reserve)
    res = sim.run(trace)
    m = request_metrics(res.requests, SLOConfig(args.ttft_slo, args.tbt_slo))
    print(f"[serve-sim] {cfg.name} x {args.scheduler} on {args.dataset} "
          f"@{args.rate} req/s ({hw.name}; "
          f"{sim.kv.n_pages} x {sim.kv.page_size}-token pages)")
    for k in ("ttft_mean", "ttft_p99", "tbt_mean", "tbt_p99",
              "slo_attainment", "e2e_mean", "queue_delay_mean",
              "queue_delay_p99", "preemption_rate"):
        print(f"[serve-sim]   {k:<16} {m[k]:.3f}")
    print(f"[serve-sim]   energy/token     "
          f"{res.energy_per_token * 1e3:.1f} mJ")
    print(f"[serve-sim]   expert traffic   "
          f"{res.total_expert_bytes / 1e12:.2f} TB")
    print(f"[serve-sim]   kv pages         "
          f"high-water {res.pages_high_water}/{res.n_pool_pages}; "
          f"{res.n_preemptions} preemptions, "
          f"{res.recompute_tokens} recomputed tokens")
    if res.n_host_pages:
        print(f"[serve-sim]   swap             "
              f"{res.n_swap_outs} out / {res.n_swap_ins} in; "
              f"{res.swap_bytes / 1e9:.2f} GB over host link, "
              f"{res.swap_stall_time:.3f} s stall; host pages "
              f"high-water {res.host_pages_high_water}/{res.n_host_pages}; "
              f"restore latency mean {m['restore_latency_mean']:.3f} s")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-30b-a3b", choices=list_configs())
    ap.add_argument("--scheduler", default="layered",
                    choices=sorted(SCHEDULERS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--dataset", default="arxiv", choices=list(DATASETS))
    ap.add_argument("--rate", type=float, default=1.3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--quantum", type=int, default=512)
    ap.add_argument("--token-budget", type=int, default=512)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--pages", type=int, default=None,
                    help="paged KV pool size in pages (default: engine "
                         "fills every slot row; simulator sizes from the "
                         "hardware's HBM capacity minus weights)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV tokens per page")
    ap.add_argument("--preemption", default="on",
                    choices=["on", "off", "recompute", "swap", "auto"],
                    help="memory-pressure eviction mode: recompute (= on; "
                         "fold + re-prefill victims), swap (KV pages to the "
                         "host pool, DMA-back restore), auto (per-victim "
                         "cost crossover), off (queueing-only admission)")
    ap.add_argument("--host-pages", type=int, default=None,
                    help="host-side swap pool size in pages (default: 4x "
                         "the device pool when swap/auto is selected)")
    ap.add_argument("--host-bw", type=float, default=None,
                    help="host<->HBM DMA bandwidth in GB/s (simulator "
                         "only; overrides the hardware spec's PCIe term)")
    ap.add_argument("--swap-in-budget", type=int, default=None,
                    help="max KV tokens DMA'd back from host per iteration "
                         "(default: unlimited; at least one restore per "
                         "iteration is always allowed)")
    ap.add_argument("--decode-reserve", type=int, default=None,
                    help="per-request decode KV reservation in tokens "
                         "(default: one page; 0 = admit on prompt KV only "
                         "and rely on preemption for decode growth)")
    ap.add_argument("--moe-dispatch", default="ragged",
                    choices=["ragged", "dense"],
                    help="dropless MoE data path: ragged (sorted "
                         "tile-aligned buffer; traffic scales with routed "
                         "work) or dense (worst-case capacity buffer)")
    ap.add_argument("--hw", default="h100x2", choices=["h100x2", "tpu_v5e"])
    ap.add_argument("--ttft-slo", type=float, default=10.0)
    ap.add_argument("--tbt-slo", type=float, default=0.125)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.simulate:
        serve_sim(args)
    else:
        if not args.smoke:
            args.smoke = True
            print("[serve] full-scale real execution needs TPU; using "
                  "--smoke model (use --simulate for full-scale numbers)")
        args.slots = min(args.slots, 8)
        serve_real(args)


if __name__ == "__main__":
    main()
