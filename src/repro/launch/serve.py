"""Production serving launcher: real-execution engine (smoke-sized models
on CPU; the same engine code path runs under a device mesh on TPU) or the
discrete-event simulator at full model scale.  Both run the SAME
ServingRuntime loop (serving/runtime.py): closed-loop drain by default,
open-loop timed-trace replay with ``--open-loop`` (engine) or
``--simulate`` (always open-loop), optional per-token streaming via
``--stream`` and multi-tenant class mixes via ``--batch-fraction``.

Usage:
  # real engine, reduced model, layered prefill, closed loop:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
      --smoke --scheduler layered --requests 8

  # real engine, open-loop Poisson replay with streamed tokens:
  PYTHONPATH=src python -m repro.launch.serve --smoke --open-loop \
      --rate 0.5 --requests 8 --stream

  # full-scale simulation of the paper's serving scenario, 30% batch-class
  # bursty background traffic, 64 pages held back for interactive:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-30b-a3b \
      --simulate --dataset arxiv --rate 1.3 --requests 100 \
      --batch-fraction 0.3 --arrival bursty --class-headroom 64
"""

from __future__ import annotations

import argparse
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config, list_configs
from repro.core.base import SCHEDULERS, make_scheduler
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2, TPU_V5E
from repro.serving.engine import Engine
from repro.serving.metrics import (SLOConfig, per_class_metrics,
                                   request_metrics)
from repro.serving.runtime import EngineExecutor, ServingRuntime
from repro.serving.simulator import Simulator
from repro.serving.traffic import (ARRIVAL_PROCESSES, DATASETS, ClassSpec,
                                   DatasetModel, LengthModel,
                                   attach_prompt_tokens, multi_class_trace)


def preemption_opts(args):
    """Map --preemption {on,off,recompute,swap,auto} onto the scheduler's
    (enabled, mode) pair: "on" is a legacy alias for "recompute"; "off"
    disables eviction entirely (queueing-only admission)."""
    enabled = args.preemption != "off"
    mode = args.preemption if args.preemption in ("swap", "auto") \
        else "recompute"
    return enabled, mode


def class_headroom_opt(args):
    """--class-headroom N reserves N pages for interactive admissions."""
    return {"interactive": args.class_headroom} if args.class_headroom \
        else None


def _print_per_class(tag, requests, slo=None) -> None:
    per = per_class_metrics(requests, slo)
    if len(per) < 2:
        return
    for cls, m in per.items():
        att = f" slo={m['slo_attainment']:.2f}" if "slo_attainment" in m \
            else ""
        print(f"[{tag}]   class {cls:<12} n={m['n_requests']:.0f} "
              f"ttft mean={m['ttft_mean']:.2f} p99={m['ttft_p99']:.2f}; "
              f"preempt rate {m['preemption_rate']:.2f}/req; "
              f"swap rate {m['swap_rate']:.2f}/req{att}")


def _engine_trace(args, cfg):
    """Open-loop trace for the smoke-scale engine, built with the SAME
    traffic generators as the simulator (``--arrival`` selects the
    process, ``--batch-fraction`` the class mix) but with a length model
    shrunk to the engine's max_len, and real token ids attached for
    replay.  ``--rate`` is requests per unit of the selected clock."""
    smoke = DatasetModel(
        name="engine-smoke",
        input_len=LengthModel(mean=args.max_len // 6, std=args.max_len // 8,
                              lo=16, hi=args.max_len // 2),
        output_len=LengthModel(mean=9, std=4, lo=4, hi=15))
    n_batch = int(round(args.requests * args.batch_fraction))
    specs = [ClassSpec("batch", smoke, args.rate * args.batch_fraction,
                       n_batch, process=args.arrival)] if n_batch else []
    if args.requests - n_batch:
        specs.append(ClassSpec(
            "interactive", smoke, args.rate * (1 - args.batch_fraction),
            args.requests - n_batch,
            process=args.arrival if not n_batch else "poisson"))
    trace = multi_class_trace(specs, seed=args.seed)
    return attach_prompt_tokens(trace, cfg.vocab_size, seed=args.seed)


def serve_real(args) -> None:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(args.scheduler, model.n_blocks,
                           n_slots=args.slots, quantum=args.quantum,
                           token_budget=args.token_budget)
    enabled, mode = preemption_opts(args)
    eng = Engine(model, params, sched, n_slots=args.slots,
                 max_len=args.max_len, moe_dispatch=args.moe_dispatch,
                 pages=args.pages, page_size=args.page_size,
                 preemption=enabled, preemption_mode=mode,
                 host_pages=args.host_pages,
                 swap_in_budget=args.swap_in_budget,
                 decode_reserve=args.decode_reserve,
                 class_headroom=class_headroom_opt(args),
                 packed=args.packed,
                 prefix_cache=args.prefix_cache,
                 prefix_lru_pages=args.prefix_lru_pages,
                 spec_mode=args.spec, spec_k=args.spec_k,
                 draft_config=args.draft_config)
    def _stream(rid, tok, t):
        print(f"[stream] t={t:8.2f} req={rid:<4} tok={tok}")
    on_token = _stream if args.stream else None
    if args.open_loop:
        # open-loop timed replay through the shared runtime: requests are
        # injected at their arrival times, the engine idles through gaps
        trace = _engine_trace(args, cfg)
        wall = args.clock == "wall"
        runtime = ServingRuntime(
            EngineExecutor(eng, wall=wall), on_token=on_token,
            clock="executor" if wall else "iteration")
        runtime.run(trace, max_iterations=100_000)
        unit = "s" if wall else "iters"
    else:
        rng = np.random.default_rng(args.seed)
        for _ in range(args.requests):
            n = int(rng.integers(16, args.max_len // 2))
            enc = None
            if cfg.encoder.enabled:
                enc = np.zeros((cfg.encoder.n_frames, cfg.d_model),
                               np.float32)
            cls = "batch" if rng.random() < args.batch_fraction \
                else "interactive"
            eng.submit(rng.integers(1, cfg.vocab_size, n).tolist(),
                       max_new_tokens=int(rng.integers(4, 16)),
                       enc_frames=enc, slo_class=cls)
        runtime = ServingRuntime(EngineExecutor(eng), on_token=on_token,
                                 clock="iteration")
        runtime.run((), max_iterations=100_000)
        unit = "iters"
    m = request_metrics(eng.requests.values())
    loop = "open-loop" if args.open_loop else "closed-loop"
    print(f"[serve] {cfg.name} x {args.scheduler} ({loop}): "
          f"{args.requests} requests in {eng.iteration} iterations")
    print(f"[serve] ttft({unit}) mean={m['ttft_mean']:.1f} "
          f"p99={m['ttft_p99']:.1f}; expert-load "
          f"{eng.expert_load_bytes / 1e6:.1f} MB")
    print(f"[serve] kv pages high-water {eng.alloc.pages_high_water}"
          f"/{eng.alloc.n_pages}; queue delay mean "
          f"{m['queue_delay_mean']:.1f} {unit}; "
          f"preemptions {eng.n_preempted} "
          f"(rate {m['preemption_rate']:.2f}/req)")
    print(f"[serve] hot path: {'packed' if args.packed else 'per-slice'}; "
          f"{eng.n_dispatches} device launches "
          f"({eng.n_dispatches / max(eng.iteration, 1):.1f}/iter), "
          f"{eng.n_prefill_dispatches} prefill batches, "
          f"{eng.n_prefill_compiles} prefill executables")
    if args.spec != "off":
        acc = m["spec_acceptance_rate"]
        tpd = (sum(r.n_generated for r in eng.requests.values())
               / max(eng.n_dispatches, 1))
        print(f"[serve] spec({args.spec}, k={args.spec_k}): "
              f"{eng.n_spec_proposed} drafted, {eng.n_spec_accepted} "
              f"accepted (rate {acc:.2f}); accepted len "
              f"p50={m['accepted_len_p50']:.1f} "
              f"p90={m['accepted_len_p90']:.1f}; "
              f"{eng.n_verify_dispatches} verify + "
              f"{eng.n_draft_dispatches} draft dispatches, "
              f"{eng.n_verify_compiles} verify executables; "
              f"{tpd:.2f} generated tokens/dispatch")
    if args.prefix_cache:
        print(f"[serve] prefix cache: hit rate "
              f"{m['prefix_hit_rate']:.2f} "
              f"({eng.alloc.n_prefix_hits} hits, "
              f"{eng.alloc.n_prefix_tokens} cached tokens, "
              f"{eng.n_prefix_restores} row restores); "
              f"{eng.alloc.n_shared_pages} shared pages live, "
              f"{eng.alloc.n_prefix_evictions} LRU reclaims")
    if eng.alloc.n_host_pages:
        print(f"[serve] swap: {eng.n_swapped_out} out / "
              f"{eng.n_swapped_in} in; host pages high-water "
              f"{eng.alloc.host_pages_high_water}/{eng.alloc.n_host_pages}; "
              f"restore latency mean {m['restore_latency_mean']:.1f} {unit}")
    _print_per_class("serve", eng.requests.values())


def serve_sim(args) -> None:
    cfg = get_config(args.arch)
    hw = H100X2 if args.hw == "h100x2" else TPU_V5E
    if args.host_bw is not None:
        hw = dataclasses.replace(hw, host_bw=args.host_bw * 1e9)
    if args.batch_fraction > 0:
        # multi-tenant mix: interactive foreground on the chosen dataset,
        # batch-class arXiv background on the selected arrival process
        n_batch = int(round(args.requests * args.batch_fraction))
        trace = multi_class_trace([
            ClassSpec("interactive", DATASETS[args.dataset],
                      args.rate * (1 - args.batch_fraction),
                      args.requests - n_batch),
            ClassSpec("batch", DATASETS["arxiv"],
                      args.rate * args.batch_fraction, n_batch,
                      process=args.arrival),
        ], seed=args.seed)
    else:
        trace = ARRIVAL_PROCESSES[args.arrival](
            DATASETS[args.dataset], args.rate, args.requests,
            seed=args.seed)
    enabled, mode = preemption_opts(args)
    sim = Simulator(cfg, args.scheduler, hw, n_slots=args.slots,
                    quantum=args.quantum, token_budget=args.token_budget,
                    moe_dispatch=args.moe_dispatch, n_pages=args.pages,
                    page_size=args.page_size,
                    preemption=enabled, preemption_mode=mode,
                    host_pages=args.host_pages,
                    swap_in_budget=args.swap_in_budget,
                    decode_reserve=args.decode_reserve,
                    swap_overlap=not args.swap_serial,
                    class_headroom=class_headroom_opt(args),
                    prefix_cache=args.prefix_cache,
                    prefix_lru_pages=args.prefix_lru_pages,
                    spec_mode=args.spec, spec_k=args.spec_k,
                    spec_acceptance=args.spec_acceptance)
    res = sim.run(trace)
    slo = SLOConfig(args.ttft_slo, args.tbt_slo)
    m = request_metrics(res.requests, slo)
    print(f"[serve-sim] {cfg.name} x {args.scheduler} on {args.dataset} "
          f"@{args.rate} req/s ({hw.name}; "
          f"{sim.kv.n_pages} x {sim.kv.page_size}-token pages)")
    for k in ("ttft_mean", "ttft_p99", "tbt_mean", "tbt_p99",
              "slo_attainment", "e2e_mean", "queue_delay_mean",
              "queue_delay_p99", "preemption_rate"):
        print(f"[serve-sim]   {k:<16} {m[k]:.3f}")
    print(f"[serve-sim]   energy/token     "
          f"{res.energy_per_token * 1e3:.1f} mJ")
    print(f"[serve-sim]   expert traffic   "
          f"{res.total_expert_bytes / 1e12:.2f} TB")
    print(f"[serve-sim]   kv pages         "
          f"high-water {res.pages_high_water}/{res.n_pool_pages}; "
          f"{res.n_preemptions} preemptions, "
          f"{res.recompute_tokens} recomputed tokens")
    if args.prefix_cache:
        print(f"[serve-sim]   prefix cache     "
              f"hit rate {res.prefix_hit_rate:.2f} "
              f"({res.n_prefix_hits} hits, "
              f"{res.prefix_cached_tokens} cached tokens)")
    if args.spec != "off":
        print(f"[serve-sim]   spec({args.spec})      "
              f"{res.total_drafted} drafted / {res.total_accepted} accepted "
              f"(rate {res.acceptance_rate:.2f}); accepted len "
              f"p50={m['accepted_len_p50']:.1f} "
              f"p90={m['accepted_len_p90']:.1f}")
    if res.n_host_pages:
        print(f"[serve-sim]   swap             "
              f"{res.n_swap_outs} out / {res.n_swap_ins} in; "
              f"{res.swap_bytes / 1e9:.2f} GB over host link, "
              f"{res.swap_dma_time:.3f} s DMA ({res.swap_stall_time:.3f} s "
              f"unhidden stall); host pages "
              f"high-water {res.host_pages_high_water}/{res.n_host_pages}; "
              f"restore latency mean {m['restore_latency_mean']:.3f} s")
    _print_per_class("serve-sim", res.requests, slo)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-30b-a3b", choices=list_configs())
    ap.add_argument("--scheduler", default="layered",
                    choices=sorted(SCHEDULERS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--simulate", action="store_true")
    ap.add_argument("--open-loop", action="store_true",
                    help="real engine: replay a timed Poisson trace "
                         "through the shared ServingRuntime (requests "
                         "injected at their arrival times) instead of the "
                         "closed-loop submit-everything drain")
    ap.add_argument("--clock", default="virtual",
                    choices=["virtual", "wall"],
                    help="open-loop engine clock: virtual (1 unit per "
                         "iteration, deterministic) or wall (arrival "
                         "times in real seconds; idles really sleep)")
    ap.add_argument("--stream", action="store_true",
                    help="print every generated token as it is emitted "
                         "(the incremental-output API; engine streams "
                         "real ids, the simulator streams placeholders)")
    ap.add_argument("--dataset", default="arxiv", choices=list(DATASETS))
    ap.add_argument("--arrival", default="poisson",
                    choices=sorted(ARRIVAL_PROCESSES),
                    help="arrival process (bursty = on/off modulated "
                         "Poisson with the same long-run rate)")
    ap.add_argument("--rate", type=float, default=1.3)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch-fraction", type=float, default=0.0,
                    help="fraction of requests tagged slo_class=batch "
                         "(evicted before interactive under memory "
                         "pressure); the simulator draws their lengths "
                         "from arXiv and their arrivals from --arrival")
    ap.add_argument("--class-headroom", type=int, default=0,
                    help="pages reserved for interactive admissions: "
                         "batch requests must leave this many pages free")
    ap.add_argument("--slots", type=int, default=64)
    ap.add_argument("--quantum", type=int, default=512)
    ap.add_argument("--token-budget", type=int, default=512)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--pages", type=int, default=None,
                    help="paged KV pool size in pages (default: engine "
                         "fills every slot row; simulator sizes from the "
                         "hardware's HBM capacity minus weights)")
    ap.add_argument("--page-size", type=int, default=16,
                    help="KV tokens per page")
    ap.add_argument("--preemption", default="on",
                    choices=["on", "off", "recompute", "swap", "auto"],
                    help="memory-pressure eviction mode: recompute (= on; "
                         "fold + re-prefill victims), swap (KV pages to the "
                         "host pool, DMA-back restore), auto (per-victim "
                         "cost crossover), off (queueing-only admission)")
    ap.add_argument("--host-pages", type=int, default=None,
                    help="host-side swap pool size in pages (default: 4x "
                         "the device pool when swap/auto is selected)")
    ap.add_argument("--host-bw", type=float, default=None,
                    help="host<->HBM DMA bandwidth in GB/s (simulator "
                         "only; overrides the hardware spec's PCIe term)")
    ap.add_argument("--swap-serial", action="store_true",
                    help="charge swap DMA as a fully serial stall "
                         "(simulator only; default overlaps it with the "
                         "iteration's compute)")
    ap.add_argument("--swap-in-budget", type=int, default=None,
                    help="max KV tokens DMA'd back from host per iteration "
                         "(default: unlimited; at least one restore per "
                         "iteration is always allowed)")
    ap.add_argument("--decode-reserve", type=int, default=None,
                    help="per-request decode KV reservation in tokens "
                         "(default: one page; 0 = admit on prompt KV only "
                         "and rely on preemption for decode growth)")
    ap.add_argument("--packed", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="packed layer-group execution: all prefill "
                         "slices sharing a (block-range, emit) rectangle "
                         "run as ONE jitted slot-vector batch per "
                         "iteration; --no-packed is the per-slice escape "
                         "hatch (one dispatch per slice)")
    ap.add_argument("--prefix-cache", default=True,
                    action=argparse.BooleanOptionalAction,
                    help="automatic prefix caching: completed prompts "
                         "publish their full KV pages into a refcounted "
                         "content-hash index; later prompts sharing a "
                         "page-aligned prefix skip its prefill (every "
                         "layer group starts past the cached boundary) "
                         "and link the shared pages copy-on-write. "
                         "--no-prefix-cache restores cold prefill")
    ap.add_argument("--prefix-lru-pages", type=int, default=None,
                    help="cap on retained refcount-0 cached pages "
                         "(default: unbounded — idle cached pages still "
                         "yield to any allocation before eviction kicks "
                         "in, they are only pinned while referenced)")
    ap.add_argument("--moe-dispatch", default="ragged",
                    choices=["ragged", "dense"],
                    help="dropless MoE data path: ragged (sorted "
                         "tile-aligned buffer; traffic scales with routed "
                         "work) or dense (worst-case capacity buffer)")
    ap.add_argument("--spec", default="off",
                    choices=["off", "ngram", "draft"],
                    help="speculative verify-k decoding: ngram (draft-free "
                         "prompt/self-lookup) or draft (tiny draft model "
                         "from --draft-config); greedy output streams are "
                         "bit-identical to --spec off — speculation only "
                         "changes tokens committed per dispatch")
    ap.add_argument("--spec-k", type=int, default=4,
                    help="max drafted tokens verified per request per "
                         "iteration (draft mode adapts below this via the "
                         "per-request acceptance EMA)")
    ap.add_argument("--draft-config", default=None,
                    help="config name whose smoke variant drafts for "
                         "--spec draft (must share the target's vocab)")
    ap.add_argument("--spec-acceptance", type=float, default=0.7,
                    help="simulator only: per-token draft acceptance "
                         "probability for the analytic verify-k model")
    ap.add_argument("--hw", default="h100x2", choices=["h100x2", "tpu_v5e"])
    ap.add_argument("--ttft-slo", type=float, default=10.0)
    ap.add_argument("--tbt-slo", type=float, default=0.125)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    if args.simulate:
        serve_sim(args)
    else:
        if not args.smoke:
            args.smoke = True
            print("[serve] full-scale real execution needs TPU; using "
                  "--smoke model (use --simulate for full-scale numbers)")
        args.slots = min(args.slots, 8)
        serve_real(args)


if __name__ == "__main__":
    main()
