"""Production serving launcher: real-execution engine (smoke-sized models
on CPU; the same engine code path runs under a device mesh on TPU) or the
discrete-event simulator at full model scale.  Both run the SAME
ServingRuntime loop (serving/runtime.py): closed-loop drain by default,
open-loop timed-trace replay with ``--open-loop`` (engine) or
``--simulate`` (always open-loop), optional per-token streaming via
``--stream``, multi-tenant class mixes via ``--batch-fraction`` — and,
with ``--http``, a live asyncio HTTP/SSE front-end (serving/server.py)
ingesting POST /v1/generate concurrently with the engine loop.

Every flag lives on ``ServeConfig`` (launch/config.py) — the same typed
configuration the benchmarks and the load generator consume, with
``to_json``/``from_json`` round-trips for recording exactly what ran.

Usage:
  # real engine, reduced model, layered prefill, closed loop:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-moe-235b-a22b \
      --smoke --scheduler layered --requests 8

  # real engine, open-loop Poisson replay with streamed tokens:
  PYTHONPATH=src python -m repro.launch.serve --smoke --open-loop \
      --rate 0.5 --requests 8 --stream

  # live HTTP/SSE service on port 8000 (per-tenant rate limit 4 req/s,
  # 429 backpressure past the queue/pool watermarks, /metrics scrape):
  PYTHONPATH=src python -m repro.launch.serve --smoke --http :8000 \
      --ratelimit-rate 4
  curl -N localhost:8000/v1/generate \
      -d '{"prompt_tokens": [1,2,3], "max_new_tokens": 8}'

  # full-scale simulation of the paper's serving scenario, 30% batch-class
  # bursty background traffic, 64 pages held back for interactive:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-30b-a3b \
      --simulate --dataset arxiv --rate 1.3 --requests 100 \
      --batch-fraction 0.3 --arrival bursty --class-headroom 64
"""

from __future__ import annotations

import argparse
import dataclasses
import math

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.core.base import make_scheduler
from repro.launch.config import ServeConfig
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2, TPU_V5E
from repro.serving.engine import Engine, EngineHandoff
from repro.serving.faults import FaultInjector, FaultPlan
from repro.serving.metrics import per_class_metrics, request_metrics
from repro.serving.runtime import (DisaggRuntime, EngineExecutor,
                                   ServingRuntime)
from repro.serving.server import ServingServer
from repro.serving.simulator import DisaggSimulator, Simulator
from repro.serving.traffic import (ARRIVAL_PROCESSES, DATASETS, ClassSpec,
                                   multi_class_trace)


def _f(v, spec: str = ".2f") -> str:
    """NaN/None-safe number formatting for the per-class report lines:
    a class with zero completed requests has NaN percentiles, and "-" is
    the honest column value (format() would happily print "nan")."""
    if v is None or (isinstance(v, float) and math.isnan(v)):
        return "-"
    return format(v, spec)


def _print_per_class(tag, requests, slo=None) -> None:
    per = per_class_metrics(requests, slo)
    if len(per) < 2:
        return
    for cls, m in per.items():
        att = f" slo={_f(m['slo_attainment'])}" if "slo_attainment" in m \
            else ""
        print(f"[{tag}]   class {cls:<12} n={m['n_requests']:.0f} "
              f"ttft mean={_f(m['ttft_mean'])} p99={_f(m['ttft_p99'])}; "
              f"preempt rate {_f(m['preemption_rate'])}/req; "
              f"swap rate {_f(m['swap_rate'])}/req{att}")


def build_faults(sc: ServeConfig) -> FaultInjector | None:
    """Chaos mode: a deterministic ``FaultInjector`` from ``--fault-plan``
    ('@file.json' | 'seed:N' | inline JSON), or None when off."""
    if sc.fault_plan is None:
        return None
    return FaultInjector(FaultPlan.load(sc.fault_plan))


def _print_faults(tag: str, fi: FaultInjector | None, requests) -> None:
    """Chaos-run report line: what was injected and what got shed (a
    DONE request with ``shed_reason`` set never completed)."""
    if fi is None:
        return
    shed: dict = {}
    for r in requests:
        if r.shed_reason is not None:
            shed[r.shed_reason] = shed.get(r.shed_reason, 0) + 1
    inj = ", ".join(f"{k[2:]}={v}" for k, v in sorted(fi.counters.items())
                    if v)
    sheds = ", ".join(f"{k}={v}" for k, v in sorted(shed.items()))
    print(f"[{tag}] chaos: {sum(fi.counters.values())} faults injected"
          + (f" ({inj})" if inj else "")
          + (f"; shed {sheds}" if sheds else "; no requests shed"))


def build_engine(sc: ServeConfig) -> Engine:
    """The one engine constructor every real-execution mode shares
    (closed loop, open-loop replay, HTTP service, load_gen verification)."""
    cfg = get_smoke_config(sc.arch) if sc.smoke else get_config(sc.arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(sc.scheduler, model.n_blocks,
                           **sc.scheduler_kwargs())
    return Engine(model, params, sched, **sc.engine_kwargs())


def serve_http(sc: ServeConfig) -> None:
    """Live HTTP/SSE service: the engine iteration loop runs on a
    background thread in wall-clock mode while asyncio ingests requests
    concurrently (serving/server.py)."""
    eng = build_engine(sc)
    server = ServingServer(eng, faults=build_faults(sc),
                           **sc.server_kwargs())
    server.serve_forever()


def build_disagg_engines(sc: ServeConfig):
    """(prefill, decode) Engine pair sharing one model + params: the
    prefill pool runs the selected scheduler, the decode pool the
    internal decode-only scheduler (residents arrive via ``adopt``)."""
    cfg = get_smoke_config(sc.arch) if sc.smoke else get_config(sc.arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sp = make_scheduler(sc.scheduler, model.n_blocks,
                        **sc.scheduler_kwargs())
    sd = make_scheduler("decode", model.n_blocks, **sc.scheduler_kwargs())
    ekw = sc.engine_kwargs()
    dkw = dict(ekw, pages=sc.decode_pages if sc.decode_pages is not None
               else ekw["pages"])
    return (Engine(model, params, sp, **ekw),
            Engine(model, params, sd, **dkw))


def serve_disagg_real(sc: ServeConfig) -> None:
    """Two-pool real execution: prefill and decode engines under one
    DisaggRuntime clock, KV handed off through ``EngineHandoff``."""
    ep, ed = build_disagg_engines(sc)
    cfg = ep.cfg

    def _stream(rid, tok, t):
        print(f"[stream] t={t:8.2f} req={rid:<4} tok={tok}")
    bridge = EngineHandoff(ep, ed, streaming=sc.handoff == "stream")
    faults = build_faults(sc)
    runtime = DisaggRuntime(
        EngineExecutor(ep), EngineExecutor(ed), bridge,
        on_token=_stream if sc.stream else None, clock="iteration",
        decode_watermark_pages=sc.decode_watermark,
        faults=faults, retry_budget=sc.retry_budget)
    if sc.open_loop:
        trace = sc.engine_trace(cfg.vocab_size)
    else:
        trace = ()
        rng = np.random.default_rng(sc.seed)
        for _ in range(sc.requests):
            n = int(rng.integers(16, sc.max_len // 2))
            enc = None
            if cfg.encoder.enabled:
                enc = np.zeros((cfg.encoder.n_frames, cfg.d_model),
                               np.float32)
            cls = "batch" if rng.random() < sc.batch_fraction \
                else "interactive"
            ep.submit(rng.integers(1, cfg.vocab_size, n).tolist(),
                      max_new_tokens=int(rng.integers(4, 16)),
                      enc_frames=enc, slo_class=cls)
    rr = runtime.run(trace, max_iterations=100_000)
    reqs = list(ep.requests.values()) + list(ed.requests.values())
    m = request_metrics(reqs)
    loop = "open-loop" if sc.open_loop else "closed-loop"
    print(f"[serve-disagg] {cfg.name} x {sc.scheduler}+decode ({loop}, "
          f"{sc.handoff} handoff): {sc.requests} requests in "
          f"{rr.n_prefill_iterations} prefill + "
          f"{rr.n_decode_iterations} decode iterations")
    print(f"[serve-disagg] ttft(iters) mean={_f(m['ttft_mean'], '.1f')} "
          f"p99={_f(m['ttft_p99'], '.1f')}; "
          f"{rr.n_migrations} migrations ({rr.n_returns} returns), "
          f"{rr.handoff_bytes / 1e6:.1f} MB handed off, "
          f"queue peak {rr.migration_queue_peak}")
    print(f"[serve-disagg] handoff chunks/req "
          f"{_f(m['handoff_chunks_mean'], '.1f')}; link ratio "
          f"{_f(m['handoff_link_ratio'])}; decode-pool prefill slices "
          f"{rr.decode_prefill_slices} (must stay 0)")
    print(f"[serve-disagg] kv high-water prefill "
          f"{ep.alloc.pages_high_water}/{ep.alloc.n_pages}, decode "
          f"{ed.alloc.pages_high_water}/{ed.alloc.n_pages}; "
          f"preemptions {ep.n_preempted}+{ed.n_preempted}")
    _print_faults("serve-disagg", faults, reqs)
    _print_per_class("serve-disagg", reqs)


def serve_real(sc: ServeConfig) -> None:
    eng = build_engine(sc)
    cfg = eng.cfg

    def _stream(rid, tok, t):
        print(f"[stream] t={t:8.2f} req={rid:<4} tok={tok}")
    on_token = _stream if sc.stream else None
    faults = build_faults(sc)
    if sc.open_loop:
        # open-loop timed replay through the shared runtime: requests are
        # injected at their arrival times, the engine idles through gaps
        trace = sc.engine_trace(cfg.vocab_size)
        wall = sc.clock == "wall"
        runtime = ServingRuntime(
            EngineExecutor(eng, wall=wall), on_token=on_token,
            clock="executor" if wall else "iteration",
            faults=faults, retry_budget=sc.retry_budget)
        runtime.run(trace, max_iterations=100_000)
        unit = "s" if wall else "iters"
    else:
        rng = np.random.default_rng(sc.seed)
        for _ in range(sc.requests):
            n = int(rng.integers(16, sc.max_len // 2))
            enc = None
            if cfg.encoder.enabled:
                enc = np.zeros((cfg.encoder.n_frames, cfg.d_model),
                               np.float32)
            cls = "batch" if rng.random() < sc.batch_fraction \
                else "interactive"
            eng.submit(rng.integers(1, cfg.vocab_size, n).tolist(),
                       max_new_tokens=int(rng.integers(4, 16)),
                       enc_frames=enc, slo_class=cls)
        runtime = ServingRuntime(EngineExecutor(eng), on_token=on_token,
                                 clock="iteration",
                                 faults=faults,
                                 retry_budget=sc.retry_budget)
        runtime.run((), max_iterations=100_000)
        unit = "iters"
    m = request_metrics(eng.requests.values())
    loop = "open-loop" if sc.open_loop else "closed-loop"
    print(f"[serve] {cfg.name} x {sc.scheduler} ({loop}): "
          f"{sc.requests} requests in {eng.iteration} iterations")
    print(f"[serve] ttft({unit}) mean={_f(m['ttft_mean'], '.1f')} "
          f"p99={_f(m['ttft_p99'], '.1f')}; expert-load "
          f"{eng.expert_load_bytes / 1e6:.1f} MB")
    print(f"[serve] kv pages high-water {eng.alloc.pages_high_water}"
          f"/{eng.alloc.n_pages}; queue delay mean "
          f"{_f(m['queue_delay_mean'], '.1f')} {unit}; "
          f"preemptions {eng.n_preempted} "
          f"(rate {_f(m['preemption_rate'])}/req)")
    print(f"[serve] hot path: {'packed' if sc.packed else 'per-slice'}; "
          f"{eng.n_dispatches} device launches "
          f"({eng.n_dispatches / max(eng.iteration, 1):.1f}/iter), "
          f"{eng.n_prefill_dispatches} prefill batches, "
          f"{eng.n_prefill_compiles} prefill executables")
    if sc.spec != "off":
        acc = m["spec_acceptance_rate"]
        tpd = (sum(r.n_generated for r in eng.requests.values())
               / max(eng.n_dispatches, 1))
        print(f"[serve] spec({sc.spec}, k={sc.spec_k}): "
              f"{eng.n_spec_proposed} drafted, {eng.n_spec_accepted} "
              f"accepted (rate {_f(acc)}); accepted len "
              f"p50={_f(m['accepted_len_p50'], '.1f')} "
              f"p90={_f(m['accepted_len_p90'], '.1f')}; "
              f"{eng.n_verify_dispatches} verify + "
              f"{eng.n_draft_dispatches} draft dispatches, "
              f"{eng.n_verify_compiles} verify executables; "
              f"{tpd:.2f} generated tokens/dispatch")
    if sc.prefix_cache:
        print(f"[serve] prefix cache: hit rate "
              f"{m['prefix_hit_rate']:.2f} "
              f"({eng.alloc.n_prefix_hits} hits, "
              f"{eng.alloc.n_prefix_tokens} cached tokens, "
              f"{eng.n_prefix_restores} row restores); "
              f"{eng.alloc.n_shared_pages} shared pages live, "
              f"{eng.alloc.n_prefix_evictions} LRU reclaims")
    if eng.alloc.n_host_pages:
        print(f"[serve] swap: {eng.n_swapped_out} out / "
              f"{eng.n_swapped_in} in; host pages high-water "
              f"{eng.alloc.host_pages_high_water}/{eng.alloc.n_host_pages};"
              f" restore latency mean "
              f"{_f(m['restore_latency_mean'], '.1f')} {unit}")
    _print_faults("serve", faults, eng.requests.values())
    _print_per_class("serve", eng.requests.values())


def serve_sim(sc: ServeConfig) -> None:
    cfg = get_config(sc.arch)
    hw = H100X2 if sc.hw == "h100x2" else TPU_V5E
    if sc.host_bw is not None:
        hw = dataclasses.replace(hw, host_bw=sc.host_bw * 1e9)
    if sc.batch_fraction > 0:
        # multi-tenant mix: interactive foreground on the chosen dataset,
        # batch-class arXiv background on the selected arrival process
        n_batch = int(round(sc.requests * sc.batch_fraction))
        trace = multi_class_trace([
            ClassSpec("interactive", DATASETS[sc.dataset],
                      sc.rate * (1 - sc.batch_fraction),
                      sc.requests - n_batch),
            ClassSpec("batch", DATASETS["arxiv"],
                      sc.rate * sc.batch_fraction, n_batch,
                      process=sc.arrival),
        ], seed=sc.seed)
    else:
        trace = ARRIVAL_PROCESSES[sc.arrival](
            DATASETS[sc.dataset], sc.rate, sc.requests, seed=sc.seed)
    if sc.disagg:
        _serve_sim_disagg(sc, cfg, hw, trace)
        return
    sim = Simulator(cfg, sc.scheduler, hw, **sc.sim_kwargs())
    faults = build_faults(sc)
    res = sim.run(trace, faults=faults, retry_budget=sc.retry_budget)
    slo = sc.slo()
    m = request_metrics(res.requests, slo)
    print(f"[serve-sim] {cfg.name} x {sc.scheduler} on {sc.dataset} "
          f"@{sc.rate} req/s ({hw.name}; "
          f"{sim.kv.n_pages} x {sim.kv.page_size}-token pages)")
    for k in ("ttft_mean", "ttft_p99", "tbt_mean", "tbt_p99",
              "slo_attainment", "e2e_mean", "queue_delay_mean",
              "queue_delay_p99", "preemption_rate"):
        print(f"[serve-sim]   {k:<16} {_f(m[k], '.3f')}")
    print(f"[serve-sim]   energy/token     "
          f"{res.energy_per_token * 1e3:.1f} mJ")
    print(f"[serve-sim]   expert traffic   "
          f"{res.total_expert_bytes / 1e12:.2f} TB")
    print(f"[serve-sim]   kv pages         "
          f"high-water {res.pages_high_water}/{res.n_pool_pages}; "
          f"{res.n_preemptions} preemptions, "
          f"{res.recompute_tokens} recomputed tokens")
    if sc.prefix_cache:
        print(f"[serve-sim]   prefix cache     "
              f"hit rate {res.prefix_hit_rate:.2f} "
              f"({res.n_prefix_hits} hits, "
              f"{res.prefix_cached_tokens} cached tokens)")
    if sc.spec != "off":
        print(f"[serve-sim]   spec({sc.spec})      "
              f"{res.total_drafted} drafted / {res.total_accepted} "
              f"accepted (rate {_f(res.acceptance_rate)}); accepted len "
              f"p50={_f(m['accepted_len_p50'], '.1f')} "
              f"p90={_f(m['accepted_len_p90'], '.1f')}")
    if res.n_host_pages:
        print(f"[serve-sim]   swap             "
              f"{res.n_swap_outs} out / {res.n_swap_ins} in; "
              f"{res.swap_bytes / 1e9:.2f} GB over host link, "
              f"{res.swap_dma_time:.3f} s DMA ({res.swap_stall_time:.3f} s"
              f" unhidden stall); host pages "
              f"high-water {res.host_pages_high_water}/{res.n_host_pages};"
              f" restore latency mean "
              f"{_f(m['restore_latency_mean'], '.3f')} s")
    _print_faults("serve-sim", faults, res.requests)
    _print_per_class("serve-sim", res.requests, slo)


def _serve_sim_disagg(sc: ServeConfig, cfg, hw, trace) -> None:
    """Two-pool analytic serving report: per-pool rollups plus the link
    accounting the monolithic report has no column for."""
    sim = DisaggSimulator(cfg, sc.scheduler, hw, handoff=sc.handoff,
                          decode_pages=sc.decode_pages,
                          decode_watermark=sc.decode_watermark,
                          **sc.sim_kwargs())
    faults = build_faults(sc)
    res = sim.run(trace, faults=faults, retry_budget=sc.retry_budget)
    slo = sc.slo()
    m = request_metrics(res.requests, slo)
    print(f"[serve-sim] {cfg.name} x {sc.scheduler}+decode on "
          f"{sc.dataset} @{sc.rate} req/s ({hw.name}; {sc.handoff} "
          f"handoff; decode pool "
          f"{sim.decode.kv.n_pages} x {sim.decode.kv.page_size}-token "
          f"pages)")
    for k in ("ttft_mean", "ttft_p99", "tbt_mean", "tbt_p99",
              "slo_attainment", "e2e_mean", "queue_delay_mean",
              "preemption_rate"):
        print(f"[serve-sim]   {k:<16} {_f(m[k], '.3f')}")
    n_tok = sum(r.n_generated for r in res.requests) or 1
    print(f"[serve-sim]   energy/token     "
          f"{res.total_energy / n_tok * 1e3:.1f} mJ "
          f"(link {res.link_energy * 1e3:.1f} mJ total)")
    print(f"[serve-sim]   handoff          "
          f"{res.n_migrations} migrations ({res.n_returns} returns); "
          f"{res.link_bytes / 1e9:.2f} GB over link, "
          f"{res.link_stall_time:.4f} s unhidden stall, "
          f"{res.handoff_wait_time:.4f} s watermark wait; "
          f"queue peak {res.migration_queue_peak}")
    print(f"[serve-sim]   decode pool      "
          f"tbt mean {_f(res.decode_pool_tbt_mean, '.4f')} s over "
          f"{res.decode.n_iterations} iterations; prefill slices "
          f"{res.decode_prefill_slices} (must stay 0)")
    print(f"[serve-sim]   kv pages         "
          f"prefill high-water "
          f"{res.prefill.pages_high_water}/{res.prefill.n_pool_pages}, "
          f"decode {res.decode.pages_high_water}"
          f"/{res.decode.n_pool_pages}; "
          f"{res.prefill.n_preemptions + res.decode.n_preemptions} "
          f"preemptions")
    _print_faults("serve-sim", faults, res.requests)
    _print_per_class("serve-sim", res.requests, slo)


def main() -> None:
    ap = argparse.ArgumentParser()
    ServeConfig.add_arguments(ap)
    sc = ServeConfig.from_args(ap.parse_args())
    if sc.simulate:
        serve_sim(sc)
        return
    if not sc.smoke:
        sc.smoke = True
        print("[serve] full-scale real execution needs TPU; using "
              "--smoke model (use --simulate for full-scale numbers)")
    sc.slots = min(sc.slots, 8)
    if sc.http is not None:
        serve_http(sc)
    elif sc.disagg:
        serve_disagg_real(sc)
    else:
        serve_real(sc)


if __name__ == "__main__":
    main()
