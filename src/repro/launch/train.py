"""Production training launcher: builds the mesh, shards params/optimizer
per the partition rules, and runs the jit'd train step over the synthetic
data pipeline.

On real hardware this runs under the (data, model) production mesh; on CPU
it runs the same code path with a 1x1 local mesh (use --smoke to shrink the
model). The multi-pod feasibility of every (arch x shape) is proven
separately by launch/dryrun.py.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch minicpm-2b --smoke \
      --steps 50 [--batch 8] [--seq 256] [--ckpt /tmp/ck.msgpack]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config, list_configs
from repro.launch.mesh import make_local_mesh, make_production_mesh
from repro.models.model import DecoderModel
from repro.sharding.partition import (default_rules, param_shardings,
                                      sharding_context)
from repro.training.data import PackedDataset, SyntheticCorpus
from repro.training.optimizer import adamw
from repro.training.train import make_train_step
from repro.training import checkpoint as ckpt_io


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minicpm-2b", choices=list_configs())
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--schedule", default="wsd",
                    choices=["wsd", "cosine", "const"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--production-mesh", action="store_true",
                    help="use the 16x16 mesh (requires 256 devices)")
    args = ap.parse_args()

    cfg = (get_smoke_config(args.arch) if args.smoke
           else get_config(args.arch))
    mesh = (make_production_mesh() if args.production_mesh
            else make_local_mesh())
    rules = default_rules(mesh)
    model = DecoderModel(cfg, remat=not args.smoke)
    opt = adamw(lr=args.lr, schedule=args.schedule, total_steps=args.steps,
                warmup=max(args.steps // 10, 1))

    with mesh, sharding_context(mesh, rules):
        params = jax.jit(
            model.init,
            out_shardings=param_shardings(
                jax.eval_shape(model.init, jax.random.PRNGKey(0)),
                mesh, rules))(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        step_fn = jax.jit(make_train_step(model, opt, cfg.encoder.enabled))

        n = sum(x.size for x in jax.tree_util.tree_leaves(params))
        print(f"[train] {cfg.name}: {n / 1e6:.1f}M params on mesh "
              f"{dict(mesh.shape)}")

        corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
        ds = iter(PackedDataset(corpus, seq_len=args.seq,
                                batch_size=args.batch, seed=0))
        t0 = time.time()
        for step in range(1, args.steps + 1):
            tokens, targets, mask = next(ds)
            batch = {"tokens": jnp.asarray(tokens),
                     "targets": jnp.asarray(targets),
                     "mask": jnp.asarray(mask)}
            if cfg.encoder.enabled:
                batch["enc_out"] = jnp.zeros(
                    (args.batch, cfg.encoder.n_frames, cfg.d_model),
                    cfg.dtype)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            if step % 10 == 0 or step == 1:
                print(f"[train] step {step:>5} loss={float(metrics['loss']):.3f} "
                      f"lr={float(metrics['lr']):.2e} "
                      f"({(time.time() - t0) / step:.2f}s/step)")
        if args.ckpt:
            ckpt_io.save(args.ckpt, {"params": params, "opt": opt_state})
            print(f"[train] checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
