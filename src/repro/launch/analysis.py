"""Compiled-artifact analysis: collective-byte extraction from lowered HLO
and the three-term roofline model (§Roofline of EXPERIMENTS.md).

  compute term    = HLO_FLOPs / (chips × peak_FLOP/s)
  memory term     = HLO_bytes / (chips × HBM_bw)
  collective term = collective_bytes / (chips × link_bw)

Hardware constants (TPU v5e, per the brief): 197 TFLOP/s bf16 per chip,
819 GB/s HBM, ~50 GB/s/link ICI.

IMPLEMENTATION NOTE (validated empirically): XLA's cost_analysis on a
GSPMD-partitioned module reports PER-DEVICE flops/bytes, and HLO shapes in
the partitioned module are per-device shards, so the terms below divide by
per-chip peaks directly (the "chips ×" in the formulas above is already
baked into the per-device numbers). The dry-run lowers with segment scans
UNROLLED because XLA counts while-loop bodies once regardless of trip
count. collective_bytes is NOT in cost_analysis — we parse the optimized
HLO and sum result-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, Optional

PEAK_FLOPS = 197e12         # bf16 / chip
HBM_BW = 819e9              # bytes/s / chip
LINK_BW = 50e9              # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one result shape, e.g. bf16[16,1024]{1,0} or f32[] — captures dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    bpe = _DTYPE_BYTES.get(dtype)
    if bpe is None:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * bpe


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind over the optimized HLO."""
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"^(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+)$", line)
        if not m:
            continue
        rhs = m.group(1)
        kind = None
        for k in _COLLECTIVES:
            # match the op name: "<shape(s)> all-gather(" / "all-gather-start("
            if re.search(rf"\)?\s{k}(?:-start)?\(", " " + rhs):
                kind = k
                break
        if kind is None:
            continue
        # result shape(s) are everything before the op name
        head = rhs.split(kind)[0]
        total = sum(_shape_bytes(d, dims)
                    for d, dims in _SHAPE_RE.findall(head))
        out[kind] += total
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_by_kind: Dict[str, int] = field(default_factory=dict)
    model_flops: float = 0.0
    peak_memory_per_device: Optional[float] = None

    @property
    def t_compute(self) -> float:
        # hlo_flops is per-device (see module docstring)
        return self.hlo_flops / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.hlo_bytes / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        per_dev_model = self.model_flops / self.n_chips
        return per_dev_model / self.hlo_flops if self.hlo_flops else 0.0

    def to_dict(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "n_chips": self.n_chips,
            "hlo_flops": self.hlo_flops, "hlo_bytes": self.hlo_bytes,
            "collective_bytes": self.coll_bytes,
            "collective_by_kind": self.coll_by_kind,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "t_compute_s": self.t_compute, "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "peak_memory_per_device": self.peak_memory_per_device,
        }


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS: 6·N·D for training, 2·N_active·D for inference forward
    (N = active params, D = processed tokens)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n_active * d
    if shape.kind == "prefill":
        d = shape.global_batch * shape.seq_len
        return 2.0 * n_active * d
    d = shape.global_batch * 1
    return 2.0 * n_active * d


def extract_cost(compiled) -> Dict[str, float]:
    """Pull flops/bytes from compiled.cost_analysis() with fallbacks."""
    flops = bytes_ = 0.0
    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, (list, tuple)):
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0))
        bytes_ = float(ca.get("bytes accessed", 0.0))
    except Exception:
        pass
    return {"flops": flops, "bytes": bytes_}


def extract_memory(compiled) -> Optional[float]:
    try:
        ma = compiled.memory_analysis()
        tot = (getattr(ma, "argument_size_in_bytes", 0)
               + getattr(ma, "output_size_in_bytes", 0)
               + getattr(ma, "temp_size_in_bytes", 0))
        return float(tot)
    except Exception:
        return None
