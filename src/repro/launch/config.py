"""ServeConfig: the one typed configuration behind every serving entry
point.

serve.py historically grew 37 loose argparse flags, and each new consumer
(benchmark drivers, now the HTTP front-end and its load generator)
re-derived its own subset with slightly different defaults.  ServeConfig
replaces that: a frozen-by-convention dataclass holding every serving
knob, with

  * ``add_arguments`` / ``from_args`` — the single argparse definition
    (serve.py and load_gen both call it, so flags can never drift),
  * ``validate`` — cross-field checks, raising ``ValueError`` with the
    offending field named,
  * ``to_json`` / ``from_json`` — lossless round-trip, so a benchmark run
    can record exactly the configuration it measured and the load
    generator can ship one to a remote server,
  * ``engine_kwargs`` / ``scheduler_kwargs`` / ``sim_kwargs`` /
    ``server_kwargs`` — the derived constructor argument dicts, i.e. the
    ONLY translation from flag namespace to constructor namespace,
  * ``engine_trace`` — the smoke-scale open-loop trace builder shared by
    serve.py replay, the load generator, and the CI smoke lane.

Everything here is declarative: no jax imports, no model construction —
importable by the thinnest client.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

from repro.configs import list_configs
from repro.core.base import SCHEDULERS
from repro.serving.metrics import SLOConfig
from repro.serving.traffic import (ARRIVAL_PROCESSES, DATASETS, ClassSpec,
                                   DatasetModel, LengthModel,
                                   attach_prompt_tokens, multi_class_trace)


@dataclass
class ServeConfig:
    # model / scheduler
    arch: str = "qwen3-30b-a3b"
    scheduler: str = "layered"
    smoke: bool = False
    simulate: bool = False
    # loop shape
    open_loop: bool = False
    clock: str = "virtual"              # virtual | wall
    stream: bool = False
    # traffic
    dataset: str = "arxiv"
    arrival: str = "poisson"
    rate: float = 1.3
    requests: int = 8
    batch_fraction: float = 0.0
    class_headroom: int = 0
    seed: int = 0
    # batching / memory
    slots: int = 64
    quantum: int = 512
    token_budget: int = 512
    max_len: int = 256
    pages: Optional[int] = None
    page_size: int = 16
    preemption: str = "on"              # on|off|recompute|swap|auto
    host_pages: Optional[int] = None
    host_bw: Optional[float] = None     # GB/s, simulator only
    swap_serial: bool = False
    swap_in_budget: Optional[int] = None
    decode_reserve: Optional[int] = None
    packed: bool = True
    # prefix cache
    prefix_cache: bool = True
    prefix_lru_pages: Optional[int] = None
    # disaggregated prefill/decode pools
    disagg: bool = False
    handoff: str = "stream"             # stream | whole
    decode_pages: Optional[int] = None
    decode_watermark: int = 0
    # MoE / speculation
    moe_dispatch: str = "ragged"
    spec: str = "off"                   # off|ngram|draft
    spec_k: int = 4
    draft_config: Optional[str] = None
    spec_acceptance: float = 0.7
    # hardware / SLO
    hw: str = "h100x2"
    ttft_slo: float = 10.0
    tbt_slo: float = 0.125
    # HTTP front-end
    http: Optional[str] = None          # "host:port" or ":port"
    queue_watermark: int = 64
    pool_watermark: float = 0.125
    ratelimit_rate: Optional[float] = None
    ratelimit_burst: float = 8.0
    keepalive_timeout: float = 5.0
    # fault tolerance / chaos (serving/faults.py)
    fault_plan: Optional[str] = None    # "@file.json" | "seed:N" | inline JSON
    deadline_ms: Optional[float] = None
    drain_timeout: float = 10.0
    retry_budget: int = 3

    # ------------------------------------------------------------ validation

    def validate(self) -> "ServeConfig":
        choices = {
            "arch": tuple(list_configs()),
            "scheduler": tuple(sorted(SCHEDULERS)),
            "clock": ("virtual", "wall"),
            "dataset": tuple(DATASETS),
            "arrival": tuple(sorted(ARRIVAL_PROCESSES)),
            "preemption": ("on", "off", "recompute", "swap", "auto"),
            "moe_dispatch": ("ragged", "dense"),
            "spec": ("off", "ngram", "draft"),
            "hw": ("h100x2", "tpu_v5e"),
            "handoff": ("stream", "whole"),
        }
        for name, opts in choices.items():
            if getattr(self, name) not in opts:
                raise ValueError(f"{name}={getattr(self, name)!r} "
                                 f"not one of {opts}")
        positive = ["rate", "requests", "slots", "quantum", "token_budget",
                    "max_len", "page_size", "spec_k", "ttft_slo", "tbt_slo",
                    "queue_watermark", "ratelimit_burst",
                    "keepalive_timeout", "drain_timeout"]
        for name in positive:
            if getattr(self, name) <= 0:
                raise ValueError(f"{name} must be positive "
                                 f"(got {getattr(self, name)})")
        for name in ("pages", "host_pages", "swap_in_budget",
                     "prefix_lru_pages", "host_bw", "ratelimit_rate",
                     "decode_pages", "deadline_ms"):
            v = getattr(self, name)
            if v is not None and v <= 0:
                raise ValueError(f"{name} must be positive or None "
                                 f"(got {v})")
        for name in ("batch_fraction", "pool_watermark"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"{name} must be in [0, 1] (got {v})")
        if not 0.0 < self.spec_acceptance <= 1.0:
            raise ValueError(f"spec_acceptance must be in (0, 1] "
                             f"(got {self.spec_acceptance})")
        if self.class_headroom < 0 or self.decode_reserve is not None \
                and self.decode_reserve < 0:
            raise ValueError("class_headroom/decode_reserve must be >= 0")
        if self.decode_watermark < 0:
            raise ValueError(f"decode_watermark must be >= 0 "
                             f"(got {self.decode_watermark})")
        if self.retry_budget < 0:
            raise ValueError(f"retry_budget must be >= 0 "
                             f"(got {self.retry_budget})")
        if self.disagg and self.http is not None:
            raise ValueError("--disagg runs the two-pool trace drivers; "
                             "it cannot be combined with --http")
        if self.spec == "draft" and not self.draft_config:
            raise ValueError("spec='draft' needs draft_config")
        if self.http is not None:
            self.http_endpoint()        # raises on malformed host:port
        if self.simulate and self.http is not None:
            raise ValueError("--http serves the real engine; "
                             "it cannot be combined with --simulate")
        return self

    # ---------------------------------------------------------- persistence

    def to_json(self) -> str:
        return json.dumps(dataclasses.asdict(self), indent=2,
                          sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ServeConfig":
        d = json.loads(text)
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            raise ValueError(f"unknown ServeConfig fields: "
                             f"{sorted(unknown)}")
        return cls(**d).validate()

    # -------------------------------------------------------------- argparse

    @staticmethod
    def add_arguments(ap: argparse.ArgumentParser) -> None:
        """THE flag definition — serve.py and load_gen share it verbatim."""
        d = ServeConfig()
        ap.add_argument("--arch", default=d.arch, choices=list_configs())
        ap.add_argument("--scheduler", default=d.scheduler,
                        choices=sorted(SCHEDULERS))
        ap.add_argument("--smoke", action="store_true")
        ap.add_argument("--simulate", action="store_true")
        ap.add_argument("--open-loop", action="store_true",
                        help="real engine: replay a timed trace through "
                             "the shared ServingRuntime (requests injected "
                             "at their arrival times) instead of the "
                             "closed-loop submit-everything drain")
        ap.add_argument("--clock", default=d.clock,
                        choices=["virtual", "wall"],
                        help="open-loop engine clock: virtual (1 unit per "
                             "iteration, deterministic) or wall (arrival "
                             "times in real seconds; idles really sleep)")
        ap.add_argument("--stream", action="store_true",
                        help="print every generated token as it is "
                             "emitted (the incremental-output API)")
        ap.add_argument("--dataset", default=d.dataset,
                        choices=list(DATASETS))
        ap.add_argument("--arrival", default=d.arrival,
                        choices=sorted(ARRIVAL_PROCESSES),
                        help="arrival process (bursty = on/off modulated "
                             "Poisson with the same long-run rate)")
        ap.add_argument("--rate", type=float, default=d.rate)
        ap.add_argument("--requests", type=int, default=d.requests)
        ap.add_argument("--batch-fraction", type=float,
                        default=d.batch_fraction,
                        help="fraction of requests tagged slo_class=batch "
                             "(evicted before interactive under memory "
                             "pressure)")
        ap.add_argument("--class-headroom", type=int,
                        default=d.class_headroom,
                        help="pages reserved for interactive admissions")
        ap.add_argument("--slots", type=int, default=d.slots)
        ap.add_argument("--quantum", type=int, default=d.quantum)
        ap.add_argument("--token-budget", type=int, default=d.token_budget)
        ap.add_argument("--max-len", type=int, default=d.max_len)
        ap.add_argument("--pages", type=int, default=d.pages,
                        help="paged KV pool size in pages (default: "
                             "engine fills every slot row; simulator "
                             "sizes from HBM capacity minus weights)")
        ap.add_argument("--page-size", type=int, default=d.page_size,
                        help="KV tokens per page")
        ap.add_argument("--preemption", default=d.preemption,
                        choices=["on", "off", "recompute", "swap", "auto"],
                        help="memory-pressure eviction mode: recompute "
                             "(= on), swap (KV pages to host, DMA-back "
                             "restore), auto (per-victim cost crossover), "
                             "off (queueing-only admission)")
        ap.add_argument("--host-pages", type=int, default=d.host_pages,
                        help="host-side swap pool size in pages (default: "
                             "4x the device pool when swap/auto)")
        ap.add_argument("--host-bw", type=float, default=d.host_bw,
                        help="host<->HBM DMA bandwidth in GB/s "
                             "(simulator only)")
        ap.add_argument("--swap-serial", action="store_true",
                        help="charge swap DMA as a fully serial stall "
                             "(simulator only)")
        ap.add_argument("--swap-in-budget", type=int,
                        default=d.swap_in_budget,
                        help="max KV tokens DMA'd back from host per "
                             "iteration (default: unlimited)")
        ap.add_argument("--decode-reserve", type=int,
                        default=d.decode_reserve,
                        help="per-request decode KV reservation in tokens "
                             "(default: one page)")
        ap.add_argument("--packed", default=d.packed,
                        action=argparse.BooleanOptionalAction,
                        help="packed layer-group execution (one jitted "
                             "slot-vector batch per rectangle); "
                             "--no-packed dispatches per slice")
        ap.add_argument("--prefix-cache", default=d.prefix_cache,
                        action=argparse.BooleanOptionalAction,
                        help="automatic prefix caching over a refcounted "
                             "content-hash page index; --no-prefix-cache "
                             "restores cold prefill")
        ap.add_argument("--prefix-lru-pages", type=int,
                        default=d.prefix_lru_pages,
                        help="cap on retained refcount-0 cached pages "
                             "(default: unbounded)")
        ap.add_argument("--disagg", action="store_true",
                        help="disaggregated serving: a prefill pool and a "
                             "decode pool under one clock, with KV handed "
                             "off over a modelled interconnect")
        ap.add_argument("--handoff", default=d.handoff,
                        choices=["stream", "whole"],
                        help="KV handoff granularity: stream each layer "
                             "group's pages as its prefill completes "
                             "(overlapping the link with the remaining "
                             "groups' compute) or ship the whole prompt "
                             "after the last group")
        ap.add_argument("--decode-pages", type=int, default=d.decode_pages,
                        help="decode-pool KV pages (default: same as the "
                             "prefill pool)")
        ap.add_argument("--decode-watermark", type=int,
                        default=d.decode_watermark,
                        help="hold migrations while decode-pool free "
                             "pages are at or below this watermark")
        ap.add_argument("--moe-dispatch", default=d.moe_dispatch,
                        choices=["ragged", "dense"],
                        help="dropless MoE data path")
        ap.add_argument("--spec", default=d.spec,
                        choices=["off", "ngram", "draft"],
                        help="speculative verify-k decoding; greedy "
                             "output streams stay bit-identical")
        ap.add_argument("--spec-k", type=int, default=d.spec_k,
                        help="max drafted tokens verified per request "
                             "per iteration")
        ap.add_argument("--draft-config", default=d.draft_config,
                        help="config whose smoke variant drafts for "
                             "--spec draft")
        ap.add_argument("--spec-acceptance", type=float,
                        default=d.spec_acceptance,
                        help="simulator only: per-token draft acceptance "
                             "probability")
        ap.add_argument("--hw", default=d.hw,
                        choices=["h100x2", "tpu_v5e"])
        ap.add_argument("--ttft-slo", type=float, default=d.ttft_slo)
        ap.add_argument("--tbt-slo", type=float, default=d.tbt_slo)
        ap.add_argument("--seed", type=int, default=d.seed)
        ap.add_argument("--http", default=d.http, metavar="HOST:PORT",
                        help="serve the engine over HTTP/SSE on this "
                             "endpoint (e.g. :8000 or 127.0.0.1:8000) "
                             "instead of running a trace")
        ap.add_argument("--queue-watermark", type=int,
                        default=d.queue_watermark,
                        help="HTTP backpressure: queue depth at which "
                             "(with the pool watermark) admission "
                             "answers 429")
        ap.add_argument("--pool-watermark", type=float,
                        default=d.pool_watermark,
                        help="HTTP backpressure: free-page fraction at "
                             "or below which (with the queue watermark) "
                             "admission answers 429")
        ap.add_argument("--ratelimit-rate", type=float,
                        default=d.ratelimit_rate,
                        help="per-tenant token-bucket refill rate in "
                             "requests/s (default: rate limiting off)")
        ap.add_argument("--ratelimit-burst", type=float,
                        default=d.ratelimit_burst,
                        help="per-tenant token-bucket burst capacity")
        ap.add_argument("--keepalive-timeout", type=float,
                        default=d.keepalive_timeout,
                        help="seconds an idle keep-alive connection is "
                             "held open before the server closes it")
        ap.add_argument("--fault-plan", default=d.fault_plan,
                        help="chaos mode: a FaultPlan spec — '@plan.json' "
                             "loads a file, 'seed:N' draws a deterministic "
                             "random schedule, anything else parses as "
                             "inline JSON (default: no fault injection)")
        ap.add_argument("--deadline-ms", type=float, default=d.deadline_ms,
                        help="default per-request completion deadline; "
                             "expired requests are shed and their KV "
                             "freed (wall clocks: ms; virtual clocks: "
                             "clock units; default: no deadline)")
        ap.add_argument("--drain-timeout", type=float,
                        default=d.drain_timeout,
                        help="graceful-drain bound: seconds the HTTP "
                             "server waits for in-flight streams before "
                             "cancelling them on shutdown")
        ap.add_argument("--retry-budget", type=int, default=d.retry_budget,
                        help="fault recoveries (crash/link-drop "
                             "recomputes) per request before it is shed")

    @classmethod
    def from_args(cls, args: argparse.Namespace) -> "ServeConfig":
        known = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in vars(args).items()
                      if k in known}).validate()

    # ------------------------------------------------------- derived kwargs

    def preemption_opts(self) -> Tuple[bool, str]:
        """(enabled, mode): "on" is a legacy alias for "recompute"; "off"
        disables eviction entirely (queueing-only admission)."""
        enabled = self.preemption != "off"
        mode = self.preemption if self.preemption in ("swap", "auto") \
            else "recompute"
        return enabled, mode

    def class_headroom_opt(self) -> Optional[Dict[str, int]]:
        return {"interactive": self.class_headroom} \
            if self.class_headroom else None

    def scheduler_kwargs(self) -> Dict:
        return dict(n_slots=self.slots, quantum=self.quantum,
                    token_budget=self.token_budget)

    def engine_kwargs(self) -> Dict:
        """Engine(...) keyword arguments (model/params/scheduler are the
        caller's three positionals)."""
        enabled, mode = self.preemption_opts()
        return dict(n_slots=self.slots, max_len=self.max_len,
                    moe_dispatch=self.moe_dispatch,
                    pages=self.pages, page_size=self.page_size,
                    preemption=enabled, preemption_mode=mode,
                    host_pages=self.host_pages,
                    swap_in_budget=self.swap_in_budget,
                    decode_reserve=self.decode_reserve,
                    class_headroom=self.class_headroom_opt(),
                    packed=self.packed,
                    prefix_cache=self.prefix_cache,
                    prefix_lru_pages=self.prefix_lru_pages,
                    spec_mode=self.spec, spec_k=self.spec_k,
                    draft_config=self.draft_config)

    def sim_kwargs(self) -> Dict:
        """Simulator(...) keyword arguments (cfg/scheduler/hw are the
        caller's three positionals)."""
        enabled, mode = self.preemption_opts()
        return dict(n_slots=self.slots, quantum=self.quantum,
                    token_budget=self.token_budget,
                    moe_dispatch=self.moe_dispatch,
                    n_pages=self.pages, page_size=self.page_size,
                    preemption=enabled, preemption_mode=mode,
                    host_pages=self.host_pages,
                    swap_in_budget=self.swap_in_budget,
                    decode_reserve=self.decode_reserve,
                    swap_overlap=not self.swap_serial,
                    class_headroom=self.class_headroom_opt(),
                    prefix_cache=self.prefix_cache,
                    prefix_lru_pages=self.prefix_lru_pages,
                    spec_mode=self.spec, spec_k=self.spec_k,
                    spec_acceptance=self.spec_acceptance)

    def http_endpoint(self) -> Tuple[str, int]:
        """Parse --http "host:port" (":8000" binds 127.0.0.1; port 0 asks
        the OS for a free port — the CI smoke lane uses that)."""
        if self.http is None:
            raise ValueError("http endpoint not configured")
        host, _, port = self.http.rpartition(":")
        try:
            return host or "127.0.0.1", int(port)
        except ValueError:
            raise ValueError(f"--http must be HOST:PORT or :PORT "
                             f"(got {self.http!r})") from None

    def server_kwargs(self) -> Dict:
        """ServingServer(...) keyword arguments (engine is positional)."""
        host, port = self.http_endpoint()
        return dict(host=host, port=port,
                    ratelimit_rate=self.ratelimit_rate,
                    ratelimit_burst=self.ratelimit_burst,
                    queue_watermark=self.queue_watermark,
                    pool_watermark=self.pool_watermark,
                    keepalive_timeout=self.keepalive_timeout,
                    deadline_ms=self.deadline_ms,
                    drain_timeout=self.drain_timeout,
                    retry_budget=self.retry_budget,
                    slo=self.slo())

    def slo(self) -> SLOConfig:
        return SLOConfig(self.ttft_slo, self.tbt_slo)

    # ---------------------------------------------------------- smoke trace

    def engine_trace(self, vocab_size: int):
        """Open-loop trace for the smoke-scale engine, built with the SAME
        traffic generators as the simulator (``arrival`` selects the
        process, ``batch_fraction`` the class mix) but with a length model
        shrunk to the engine's max_len, and real token ids attached for
        replay.  ``rate`` is requests per unit of the selected clock."""
        smoke = DatasetModel(
            name="engine-smoke",
            input_len=LengthModel(mean=self.max_len // 6,
                                  std=self.max_len // 8,
                                  lo=16, hi=self.max_len // 2),
            output_len=LengthModel(mean=9, std=4, lo=4, hi=15))
        n_batch = int(round(self.requests * self.batch_fraction))
        specs = [ClassSpec("batch", smoke,
                           self.rate * self.batch_fraction,
                           n_batch, process=self.arrival)] if n_batch \
            else []
        if self.requests - n_batch:
            specs.append(ClassSpec(
                "interactive", smoke,
                self.rate * (1 - self.batch_fraction),
                self.requests - n_batch,
                process=self.arrival if not n_batch else "poisson"))
        trace = multi_class_trace(specs, seed=self.seed)
        return attach_prompt_tokens(trace, vocab_size, seed=self.seed)
