"""Step functions lowered by the dry-run and used by the launchers.

One factory per input-shape kind:
  train   : (params, opt_state, batch)        -> (params, opt_state, metrics)
  prefill : (params, cache, batch)            -> (last_logits, cache)
  decode  : (params, cache, batch)            -> (logits, cache)

All are mesh-agnostic; distribution comes from in_shardings (params/cache)
plus the shard_hint constraints inside the model.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.shapes import InputShape
from repro.models.model import DecoderModel
from repro.training.optimizer import AdamW, adamw
from repro.training.train import make_train_step


def make_step_fn(model: DecoderModel, shape: InputShape,
                 opt: Optional[AdamW] = None) -> Callable:
    cfg = model.cfg
    if shape.kind == "train":
        opt = opt or adamw(lr=3e-4, schedule="cosine", total_steps=1000,
                           warmup=100)
        return make_train_step(model, opt, cfg.encoder.enabled)

    if shape.kind == "prefill":
        def prefill_step(params, cache, batch):
            tokens = batch["tokens"]
            b = tokens.shape[0]
            offset = jnp.zeros((b,), jnp.int32)
            enc_frames = batch.get("enc_out")
            enc_out = (model.encode(params, enc_frames)
                       if enc_frames is not None else None)
            logits, cache, _ = model.forward(
                params, tokens, cache=cache, offset=offset, enc_out=enc_out,
                extra_embeds=batch.get("extra_embeds"))
            return logits[:, -1], cache
        return prefill_step

    def decode_step(params, cache, batch):
        logits, cache, _ = model.forward(
            params, batch["tokens"], cache=cache, offset=batch["offsets"])
        return logits[:, -1], cache
    return decode_step


def make_layered_step_fn(model: DecoderModel, *, group: tuple,
                         prefill_len: int):
    """The paper's fused iteration: decode one token for the whole batch
    across ALL blocks while prefilling ``prefill_len`` tokens of one request
    through blocks [group[0], group[1]). Lowered by the dry-run for the
    paper's own models to prove the layered schedule shards."""
    b0, b1 = group

    def layered_step(params, cache, batch):
        from repro.serving.engine import _scatter_cache, _slice_cache
        # decode part (all blocks)
        logits, cache, _ = model.forward(
            params, batch["tokens"], cache=cache, offset=batch["offsets"],
            valid=batch["valid"][:, None])
        # prefill part (one layer group over slot 0's cache row, boundary
        # activations in/out — the layered-prefill carry state)
        hidden = batch["hidden"]        # (1, prefill_len, d)
        positions = jnp.arange(prefill_len, dtype=jnp.int32)[None]
        offset = jnp.zeros((1,), jnp.int32)
        row = _slice_cache(cache, jnp.int32(0))
        h_out, row, _ = model.run_blocks(
            params, hidden, b0, b1 - b0, positions=positions, offset=offset,
            cache=row)
        cache = _scatter_cache(cache, row, jnp.int32(0))
        return logits[:, -1], h_out, cache
    return layered_step
