"""The HTTP/SSE serving front-end (serving/server.py), its rate limiter,
and the unified SubmitSpec/ServeConfig ingestion API.

The acceptance bar for the live server is the PR-2 token-identity
invariant lifted to HTTP: requests submitted CONCURRENTLY over sockets
while the engine loop runs on its own wall-clock thread must produce
token streams bit-identical to an offline iteration-clock replay of the
same trace on a fresh engine — under memory pressure, in BOTH preemption
flavours.  Wall-clock nondeterminism may reorder admissions and change
every latency; it must never change a token.
"""

from __future__ import annotations

import asyncio

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, settings, strategies as st

from conftest import tiny_dense
from repro.core.base import make_scheduler
from repro.core.plan import Request, SubmitSpec
from repro.launch.config import ServeConfig
from repro.launch.load_gen import (_fetch, _post_generate, run_load,
                                   verify_identity)
from repro.models.model import DecoderModel
from repro.serving.engine import Engine
from repro.serving.ratelimit import TenantRateLimiter, TokenBucket
from repro.serving.runtime import EngineExecutor, ServingRuntime
from repro.serving.server import ServingServer
from repro.serving.traffic import TraceRequest


def _make_engine(**eng_kw):
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                           quantum=8, token_budget=16)
    return Engine(model, params, sched, n_slots=4, max_len=64, **eng_kw)


def _trace(n=12, seed=0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        n_tok = int(rng.integers(4, 10))
        out.append(TraceRequest(
            arrival_time=float(i), prompt_len=n_tok,
            output_len=int(rng.integers(6, 11)),
            slo_class="batch" if i % 3 == 0 else "interactive",
            prompt_tokens=tuple(int(x)
                                for x in rng.integers(1, 200, n_tok))))
    return out


def _offline_tokens(trace, **eng_kw):
    eng = _make_engine(**eng_kw)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    res = rt.run(trace, max_iterations=100_000)
    return [list(eng.outputs[r.req_id]) for r in res.requests]


async def _with_server(body, **server_kw):
    """Start a ServingServer on an OS port, run ``body(srv)``, stop."""
    eng = server_kw.pop("engine", None) or _make_engine(
        **server_kw.pop("engine_kw", {}))
    srv = ServingServer(eng, port=0, **server_kw)
    await srv.start()
    try:
        return await body(srv)
    finally:
        await srv.stop()


# ------------------------------------------------------- token identity


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_concurrent_http_submit_matches_offline_replay(mode):
    """Concurrent socket submissions during a live wall-clock run, under
    an oversubscribed pool that really evicts, must stream tokens
    bit-identical to the offline iteration-clock replay — both modes."""
    kw = dict(pages=16, page_size=4, decode_reserve=1,
              preemption_mode=mode)
    trace = _trace(n=10)
    offline = _offline_tokens(trace, **kw)

    async def body(srv):
        report = await run_load(srv.host, srv.port, trace, n_clients=5)
        eng = srv.engine
        assert (eng.n_preempted + eng.n_swapped_out) >= 0
        return report

    report = asyncio.run(_with_server(body, engine_kw=kw))
    assert all(r.status == 200 for r in report.results)
    assert verify_identity(report, offline) == 0


def test_sse_stream_order_matches_on_token_order():
    """Per-request SSE token events must arrive in exactly the engine's
    on_token emission order, contiguously indexed from 0, and equal the
    done event's full list and the engine's recorded outputs."""
    async def body(srv):
        tr = _trace(n=1)[0]
        status, _, events = await _post_generate(
            srv.host, srv.port,
            {"prompt_tokens": list(tr.prompt_tokens),
             "max_new_tokens": tr.output_len})
        assert status == 200
        toks = [d["token"] for k, d in events if k == "token"]
        idxs = [d["index"] for k, d in events if k == "token"]
        done = [d for k, d in events if k == "done"]
        assert idxs == list(range(len(toks)))
        assert len(done) == 1 and events[-1][0] == "done"
        assert toks == done[0]["tokens"]
        rid = done[0]["req_id"]
        # the server's token_log is appended inside on_token itself
        assert [t for r, t in srv.token_log if r == rid] == toks
        assert list(srv.engine.outputs[rid]) == toks

    asyncio.run(_with_server(body))


def test_non_streaming_json_response():
    async def body(srv):
        tr = _trace(n=1)[0]
        status, _, events = await _post_generate(
            srv.host, srv.port,
            {"prompt_tokens": list(tr.prompt_tokens),
             "max_new_tokens": tr.output_len, "stream": False})
        assert status == 200
        kind, doc = events[0]
        assert kind == "json"
        assert doc["tokens"] == list(srv.engine.outputs[doc["req_id"]])
        assert doc["n_generated"] == len(doc["tokens"])

    asyncio.run(_with_server(body))


# --------------------------------------------------------- backpressure


def test_backpressure_429_with_retry_after():
    """Watermarks set to 'always overloaded' must answer 429 with a
    positive integer Retry-After and never enqueue the request."""
    async def body(srv):
        tr = _trace(n=1)[0]
        status, headers, events = await _post_generate(
            srv.host, srv.port,
            {"prompt_tokens": list(tr.prompt_tokens),
             "max_new_tokens": 4})
        assert status == 429
        assert int(headers["retry-after"]) >= 1
        assert events[0][1]["error"] == "overloaded"
        assert len(srv.engine.requests) == 0

    asyncio.run(_with_server(body, queue_watermark=0, pool_watermark=1.0))


def test_backpressure_429_under_oversubscribed_pool():
    """Organic overload: a 16-page pool holding ~2 residents with 8
    long-running concurrent streams must trip the queue+pool watermark
    and 429 a probe request while saturated — and still complete every
    admitted stream correctly afterwards."""
    kw = dict(pages=16, page_size=4, decode_reserve=1)
    trace = _trace(n=8, seed=3)
    offline = _offline_tokens(trace, **kw)

    async def body(srv):
        streams = [asyncio.ensure_future(_post_generate(
            srv.host, srv.port,
            {"prompt_tokens": list(tr.prompt_tokens),
             "max_new_tokens": tr.output_len, "tag": i}))
            for i, tr in enumerate(trace)]
        saw_429 = None
        probe = {"prompt_tokens": [1, 2, 3], "max_new_tokens": 4,
                 "stream": False}
        for _ in range(200):
            status, headers, _ = await _post_generate(
                srv.host, srv.port, probe)
            if status == 429:
                saw_429 = headers
                break
            await asyncio.sleep(0.01)
        done = await asyncio.gather(*streams)
        assert saw_429 is not None, "never saturated"
        assert int(saw_429["retry-after"]) >= 1
        by_tag = {}
        for status, _, events in done:
            assert status == 200
            final = [d for k, d in events if k == "done"][0]
            by_tag[final["tag"]] = [d["token"] for k, d in events
                                    if k == "token"]
        for i in range(len(trace)):
            assert by_tag[i] == offline[i], i

    asyncio.run(_with_server(body, engine_kw=kw,
                             queue_watermark=2, pool_watermark=0.9))


def test_ratelimit_429_per_tenant():
    """burst=1: a tenant's second immediate request is rate-limited, a
    DIFFERENT tenant's is not; Retry-After reflects the refill deficit."""
    async def body(srv):
        tr = _trace(n=1)[0]
        payload = {"prompt_tokens": list(tr.prompt_tokens),
                   "max_new_tokens": 4, "tenant": "a", "stream": False}
        s1, _, _ = await _post_generate(srv.host, srv.port, payload)
        s2, h2, ev2 = await _post_generate(srv.host, srv.port, payload)
        s3, _, _ = await _post_generate(
            srv.host, srv.port, dict(payload, tenant="b"))
        assert (s1, s2, s3) == (200, 429, 200)
        assert ev2[0][1]["error"] == "rate limited"
        assert int(h2["retry-after"]) >= 1
        counters = srv.limiter.counters()
        assert counters["a"]["rejected"] == 1
        assert counters["b"]["granted"] == 1

    asyncio.run(_with_server(body, ratelimit_rate=0.01,
                             ratelimit_burst=1.0))


def test_bad_request_400_and_metrics_and_healthz():
    async def body(srv):
        status, _, events = await _post_generate(
            srv.host, srv.port, {"max_new_tokens": 4})   # no prompt
        assert status == 400 and "bad request" in events[0][1]["error"]
        tr = _trace(n=1)[0]
        status, _, _ = await _post_generate(
            srv.host, srv.port,
            {"prompt_tokens": list(tr.prompt_tokens),
             "max_new_tokens": 4, "stream": False})
        assert status == 200
        status, body_bytes = await _fetch(srv.host, srv.port, "/metrics")
        text = body_bytes.decode()
        assert status == 200
        for family in ("repro_requests_completed", "repro_ttft",
                       "repro_tbt", "repro_queue_depth",
                       "repro_kv_pages_total",
                       "repro_http_responses_total"):
            assert family in text, family
        assert 'quantile="0.99"' in text
        assert "nan" not in text.lower()
        status, _ = await _fetch(srv.host, srv.port, "/healthz")
        assert status == 200
        status, _ = await _fetch(srv.host, srv.port, "/nope")
        assert status == 404

    asyncio.run(_with_server(body))


# ------------------------------------------------------------- keep-alive


async def _raw(writer, reader, method, path, payload=None, keep=True):
    """One request over an ALREADY-OPEN connection; returns (status,
    headers, body) parsed by Content-Length so the socket can be reused."""
    import json as _json
    body = b"" if payload is None else _json.dumps(payload).encode()
    head = f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
    if keep:
        head += "Connection: keep-alive\r\n"
    if body:
        head += f"Content-Length: {len(body)}\r\n"
    writer.write(head.encode() + b"\r\n" + body)
    await writer.drain()
    status = int((await reader.readline()).split()[1])
    headers = {}
    while True:
        line = await reader.readline()
        if line in (b"\r\n", b"\n", b""):
            break
        k, _, v = line.decode().partition(":")
        headers[k.strip().lower()] = v.strip()
    n = int(headers.get("content-length", "0") or 0)
    return status, headers, await reader.readexactly(n) if n else b""


def test_http_keepalive_reuses_connection():
    """Connection: keep-alive must serve multiple requests over ONE
    socket — mixed POST /v1/generate (non-streaming) and GETs — with the
    keep-alive echoed in every response; a request WITHOUT the header
    gets Connection: close and the server really closes."""
    async def body(srv):
        tr = _trace(n=1)[0]
        payload = {"prompt_tokens": list(tr.prompt_tokens),
                   "max_new_tokens": 4, "stream": False}
        reader, writer = await asyncio.open_connection(srv.host, srv.port)
        for _ in range(2):
            status, headers, doc = await _raw(
                writer, reader, "POST", "/v1/generate", payload)
            assert status == 200
            assert headers["connection"] == "keep-alive"
            assert b"tokens" in doc
        status, headers, _ = await _raw(writer, reader, "GET", "/healthz")
        assert status == 200 and headers["connection"] == "keep-alive"
        # same socket, no keep-alive header: the server answers then
        # closes the connection
        status, headers, _ = await _raw(writer, reader, "GET", "/healthz",
                                        keep=False)
        assert status == 200 and headers["connection"] == "close"
        assert await reader.read() == b""          # EOF
        writer.close()
        # the whole exchange used ONE connection: 4 responses served
        assert srv._status_counts[200] == 4

    asyncio.run(_with_server(body))


def test_http_keepalive_idle_timeout_closes():
    """An idle keep-alive connection must be closed once
    ``keepalive_timeout`` expires — idle sockets cannot pin the server."""
    async def body(srv):
        reader, writer = await asyncio.open_connection(srv.host, srv.port)
        status, headers, _ = await _raw(writer, reader, "GET", "/healthz")
        assert status == 200 and headers["connection"] == "keep-alive"
        got = await asyncio.wait_for(reader.read(), timeout=5.0)
        assert got == b""                          # server-side close
        writer.close()

    asyncio.run(_with_server(body, keepalive_timeout=0.2))


# ----------------------------------------------------------- rate limiter


@settings(max_examples=30)
@given(st.floats(0.1, 50.0), st.floats(0.5, 20.0),
       st.lists(st.tuples(st.floats(0.0, 2.0), st.floats(0.1, 3.0)),
                min_size=1, max_size=40))
def test_token_bucket_conservation(rate, burst, steps):
    """Over ANY acquire sequence spanning T seconds a bucket can never
    grant more than burst + rate*T tokens' worth of cost, and a rejection
    reports exactly the time until the deficit refills."""
    now = [0.0]
    tb = TokenBucket(rate, burst, clock=lambda: now[0])
    granted_cost = 0.0
    for dt, cost in steps:
        now[0] += dt
        cost = min(cost, burst)
        wait = tb.acquire(cost)
        if wait == 0.0:
            granted_cost += cost
        else:
            # deficit accounting is exact: after `wait` more seconds
            # (plus float-rounding dust) the same cost must be granted
            now[0] += wait + 1e-9
            assert tb.acquire(cost) == 0.0
            granted_cost += cost
    assert granted_cost <= burst + rate * now[0] + 1e-6


def test_token_bucket_validation_and_tenants():
    with pytest.raises(ValueError):
        TokenBucket(0.0, 1.0)
    with pytest.raises(ValueError):
        TokenBucket(1.0, -1.0)
    tb = TokenBucket(1.0, 2.0, clock=lambda: 0.0)
    with pytest.raises(ValueError):
        tb.acquire(3.0)                     # can never fit the burst
    now = [0.0]
    rl = TenantRateLimiter(1.0, 1.0, clock=lambda: now[0])
    assert rl.acquire("x") == 0.0
    assert rl.acquire("x") > 0.0            # x drained
    assert rl.acquire("y") == 0.0           # y fresh
    now[0] += 1.0
    assert rl.acquire("x") == 0.0           # refilled


# ------------------------------------------------- SubmitSpec / ServeConfig


def test_submit_spec_unifies_ingestion_paths():
    """TraceRequest.to_spec, Engine.submit (legacy), Engine.submit_spec
    and the HTTP body all converge on the same frozen SubmitSpec."""
    tr = _trace(n=1)[0]
    spec = tr.to_spec()
    assert spec.prompt_len == tr.prompt_len
    assert spec.prompt_tokens == tr.prompt_tokens
    assert spec.arrival_time == tr.arrival_time
    assert spec.tenant == spec.slo_class    # tenant defaults to the class

    with pytest.raises(ValueError):
        SubmitSpec(max_new_tokens=0, prompt_len=4)
    with pytest.raises(ValueError):
        SubmitSpec(max_new_tokens=4)        # no length at all
    s = SubmitSpec(max_new_tokens=4, prompt_tokens=[1, 2, 3])
    assert s.prompt_len == 3 and isinstance(s.prompt_tokens, tuple)

    eng = _make_engine()
    rid_legacy = eng.submit([1, 2, 3, 4], max_new_tokens=5,
                            slo_class="batch")
    req = eng.submit_spec(SubmitSpec(
        max_new_tokens=5, prompt_tokens=(1, 2, 3, 4), slo_class="batch"))
    legacy, unified = eng.requests[rid_legacy], req
    assert (legacy.prompt_len, legacy.max_new_tokens, legacy.slo_class) \
        == (unified.prompt_len, unified.max_new_tokens, unified.slo_class)
    # per-request opt-outs ride the spec
    r2 = eng.submit_spec(SubmitSpec(
        max_new_tokens=4, prompt_tokens=(5, 6, 7), prefix_cache=False,
        speculative=False))
    assert r2.cacheable_prompt is None and not r2.use_speculation
    with pytest.raises(ValueError):
        eng.submit_spec(SubmitSpec(max_new_tokens=4, prompt_len=8))


def test_request_from_spec_round_trip():
    spec = SubmitSpec(max_new_tokens=6, prompt_tokens=(9, 8, 7),
                      slo_class="batch", tenant="acme",
                      arrival_time=3.5)
    r = Request.from_spec(spec, req_id=7, arrival_time=spec.arrival_time)
    assert (r.req_id, r.prompt_len, r.max_new_tokens) == (7, 3, 6)
    assert (r.slo_class, r.tenant, r.arrival_time) \
        == ("batch", "acme", 3.5)


def test_serve_config_round_trip_and_validation():
    sc = ServeConfig(arch="qwen3-30b-a3b", scheduler="layered",
                     rate=2.5, requests=16, batch_fraction=0.25,
                     pages=64, preemption="swap", spec="ngram",
                     http=":8000", ratelimit_rate=4.0).validate()
    sc2 = ServeConfig.from_json(sc.to_json())
    assert sc2 == sc
    assert sc2.http_endpoint() == ("127.0.0.1", 8000)
    enabled, mode = sc2.preemption_opts()
    assert enabled and mode == "swap"
    ek, sk = sc2.engine_kwargs(), sc2.sim_kwargs()
    assert ek["preemption_mode"] == sk["preemption_mode"] == "swap"
    assert ek["pages"] == sk["n_pages"] == 64
    assert sk["spec_mode"] == "ngram"

    for bad in (dict(scheduler="nope"), dict(rate=0.0),
                dict(batch_fraction=1.5), dict(preemption="maybe"),
                dict(http="not-an-endpoint"),
                dict(spec="draft"),            # draft needs draft_config
                dict(simulate=True, http=":1")):
        with pytest.raises(ValueError):
            ServeConfig(**bad).validate()
    with pytest.raises(ValueError):
        ServeConfig.from_json('{"no_such_field": 1}')


def test_serve_config_argparse_matches_fields():
    import argparse
    ap = argparse.ArgumentParser()
    ServeConfig.add_arguments(ap)
    args = ap.parse_args([
        "--smoke", "--scheduler", "layered", "--requests", "9",
        "--preemption", "swap", "--no-prefix-cache",
        "--http", ":0", "--ratelimit-rate", "3",
        "--queue-watermark", "7", "--pool-watermark", "0.5"])
    sc = ServeConfig.from_args(args)
    assert (sc.smoke, sc.requests, sc.preemption) == (True, 9, "swap")
    assert not sc.prefix_cache
    assert (sc.queue_watermark, sc.pool_watermark) == (7, 0.5)
    # every dataclass field is settable from the CLI namespace
    import dataclasses as dc
    missing = {f.name for f in dc.fields(ServeConfig)} - set(vars(args))
    assert not missing, missing
