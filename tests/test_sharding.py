"""Sharding rules: parameter partition specs per family, divisibility
guards, and a real subprocess dry-run (the 512-device multi-pod config)."""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.sharding.partition import (default_rules, shard_hint,
                                      sharding_context, spec_for_path)

RULES_16 = {  # what default_rules produces on the (16, 16) mesh
    "batch": ("data",), "data": ("data",), "expert": ("model",),
    "expert_inner": ("data",), "tp": ("model",), "vocab": ("model",),
    "seq": ("model",), None: None,
}


@pytest.mark.parametrize("path,ndim,want", [
    ("segments/0/pattern/0/moe/w_gate", 3, P("model", None, "data")),
    ("segments/0/pattern/0/moe/w_down", 3, P("model", "data", None)),
    ("segments/0/pattern/0/moe/router", 2, P(None, None)),
    ("segments/0/pattern/0/mlp/w_up", 3, P(None, None, "model")),  # stacked
    ("segments/0/pattern/0/mlp/w_down", 2, P("model", None)),
    ("segments/0/pattern/0/attn/w_q", 2, P(None, "model")),
    ("segments/0/pattern/0/attn/w_o", 2, P("model", None)),
    ("segments/0/pattern/0/attn/w_dkv", 2, P(None, None)),     # MLA compress
    ("segments/0/pattern/0/attn/w_uq", 2, P(None, "model")),   # MLA decompress
    ("embed/tok", 2, P("model", None)),
    ("segments/0/pattern/0/ln1/scale", 1, P()),                # replicated
    ("segments/0/pattern/0/rglru/w_in", 2, P(None, "model")),
    ("segments/0/pattern/0/rglru/a_param", 1, P()),
])
def test_param_rules(path, ndim, want):
    got = spec_for_path(path, ndim, RULES_16)
    assert tuple(got) == tuple(want), (path, got, want)


def test_scan_stacked_leading_axis_unsharded():
    # (reps, d, f) stacked MoE leaf: trailing rule right-aligned
    got = spec_for_path("segments/0/pattern/0/moe/w_gate", 4, RULES_16)
    assert tuple(got) == (None, "model", None, "data")


def test_shard_hint_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shard_hint(x, "batch", None)
    assert y is x


def test_shard_hint_applies_in_context():
    import jax.numpy as jnp
    mesh = make_local_mesh()
    with sharding_context(mesh):
        y = shard_hint(jnp.ones((4, 4)), "batch", None)
    assert y.shape == (4, 4)


@pytest.mark.slow
def test_dryrun_subprocess_multipod():
    """End-to-end: the real dry-run entry point compiles one (arch, shape)
    on the 2x16x16 multi-pod mesh with 512 forced host devices."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "decode_32k", "--multi-pod",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "all requested combinations compiled" in out.stdout


def test_device_count_is_one_here():
    """The 512-device forcing must NOT leak outside launch/dryrun (the
    brief's requirement: smoke tests and benches see 1 device)."""
    assert jax.device_count() == 1
