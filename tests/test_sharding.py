"""Sharding rules: parameter partition specs per family, divisibility
guards, and a real subprocess dry-run (the 512-device multi-pod config)."""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_local_mesh
from repro.sharding.partition import (default_rules, shard_hint,
                                      sharding_context, spec_for_path)

RULES_16 = {  # what default_rules produces on the (16, 16) mesh
    "batch": ("data",), "data": ("data",), "expert": ("model",),
    "expert_inner": ("data",), "tp": ("model",), "vocab": ("model",),
    "seq": ("model",), None: None,
}


@pytest.mark.parametrize("path,ndim,want", [
    ("segments/0/pattern/0/moe/w_gate", 3, P("model", None, "data")),
    ("segments/0/pattern/0/moe/w_down", 3, P("model", "data", None)),
    ("segments/0/pattern/0/moe/router", 2, P(None, None)),
    ("segments/0/pattern/0/mlp/w_up", 3, P(None, None, "model")),  # stacked
    ("segments/0/pattern/0/mlp/w_down", 2, P("model", None)),
    ("segments/0/pattern/0/attn/w_q", 2, P(None, "model")),
    ("segments/0/pattern/0/attn/w_o", 2, P("model", None)),
    ("segments/0/pattern/0/attn/w_dkv", 2, P(None, None)),     # MLA compress
    ("segments/0/pattern/0/attn/w_uq", 2, P(None, "model")),   # MLA decompress
    ("embed/tok", 2, P("model", None)),
    ("segments/0/pattern/0/ln1/scale", 1, P()),                # replicated
    ("segments/0/pattern/0/rglru/w_in", 2, P(None, "model")),
    ("segments/0/pattern/0/rglru/a_param", 1, P()),
])
def test_param_rules(path, ndim, want):
    got = spec_for_path(path, ndim, RULES_16)
    assert tuple(got) == tuple(want), (path, got, want)


def test_scan_stacked_leading_axis_unsharded():
    # (reps, d, f) stacked MoE leaf: trailing rule right-aligned
    got = spec_for_path("segments/0/pattern/0/moe/w_gate", 4, RULES_16)
    assert tuple(got) == (None, "model", None, "data")


def test_shard_hint_noop_outside_context():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    y = shard_hint(x, "batch", None)
    assert y is x


def test_shard_hint_applies_in_context():
    import jax.numpy as jnp
    mesh = make_local_mesh()
    with sharding_context(mesh):
        y = shard_hint(jnp.ones((4, 4)), "batch", None)
    assert y.shape == (4, 4)


@pytest.mark.slow
def test_dryrun_subprocess_multipod():
    """End-to-end: the real dry-run entry point compiles one (arch, shape)
    on the 2x16x16 multi-pod mesh with 512 forced host devices."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "whisper-base", "--shape", "decode_32k", "--multi-pod",
         "--out", "/tmp/dryrun_test"],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=900)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "all requested combinations compiled" in out.stdout


_MOE_SHARD_SCRIPT = r"""
import jax, jax.numpy as jnp, numpy as np
jax.config.update("jax_default_matmul_precision", "highest")
from jax.sharding import Mesh
import sys, os
sys.path.insert(0, os.path.join(os.getcwd(), "tests"))
from conftest import tiny_moe
from repro.models import moe
from repro.sharding.partition import sharding_context

cfg = tiny_moe()                      # E=4, top_k=2
p = moe.init_moe(cfg, jax.random.PRNGKey(0))
mesh = Mesh(np.array(jax.devices()).reshape(2, 2), ("data", "model"))
rules = {"batch": ("data",), "tp": ("model",)}

# a2a mode: b=4, s=16 -> 64 tokens, tokens*k >= 16*E
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
ref, aux0 = moe.apply_moe(cfg, p, x, dropless=True)
with sharding_context(mesh, rules):
    assert moe._sharded_moe_plan(cfg, 4, 16)[-1] == "a2a"
    rr, ar = moe.apply_moe(cfg, p, x, moe_dispatch="ragged")
assert float(jnp.abs(rr - ref).max()) < 1e-5, "a2a ragged diverged"
np.testing.assert_array_equal(np.asarray(aux0["expert_counts"]),
                              np.asarray(ar["expert_counts"]))

# psum mode: decode-like s=1
x2 = jax.random.normal(jax.random.PRNGKey(2), (64, 1, cfg.d_model))
ref2, _ = moe.apply_moe(cfg, p, x2, dropless=True)
with sharding_context(mesh, rules):
    assert moe._sharded_moe_plan(cfg, 64, 1)[-1] == "psum"
    rr2, _ = moe.apply_moe(cfg, p, x2, moe_dispatch="ragged")
assert float(jnp.abs(rr2 - ref2).max()) < 1e-5, "psum ragged diverged"
print("SHARDED-RAGGED-OK")
"""


@pytest.mark.slow
def test_moe_ragged_shard_map_matches_unsharded():
    """The ragged expert-parallel paths (a2a with per-shard ragged chunks,
    psum with local ragged dispatch) must reproduce the unsharded oracle.
    Runs in a subprocess with 4 forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = "src"
    out = subprocess.run(
        [sys.executable, "-c", _MOE_SHARD_SCRIPT],
        capture_output=True, text=True, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."), timeout=600)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "SHARDED-RAGGED-OK" in out.stdout


def test_device_count_is_one_here():
    """The 512-device forcing must NOT leak outside launch/dryrun (the
    brief's requirement: smoke tests and benches see 1 device)."""
    assert jax.device_count() == 1
