"""Packed layer-group execution (DESIGN.md §Engine hot path): the packed
slot-vector path must be BIT-IDENTICAL to per-slice execution — token
streams, expert-load bytes and the per-iteration page counters — under
memory pressure in both preemption modes; dispatch counts must scale with
layer groups instead of co-resident requests; and the engine iteration
must sync with the host exactly once."""

from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import tiny_dense, tiny_moe
from test_runtime import _mixed_trace
from repro.core.base import make_scheduler
from repro.models.model import DecoderModel
from repro.serving.engine import Engine
from repro.serving.runtime import EngineExecutor, ServingRuntime


def _engine(cfg, packed, n_slots=4, **kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=n_slots,
                           quantum=8, token_budget=16)
    return Engine(model, params, sched, n_slots=n_slots, max_len=64,
                  packed=packed, **kw)


def _replay(cfg, packed, mode):
    """The multi-class oversubscribed trace from test_runtime, through the
    shared runtime loop on a ~3-resident pool (the regime where cohorts,
    evictions and swap-ins all coexist in one plan)."""
    eng = _engine(cfg, packed, pages=16, page_size=4, decode_reserve=1,
                  preemption_mode=mode)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                        record_plans=True)
    res = rt.run(_mixed_trace(), max_iterations=100_000)
    return eng, rt, res


ITER_KEYS = ("iteration", "n_decode", "prefill_tokens", "expert_load_bytes",
             "pages_in_use", "host_pages_in_use", "n_preempted",
             "n_swapped_out", "n_swapped_in")


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_packed_vs_per_slice_equivalence(mode):
    """Acceptance: the packed path produces bit-identical tokens,
    expert-load bytes and iter_log page counters to the per-slice path on
    the cross-backend oversubscribed trace, in both preemption modes."""
    cfg = tiny_moe()
    pk_eng, pk_rt, pk_res = _replay(cfg, True, mode)
    ps_eng, ps_rt, ps_res = _replay(cfg, False, mode)
    if mode == "swap":
        assert pk_eng.n_swapped_out > 0, "scenario must actually swap"
    else:
        assert pk_eng.n_preempted > 0, "scenario must actually preempt"

    assert pk_eng.outputs == ps_eng.outputs, \
        "packing changed generated tokens"
    assert pk_eng.expert_load_bytes == ps_eng.expert_load_bytes > 0
    assert [{k: row[k] for k in ITER_KEYS} for row in pk_eng.iter_log] \
        == [{k: row[k] for k in ITER_KEYS} for row in ps_eng.iter_log]
    # identical plan streams (scheduling is execution-independent) but
    # strictly fewer device launches for the same work
    assert len(pk_rt.plans) == len(ps_rt.plans)
    assert pk_res.n_dispatches < ps_res.n_dispatches
    assert pk_eng.alloc.pages_in_use() == 0


def test_packed_dispatch_count_regression():
    """A mixed-shape cohort of >= 4 co-resident prefills: the packed path
    must launch >= 2x fewer prefill executions AND compile no more prefill
    executables than per-slice (the P/B-bucketed LRU keys count real
    executables on both paths)."""
    cfg = tiny_dense(n_layers=4)
    jobs = [list(range(1, n)) for n in (11, 21, 13, 25, 15, 29)]

    def run(packed):
        eng = _engine(cfg, packed, n_slots=8)
        for p in jobs:
            eng.submit(p, 4)
        eng.run(max_iterations=10_000)
        return eng

    pk, ps = run(True), run(False)
    assert pk.outputs == ps.outputs
    # 6 requests form one layered cohort: per-slice launches one prefill
    # per (request x group), packed one per group
    assert pk.n_prefill_dispatches * 2 <= ps.n_prefill_dispatches
    assert pk.n_prefill_compiles <= ps.n_prefill_compiles
    assert pk.n_dispatches < ps.n_dispatches


def test_one_device_sync_per_iteration(monkeypatch):
    """The sync-free contract: execute_plan performs at most ONE
    jax.device_get per iteration — tokens, expert masks and swap rows all
    ride the same fetch."""
    cfg = tiny_dense()
    eng = _engine(cfg, True, pages=16, page_size=4, decode_reserve=1,
                  preemption_mode="swap")
    rng = np.random.default_rng(0)
    for _ in range(16):
        eng.submit(list(rng.integers(1, 200, int(rng.integers(4, 10)))), 12)
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get",
                        lambda tree: calls.append(1) or real(tree))
    while eng.scheduler.has_work():
        before = len(calls)
        eng.step()
        assert len(calls) - before <= 1
    assert eng.n_swapped_out > 0          # swap rows joined the one fetch
    assert all(len(toks) == 12 for toks in eng.outputs.values())


def test_stash_rows_reference_packed_batch():
    """A layered cohort's boundary activations are stashed as (batch, row)
    references into ONE packed array — group g+1 consumes the stash
    wholesale instead of per-request splits."""
    cfg = tiny_dense(n_layers=4)
    eng = _engine(cfg, True, n_slots=4)
    for i in range(3):
        eng.submit([1 + i, 2, 3, 4, 5, 6, 7, 8, 9], 2)
    saw_shared = False
    while eng.scheduler.has_work():
        eng.step()
        if len(eng.stash) >= 2:
            srcs = {id(src) for src, _, _ in eng.stash.values()}
            rows = sorted(row for _, row, _ in eng.stash.values())
            saw_shared = True
            assert len(srcs) == 1, "cohort stash must share one batch"
            assert rows == list(range(len(eng.stash)))
    assert saw_shared
    assert not eng.stash


def test_packed_survives_mid_cohort_preemption():
    """Preempting a cohort member between layer groups forces the stash
    regather path (survivor rows no longer match the stored batch); the
    survivors' tokens must still match an undisturbed run."""
    from repro.core.plan import RequestState
    cfg = tiny_dense(n_layers=4)
    eng = _engine(cfg, True, n_slots=4)
    sched = eng.scheduler
    rids = [eng.submit([9 - i, 2, 3, 4, 5, 6, 7, 8], 3) for i in range(3)]
    forced = False
    while eng.scheduler.has_work():
        victim = sched.requests[rids[0]]
        if not forced and victim.state == RequestState.PREFILL \
                and eng.stash:
            sched.preempt(rids[0])        # what the pressure pass would do
            eng._preempt(rids[0])
            forced = True
        eng.step()
    assert forced
    clean = _engine(cfg, True, n_slots=4)
    for i in range(3):
        clean.submit([9 - i, 2, 3, 4, 5, 6, 7, 8], 3)
    clean.run()
    assert eng.outputs == {rid: clean.outputs[rid] for rid in eng.outputs}
