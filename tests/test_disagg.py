"""Disaggregated prefill/decode serving (serving/runtime.DisaggRuntime,
serving/simulator.DisaggSimulator, engine.EngineHandoff).

The correctness bar: on the multi-class oversubscribed trace, the
two-pool engine produces BIT-IDENTICAL tokens to the monolithic engine
in both preemption modes, with zero page leaks on both pools.  The perf
claim: group-granular streaming handoff strictly beats whole-prompt
handoff under the layered schedule (the link overlaps the remaining
groups' compute), while chunked prefill degenerates stream == whole
(its final chunk covers every block, so nothing completes early).  And
the decode pool's iteration clock NEVER contains prefill work — its TBT
is prefill-free by construction.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import tiny_dense
from repro.configs import get_config
from repro.core.base import make_scheduler
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2
from repro.serving.engine import Engine, EngineHandoff
from repro.serving.runtime import DisaggRuntime, EngineExecutor
from repro.serving.simulator import DisaggSimulator
from repro.serving.traffic import TraceRequest


def _mixed_trace(n=32, seed=0, spread=40):
    """Multi-class oversubscribed trace with iteration-indexed arrivals
    and real token ids (interactive/batch interleaved)."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.integers(0, spread, n)).astype(float)
    trace = []
    for i, t in enumerate(arrivals):
        n_tok = int(rng.integers(4, 10))
        trace.append(TraceRequest(
            arrival_time=float(t), prompt_len=n_tok,
            output_len=int(rng.integers(8, 13)),
            slo_class="batch" if i % 3 == 0 else "interactive",
            prompt_tokens=tuple(int(x)
                                for x in rng.integers(1, 200, n_tok))))
    return trace


def _engine_pair(cfg, **eng_kw):
    """(prefill, decode) engines sharing one model + params — the KV
    layouts must match for the handoff payloads to scatter correctly."""
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched_kw = dict(n_slots=4, quantum=8, token_budget=16)
    sp = make_scheduler("layered", model.n_blocks, **sched_kw)
    sd = make_scheduler("decode", model.n_blocks, **sched_kw)
    common = dict(n_slots=4, max_len=64, **eng_kw)
    return Engine(model, params, sp, **common), \
        Engine(model, params, sd, **common)


def _mono_engine(cfg):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=4,
                           quantum=8, token_budget=16)
    return Engine(model, params, sched, n_slots=4, max_len=64)


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_disagg_tokens_bit_identical_to_monolithic(mode):
    """Oversubscribed two-pool replay == unconstrained monolithic run,
    token for token, in BOTH preemption modes; no pages leak from either
    pool and both allocators' invariants hold at drain.  (The swap mode
    also regresses the swap-pin pressure valve: imported prompt pages
    are all shared on the decode pool, so swapped victims pin HBM and
    the _demote_swapped fold is what lets decode growth proceed.)"""
    cfg = tiny_dense()
    trace = _mixed_trace()
    ep, ed = _engine_pair(cfg, pages=16, page_size=4, decode_reserve=1,
                          preemption_mode=mode)
    bridge = EngineHandoff(ep, ed, streaming=True)
    rt = DisaggRuntime(EngineExecutor(ep), EngineExecutor(ed), bridge,
                       clock="iteration")
    rr = rt.run(trace, max_iterations=100_000)

    assert rr.n_migrations > 0
    assert rr.decode_prefill_slices == 0
    if mode == "swap":
        assert rr.n_swap_outs > 0, "scenario must actually swap"
    else:
        assert rr.n_preemptions > 0, "scenario must actually preempt"
    # decode-pool recompute victims really routed back to prefill
    assert rr.n_returns > 0, "scenario must route victims back"

    # unconstrained monolithic reference: same prompts, no pressure
    free = _mono_engine(cfg)
    for tr in trace:
        free.submit(list(tr.prompt_tokens), tr.output_len,
                    slo_class=tr.slo_class)
    free.run(max_iterations=100_000)
    outs = {**ep.outputs, **ed.outputs}
    assert outs == free.outputs, \
        "disaggregation changed generated tokens"

    # zero leaks, invariants hold across the export/import boundary
    assert ep.alloc.pages_in_use() == 0
    assert ed.alloc.pages_in_use() == 0
    ep.alloc.check_invariants()
    ed.alloc.check_invariants()


def test_disagg_whole_handoff_also_bit_identical():
    """The whole-prompt baseline must be equally correct — only the
    transfer timing differs, never the tokens."""
    cfg = tiny_dense()
    trace = _mixed_trace(n=16, spread=20)
    ep, ed = _engine_pair(cfg, pages=16, page_size=4, decode_reserve=1)
    bridge = EngineHandoff(ep, ed, streaming=False)
    rt = DisaggRuntime(EngineExecutor(ep), EngineExecutor(ed), bridge,
                       clock="iteration")
    rr = rt.run(trace, max_iterations=100_000)
    assert rr.n_migrations > 0

    free = _mono_engine(cfg)
    for tr in trace:
        free.submit(list(tr.prompt_tokens), tr.output_len,
                    slo_class=tr.slo_class)
    free.run(max_iterations=100_000)
    assert {**ep.outputs, **ed.outputs} == free.outputs
    assert ep.alloc.pages_in_use() == 0
    assert ed.alloc.pages_in_use() == 0


def _long_trace(n=20, rate=2.0, seed=0, prompt=8192, out=32):
    rng = np.random.default_rng(seed)
    t = np.cumsum(rng.exponential(1.0 / rate, n))
    return [TraceRequest(float(a), prompt, out) for a in t]


def _sim_stall(sched, handoff):
    sim = DisaggSimulator(get_config("qwen3-30b-a3b"), sched, H100X2,
                          handoff=handoff, n_slots=64, token_budget=512,
                          quantum=512)
    res = sim.run(_long_trace())
    assert res.decode_prefill_slices == 0
    assert all(r.finish_time is not None for r in res.requests)
    return res


def test_sim_streaming_strictly_dominates_whole_for_layered():
    """Layered prefill completes each layer group's KV early; streaming
    those pages overlaps the link with the remaining groups' compute, so
    the exposed stall must be STRICTLY smaller than shipping the whole
    prompt after the final group."""
    stream = _sim_stall("layered", "stream")
    whole = _sim_stall("layered", "whole")
    assert stream.link_stall_time < whole.link_stall_time
    # the same bytes cross the link either way — only the timing moves
    assert stream.link_bytes == pytest.approx(whole.link_bytes)
    assert stream.n_migrations == whole.n_migrations


def test_sim_chunked_stream_degenerates_to_whole():
    """Chunked prefill's final chunk covers every block, so no group's
    KV completes before the prompt does: stream == whole exactly."""
    stream = _sim_stall("chunked", "stream")
    whole = _sim_stall("chunked", "whole")
    assert stream.link_stall_time == pytest.approx(whole.link_stall_time)


def test_sim_decode_pool_tbt_prefill_free():
    """Every decode-pool TBT sample postdates the request's handoff, and
    the decode pool's clock contains zero prefill slices — the paper's
    disaggregation guarantee."""
    res = _sim_stall("layered", "stream")
    assert res.decode_prefill_slices == 0
    tbts = res.decode_pool_tbts()
    assert tbts and all(x >= 0 for x in tbts)
    assert res.decode_pool_tbt_mean == pytest.approx(
        sum(tbts) / len(tbts))


def test_sim_decode_watermark_holds_migrations():
    """An absurd watermark (the whole decode pool) must hold every
    migration and accumulate handoff wait — backpressure engages."""
    sim = DisaggSimulator(get_config("qwen3-30b-a3b"), "layered", H100X2,
                          handoff="stream", n_slots=64, token_budget=512,
                          quantum=512, decode_pages=4096,
                          decode_watermark=2048)
    res = sim.run(_long_trace(n=6))
    assert all(r.finish_time is not None for r in res.requests)
    assert res.handoff_wait_time > 0
    assert res.migration_queue_peak >= 1


def test_disagg_sim_counters_consistent():
    res = _sim_stall("layered", "stream")
    assert res.n_migrations >= len(res.requests)
    assert res.handoff_bytes > 0
    assert res.link_bytes > 0
    assert res.link_energy > 0
    # total energy folds both pools plus the link
    assert res.total_energy == pytest.approx(
        res.prefill.total_energy + res.decode.total_energy
        + res.link_energy)
