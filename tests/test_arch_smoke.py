"""Per-architecture smoke tests (the brief's deliverable f): every assigned
architecture instantiates a REDUCED same-family variant (<=2 layers unless
the mixer pattern needs a full period, d_model<=512, <=4 experts) and runs
one forward pass AND one train step on CPU, asserting output shapes and
no-NaN. Decode-capable archs also run one cached decode step."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke_config, list_configs
from repro.models.model import DecoderModel
from repro.training.optimizer import adamw
from repro.training.train import make_train_step

B, S = 2, 32


def _inputs(cfg):
    kw = {}
    tokens = jnp.arange(1, B * S + 1, dtype=jnp.int32).reshape(B, S) \
        % (cfg.vocab_size - 1) + 1
    if cfg.encoder.enabled:
        kw["enc_frames"] = jnp.ones((B, cfg.encoder.n_frames, cfg.d_model),
                                    cfg.dtype) * 0.01
    if cfg.vision.enabled:
        kw["extra_embeds"] = jnp.ones((B, 8, cfg.d_model), cfg.dtype) * 0.01
    return tokens, kw


@pytest.mark.parametrize("arch", ASSIGNED)
def test_full_config_matches_brief(arch):
    """The FULL config must carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    brief = {
        "qwen3-moe-235b-a22b": dict(n_layers=94, d_model=4096, n_heads=64,
                                    n_kv_heads=4, vocab_size=151936),
        "qwen2-vl-72b": dict(n_layers=80, d_model=8192, n_heads=64,
                             n_kv_heads=8, d_ff=29568, vocab_size=152064),
        "minicpm-2b": dict(n_layers=40, d_model=2304, n_heads=36,
                           n_kv_heads=36, d_ff=5760, vocab_size=122753),
        "stablelm-1.6b": dict(n_layers=24, d_model=2048, n_heads=32,
                              n_kv_heads=32, d_ff=5632, vocab_size=100352),
        "recurrentgemma-9b": dict(n_layers=38, d_model=4096, n_heads=16,
                                  n_kv_heads=1, d_ff=12288, vocab_size=256000),
        "whisper-base": dict(n_layers=6, d_model=512, n_heads=8,
                             n_kv_heads=8, d_ff=2048, vocab_size=51865),
        "yi-34b": dict(n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8,
                       d_ff=20480, vocab_size=64000),
        "phi4-mini-3.8b": dict(n_layers=32, d_model=3072, n_heads=24,
                               n_kv_heads=8, d_ff=8192, vocab_size=200064),
        "xlstm-1.3b": dict(n_layers=48, d_model=2048, n_heads=4,
                           n_kv_heads=4, vocab_size=50304),
        "deepseek-v2-236b": dict(n_layers=60, d_model=5120, n_heads=128,
                                 vocab_size=102400),
    }[arch]
    for k, v in brief.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    if arch == "qwen3-moe-235b-a22b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (128, 8)
        assert cfg.moe.expert_d_ff == 1536
    if arch == "deepseek-v2-236b":
        assert (cfg.moe.n_experts, cfg.moe.top_k) == (160, 6)
        assert cfg.moe.n_shared_experts == 2
        assert cfg.mla.kv_lora_rank == 512
    assert cfg.source, f"{arch} missing source citation"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_reduced_variant_bounds(arch):
    cfg = get_smoke_config(arch)
    assert cfg.d_model <= 512
    assert cfg.moe.n_experts <= 4
    # 2 layers, except hybrids that need one full mixer period
    assert cfg.n_layers <= max(2, len(cfg.mixer_pattern) or 0, 3)


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens, kw = _inputs(cfg)
    enc_out = None
    if cfg.encoder.enabled:
        enc_out = model.encode(params, kw["enc_frames"])
    logits, _, aux = model.forward(params, tokens, enc_out=enc_out,
                                   extra_embeds=kw.get("extra_embeds"))
    s_all = S + (kw["extra_embeds"].shape[1] if "extra_embeds" in kw else 0)
    assert logits.shape == (B, s_all, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any()), arch
    if cfg.moe.enabled:
        assert int(aux["expert_counts"].sum()) > 0


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3, total_steps=10, warmup=1)
    step = jax.jit(make_train_step(model, opt, cfg.encoder.enabled))
    opt_state = opt.init(params)
    tokens, kw = _inputs(cfg)
    batch = {"tokens": tokens, "targets": jnp.roll(tokens, -1, axis=1),
             "mask": jnp.ones((B, S), bool)}
    if cfg.encoder.enabled:
        batch["enc_out"] = kw["enc_frames"]
    p2, o2, metrics = step(params, opt_state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0, (arch, loss)
    # params actually changed
    delta = jax.tree_util.tree_reduce(
        lambda a, x: a + float(jnp.abs(x).sum()),
        jax.tree_util.tree_map(lambda a, b: a - b, p2, params), 0.0)
    assert delta > 0, arch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_decode_step(arch):
    """One cached decode step (whisper decodes too — enc-dec has a decode
    stage; its encoder output is a stub embedding)."""
    cfg = get_smoke_config(arch)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(B, 64)
    if cfg.encoder.enabled:
        # install cross-KV from a stub encoding
        enc = model.encode(params, jnp.ones((B, cfg.encoder.n_frames,
                                             cfg.d_model), cfg.dtype) * 0.01)
        xkv = model.precompute_cross_kv(params, enc)
        for s, seg in enumerate(xkv):
            for p_idx, kv in enumerate(seg):
                if kv is not None:
                    cache[s][p_idx] = dict(cache[s][p_idx], **kv)
    tokens, _ = _inputs(cfg)
    # prefill S tokens then decode one
    logits, cache, _ = model.forward(params, tokens, cache=cache,
                                     offset=jnp.zeros((B,), jnp.int32),
                                     dropless=cfg.moe.enabled)
    one = tokens[:, -1:]
    logits1, cache, _ = model.forward(params, one, cache=cache,
                                      offset=jnp.full((B,), S, jnp.int32),
                                      dropless=cfg.moe.enabled)
    assert logits1.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits1).any()), arch


def test_registry_covers_paper_models():
    names = list_configs()
    assert "qwen3-30b-a3b" in names and "gpt-oss-20b" in names
    q = get_config("qwen3-30b-a3b")
    assert (q.moe.n_experts, q.moe.top_k) == (128, 8)   # paper Table 3
    g = get_config("gpt-oss-20b")
    assert (g.moe.n_experts, g.moe.top_k) == (32, 4)
