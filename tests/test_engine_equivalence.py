"""The paper's implicit correctness requirement: scheduling must not change
model outputs. Running the SAME tiny model + prompts through the engine
under every scheduler must generate identical token sequences — layered
prefill (group-wise vertical execution with stashed boundary activations)
is numerically the same function as one-shot prefill.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import tiny_dense, tiny_hybrid, tiny_mla, tiny_moe, tiny_xlstm
from repro.core.base import make_scheduler
from repro.core.plan import RequestState
from repro.models.model import DecoderModel
from repro.serving.engine import Engine

SCHEDS = ["continuous", "chunked", "layered", "hybrid", "static"]


def generate(cfg, sched_name, prompts, max_new=6, moe_dispatch="ragged",
             **sched_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(sched_name, model.n_blocks, n_slots=4,
                           quantum=8, token_budget=16, **sched_kw)
    eng = Engine(model, params, sched, n_slots=4, max_len=128,
                 moe_dispatch=moe_dispatch)
    for p in prompts:
        eng.submit(p, max_new)
    eng.run()
    return {rid: list(toks) for rid, toks in eng.outputs.items()}


def reference_generate(cfg, prompts, max_new=6):
    """Naive greedy loop: full forward over the growing sequence."""
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    outs = {}
    for rid, p in enumerate(prompts):
        toks = list(p)
        out = []
        for _ in range(max_new):
            logits, _, _ = model.forward(
                params, jax.numpy.asarray([toks], dtype=jax.numpy.int32))
            nxt = int(jax.numpy.argmax(logits[0, -1]))
            out.append(nxt)
            toks.append(nxt)
        outs[rid] = out
    return outs


PROMPTS = [list(range(1, 12)), [5, 3, 7] * 9, list(range(40, 10, -1))]


@pytest.mark.parametrize("make_cfg", [tiny_dense, tiny_moe, tiny_mla,
                                      tiny_hybrid, tiny_xlstm],
                         ids=["dense", "moe", "mla", "hybrid", "xlstm"])
def test_all_schedulers_agree(make_cfg):
    cfg = make_cfg()
    base = generate(cfg, "continuous", PROMPTS)
    for name in SCHEDS[1:]:
        got = generate(cfg, name, PROMPTS)
        assert got == base, f"{name} diverged from continuous on {cfg.name}"


@pytest.mark.parametrize("sched", ["layered", "chunked"])
def test_moe_engine_dense_vs_ragged_dispatch(sched):
    """Acceptance: the dropless engine must produce IDENTICAL tokens with
    the dense capacity buffer and the ragged tile-aligned pipeline, under
    both the layered and chunked schedulers."""
    cfg = tiny_moe()
    dense = generate(cfg, sched, PROMPTS, moe_dispatch="dense")
    ragged = generate(cfg, sched, PROMPTS, moe_dispatch="ragged")
    assert ragged == dense, f"{sched}: ragged dispatch changed outputs"


def test_engine_matches_naive_reference():
    cfg = tiny_dense()
    eng_out = generate(cfg, "layered", PROMPTS)
    ref_out = reference_generate(cfg, PROMPTS)
    assert eng_out == ref_out


def test_layered_stash_carries_activations():
    """A layered run on a deep stack forces >1 group: the boundary stash
    must be written and consumed (empty at drain)."""
    cfg = tiny_dense(n_layers=4)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=2, quantum=8)
    eng = Engine(model, params, sched, n_slots=2, max_len=128)
    eng.submit(list(range(1, 30)), 3)   # 29 tokens, quantum 8 -> G=4
    saw_stash = False
    while eng.scheduler.has_work():
        eng.step()
        saw_stash = saw_stash or bool(eng.stash)
    assert saw_stash
    assert not eng.stash


def test_moe_expert_loads_layered_leq_chunked():
    """Table 7's mechanism on a real router: layered prefill must load
    fewer (or equal) expert-bytes than chunked for long prompts."""
    cfg = tiny_moe(n_layers=4, moe=tiny_moe().moe)
    long_prompts = [list(np.random.default_rng(i).integers(1, 200, 64))
                    for i in range(2)]
    outs = {}
    loads = {}
    for name in ("chunked", "layered"):
        model = DecoderModel(cfg)
        params = model.init(jax.random.PRNGKey(0))
        sched = make_scheduler(name, model.n_blocks, n_slots=4, quantum=8,
                               token_budget=16)
        eng = Engine(model, params, sched, n_slots=4, max_len=128)
        for p in long_prompts:
            eng.submit(p, 4)
        eng.run()
        outs[name] = eng.outputs
        loads[name] = eng.expert_load_bytes
    assert outs["layered"] == outs["chunked"]
    assert loads["layered"] <= loads["chunked"]
    # 64-token prompts at quantum 8 => 8 chunks; amplification must be real
    assert loads["layered"] < 0.75 * loads["chunked"]


def test_engine_eos_early_exit():
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # find the first greedily generated token, then use it as EOS
    ref = reference_generate(cfg, [PROMPTS[0]], max_new=1)[0][0]
    eng = Engine(model, params, "layered", n_slots=2, max_len=128,
                 eos_token=ref)
    rid = eng.submit(PROMPTS[0], 50)
    eng.run()
    assert eng.outputs[rid] == [ref]       # stopped at EOS, not 50 tokens
    assert eng.requests[rid].finish_time is not None


def test_bucket_capped_at_max_len():
    from repro.serving.engine import _bucket
    assert _bucket(5) == 16
    assert _bucket(17) == 32
    assert _bucket(100, cap=112) == 112     # clamped below the pow2 bucket
    assert _bucket(100, cap=64) == 100      # never below n itself
    assert _bucket(60, cap=96) == 64        # cap above the bucket: no-op
    assert _bucket(100) == 128


def test_prefill_jit_cache_is_lru_bounded():
    from repro.serving.engine import PREFILL_CACHE_SIZE
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, "layered", n_slots=2, max_len=64)
    for start in range(PREFILL_CACHE_SIZE + 8):
        eng._get_prefill_fn(start % (PREFILL_CACHE_SIZE + 4), 1, False, 1, 16)
    assert len(eng._jit_prefill) <= PREFILL_CACHE_SIZE
    # hits refresh recency: oldest surviving key evicts first, hit key stays
    keys = list(eng._jit_prefill)
    eng._get_prefill_fn(*keys[0])                 # touch the LRU entry
    eng._get_prefill_fn(999, 1, False, 1, 16)     # force one eviction
    assert keys[0] in eng._jit_prefill
    assert keys[1] not in eng._jit_prefill


def test_prefill_jit_cache_keys_include_shape_buckets():
    """The LRU key folds the batch and padded-token buckets in: shape
    retraces land in their own entries (one entry == one executable), so
    the PREFILL_CACHE_SIZE bound is real on mixed-shape traces."""
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, "layered", n_slots=4, max_len=64)
    eng._get_prefill_fn(0, 1, False, 1, 16)
    eng._get_prefill_fn(0, 1, False, 1, 32)      # P retrace: new entry
    eng._get_prefill_fn(0, 1, False, 4, 16)      # B retrace: new entry
    eng._get_prefill_fn(0, 1, False, 1, 16)      # hit, not a compile
    assert len(eng._jit_prefill) == 3
    assert eng.n_prefill_compiles == 3


def _run_engine(cfg, sched_name, jobs, **eng_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(sched_name, model.n_blocks, n_slots=4, quantum=8,
                           token_budget=16)
    eng = Engine(model, params, sched, n_slots=4, max_len=64, **eng_kw)
    for prompt, max_new in jobs:
        eng.submit(prompt, max_new)
    eng.run(max_iterations=100_000)
    return eng


@pytest.mark.parametrize("sched", ["layered", "chunked"])
def test_oversubscribed_pool_preempts_and_matches_unconstrained(sched):
    """Acceptance: requests >> pool capacity must COMPLETE via queueing +
    preemption (never 'pool exhausted'), and every request — including the
    recompute-restored victims — must emit exactly the tokens of an
    unconstrained run."""
    cfg = tiny_dense()
    rng = np.random.default_rng(0)
    jobs = [(list(rng.integers(1, 200, int(rng.integers(4, 10)))), 12)
            for _ in range(32)]
    # pool sized for ~3 resident requests (16 pages) against 32 submitted;
    # decode_reserve=1 forces growth pressure once decodes lengthen
    tight = _run_engine(cfg, sched, jobs, pages=16, page_size=4,
                        decode_reserve=1)
    assert tight.n_preempted > 0, "scenario must actually preempt"
    assert tight.alloc.pages_high_water <= tight.alloc.n_pages
    assert tight.alloc.pages_in_use() == 0

    free = _run_engine(cfg, sched, jobs)        # unconstrained pool
    assert free.n_preempted == 0
    assert tight.outputs == free.outputs, \
        "preemption/recompute changed generated tokens"
    # recompute-restored requests specifically were exercised and agree
    restored = [rid for rid, r in tight.requests.items()
                if r.n_preemptions > 0]
    assert restored
    for rid in restored:
        assert tight.outputs[rid] == free.outputs[rid]
        assert len(tight.outputs[rid]) == 12


def test_double_preemption_tokens_identical():
    """Force the SAME request through two evictions (fold-on-fold): the
    recompute prompt must extend by only the unfolded tail each time and
    the generated tokens must match an undisturbed run."""
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=2, quantum=8)
    eng = Engine(model, params, sched, n_slots=2, max_len=64)
    rid = eng.submit(list(range(1, 9)), 12)
    forced = []
    while eng.scheduler.has_work():
        r = eng.requests[rid]
        if r.state == RequestState.DECODE and r.n_generated in (3, 7) \
                and r.n_generated not in forced:
            sched.preempt(rid)            # what the pressure pass would do
            eng._preempt(rid)             # what step() would execute
            forced.append(r.n_generated)
        eng.step()
    assert forced == [3, 7]
    assert eng.requests[rid].n_preemptions == 2
    assert len(eng.prompts[rid]) == 8 + 7   # orig + folded, not 8+3+7+...
    clean = _run_engine(cfg, "layered", [(list(range(1, 9)), 12)])
    assert eng.outputs[rid] == clean.outputs[0]
    assert len(eng.outputs[rid]) == 12


def test_preemption_off_queues_but_can_exhaust():
    """--preemption off: admission still queues on pressure (no crash on
    submit), but unreservable decode growth surfaces PagedPoolExhausted."""
    from repro.serving.kvcache import PagedPoolExhausted
    cfg = tiny_dense()
    # each request alone fits the pool (passes the admission guard), but
    # two residents' CONCURRENT decode growth overcommits it
    jobs = [([1, 2, 3, 4], 14) for _ in range(2)]
    with pytest.raises(PagedPoolExhausted):
        _run_engine(cfg, "chunked", jobs, pages=8, page_size=4,
                    decode_reserve=0, preemption=False)


def test_engine_run_iteration_cap_checked_before_step():
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, "layered", n_slots=2, max_len=64)
    eng.submit(list(range(1, 9)), 20)
    with pytest.raises(RuntimeError, match="did not drain"):
        eng.run(max_iterations=3)
    assert eng.iteration == 3              # cap enforced AT the cap


def test_submit_rejects_prompt_plus_max_new_over_max_len():
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, "layered", n_slots=2, max_len=32)
    with pytest.raises(ValueError, match="exceeds max_len"):
        eng.submit(list(range(1, 30)), 8)


def test_engine_slot_reuse_many_requests():
    """More requests than slots: allocator must recycle; all finish."""
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, "layered", n_slots=2, max_len=64)
    rids = [eng.submit([1 + i, 2, 3, 4], 3) for i in range(7)]
    eng.run()
    for rid in rids:
        assert len(eng.outputs[rid]) == 3
        assert eng.requests[rid].finish_time is not None
