"""The unified serving loop (serving/runtime.py): open-loop trace replay
on the REAL engine, cross-backend equivalence (one loop, two executors),
streaming callbacks, and arrival-clock semantics.

The acceptance bar: Engine and Simulator both execute timed traces through
the SAME ServingRuntime loop.  Under the deterministic iteration clock the
two backends see identical submit/next_plan sequences, so their full plan
streams (admissions, slices, decode batches, evictions, swaps) must be
IDENTICAL — and the engine's token values are invariant to scheduling, so
per-request tokens under replay equal an unconstrained closed-loop run.
Together that is token identity across the two backends: the simulator
emits the engine's exact token schedule, the engine fills in the values.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.base import make_scheduler
from repro.models.model import DecoderModel
from repro.serving.engine import Engine
from repro.serving.cost_model import H100X2
from repro.serving.runtime import (EngineExecutor, ServingRuntime,
                                   SimExecutor)
from repro.serving.simulator import Simulator
from repro.serving.traffic import TraceRequest


def _mixed_trace(n=32, seed=0, spread=40):
    """Multi-class oversubscribed trace with iteration-indexed arrivals
    and real token ids (interactive/batch interleaved)."""
    rng = np.random.default_rng(seed)
    arrivals = np.sort(rng.integers(0, spread, n)).astype(float)
    trace = []
    for i, t in enumerate(arrivals):
        n_tok = int(rng.integers(4, 10))
        trace.append(TraceRequest(
            arrival_time=float(t), prompt_len=n_tok,
            output_len=int(rng.integers(8, 13)),
            slo_class="batch" if i % 3 == 0 else "interactive",
            prompt_tokens=tuple(int(x)
                                for x in rng.integers(1, 200, n_tok))))
    return trace


def _make_engine(cfg, sched_name, **eng_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(sched_name, model.n_blocks, n_slots=4,
                           quantum=8, token_budget=16)
    return Engine(model, params, sched, n_slots=4, max_len=64, **eng_kw)


def _plan_key(plan):
    return (tuple(plan.admitted_ids), tuple(plan.decode_ids),
            tuple((s.req_id, s.token_start, s.token_end, s.block_start,
                   s.block_end, s.emits_first_token) for s in plan.prefill),
            tuple(plan.preempted_ids), tuple(plan.swapped_out_ids),
            tuple(plan.swapped_in_ids))


@pytest.mark.parametrize("mode", ["recompute", "swap"])
def test_trace_replay_equivalence_engine_vs_sim(mode):
    """Same multi-class oversubscribed trace, same scheduler, iteration
    clock: the engine and simulator backends must produce IDENTICAL plan
    streams and per-request timelines, and the engine's replayed tokens
    must equal an unconstrained closed-loop run."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    kw = dict(page_size=4, decode_reserve=1, preemption_mode=mode)

    # engine backend, oversubscribed (~3 residents in 16 pages)
    eng = _make_engine(cfg, "layered", pages=16, **kw)
    eng_rt = ServingRuntime(EngineExecutor(eng), clock="iteration",
                            record_plans=True)
    eng_res = eng_rt.run(trace, max_iterations=100_000)

    # simulator backend: same scheduler type/params, same pool
    sim_sched = make_scheduler("layered", eng.model.n_blocks, n_slots=4,
                               quantum=8, token_budget=16)
    sim = Simulator(cfg, sim_sched, H100X2, n_pages=16, **kw)
    sim_rt = ServingRuntime(SimExecutor(sim), clock="iteration",
                            record_plans=True)
    sim_res = sim_rt.run(trace, max_iterations=100_000)

    # one loop, two backends: the full scheduling history agrees
    assert [_plan_key(p) for p in eng_rt.plans] \
        == [_plan_key(p) for p in sim_rt.plans]
    assert eng_res.n_iterations == sim_res.n_iterations
    assert eng_res.n_preemptions == sim_res.n_preemptions
    assert eng_res.n_swap_outs == sim_res.n_swap_outs
    if mode == "swap":
        assert eng_res.n_swap_outs > 0, "scenario must actually swap"
    else:
        assert eng_res.n_preemptions > 0, "scenario must actually preempt"

    # identical per-request timelines (classes, arrivals, every timestamp)
    for er, sr in zip(eng_res.requests, sim_res.requests):
        assert er.req_id == sr.req_id
        assert er.slo_class == sr.slo_class
        assert er.arrival_time == sr.arrival_time
        assert er.admit_time == sr.admit_time
        assert er.first_token_time == sr.first_token_time
        assert er.token_times == sr.token_times
        assert er.finish_time == sr.finish_time
        assert er.n_generated == sr.n_generated

    # token identity: replay under pressure == unconstrained closed loop
    free = _make_engine(cfg, "layered")
    for tr in trace:
        free.submit(list(tr.prompt_tokens), tr.output_len,
                    slo_class=tr.slo_class)
    free.run(max_iterations=100_000)
    assert eng.outputs == free.outputs, \
        "timed replay changed generated tokens"
    assert eng.alloc.pages_in_use() == 0


def test_engine_open_loop_idles_to_next_arrival():
    """A huge arrival gap must fast-forward the clock, not spin iterations
    or raise 'did not drain' (the closed-loop harness's failure mode)."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered")
    trace = [TraceRequest(0.0, 5, 4, prompt_tokens=(1, 2, 3, 4, 5)),
             TraceRequest(1000.0, 5, 4, prompt_tokens=(9, 8, 7, 6, 5))]
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    res = rt.run(trace, max_iterations=500)   # << 1000: no spin allowed
    assert res.clock >= 1000.0
    assert res.n_iterations < 500
    late = res.requests[1]
    assert late.arrival_time == 1000.0
    assert late.admit_time >= 1000.0
    assert late.first_token_time > 1000.0
    assert all(len(eng.outputs[r.req_id]) == 4 for r in res.requests)


def test_engine_second_run_keeps_clock_monotone():
    """The iteration clock resumes from the engine's persistent counter:
    a request submitted AFTER a first run() (arrival stamped at the
    current iteration) must get a positive TTFT from the second run(),
    not timestamps from a clock reset to zero."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered")
    r0 = eng.submit([1, 2, 3, 4], 4)
    eng.run()
    it = eng.iteration
    assert it > 0
    r1 = eng.submit([5, 6, 7, 8], 4)
    assert eng.requests[r1].arrival_time == float(it)
    eng.run()
    req = eng.requests[r1]
    assert req.first_token_time > req.arrival_time
    assert req.ttft() > 0
    assert req.queue_delay() >= 0
    assert eng.requests[r0].finish_time < req.first_token_time


def test_engine_manual_step_still_timestamps():
    """Hand-driving eng.step() (no runtime) must stamp the same
    iteration-clock timestamps the loop would — external drivers that
    call request_metrics afterwards keep working."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered")
    rid = eng.submit([1, 2, 3, 4, 5], 4)
    while eng.scheduler.has_work():
        eng.step()
    req = eng.requests[rid]
    assert req.first_token_time is not None
    assert len(req.token_times) == 3
    assert req.finish_time == req.token_times[-1]
    # identical to what a runtime-driven run stamps
    ref = _make_engine(cfg, "layered")
    ref_rid = ref.submit([1, 2, 3, 4, 5], 4)
    ref.run()
    assert req.first_token_time == ref.requests[ref_rid].first_token_time
    assert req.token_times == ref.requests[ref_rid].token_times


def test_engine_replay_requires_prompt_tokens():
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered")
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    with pytest.raises(ValueError, match="prompt_tokens"):
        rt.run([TraceRequest(0.0, 4, 4)])


def test_streaming_callback_ordering():
    """on_token streams every generated token: per-request order matches
    the final outputs, timestamps are nondecreasing iteration ends, and
    the first streamed token of a request carries its TTFT timestamp."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered", pages=16, page_size=4,
                       decode_reserve=1)   # pressure: restarts happen too
    events = []
    rt = ServingRuntime(EngineExecutor(eng),
                        on_token=lambda rid, tok, t:
                        events.append((rid, tok, t)),
                        clock="iteration")
    trace = _mixed_trace(n=12, seed=3, spread=10)
    rt.run(trace, max_iterations=100_000)

    ts = [t for _, _, t in events]
    assert ts == sorted(ts)                      # emission order
    streamed = {}
    first_t = {}
    for rid, tok, t in events:
        assert tok is not None                   # engine streams real ids
        streamed.setdefault(rid, []).append(tok)
        first_t.setdefault(rid, t)
    assert streamed == eng.outputs               # complete, in order
    for rid, t in first_t.items():
        assert eng.requests[rid].first_token_time == t


def test_sim_streaming_tokens_are_placeholders():
    cfg = tiny_dense()
    events = []
    sim = Simulator(cfg, "layered", H100X2, n_slots=8, quantum=16,
                    token_budget=64)
    trace = [TraceRequest(i * 0.5, 8, 4) for i in range(6)]
    res = sim.run(trace, on_token=lambda rid, tok, t:
                  events.append((rid, tok, t)))
    assert len(events) == sum(r.n_generated for r in res.requests)
    assert all(tok is None for _, tok, _ in events)


def test_engine_wall_clock_replay_sleeps_to_arrivals():
    """wall=True: arrival times are real seconds — the runtime sleeps
    through the gap and timestamps in wall time."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered")
    trace = [TraceRequest(0.0, 4, 3, prompt_tokens=(1, 2, 3, 4)),
             TraceRequest(0.3, 4, 3, prompt_tokens=(4, 3, 2, 1))]
    rt = ServingRuntime(EngineExecutor(eng, wall=True), clock="executor")
    res = rt.run(trace, max_iterations=10_000)
    assert res.clock >= 0.3                     # really waited
    assert all(len(eng.outputs[r.req_id]) == 3 for r in res.requests)
    r1 = res.requests[1]
    assert r1.first_token_time > r1.arrival_time
