"""Shared fixtures: tiny model configs for fast CPU tests.

Device count stays at 1 here (the 512-device forcing happens ONLY inside
repro.launch.dryrun, per the brief).
"""

from __future__ import annotations

import os

import jax
import pytest

from repro.models.config import MLAConfig, ModelConfig, MoEConfig

jax.config.update("jax_enable_x64", False)

# -- pre-existing seed failures -------------------------------------------
# tests/seed_xfails.txt is the single source of truth for the known-bad
# node ids ("no worse than seed" bar): they run as xfail(strict=False), so
# plain `pytest -x -q` agrees between local runs and CI with no deselect
# flags — and an accidental fix shows up as XPASS instead of breaking.

_XFAIL_FILE = os.path.join(os.path.dirname(__file__), "seed_xfails.txt")


def _seed_xfail_prefixes():
    try:
        with open(_XFAIL_FILE) as f:
            lines = (ln.strip() for ln in f)
            return [ln for ln in lines if ln and not ln.startswith("#")]
    except OSError:
        return []


def pytest_collection_modifyitems(config, items):
    prefixes = _seed_xfail_prefixes()
    if not prefixes:
        return
    marker = pytest.mark.xfail(
        reason="pre-existing seed failure (tests/seed_xfails.txt)",
        strict=False)
    for item in items:
        nodeid = item.nodeid.replace(os.sep, "/")
        for p in prefixes:
            # a bare prefix matches the whole function incl. parametrized
            # variants (::name[...]), but not a longer name sharing it
            if nodeid == p or nodeid.startswith(p + "["):
                item.add_marker(marker)
                break


def tiny_dense(**kw) -> ModelConfig:
    base = dict(name="tiny-dense", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                max_seq_len=256)
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_moe(**kw) -> ModelConfig:
    base = dict(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                max_seq_len=256,
                moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=64))
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_mla(**kw) -> ModelConfig:
    base = dict(name="tiny-mla", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                max_seq_len=256,
                mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_rope_dim=8,
                              qk_nope_dim=16, v_head_dim=16))
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_hybrid(**kw) -> ModelConfig:
    base = dict(name="tiny-hybrid", family="hybrid", n_layers=3, d_model=64,
                n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=256,
                max_seq_len=256, mixer_pattern=("rglru", "rglru", "local_gqa"),
                local_window=32, lru_width=64)
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_xlstm(**kw) -> ModelConfig:
    base = dict(name="tiny-xlstm", family="ssm", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=256,
                max_seq_len=256, mixer_pattern=("mlstm", "slstm"))
    base.update(kw)
    return ModelConfig(**base).validate()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
