"""Shared fixtures: tiny model configs for fast CPU tests.

Device count stays at 1 here (the 512-device forcing happens ONLY inside
repro.launch.dryrun, per the brief).
"""

from __future__ import annotations

import jax
import pytest

from repro.models.config import (FFN_MOE, MLAConfig, ModelConfig, MoEConfig)

jax.config.update("jax_enable_x64", False)


def tiny_dense(**kw) -> ModelConfig:
    base = dict(name="tiny-dense", family="dense", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                max_seq_len=256)
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_moe(**kw) -> ModelConfig:
    base = dict(name="tiny-moe", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=256,
                max_seq_len=256,
                moe=MoEConfig(n_experts=4, top_k=2, expert_d_ff=64))
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_mla(**kw) -> ModelConfig:
    base = dict(name="tiny-mla", family="moe", n_layers=2, d_model=64,
                n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256,
                max_seq_len=256,
                mla=MLAConfig(kv_lora_rank=32, q_lora_rank=0, qk_rope_dim=8,
                              qk_nope_dim=16, v_head_dim=16))
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_hybrid(**kw) -> ModelConfig:
    base = dict(name="tiny-hybrid", family="hybrid", n_layers=3, d_model=64,
                n_heads=4, n_kv_heads=1, d_ff=128, vocab_size=256,
                max_seq_len=256, mixer_pattern=("rglru", "rglru", "local_gqa"),
                local_window=32, lru_width=64)
    base.update(kw)
    return ModelConfig(**base).validate()


def tiny_xlstm(**kw) -> ModelConfig:
    base = dict(name="tiny-xlstm", family="ssm", n_layers=2, d_model=64,
                n_heads=2, n_kv_heads=2, d_ff=0, vocab_size=256,
                max_seq_len=256, mixer_pattern=("mlstm", "slstm"))
    base.update(kw)
    return ModelConfig(**base).validate()


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
