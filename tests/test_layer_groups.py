"""Property tests for the paper's §4.4 layer-group rule G(L)."""

import math

try:
    from hypothesis import given, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, strategies as st

from repro.core import layer_groups


@given(st.integers(1, 200_000), st.integers(1, 128),
       st.sampled_from([256, 512, 1024]))
def test_num_groups_matches_paper_rule(prompt_len, n_blocks, quantum):
    g = layer_groups.num_groups(prompt_len, n_blocks, quantum)
    want = max(1, math.ceil(prompt_len / quantum))
    assert g == min(want, n_blocks)
    assert 1 <= g <= n_blocks


def test_paper_examples():
    # §4.4: 8192-token prompt -> G=16; 512-token prompt -> G=1.
    assert layer_groups.num_groups(8192, 48, 512) == 16
    assert layer_groups.num_groups(512, 48, 512) == 1
    # capped by depth: whisper-base has 6 layers
    assert layer_groups.num_groups(8192, 6, 512) == 6


@given(st.integers(1, 128).flatmap(
    lambda n: st.tuples(st.just(n), st.integers(1, n))))
def test_partition_tiles_exactly_and_balanced(n_and_g):
    n_blocks, g = n_and_g
    groups = layer_groups.partition(n_blocks, g)
    assert len(groups) == g
    # contiguous, exact tiling of [0, n_blocks)
    assert groups[0][0] == 0 and groups[-1][1] == n_blocks
    for (a0, a1), (b0, b1) in zip(groups, groups[1:]):
        assert a1 == b0
    sizes = [b - a for a, b in groups]
    assert all(s >= 1 for s in sizes)
    # balanced to within one block (paper's future-work L % G case)
    assert max(sizes) - min(sizes) <= 1


@given(st.lists(st.floats(0.0, 100.0), min_size=1, max_size=96).flatmap(
    lambda cs: st.tuples(st.just(cs), st.integers(1, len(cs)))))
def test_partition_weighted_valid_and_balanced(args):
    costs, g = args
    groups = layer_groups.partition_weighted(costs, g)
    assert len(groups) == g
    assert groups[0][0] == 0 and groups[-1][1] == len(costs)
    for (a0, a1), (b0, b1) in zip(groups, groups[1:]):
        assert a1 == b0
    assert all(b > a for a, b in groups)


def test_partition_weighted_balances_heterogeneous_stack():
    # MoE-heavy back half: uniform split would put 4x the weight-bytes in
    # the later groups; weighted split moves boundaries earlier.
    costs = [1.0] * 8 + [4.0] * 8
    w = layer_groups.partition_weighted(costs, 4)
    u = layer_groups.partition(16, 4)
    def spread(groups):
        sums = [sum(costs[a:b]) for a, b in groups]
        return max(sums) - min(sums)
    assert spread(w) < spread(u)


def test_partition_weighted_uniform_matches_count_split():
    w = layer_groups.partition_weighted([1.0] * 12, 4)
    assert w == layer_groups.partition(12, 4)
