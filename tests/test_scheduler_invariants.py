"""Property tests for the scheduling invariants (DESIGN.md / core/base.py):

  I1 (stall-free): every plan decodes EVERY request in DECODE state.
  I2 (coverage): a request's prefill slices tile [0, prompt_len) x
      [0, n_blocks) exactly once.
  I3 (order): slices are causally ordered (block-major within a token range;
      token ranges in order).
  Layered-specific: at most one layer group prefills per iteration and a
      request's prefill spans exactly G iterations.
"""

from __future__ import annotations

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import layer_groups
from repro.core.base import SCHEDULERS, make_scheduler
from repro.core.plan import Request, RequestState

ALL = sorted(SCHEDULERS)


def drive(sched, reqs, max_iters=100_000):
    """Submit all requests at t=0 and run to drain; returns per-iteration
    plans plus the decode-state snapshot taken BEFORE each plan."""
    for r in reqs:
        sched.submit(r)
    plans, pre_decode = [], []
    it = 0
    while sched.has_work():
        pre = {rid for rid, r in sched.requests.items()
               if r.state == RequestState.DECODE}
        plan = sched.next_plan(now=float(it))
        plans.append(plan)
        pre_decode.append(pre)
        it += 1
        assert it < max_iters, f"{sched.name} did not drain"
    return plans, pre_decode


reqs_strategy = st.lists(
    st.tuples(st.integers(1, 3000), st.integers(1, 20)),
    min_size=1, max_size=12)


@pytest.mark.parametrize("name", ALL)
@given(spec=reqs_strategy)
@settings(max_examples=25, deadline=None)
def test_invariants(name, spec):
    n_blocks = 12
    sched = make_scheduler(name, n_blocks, n_slots=8, token_budget=256,
                           quantum=256)
    reqs = [Request(req_id=i, prompt_len=p, max_new_tokens=m)
            for i, (p, m) in enumerate(spec)]
    plans, pre_decode = drive(sched, reqs)

    # I1 stall-free: every pre-iteration DECODE request is in decode_ids.
    for plan, pre in zip(plans, pre_decode):
        assert pre.issubset(set(plan.decode_ids)), sched.name

    # I2 coverage: slices tile the (token x block) rectangle exactly once.
    cover = {r.req_id: {} for r in reqs}
    for plan in plans:
        for sl in plan.prefill:
            grid = cover[sl.req_id]
            for tok in range(sl.token_start, sl.token_end):
                for b in range(sl.block_start, sl.block_end):
                    key = (tok, b)
                    assert key not in grid, (sched.name, sl.req_id, key)
                    grid[key] = True
    for r in reqs:
        assert len(cover[r.req_id]) == r.prompt_len * n_blocks, sched.name

    # I3 order: per request, block ranges advance within a token range and
    # token ranges advance monotonically.
    seen = {r.req_id: (0, 0) for r in reqs}  # (tokens completed, next block)
    for plan in plans:
        for sl in plan.prefill:
            tok_done, next_block = seen[sl.req_id]
            assert sl.token_start == tok_done
            assert sl.block_start == next_block
            if sl.block_end == n_blocks:
                seen[sl.req_id] = (sl.token_end, 0)
            else:
                seen[sl.req_id] = (tok_done, sl.block_end)

    # every request decoded exactly max_new_tokens (first token from the
    # final prefill slice, the rest from decode iterations)
    n_decodes = {r.req_id: 0 for r in reqs}
    for plan in plans:
        for rid in plan.decode_ids:
            n_decodes[rid] += 1
    for r in reqs:
        assert n_decodes[r.req_id] == r.max_new_tokens - 1


@given(spec=st.tuples(st.integers(1, 20000), st.integers(1, 4)))
@settings(max_examples=40, deadline=None)
def test_layered_one_group_per_iteration(spec):
    prompt_len, _ = spec
    n_blocks = 24
    sched = make_scheduler("layered", n_blocks, n_slots=4, quantum=512)
    reqs = [Request(req_id=0, prompt_len=prompt_len, max_new_tokens=4)]
    plans, _ = drive(sched, reqs)

    g = layer_groups.num_groups(prompt_len, n_blocks, 512)
    prefill_iters = [p for p in plans if p.prefill]
    # prefill completes in exactly G iterations (§4.2)
    assert len(prefill_iters) == g
    for plan in prefill_iters:
        blocks = {(s.block_start, s.block_end) for s in plan.prefill}
        # one-group-per-iteration rule
        assert len(blocks) == 1


def test_layered_cohort_merging():
    """§4.4: multiple small inputs arriving concurrently are merged into a
    single batch (cohort) that advances through the groups together."""
    sched = make_scheduler("layered", 8, n_slots=8, quantum=512)
    reqs = [Request(req_id=i, prompt_len=300, max_new_tokens=2)
            for i in range(3)]
    plans, _ = drive(sched, reqs)
    first = plans[0]
    assert len(first.prefill) == 3          # all three in the same cohort
    groups = {(s.block_start, s.block_end) for s in first.prefill}
    assert len(groups) == 1


def test_hybrid_degenerates_to_layered_and_chunked():
    """§4.3: chunk_size >= prompt -> pure layered; G=1 -> pure chunked."""
    n_blocks = 8
    # huge chunk => slices all have full token range (layered shape)
    h = make_scheduler("hybrid", n_blocks, n_slots=4, chunk_size=10_000,
                       quantum=512)
    reqs = [Request(req_id=0, prompt_len=2000, max_new_tokens=2)]
    plans, _ = drive(h, reqs)
    for p in plans:
        for sl in p.prefill:
            assert sl.token_start == 0 and sl.token_end == 2000
    # tiny prompt => one group => chunked shape (all blocks per slice)
    h2 = make_scheduler("hybrid", n_blocks, n_slots=4, chunk_size=512,
                        quantum=512)
    reqs2 = [Request(req_id=0, prompt_len=1500, max_new_tokens=2)]
    plans2, _ = drive(h2, reqs2)
    for p in plans2:
        for sl in p.prefill:
            assert (sl.block_start, sl.block_end) == (0, n_blocks)


# ------------------------------------------------------------------------
# Paged-memory admission gating + preemption (restore-by-recompute)
# ------------------------------------------------------------------------

from repro.serving.kvcache import PagedKVAllocator  # noqa: E402


def drive_paged(name, reqs, *, n_pages, page_size=4, decode_reserve=2,
                n_blocks=6, max_iters=100_000, **sched_kw):
    """Drive to drain under an oversubscribed page pool; returns the plans,
    the pre-plan decode snapshots, and the shared allocator."""
    sched = make_scheduler(name, n_blocks, **sched_kw)
    kv = PagedKVAllocator(n_pages, page_size, stash_factor=0.25)
    sched.attach_kv(kv, decode_reserve=decode_reserve)
    for r in reqs:
        sched.submit(r)
    plans, pre_decode = [], []
    it = 0
    while sched.has_work():
        pre = {rid for rid, r in sched.requests.items()
               if r.state == RequestState.DECODE}
        plan = sched.next_plan(now=float(it))
        plans.append(plan)
        pre_decode.append(pre)
        it += 1
        assert it < max_iters, f"{name} did not drain under pressure"
    return plans, pre_decode, sched, kv


PAGED_SPECS = [
    # (prompt_len, max_new_tokens) — sized so decode growth past the
    # reservation collides with concurrent residents
    [(10, 12)] * 8,
    [(30, 6), (6, 20), (14, 14), (22, 4), (9, 18), (17, 9)],
    [(40, 10), (5, 5), (5, 5), (5, 5), (12, 16), (3, 24), (8, 8)],
]


@pytest.mark.parametrize("name", ALL)
def test_invariants_under_admission_gating_and_preemption(name):
    total_preemptions = 0
    for spec in PAGED_SPECS:
        total_preemptions += _check_paged_invariants(name, spec)
    # across the workload set the pool really was oversubscribed and
    # pressure really evicted someone (single specs may drain pressure-free
    # for serial-admission schedulers like hybrid)
    assert total_preemptions > 0, name


def _check_paged_invariants(name, spec) -> int:
    n_blocks = 6
    reqs = [Request(req_id=i, prompt_len=p, max_new_tokens=m,
                    arrival_time=float(i))
            for i, (p, m) in enumerate(spec)]
    plans, pre_decode, sched, kv = drive_paged(
        name, reqs, n_pages=16, n_blocks=n_blocks, n_slots=8,
        token_budget=64, quantum=16)

    assert kv.pages_high_water <= kv.n_pages
    assert kv.pages_in_use() == 0          # every page returned at drain

    # I1 modulo preemption: every pre-iteration DECODE request is either
    # decoded or was evicted by THIS iteration's pressure pass
    for plan, pre in zip(plans, pre_decode):
        assert pre.issubset(set(plan.decode_ids) | set(plan.preempted_ids)), \
            name

    # I2/I3 per epoch: between preemptions, slices tile the CURRENT
    # recompute rectangle at most once in causal order; the final epoch
    # tiles it exactly once.
    epochs = {r.req_id: [[]] for r in reqs}
    for plan in plans:
        for rid in plan.preempted_ids:
            epochs[rid].append([])
        for sl in plan.prefill:
            epochs[sl.req_id][-1].append(sl)
    for r in reqs:
        assert len(epochs[r.req_id]) == r.n_preemptions + 1, name
        for ep, slices in enumerate(epochs[r.req_id]):
            grid = set()
            seen_tok, seen_blk = 0, 0
            for sl in slices:
                # I3 within the epoch
                assert sl.token_start == seen_tok, (name, r.req_id, ep)
                assert sl.block_start == seen_blk, (name, r.req_id, ep)
                for tok in range(sl.token_start, sl.token_end):
                    for blk in range(sl.block_start, sl.block_end):
                        assert (tok, blk) not in grid, (name, r.req_id, ep)
                        grid.add((tok, blk))
                if sl.block_end == n_blocks:
                    seen_tok, seen_blk = sl.token_end, 0
                else:
                    seen_blk = sl.block_end
            if ep == len(epochs[r.req_id]) - 1:
                # final epoch: full coverage of the recompute rectangle
                assert len(grid) == r.prompt_len * n_blocks, (name, r.req_id)

    # restore-by-recompute bookkeeping: every request produced exactly
    # max_new_tokens and recompute prompts grew by the folded generations
    for r in reqs:
        assert r.n_generated == r.max_new_tokens, (name, r.req_id)
        if r.n_preemptions:
            assert r.orig_prompt_len is not None
            assert r.prompt_len >= r.orig_prompt_len
    return sched.n_preemptions


def test_admission_gates_on_pages_not_just_slots():
    """8 slots but a pool that only fits ~2 requests: concurrency must be
    page-bound, never PagedPoolExhausted."""
    reqs = [Request(req_id=i, prompt_len=16, max_new_tokens=4,
                    arrival_time=float(i)) for i in range(6)]
    sched = make_scheduler("continuous", 4, n_slots=8)
    kv = PagedKVAllocator(n_pages=10, page_size=4)
    sched.attach_kv(kv, decode_reserve=4)
    for r in reqs:
        sched.submit(r)
    max_resident = 0
    it = 0
    while sched.has_work():
        sched.next_plan(now=float(it))
        max_resident = max(max_resident, sched.n_active)
        it += 1
        assert it < 1000
    assert max_resident == 2               # 5 pages each into a 10-page pool
    for r in reqs:
        assert r.n_generated == 4


def test_victims_chosen_latest_arrival_first():
    sched = make_scheduler("continuous", 4, n_slots=4)
    kv = PagedKVAllocator(n_pages=12, page_size=2)
    sched.attach_kv(kv, decode_reserve=0)
    # three residents admitted together; growth pressure must evict the
    # LATEST arrival (req 2) first
    for i in range(3):
        sched.submit(Request(req_id=i, prompt_len=7, max_new_tokens=10,
                             arrival_time=float(i)))
    preempted = []
    it = 0
    while sched.has_work():
        plan = sched.next_plan(now=float(it))
        preempted.extend(plan.preempted_ids)
        it += 1
        assert it < 1000
    assert preempted, "scenario must create pressure"
    assert preempted[0] == 2
    assert 0 not in preempted              # earliest resident never evicted


def test_double_preemption_folds_only_unfolded_tail():
    """A request preempted twice must fold each generated token into the
    recompute prompt exactly once: prompt_len == orig + n_generated."""
    sched = make_scheduler("continuous", 4, n_slots=4)
    kv = PagedKVAllocator(n_pages=64, page_size=2)
    sched.attach_kv(kv, decode_reserve=0)
    sched.submit(Request(req_id=0, prompt_len=8, max_new_tokens=20))
    it = 0
    forced = []
    while sched.has_work():
        r = sched.requests[0]
        if r.state == RequestState.DECODE and r.n_generated in (3, 7) \
                and r.n_generated not in forced:
            sched.preempt(0)
            forced.append(r.n_generated)
            assert r.prompt_len == 8 + r.n_generated   # no double fold
            assert r.n_folded == r.n_generated
        sched.next_plan(now=float(it))
        it += 1
        assert it < 1000
    assert forced == [3, 7]
    r = sched.requests[0]
    assert r.n_preemptions == 2
    assert r.n_generated == 20
    assert r.orig_prompt_len == 8
    assert r.prompt_len == 8 + 7       # folded at the second preemption
    assert kv.pages_in_use() == 0


def test_batch_victims_evicted_before_interactive():
    """Class-aware eviction: the victim walk ranks by CLASS_EVICT_RANK
    first — a batch resident is evicted before a LATER-arriving
    interactive one (pure recency would pick the interactive request)."""
    sched = make_scheduler("continuous", 4, n_slots=4)
    kv = PagedKVAllocator(n_pages=18, page_size=2)
    sched.attach_kv(kv, decode_reserve=0)
    # arrival order: interactive (earliest, protected), batch, interactive
    specs = [("interactive", 0), ("batch", 1), ("interactive", 2)]
    for i, (cls, t) in enumerate(specs):
        sched.submit(Request(req_id=i, prompt_len=7, max_new_tokens=10,
                             arrival_time=float(t), slo_class=cls))
    preempted = []
    it = 0
    while sched.has_work():
        plan = sched.next_plan(now=float(it))
        preempted.extend(plan.preempted_ids)
        it += 1
        assert it < 1000
    assert preempted, "scenario must create pressure"
    assert preempted[0] == 1               # the batch request, not req 2
    assert 0 not in preempted              # earliest resident never evicted


def test_batch_earliest_resident_does_not_shield_itself():
    """Class-aware forward-progress guard: the earliest-resident shield
    protects the earliest request of the HIGHEST-priority class present.
    With a batch request as the earliest resident and interactive ones
    behind it, pressure must evict the batch request first — under a
    class-blind guard it would shield itself while interactive requests
    starve (pure recency would evict request 2 instead)."""
    sched = make_scheduler("continuous", 4, n_slots=4)
    kv = PagedKVAllocator(n_pages=18, page_size=2)
    sched.attach_kv(kv, decode_reserve=0)
    specs = [("batch", 0), ("interactive", 1), ("interactive", 2)]
    for i, (cls, t) in enumerate(specs):
        sched.submit(Request(req_id=i, prompt_len=7, max_new_tokens=10,
                             arrival_time=float(t), slo_class=cls))
    preempted = []
    it = 0
    while sched.has_work():
        plan = sched.next_plan(now=float(it))
        preempted.extend(plan.preempted_ids)
        it += 1
        assert it < 1000
    assert preempted, "scenario must create pressure"
    assert preempted[0] == 0               # the batch EARLIEST resident
    assert 1 not in preempted              # earliest interactive protected
    for r in sched.requests.values():
        assert r.n_generated == r.max_new_tokens
    assert kv.pages_in_use() == 0


def test_class_headroom_blocks_batch_admission_only():
    """class_headroom={"interactive": k}: a batch request must leave k
    pages free at admission; an identical interactive request is exempt."""
    def drain(cls, headroom):
        sched = make_scheduler("continuous", 4, n_slots=4)
        kv = PagedKVAllocator(n_pages=10, page_size=4)
        sched.attach_kv(kv, decode_reserve=0,
                        class_headroom={"interactive": headroom})
        # needs 8 pages of the 10-page pool (32-token prompt, page 4)
        sched.submit(Request(req_id=0, prompt_len=32, max_new_tokens=2,
                             slo_class=cls))
        return sched

    ok = drain("interactive", 4)
    ok.next_plan()
    assert ok.requests[0].state != RequestState.WAITING   # admitted

    blocked = drain("batch", 4)
    with pytest.raises(RuntimeError, match="headroom"):
        blocked.next_plan()        # 8 + 4 headroom can NEVER fit 10 pages

    queued = drain("batch", 1)     # 8 + 1 fits the pool but not right now?
    queued.next_plan()             # 10 free - 1 headroom >= 8: admitted
    assert queued.requests[0].state != RequestState.WAITING


def test_class_headroom_batch_waits_while_interactive_flows():
    """Under a shared pool with interactive headroom, batch admission
    queues when it would eat into the reserve, while interactive requests
    keep being admitted — and the batch request still completes once the
    pool drains (no starvation-deadlock)."""
    sched = make_scheduler("continuous", 4, n_slots=8)
    kv = PagedKVAllocator(n_pages=12, page_size=4)
    sched.attach_kv(kv, decode_reserve=0,
                    class_headroom={"interactive": 4})
    sched.submit(Request(req_id=0, prompt_len=16, max_new_tokens=6,
                         arrival_time=0.0, slo_class="batch"))
    sched.submit(Request(req_id=1, prompt_len=16, max_new_tokens=6,
                         arrival_time=1.0, slo_class="interactive"))
    it = 0
    while sched.has_work():
        sched.next_plan(now=float(it))
        it += 1
        assert it < 1000
    for r in sched.requests.values():
        assert r.n_generated == r.max_new_tokens
    # batch (earlier arrival!) needed 4+4 headroom pages free of 12 — it
    # was admitted, but an interactive admission was never blocked by the
    # batch reserve; both made it through and the pool drained clean
    assert kv.pages_in_use() == 0


def test_oversized_request_raises_instead_of_deadlocking():
    sched = make_scheduler("chunked", 4, n_slots=4, token_budget=64)
    kv = PagedKVAllocator(n_pages=4, page_size=4)    # 16-token pool
    sched.attach_kv(kv)
    sched.submit(Request(req_id=0, prompt_len=100, max_new_tokens=4))
    with pytest.raises(RuntimeError, match="pool holds only"):
        while sched.has_work():
            sched.next_plan()


def test_no_allocator_means_legacy_behaviour():
    """Without attach_kv the schedulers must not preempt or gate."""
    sched = make_scheduler("chunked", 6, n_slots=4, token_budget=64)
    reqs = [Request(req_id=i, prompt_len=50, max_new_tokens=6)
            for i in range(6)]
    plans, _ = drive(sched, reqs)
    assert all(not p.preempted_ids for p in plans)
    assert sched.n_preemptions == 0


@given(spec=reqs_strategy)
@settings(max_examples=15, deadline=None)
def test_chunked_token_budget(spec):
    budget = 256
    sched = make_scheduler("chunked", 12, n_slots=8, token_budget=budget)
    reqs = [Request(req_id=i, prompt_len=p, max_new_tokens=m)
            for i, (p, m) in enumerate(spec)]
    plans, _ = drive(sched, reqs)
    for plan in plans:
        n_prefill = sum(s.n_tokens for s in plan.prefill)
        # hybrid-batch budget: decode tokens + prefill tokens <= budget
        # (unless decode alone exceeds it)
        if n_prefill:
            assert len(plan.decode_ids) + n_prefill <= budget
