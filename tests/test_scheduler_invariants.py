"""Property tests for the scheduling invariants (DESIGN.md / core/base.py):

  I1 (stall-free): every plan decodes EVERY request in DECODE state.
  I2 (coverage): a request's prefill slices tile [0, prompt_len) x
      [0, n_blocks) exactly once.
  I3 (order): slices are causally ordered (block-major within a token range;
      token ranges in order).
  Layered-specific: at most one layer group prefills per iteration and a
      request's prefill spans exactly G iterations.
"""

from __future__ import annotations

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, settings, strategies as st

from repro.core import layer_groups
from repro.core.base import SCHEDULERS, make_scheduler
from repro.core.plan import Request, RequestState

ALL = sorted(SCHEDULERS)


def drive(sched, reqs, max_iters=100_000):
    """Submit all requests at t=0 and run to drain; returns per-iteration
    plans plus the decode-state snapshot taken BEFORE each plan."""
    for r in reqs:
        sched.submit(r)
    plans, pre_decode = [], []
    it = 0
    while sched.has_work():
        pre = {rid for rid, r in sched.requests.items()
               if r.state == RequestState.DECODE}
        plan = sched.next_plan(now=float(it))
        plans.append(plan)
        pre_decode.append(pre)
        it += 1
        assert it < max_iters, f"{sched.name} did not drain"
    return plans, pre_decode


reqs_strategy = st.lists(
    st.tuples(st.integers(1, 3000), st.integers(1, 20)),
    min_size=1, max_size=12)


@pytest.mark.parametrize("name", ALL)
@given(spec=reqs_strategy)
@settings(max_examples=25, deadline=None)
def test_invariants(name, spec):
    n_blocks = 12
    sched = make_scheduler(name, n_blocks, n_slots=8, token_budget=256,
                           quantum=256)
    reqs = [Request(req_id=i, prompt_len=p, max_new_tokens=m)
            for i, (p, m) in enumerate(spec)]
    plans, pre_decode = drive(sched, reqs)

    # I1 stall-free: every pre-iteration DECODE request is in decode_ids.
    for plan, pre in zip(plans, pre_decode):
        assert pre.issubset(set(plan.decode_ids)), sched.name

    # I2 coverage: slices tile the (token x block) rectangle exactly once.
    cover = {r.req_id: {} for r in reqs}
    for plan in plans:
        for sl in plan.prefill:
            grid = cover[sl.req_id]
            for tok in range(sl.token_start, sl.token_end):
                for b in range(sl.block_start, sl.block_end):
                    key = (tok, b)
                    assert key not in grid, (sched.name, sl.req_id, key)
                    grid[key] = True
    for r in reqs:
        assert len(cover[r.req_id]) == r.prompt_len * n_blocks, sched.name

    # I3 order: per request, block ranges advance within a token range and
    # token ranges advance monotonically.
    seen = {r.req_id: (0, 0) for r in reqs}  # (tokens completed, next block)
    for plan in plans:
        for sl in plan.prefill:
            tok_done, next_block = seen[sl.req_id]
            assert sl.token_start == tok_done
            assert sl.block_start == next_block
            if sl.block_end == n_blocks:
                seen[sl.req_id] = (sl.token_end, 0)
            else:
                seen[sl.req_id] = (tok_done, sl.block_end)

    # every request decoded exactly max_new_tokens (first token from the
    # final prefill slice, the rest from decode iterations)
    n_decodes = {r.req_id: 0 for r in reqs}
    for plan in plans:
        for rid in plan.decode_ids:
            n_decodes[rid] += 1
    for r in reqs:
        assert n_decodes[r.req_id] == r.max_new_tokens - 1


@given(spec=st.tuples(st.integers(1, 20000), st.integers(1, 4)))
@settings(max_examples=40, deadline=None)
def test_layered_one_group_per_iteration(spec):
    prompt_len, _ = spec
    n_blocks = 24
    sched = make_scheduler("layered", n_blocks, n_slots=4, quantum=512)
    reqs = [Request(req_id=0, prompt_len=prompt_len, max_new_tokens=4)]
    plans, _ = drive(sched, reqs)

    g = layer_groups.num_groups(prompt_len, n_blocks, 512)
    prefill_iters = [p for p in plans if p.prefill]
    # prefill completes in exactly G iterations (§4.2)
    assert len(prefill_iters) == g
    for plan in prefill_iters:
        blocks = {(s.block_start, s.block_end) for s in plan.prefill}
        # one-group-per-iteration rule
        assert len(blocks) == 1


def test_layered_cohort_merging():
    """§4.4: multiple small inputs arriving concurrently are merged into a
    single batch (cohort) that advances through the groups together."""
    sched = make_scheduler("layered", 8, n_slots=8, quantum=512)
    reqs = [Request(req_id=i, prompt_len=300, max_new_tokens=2)
            for i in range(3)]
    plans, _ = drive(sched, reqs)
    first = plans[0]
    assert len(first.prefill) == 3          # all three in the same cohort
    groups = {(s.block_start, s.block_end) for s in first.prefill}
    assert len(groups) == 1


def test_hybrid_degenerates_to_layered_and_chunked():
    """§4.3: chunk_size >= prompt -> pure layered; G=1 -> pure chunked."""
    n_blocks = 8
    # huge chunk => slices all have full token range (layered shape)
    h = make_scheduler("hybrid", n_blocks, n_slots=4, chunk_size=10_000,
                       quantum=512)
    reqs = [Request(req_id=0, prompt_len=2000, max_new_tokens=2)]
    plans, _ = drive(h, reqs)
    for p in plans:
        for sl in p.prefill:
            assert sl.token_start == 0 and sl.token_end == 2000
    # tiny prompt => one group => chunked shape (all blocks per slice)
    h2 = make_scheduler("hybrid", n_blocks, n_slots=4, chunk_size=512,
                        quantum=512)
    reqs2 = [Request(req_id=0, prompt_len=1500, max_new_tokens=2)]
    plans2, _ = drive(h2, reqs2)
    for p in plans2:
        for sl in p.prefill:
            assert (sl.block_start, sl.block_end) == (0, n_blocks)


@given(spec=reqs_strategy)
@settings(max_examples=15, deadline=None)
def test_chunked_token_budget(spec):
    budget = 256
    sched = make_scheduler("chunked", 12, n_slots=8, token_budget=budget)
    reqs = [Request(req_id=i, prompt_len=p, max_new_tokens=m)
            for i, (p, m) in enumerate(spec)]
    plans, _ = drive(sched, reqs)
    for plan in plans:
        n_prefill = sum(s.n_tokens for s in plan.prefill)
        # hybrid-batch budget: decode tokens + prefill tokens <= budget
        # (unless decode alone exceeds it)
        if n_prefill:
            assert len(plan.decode_ids) + n_prefill <= budget
