"""Per-kernel validation: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracles in kernels/ref.py. All kernels execute in Pallas
interpret mode on CPU (the TPU lowering path is identical code)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_default_matmul_precision", "highest")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- flash attn

FLASH_SWEEP = [
    # (B, S, H, Hkv, hd)
    (1, 128, 4, 4, 64),      # MHA, single tile
    (2, 256, 4, 2, 64),      # GQA 2:1, two tiles
    (1, 384, 8, 1, 32),      # MQA, non-square tiling
    (2, 100, 4, 4, 64),      # ragged S (padding path)
    (1, 257, 4, 2, 128),     # ragged S + MXU-width head
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,hd", FLASH_SWEEP)
def test_flash_attention_causal(b, s, h, hkv, hd, dtype):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(s * h), 3)
    q = _rand(kq, (b, s, h, hd), dtype)
    k = _rand(kk, (b, s, hkv, hd), dtype)
    v = _rand(kv, (b, s, hkv, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128, 300])
def test_flash_attention_windowed(window):
    b, s, h, hkv, hd = 1, 256, 4, 2, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(window), 3)
    q = _rand(kq, (b, s, h, hd), jnp.float32)
    k = _rand(kk, (b, s, hkv, hd), jnp.float32)
    v = _rand(kv, (b, s, hkv, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, hkv, hd = 1, 256, 4, 4, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(kq, (b, s, h, hd), jnp.float32)
    k = _rand(kk, (b, s, hkv, hd), jnp.float32)
    v = _rand(kv, (b, s, hkv, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- decode attn

DECODE_SWEEP = [
    # (B, S_max, H, Hkv, hd)
    (4, 128, 4, 4, 64),
    (2, 256, 8, 2, 64),
    (3, 200, 4, 1, 32),      # ragged cache length
    (1, 512, 4, 4, 128),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,hd", DECODE_SWEEP)
def test_decode_attention(b, s, h, hkv, hd, dtype):
    kq, kk, kv, kl = jax.random.split(jax.random.PRNGKey(b * s), 4)
    q = _rand(kq, (b, h, hd), dtype)
    k = _rand(kk, (b, s, hkv, hd), dtype)
    v = _rand(kv, (b, s, hkv, hd), dtype)
    lengths = jax.random.randint(kl, (b,), 1, s + 1)
    got = ops.decode_attention(q, k, v, lengths, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_windowed():
    b, s, h, hkv, hd = 2, 256, 4, 2, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(kq, (b, h, hd), jnp.float32)
    k = _rand(kk, (b, s, hkv, hd), jnp.float32)
    v = _rand(kv, (b, s, hkv, hd), jnp.float32)
    lengths = jnp.asarray([200, 64])
    got = ops.decode_attention(q, k, v, lengths, window=32, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------- paged decode attn

PAGED_SWEEP = [
    # (B, H, Hkv, hd, page_size, n_pages, max_pages)
    (3, 4, 2, 64, 16, 24, 6),
    (2, 8, 1, 32, 8, 40, 10),      # MQA, small pages
    (1, 4, 4, 128, 32, 8, 4),      # MHA, MXU-width head
    (4, 4, 2, 64, 16, 20, 4),      # tight pool, short sequences
]


def _ragged_block_tables(rng, b, page_size, n_pages, max_pages):
    """Ragged lengths + SHUFFLED physical page assignment: logical order
    must come entirely from the block table, not from page locality."""
    lengths = rng.integers(1, max_pages * page_size + 1, size=b)
    bt = np.zeros((b, max_pages), np.int32)
    perm = rng.permutation(n_pages)
    k = 0
    for i in range(b):
        n = -(-int(lengths[i]) // page_size)
        bt[i, :n] = perm[k:k + n]
        k += n
    assert k <= n_pages, "sweep entry overcommits the page pool"
    return jnp.asarray(lengths, jnp.int32), jnp.asarray(bt)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,h,hkv,hd,page,npages,maxp", PAGED_SWEEP)
def test_paged_decode_attention(b, h, hkv, hd, page, npages, maxp, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * hd + page), 3)
    q = _rand(ks[0], (b, h, hd), dtype)
    kp = _rand(ks[1], (npages, page, hkv, hd), dtype)
    vp = _rand(ks[2], (npages, page, hkv, hd), dtype)
    lengths, bt = _ragged_block_tables(
        np.random.default_rng(b * page), b, page, npages, maxp)
    got = ops.paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_decode_attention_windowed():
    b, h, hkv, hd, page, npages, maxp = 2, 4, 2, 64, 16, 16, 5
    ks = jax.random.split(jax.random.PRNGKey(11), 3)
    q = _rand(ks[0], (b, h, hd), jnp.float32)
    kp = _rand(ks[1], (npages, page, hkv, hd), jnp.float32)
    vp = _rand(ks[2], (npages, page, hkv, hd), jnp.float32)
    lengths, bt = _ragged_block_tables(
        np.random.default_rng(5), b, page, npages, maxp)
    got = ops.paged_decode_attention(q, kp, vp, bt, lengths, window=24,
                                     interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, bt, lengths, window=24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_paged_matches_contiguous_decode_via_allocator_tables():
    """End-to-end mapping check: scatter a contiguous slot cache into the
    page pool with PagedKVAllocator block tables, then the paged kernel
    over the pool must equal the contiguous kernel over the slot rows."""
    from repro.serving.kvcache import PagedKVAllocator
    b, h, hkv, hd, page = 3, 4, 2, 32, 8
    s_max = 64
    kv = PagedKVAllocator(n_pages=b * s_max // page, page_size=page)
    lengths = np.array([50, 17, 8], np.int32)
    for rid, n in enumerate(lengths):
        kv.reserve(rid, int(n))
    max_pages = s_max // page
    bt = np.zeros((b, max_pages), np.int32)
    for rid in range(b):
        t = kv.block_table(rid)
        bt[rid, :len(t)] = t
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(ks[0], (b, h, hd), jnp.float32)
    k_slot = _rand(ks[1], (b, s_max, hkv, hd), jnp.float32)
    v_slot = _rand(ks[2], (b, s_max, hkv, hd), jnp.float32)
    # physical placement: page j of request rid holds slot row tokens
    # [j*page, (j+1)*page) — exactly what the engine's scatter would do
    kp = np.zeros((kv.n_pages, page, hkv, hd), np.float32)
    vp = np.zeros_like(kp)
    for rid in range(b):
        for j, pid in enumerate(kv.block_table(rid)):
            kp[pid] = np.asarray(k_slot[rid, j * page:(j + 1) * page])
            vp[pid] = np.asarray(v_slot[rid, j * page:(j + 1) * page])
    got = ops.paged_decode_attention(q, jnp.asarray(kp), jnp.asarray(vp),
                                     jnp.asarray(bt),
                                     jnp.asarray(lengths), interpret=True)
    want = ops.decode_attention(q, k_slot, v_slot, jnp.asarray(lengths),
                                interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------ paged verify attn

VERIFY_SWEEP = [
    # (B, W, H, Hkv, hd, page_size, n_pages, max_pages)
    (3, 3, 4, 2, 64, 16, 24, 6),
    (2, 5, 8, 1, 32, 8, 40, 10),     # MQA, small pages, k=4 window
    (1, 2, 4, 4, 128, 32, 8, 4),     # MHA, MXU-width head
    (4, 4, 4, 2, 64, 16, 20, 4),     # tight pool, short sequences
]


def _verify_tables(rng, b, w, page_size, n_pages, max_pages):
    """Like _ragged_block_tables but lengths always cover the W-token
    window (the engine writes the window's K/V before verifying)."""
    lengths = rng.integers(w, max_pages * page_size + 1, size=b)
    bt = np.zeros((b, max_pages), np.int32)
    perm = rng.permutation(n_pages)
    k = 0
    for i in range(b):
        n = -(-int(lengths[i]) // page_size)
        bt[i, :n] = perm[k:k + n]
        k += n
    assert k <= n_pages, "sweep entry overcommits the page pool"
    return jnp.asarray(lengths, jnp.int32), jnp.asarray(bt)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,w,h,hkv,hd,page,npages,maxp", VERIFY_SWEEP)
def test_paged_verify_attention(b, w, h, hkv, hd, page, npages, maxp, dtype):
    ks = jax.random.split(jax.random.PRNGKey(b * hd + w), 3)
    q = _rand(ks[0], (b, w, h, hd), dtype)
    kp = _rand(ks[1], (npages, page, hkv, hd), dtype)
    vp = _rand(ks[2], (npages, page, hkv, hd), dtype)
    lengths, bt = _verify_tables(
        np.random.default_rng(b * page + w), b, w, page, npages, maxp)
    got = ops.paged_verify_attention(q, kp, vp, bt, lengths, interpret=True)
    want = ref.paged_verify_attention_ref(q, kp, vp, bt, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_paged_verify_window_one_matches_decode():
    """W=1 degenerates to plain paged decode — same numbers, not merely
    close: both kernels must agree bit-for-bit on the single-query path
    (the spec-off equivalence the engine relies on)."""
    b, h, hkv, hd, page, npages, maxp = 3, 4, 2, 64, 16, 24, 6
    ks = jax.random.split(jax.random.PRNGKey(17), 3)
    q = _rand(ks[0], (b, h, hd), jnp.float32)
    kp = _rand(ks[1], (npages, page, hkv, hd), jnp.float32)
    vp = _rand(ks[2], (npages, page, hkv, hd), jnp.float32)
    lengths, bt = _ragged_block_tables(
        np.random.default_rng(9), b, page, npages, maxp)
    got = ops.paged_verify_attention(q[:, None], kp, vp, bt, lengths,
                                     interpret=True)[:, 0]
    want = ops.paged_decode_attention(q, kp, vp, bt, lengths, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_paged_verify_attention_windowed():
    b, w, h, hkv, hd, page, npages, maxp = 2, 3, 4, 2, 64, 16, 16, 5
    ks = jax.random.split(jax.random.PRNGKey(23), 3)
    q = _rand(ks[0], (b, w, h, hd), jnp.float32)
    kp = _rand(ks[1], (npages, page, hkv, hd), jnp.float32)
    vp = _rand(ks[2], (npages, page, hkv, hd), jnp.float32)
    lengths, bt = _verify_tables(
        np.random.default_rng(6), b, w, page, npages, maxp)
    got = ops.paged_verify_attention(q, kp, vp, bt, lengths, window=24,
                                     interpret=True)
    want = ref.paged_verify_attention_ref(q, kp, vp, bt, lengths, window=24)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------- moe gmm

GMM_SWEEP = [
    # (E, C, d, f)
    (4, 128, 64, 128),
    (8, 64, 128, 256),       # C below tile size (padding path)
    (2, 300, 64, 100),       # ragged C and f
    (16, 8, 32, 64),         # tiny capacity (decode-like)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", GMM_SWEEP)
def test_moe_gmm(e, c, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(e * c), 4)
    x = _rand(ks[0], (e, c, d), dtype)
    wg = _rand(ks[1], (e, d, f), dtype) / np.sqrt(d)
    wu = _rand(ks[2], (e, d, f), dtype) / np.sqrt(d)
    wd = _rand(ks[3], (e, f, d), dtype) / np.sqrt(f)
    got = ops.moe_gmm(x, wg, wu, wd, interpret=True)
    want = ref.moe_gmm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ------------------------------------------------------------ ragged gmm

RAGGED_SWEEP = [
    # (E, d, f, m_blk, counts) — skewed loads, empty experts, sentinel tail
    (4, 64, 128, 8, [16, 0, 3, 1]),          # empty expert + tiny groups
    (8, 64, 256, 16, [64, 0, 0, 0, 0, 0, 0, 1]),   # heavy skew
    (2, 64, 100, 128, [128, 128]),           # exact tiles, ragged f
    (4, 32, 64, 8, [0, 0, 0, 0]),            # fully masked batch
]


def _ragged_layout(e, m_blk, counts):
    """Tile-aligned group layout + metadata from per-expert counts."""
    padded = [-(-c // m_blk) * m_blk for c in counts]
    used = sum(padded)
    n_rows = used + m_blk            # leave a sentinel tail tile
    tile_expert = []
    for ex, p_ in enumerate(padded):
        tile_expert += [ex] * (p_ // m_blk)
    tile_expert += [e] * ((n_rows - used) // m_blk)
    return n_rows, jnp.asarray(tile_expert, jnp.int32)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,d,f,m_blk,counts", RAGGED_SWEEP)
def test_moe_gmm_ragged(e, d, f, m_blk, counts, dtype):
    n_rows, tile_expert = _ragged_layout(e, m_blk, counts)
    ks = jax.random.split(jax.random.PRNGKey(e * d + m_blk), 4)
    rows = _rand(ks[0], (n_rows, d), dtype)
    wg = _rand(ks[1], (e, d, f), dtype) / np.sqrt(d)
    wu = _rand(ks[2], (e, d, f), dtype) / np.sqrt(d)
    wd = _rand(ks[3], (e, f, d), dtype) / np.sqrt(f)
    got = ops.moe_gmm_ragged(rows, wg, wu, wd, tile_expert, m_blk=m_blk,
                             interpret=True)
    want = ref.moe_gmm_ragged_ref(rows, wg, wu, wd, tile_expert, m_blk)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))
    # sentinel tiles must come out exactly zero
    sent = np.repeat(np.asarray(tile_expert) == e, m_blk)
    assert not np.asarray(got, np.float32)[sent].any()


def test_fetch_expert_ids_forward_fill():
    te = jnp.asarray([1, 1, 3, 4, 4], jnp.int32)
    got = ops.fetch_expert_ids(te, 4)       # id 4 == sentinel
    np.testing.assert_array_equal(np.asarray(got), [1, 1, 3, 3, 3])
    all_sent = ops.fetch_expert_ids(jnp.asarray([4, 4], jnp.int32), 4)
    np.testing.assert_array_equal(np.asarray(all_sent), [0, 0])


def test_ragged_dispatch_matches_expert_ffn_ref():
    """Acceptance: the ragged pipeline (dispatch + Pallas kernel + combine)
    must match the dense path over expert_ffn_ref on skewed routings with
    empty experts and masked padding tokens."""
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from conftest import tiny_moe
    from repro.models import moe

    cfg = tiny_moe()          # E=4, top_k=2
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    t = 24
    xf = jax.random.normal(jax.random.PRNGKey(1), (t, cfg.d_model))
    w = jax.nn.softmax(jax.random.normal(jax.random.PRNGKey(2), (t, 2)), -1)
    # skew: most tokens on expert 0, expert 1 empty, tail tokens masked
    idx = np.zeros((t, 2), np.int32)
    idx[:, 1] = 2
    idx[5:8, 1] = 3
    idx[-4:] = cfg.moe.n_experts            # masked (padding) tokens
    idx = jnp.asarray(idx)

    dense, counts_d, _ = moe._dispatch_gmm_combine(
        cfg, p, xf, idx, w, t, cfg.moe.n_experts, moe.expert_ffn_ref)
    ragged, counts_r, _ = moe._dispatch_gmm_combine_ragged(
        cfg, p, xf, idx, w, cfg.moe.n_experts,
        lambda c, p_, rows, te, mb: ops.moe_gmm_ragged(
            rows, p_["w_gate"], p_["w_up"], p_["w_down"], te, m_blk=mb,
            interpret=True))
    np.testing.assert_array_equal(np.asarray(counts_d), np.asarray(counts_r))
    assert int(counts_r[1]) == 0            # expert 1 really is empty
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(dense),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------- kernel <-> model integration

def test_model_forward_with_pallas_gmm_matches_ref():
    """Plugging the Pallas moe_gmm into the real model must not change
    outputs vs the jnp expert FFN."""
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from conftest import tiny_moe
    from repro.models.model import DecoderModel

    cfg = tiny_moe()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.arange(1, 33, dtype=jnp.int32).reshape(2, 16)
    ref_logits, _, _ = model.forward(params, tokens)
    got_logits, _, _ = model.forward(params, tokens,
                                     gmm_fn=ops.model_gmm_fn(cfg))
    np.testing.assert_allclose(np.asarray(got_logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)


def test_model_forward_with_ragged_pallas_gmm_matches_ref():
    """The ragged Pallas pipeline plugged into the real model (dropless
    serving path) must match the dense jnp expert FFN."""
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from conftest import tiny_moe
    from repro.models.model import DecoderModel

    cfg = tiny_moe()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.arange(1, 33, dtype=jnp.int32).reshape(2, 16)
    ref_logits, _, ref_aux = model.forward(params, tokens, dropless=True)
    got_logits, _, got_aux = model.forward(params, tokens,
                                           gmm_fn=ops.ragged_gmm_fn(cfg),
                                           moe_dispatch="ragged")
    np.testing.assert_allclose(np.asarray(got_logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_array_equal(np.asarray(ref_aux["expert_counts"]),
                                  np.asarray(got_aux["expert_counts"]))


def test_gmm_fn_dispatch_contract_mismatch_raises():
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from conftest import tiny_moe
    from repro.models import moe

    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jnp.zeros((1, 4, cfg.d_model))
    with pytest.raises(ValueError):
        moe.apply_moe(cfg, p, x, gmm_fn=ops.ragged_gmm_fn(cfg),
                      moe_dispatch="dense")
    with pytest.raises(ValueError):
        moe.apply_moe(cfg, p, x, gmm_fn=ops.model_gmm_fn(cfg),
                      moe_dispatch="ragged")
