"""Per-kernel validation: sweep shapes/dtypes and assert_allclose against
the pure-jnp oracles in kernels/ref.py. All kernels execute in Pallas
interpret mode on CPU (the TPU lowering path is identical code)."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

jax.config.update("jax_default_matmul_precision", "highest")


def _rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------- flash attn

FLASH_SWEEP = [
    # (B, S, H, Hkv, hd)
    (1, 128, 4, 4, 64),      # MHA, single tile
    (2, 256, 4, 2, 64),      # GQA 2:1, two tiles
    (1, 384, 8, 1, 32),      # MQA, non-square tiling
    (2, 100, 4, 4, 64),      # ragged S (padding path)
    (1, 257, 4, 2, 128),     # ragged S + MXU-width head
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,hd", FLASH_SWEEP)
def test_flash_attention_causal(b, s, h, hkv, hd, dtype):
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(s * h), 3)
    q = _rand(kq, (b, s, h, hd), dtype)
    k = _rand(kk, (b, s, hkv, hd), dtype)
    v = _rand(kv, (b, s, hkv, hd), dtype)
    got = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window", [32, 128, 300])
def test_flash_attention_windowed(window):
    b, s, h, hkv, hd = 1, 256, 4, 2, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(window), 3)
    q = _rand(kq, (b, s, h, hd), jnp.float32)
    k = _rand(kk, (b, s, hkv, hd), jnp.float32)
    v = _rand(kv, (b, s, hkv, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=True, window=window,
                              interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_noncausal():
    b, s, h, hkv, hd = 1, 256, 4, 4, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(7), 3)
    q = _rand(kq, (b, s, h, hd), jnp.float32)
    k = _rand(kk, (b, s, hkv, hd), jnp.float32)
    v = _rand(kv, (b, s, hkv, hd), jnp.float32)
    got = ops.flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ------------------------------------------------------------- decode attn

DECODE_SWEEP = [
    # (B, S_max, H, Hkv, hd)
    (4, 128, 4, 4, 64),
    (2, 256, 8, 2, 64),
    (3, 200, 4, 1, 32),      # ragged cache length
    (1, 512, 4, 4, 128),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,hkv,hd", DECODE_SWEEP)
def test_decode_attention(b, s, h, hkv, hd, dtype):
    kq, kk, kv, kl = jax.random.split(jax.random.PRNGKey(b * s), 4)
    q = _rand(kq, (b, h, hd), dtype)
    k = _rand(kk, (b, s, hkv, hd), dtype)
    v = _rand(kv, (b, s, hkv, hd), dtype)
    lengths = jax.random.randint(kl, (b,), 1, s + 1)
    got = ops.decode_attention(q, k, v, lengths, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_decode_attention_windowed():
    b, s, h, hkv, hd = 2, 256, 4, 2, 64
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(3), 3)
    q = _rand(kq, (b, h, hd), jnp.float32)
    k = _rand(kk, (b, s, hkv, hd), jnp.float32)
    v = _rand(kv, (b, s, hkv, hd), jnp.float32)
    lengths = jnp.asarray([200, 64])
    got = ops.decode_attention(q, k, v, lengths, window=32, interpret=True)
    want = ref.decode_attention_ref(q, k, v, lengths, window=32)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


# ----------------------------------------------------------------- moe gmm

GMM_SWEEP = [
    # (E, C, d, f)
    (4, 128, 64, 128),
    (8, 64, 128, 256),       # C below tile size (padding path)
    (2, 300, 64, 100),       # ragged C and f
    (16, 8, 32, 64),         # tiny capacity (decode-like)
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("e,c,d,f", GMM_SWEEP)
def test_moe_gmm(e, c, d, f, dtype):
    ks = jax.random.split(jax.random.PRNGKey(e * c), 4)
    x = _rand(ks[0], (e, c, d), dtype)
    wg = _rand(ks[1], (e, d, f), dtype) / np.sqrt(d)
    wu = _rand(ks[2], (e, d, f), dtype) / np.sqrt(d)
    wd = _rand(ks[3], (e, f, d), dtype) / np.sqrt(f)
    got = ops.moe_gmm(x, wg, wu, wd, interpret=True)
    want = ref.moe_gmm_ref(x, wg, wu, wd)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# ------------------------------------------- kernel <-> model integration

def test_model_forward_with_pallas_gmm_matches_ref():
    """Plugging the Pallas moe_gmm into the real model must not change
    outputs vs the jnp expert FFN."""
    import sys, os
    sys.path.insert(0, os.path.dirname(__file__))
    from conftest import tiny_moe
    from repro.models.model import DecoderModel

    cfg = tiny_moe()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    tokens = jnp.arange(1, 33, dtype=jnp.int32).reshape(2, 16)
    ref_logits, _, _ = model.forward(params, tokens)
    got_logits, _, _ = model.forward(params, tokens,
                                     gmm_fn=ops.model_gmm_fn(cfg))
    np.testing.assert_allclose(np.asarray(got_logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)
