"""Metrics: percentiles, TBT extraction, per-request SLO rule."""

from __future__ import annotations

import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, strategies as st

from repro.core.plan import Request
from repro.serving.metrics import SLOConfig, percentile, request_metrics


def _req(arrival, first, gaps):
    r = Request(req_id=0, prompt_len=10, max_new_tokens=len(gaps) + 1,
                arrival_time=arrival)
    r.first_token_time = first
    t = first
    for g in gaps:
        t += g
        r.token_times.append(t)
    return r


def test_ttft_and_tbts():
    r = _req(1.0, 3.0, [0.1, 0.2, 0.05])
    assert r.ttft() == pytest.approx(2.0)
    assert r.tbts() == pytest.approx([0.1, 0.2, 0.05])


def test_slo_per_request_rule():
    slo = SLOConfig(ttft_slo=2.5, tbt_slo=0.15)
    ok = _req(0.0, 2.0, [0.1, 0.1])
    bad_ttft = _req(0.0, 3.0, [0.1])
    bad_tail = _req(0.0, 1.0, [0.1, 0.2])   # one violating gap kills it
    assert slo.attained(ok)
    assert not slo.attained(bad_ttft)
    assert not slo.attained(bad_tail)
    m = request_metrics([ok, bad_ttft, bad_tail], slo)
    assert m["slo_attainment"] == pytest.approx(1 / 3)
    assert m["ttft_attainment"] == pytest.approx(2 / 3)
    assert m["tbt_attainment"] == pytest.approx(2 / 3)


@given(st.lists(st.floats(0, 1e3), min_size=1, max_size=200))
def test_percentile_bounds(xs):
    p0, p50, p99 = (percentile(xs, q) for q in (0, 50, 99))
    assert min(xs) <= p0 <= p50 <= p99 <= max(xs)


def test_percentile_empty_nan():
    import math
    assert math.isnan(percentile([], 99))


def test_percentile_linear_interpolation():
    """Satellite: proper linear-interpolation percentiles (numpy's
    default), not nearest-rank-via-round — which returned the MAXIMUM for
    p99 on any sample smaller than ~50 points."""
    import numpy as np
    assert percentile([0.0, 10.0], 50) == pytest.approx(5.0)
    assert percentile([0.0, 10.0], 99) == pytest.approx(9.9)
    xs = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0, 10.0]
    # 10 samples: the old round() rule mapped p99 -> the max; linear
    # interpolation lands strictly below it
    assert percentile(xs, 99) == pytest.approx(float(np.percentile(xs, 99)))
    assert percentile(xs, 99) < 10.0
    for q in (0, 10, 25, 50, 90, 99, 100):
        assert percentile(xs, q) == pytest.approx(
            float(np.percentile(xs, q)))
    assert percentile([7.0], 99) == 7.0


def test_request_metrics_p50_p90_columns():
    reqs = [_req(0.0, float(i + 1), [0.1 * (i + 1)]) for i in range(10)]
    m = request_metrics(reqs)
    assert m["ttft_p50"] == pytest.approx(5.5)
    assert m["ttft_p50"] <= m["ttft_p90"] <= m["ttft_p99"]
    assert m["tbt_p50"] <= m["tbt_p90"] <= m["tbt_p99"]


def test_per_class_metrics_split_and_slos():
    from repro.serving.metrics import per_class_metrics
    fast = _req(0.0, 1.0, [0.1, 0.1])
    slow = _req(0.0, 9.0, [0.3, 0.3])
    fast.slo_class = "interactive"
    slow.slo_class = "batch"
    per = per_class_metrics(
        [fast, slow],
        {"interactive": SLOConfig(2.0, 0.15), "batch": SLOConfig(10.0, 0.2)})
    assert set(per) == {"interactive", "batch"}
    assert per["interactive"]["n_requests"] == 1
    assert per["interactive"]["slo_attainment"] == 1.0
    assert per["batch"]["slo_attainment"] == 0.0      # TBT 0.3 > 0.2
    assert per["interactive"]["ttft_mean"] == pytest.approx(1.0)
    assert per["batch"]["ttft_mean"] == pytest.approx(9.0)
    # single shared SLOConfig applies to every class
    per2 = per_class_metrics([fast, slow], SLOConfig(10.0, 0.5))
    assert per2["batch"]["slo_attainment"] == 1.0
