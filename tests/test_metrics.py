"""Metrics: percentiles, TBT extraction, per-request SLO rule."""

from __future__ import annotations

import pytest
try:
    from hypothesis import given, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, strategies as st

from repro.core.plan import Request
from repro.serving.metrics import SLOConfig, percentile, request_metrics


def _req(arrival, first, gaps):
    r = Request(req_id=0, prompt_len=10, max_new_tokens=len(gaps) + 1,
                arrival_time=arrival)
    r.first_token_time = first
    t = first
    for g in gaps:
        t += g
        r.token_times.append(t)
    return r


def test_ttft_and_tbts():
    r = _req(1.0, 3.0, [0.1, 0.2, 0.05])
    assert r.ttft() == pytest.approx(2.0)
    assert r.tbts() == pytest.approx([0.1, 0.2, 0.05])


def test_slo_per_request_rule():
    slo = SLOConfig(ttft_slo=2.5, tbt_slo=0.15)
    ok = _req(0.0, 2.0, [0.1, 0.1])
    bad_ttft = _req(0.0, 3.0, [0.1])
    bad_tail = _req(0.0, 1.0, [0.1, 0.2])   # one violating gap kills it
    assert slo.attained(ok)
    assert not slo.attained(bad_ttft)
    assert not slo.attained(bad_tail)
    m = request_metrics([ok, bad_ttft, bad_tail], slo)
    assert m["slo_attainment"] == pytest.approx(1 / 3)
    assert m["ttft_attainment"] == pytest.approx(2 / 3)
    assert m["tbt_attainment"] == pytest.approx(2 / 3)


@given(st.lists(st.floats(0, 1e3), min_size=1, max_size=200))
def test_percentile_bounds(xs):
    p0, p50, p99 = (percentile(xs, q) for q in (0, 50, 99))
    assert min(xs) <= p0 <= p50 <= p99 <= max(xs)


def test_percentile_empty_nan():
    import math
    assert math.isnan(percentile([], 99))
