"""Analytic cost model: the apparatus behind every simulator-driven paper
number. Validates the paper's qualitative claims hold inside the model:
chunk-count amplification of expert bytes, ridge-point shift, energy
accounting."""

from __future__ import annotations


import pytest

from repro.configs import get_config
from repro.core.plan import IterationPlan, PrefillSlice, Request
from repro.serving.cost_model import (CostModel, H100X2, TPU_V5E,
                                      expected_coverage)


@pytest.fixture(scope="module")
def qwen():
    return get_config("qwen3-30b-a3b")


def _prefill_only_plan(cfg, n_chunks: int, prompt_len: int):
    """Expert bytes for a prompt split into n_chunks full-stack chunks."""
    cm = CostModel(cfg, H100X2)
    total = 0.0
    chunk = prompt_len // n_chunks
    for i in range(n_chunks):
        plan = IterationPlan(prefill=[PrefillSlice(
            req_id=0, token_start=i * chunk, token_end=(i + 1) * chunk,
            block_start=0, block_end=cfg.n_layers)])
        total += cm.iteration_cost(plan, {})["expert_bytes"]
    return total


def test_chunking_amplifies_expert_bytes(qwen):
    """§3.1 sparsity erosion: more chunks -> more expert-weight traffic."""
    one = _prefill_only_plan(qwen, 1, 8192)
    four = _prefill_only_plan(qwen, 4, 8192)
    sixteen = _prefill_only_plan(qwen, 16, 8192)
    assert one < four < sixteen
    # 16 chunks of 512 tokens: each chunk covers ~98% of experts
    # => ~16x the single-pass load is the theoretical ceiling; expect >8x
    assert sixteen / one > 8


def test_layered_prefill_has_no_amplification(qwen):
    """Layered slices (full token range, one group each) sum to exactly the
    single-pass expert load."""
    cm = CostModel(qwen, H100X2)
    L = qwen.n_layers
    groups = [(i * L // 16, (i + 1) * L // 16) for i in range(16)]
    layered = 0.0
    for b0, b1 in groups:
        plan = IterationPlan(prefill=[PrefillSlice(
            req_id=0, token_start=0, token_end=8192,
            block_start=b0, block_end=b1)])
        layered += cm.iteration_cost(plan, {})["expert_bytes"]
    one_shot = _prefill_only_plan(qwen, 1, 8192)
    assert abs(layered - one_shot) / one_shot < 1e-9


def test_fig2_shape_load_inverse_in_chunk_size(qwen):
    """Fig 2: MoE weight load falls roughly as 1/chunk-size (until
    coverage saturates)."""
    loads = {c: _prefill_only_plan(qwen, 8192 // c, 8192)
             for c in (512, 1024, 2048, 4096)}
    # halving chunk count roughly halves load while coverage is saturated
    assert loads[512] / loads[1024] == pytest.approx(2.0, rel=0.2)
    assert loads[1024] / loads[2048] == pytest.approx(2.0, rel=0.3)


def test_ridge_point_batch_threshold(qwen):
    """§2.5: ~200-600 tokens per expert needed to cross the ridge point;
    a 2048-token prompt leaves each expert memory-bound, 8192+ compute-
    bound territory (paper: 'more than 8192 tokens')."""
    cm = CostModel(qwen, H100X2)
    for prompt, bound in ((2048, "memory"), (16384, "compute")):
        plan = IterationPlan(prefill=[PrefillSlice(
            req_id=0, token_start=0, token_end=prompt,
            block_start=0, block_end=qwen.n_layers)])
        cost = cm.iteration_cost(plan, {})
        assert cost["bound"] == bound, (prompt, cost["bound"])


def test_decode_iteration_memory_bound(qwen):
    cm = CostModel(qwen, H100X2)
    reqs = {i: Request(req_id=i, prompt_len=2048, max_new_tokens=64,
                       n_generated=8) for i in range(16)}
    plan = IterationPlan(decode_ids=list(reqs))
    cost = cm.iteration_cost(plan, reqs)
    assert cost["bound"] == "memory"
    assert cost["duration"] > 0 and cost["energy"] > 0


def test_energy_scales_with_traffic(qwen):
    cm = CostModel(qwen, H100X2)
    p1 = IterationPlan(prefill=[PrefillSlice(0, 0, 512, 0, qwen.n_layers)])
    p2 = IterationPlan(prefill=[PrefillSlice(0, 0, 4096, 0, qwen.n_layers)])
    c1, c2 = cm.iteration_cost(p1, {}), cm.iteration_cost(p2, {})
    assert c2["energy"] > c1["energy"]
    assert c2["flops"] > 7 * c1["flops"]


def test_union_rule_no_double_count(qwen):
    """Decode + prefill slice in the same iteration share expert loads at
    full coverage (the fused-hybrid-batch union semantics)."""
    cm = CostModel(qwen, H100X2)
    reqs = {0: Request(req_id=0, prompt_len=128, max_new_tokens=8,
                       n_generated=2)}
    big = PrefillSlice(1, 0, 8192, 0, qwen.n_layers)
    both = cm.iteration_cost(IterationPlan(decode_ids=[0], prefill=[big]),
                             reqs)
    alone = cm.iteration_cost(IterationPlan(prefill=[big]), reqs)
    # decode adds almost nothing on top of a coverage-saturating chunk
    assert both["expert_bytes"] < alone["expert_bytes"] * 1.02


def test_tpu_ridge_point_constant():
    assert TPU_V5E.ridge_op_per_byte == pytest.approx(197e12 / 819e9)
    assert H100X2.ridge_op_per_byte == pytest.approx(989e12 / 3.35e12)


def test_coverage_monotone_saturating():
    prev = 0.0
    for n in (1, 2, 4, 8, 16, 64, 256, 1024):
        c = expected_coverage(128, 8, n)
        assert c > prev
        prev = c
    assert prev <= 128.0
