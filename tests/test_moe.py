"""MoE subsystem: routing, capacity dispatch, dropless mode, expert-load
accounting (the paper's central counter) and the coverage model behind the
simulator."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, settings, strategies as st

from conftest import tiny_moe
from repro.models import moe
from repro.serving.cost_model import expected_coverage


def test_route_topk_weights_normalized():
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    idx, w, probs = moe.route(cfg, p, x)
    assert idx.shape == (16, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    # top-k really is top-k of probs
    got = np.sort(np.asarray(idx), axis=-1)
    want = np.sort(np.argsort(-np.asarray(probs), axis=-1)[:, :cfg.moe.top_k],
                   axis=-1)
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 64), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_dispatch_counts_and_capacity(t, e):
    rng = np.random.default_rng(t * e)
    k = min(2, e)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)))
    cap = 4
    slot, keep, counts = moe.dispatch_indices(idx, e, cap)
    counts = np.asarray(counts)
    np.testing.assert_array_equal(counts, np.bincount(
        np.asarray(idx).ravel(), minlength=e))
    kept_per_expert = np.zeros(e, int)
    slots_seen = set()
    for s_, kp, ex in zip(np.asarray(slot), np.asarray(keep),
                          np.asarray(idx).ravel()):
        if kp:
            assert s_ // cap == ex
            assert s_ not in slots_seen        # no slot collisions
            slots_seen.add(int(s_))
            kept_per_expert[ex] += 1
    assert (kept_per_expert <= cap).all()
    # kept = min(count, cap) per expert
    np.testing.assert_array_equal(kept_per_expert, np.minimum(counts, cap))


@given(st.integers(1, 64), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_ragged_dispatch_invariants(t, e):
    """Tile-aligned ragged dispatch: counts exact, slots collision-free and
    inside the owner's tile run, tile metadata consistent with the slots."""
    rng = np.random.default_rng(t * e + 1)
    k = min(2, e)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)))
    m_blk, n_rows = moe.ragged_tile_rows(t * k, e)
    slot, keep, counts, tile_expert = moe.ragged_dispatch_indices(
        idx, e, m_blk, n_rows)
    counts = np.asarray(counts)
    np.testing.assert_array_equal(counts, np.bincount(
        np.asarray(idx).ravel(), minlength=e))
    assert bool(np.asarray(keep).all())            # ragged never drops
    slots = np.asarray(slot)
    te = np.asarray(tile_expert)
    assert len(set(slots.tolist())) == slots.size  # no collisions
    for s_, ex in zip(slots, np.asarray(idx).ravel()):
        assert 0 <= s_ < n_rows
        assert te[s_ // m_blk] == ex               # row sits in owner's tile
    # padded group sizes tile-align and cover the counts
    n_active_tiles = int((te < e).sum())
    assert n_active_tiles == sum(-(-c // m_blk) for c in counts)
    # active tiles stream exactly the active experts' weights
    assert ({int(x) for x in te if x < e}
            == {i for i, c in enumerate(counts) if c > 0})


def test_ragged_masked_tokens_dropped_from_buffer():
    cfg = tiny_moe()
    e = cfg.moe.n_experts
    idx = jnp.asarray([[0, 1], [e, e], [2, 0]])    # middle token masked
    m_blk, n_rows = moe.ragged_tile_rows(6, e)
    slot, keep, counts, _ = moe.ragged_dispatch_indices(idx, e, m_blk, n_rows)
    np.testing.assert_array_equal(np.asarray(keep),
                                  [True, True, False, False, True, True])
    assert int(counts.sum()) == 4
    assert (np.asarray(slot)[2:4] == n_rows).all()


def test_apply_moe_ragged_matches_dense_dropless():
    """The two dropless data paths are the same function (bit-for-bit on
    CPU): per-row GEMMs are order-independent and the combine is
    identical."""
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, cfg.d_model))
    dense, aux_d = moe.apply_moe(cfg, p, x, dropless=True)
    ragged, aux_r = moe.apply_moe(cfg, p, x, moe_dispatch="ragged")
    np.testing.assert_allclose(np.asarray(ragged), np.asarray(dense),
                               atol=1e-6, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(aux_d["expert_counts"]),
                                  np.asarray(aux_r["expert_counts"]))
    assert int(aux_r["dropped"]) == 0


def test_ragged_tile_rows_bounds():
    for a, e in [(1, 1), (8, 4), (64, 128), (4096, 128), (260_000, 128)]:
        m_blk, rows = moe.ragged_tile_rows(a, e)
        assert rows % m_blk == 0
        assert rows >= a
        # worst-case alignment padding: at most one tile per expert + round
        assert rows <= a + e * (m_blk - 1) + m_blk
        assert 8 <= m_blk <= 128


def test_dropless_never_drops():
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    _, aux = moe.apply_moe(cfg, p, x, dropless=True)
    assert int(aux["dropped"]) == 0


def test_apply_moe_is_per_token():
    """MoE output for a token must not depend on the rest of the batch
    (dropless mode) — the property that makes scheduling output-invariant."""
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    full, _ = moe.apply_moe(cfg, p, x, dropless=True)
    half1, _ = moe.apply_moe(cfg, p, x[:, :4], dropless=True)
    half2, _ = moe.apply_moe(cfg, p, x[:, 4:], dropless=True)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([half1, half2], 1)),
                               atol=1e-5, rtol=1e-5)


def test_valid_mask_excludes_padding_from_counts():
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
    valid = jnp.asarray([[True] * 5 + [False] * 3])
    _, aux = moe.apply_moe(cfg, p, x, valid=valid, dropless=True)
    assert int(aux["expert_counts"].sum()) == 5 * cfg.moe.top_k
    # padded-out call == truncated call
    out_m, _ = moe.apply_moe(cfg, p, x, valid=valid, dropless=True)
    out_t, _ = moe.apply_moe(cfg, p, x[:, :5], dropless=True)
    np.testing.assert_allclose(np.asarray(out_m[:, :5]), np.asarray(out_t),
                               atol=1e-5, rtol=1e-5)


def test_aux_loss_favors_balance():
    cfg = tiny_moe()
    e = cfg.moe
    # balanced counts give lower switch loss than concentrated ones
    # fake: loss = E * sum(f * pbar); compute directly
    f_bal = jnp.full((e.n_experts,), 1.0 / e.n_experts)
    f_conc = jnp.zeros((e.n_experts,)).at[0].set(1.0)
    pbar = jnp.full((e.n_experts,), 1.0 / e.n_experts)
    pbar_conc = jnp.zeros((e.n_experts,)).at[0].set(1.0)
    loss_bal = e.n_experts * jnp.sum(f_bal * pbar)
    loss_conc = e.n_experts * jnp.sum(f_conc * pbar_conc)
    assert float(loss_bal) < float(loss_conc)


def test_expected_coverage_reproduces_table1():
    """Paper Table 1 (Qwen3: 128 experts, top-8, ShareGPT): the calibrated
    correlated-routing model must land within 20% of every measured point
    and be exact at batch=1."""
    table1 = {1: 6.25, 2: 11.7, 4: 21.3, 8: 29.0, 16: 44.5, 32: 54.7,
              64: 69.4, 128: 86.3, 256: 93.4}
    for batch, pct in table1.items():
        got = expected_coverage(128, 8, batch) / 128 * 100
        assert abs(got - pct) / pct < 0.20, (batch, got, pct)
    assert expected_coverage(128, 8, 1) / 128 * 100 == pytest.approx(6.25)
    assert expected_coverage(128, 8, 512) / 128 >= 0.98   # ">=98% @ 512"


def test_shared_experts_always_active():
    from conftest import tiny_moe as tm
    import dataclasses
    cfg = tm()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_shared_experts=1,
                                     shared_d_ff=32))
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, cfg.d_model))
    out, _ = moe.apply_moe(cfg, p, x, dropless=True)
    assert out.shape == x.shape
