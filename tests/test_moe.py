"""MoE subsystem: routing, capacity dispatch, dropless mode, expert-load
accounting (the paper's central counter) and the coverage model behind the
simulator."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from conftest import tiny_moe
from repro.models import moe
from repro.serving.cost_model import expected_coverage


def test_route_topk_weights_normalized():
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, cfg.d_model))
    idx, w, probs = moe.route(cfg, p, x)
    assert idx.shape == (16, cfg.moe.top_k)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, atol=1e-6)
    # top-k really is top-k of probs
    got = np.sort(np.asarray(idx), axis=-1)
    want = np.sort(np.argsort(-np.asarray(probs), axis=-1)[:, :cfg.moe.top_k],
                   axis=-1)
    np.testing.assert_array_equal(got, want)


@given(st.integers(1, 64), st.integers(2, 8))
@settings(max_examples=20, deadline=None)
def test_dispatch_counts_and_capacity(t, e):
    rng = np.random.default_rng(t * e)
    k = min(2, e)
    idx = jnp.asarray(rng.integers(0, e, size=(t, k)))
    cap = 4
    slot, keep, counts = moe.dispatch_indices(idx, e, cap)
    counts = np.asarray(counts)
    np.testing.assert_array_equal(counts, np.bincount(
        np.asarray(idx).ravel(), minlength=e))
    kept_per_expert = np.zeros(e, int)
    slots_seen = set()
    for s_, kp, ex in zip(np.asarray(slot), np.asarray(keep),
                          np.asarray(idx).ravel()):
        if kp:
            assert s_ // cap == ex
            assert s_ not in slots_seen        # no slot collisions
            slots_seen.add(int(s_))
            kept_per_expert[ex] += 1
    assert (kept_per_expert <= cap).all()
    # kept = min(count, cap) per expert
    np.testing.assert_array_equal(kept_per_expert, np.minimum(counts, cap))


def test_dropless_never_drops():
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, cfg.d_model))
    _, aux = moe.apply_moe(cfg, p, x, dropless=True)
    assert int(aux["dropped"]) == 0


def test_apply_moe_is_per_token():
    """MoE output for a token must not depend on the rest of the batch
    (dropless mode) — the property that makes scheduling output-invariant."""
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 8, cfg.d_model))
    full, _ = moe.apply_moe(cfg, p, x, dropless=True)
    half1, _ = moe.apply_moe(cfg, p, x[:, :4], dropless=True)
    half2, _ = moe.apply_moe(cfg, p, x[:, 4:], dropless=True)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(jnp.concatenate([half1, half2], 1)),
                               atol=1e-5, rtol=1e-5)


def test_valid_mask_excludes_padding_from_counts():
    cfg = tiny_moe()
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(4), (1, 8, cfg.d_model))
    valid = jnp.asarray([[True] * 5 + [False] * 3])
    _, aux = moe.apply_moe(cfg, p, x, valid=valid, dropless=True)
    assert int(aux["expert_counts"].sum()) == 5 * cfg.moe.top_k
    # padded-out call == truncated call
    out_m, _ = moe.apply_moe(cfg, p, x, valid=valid, dropless=True)
    out_t, _ = moe.apply_moe(cfg, p, x[:, :5], dropless=True)
    np.testing.assert_allclose(np.asarray(out_m[:, :5]), np.asarray(out_t),
                               atol=1e-5, rtol=1e-5)


def test_aux_loss_favors_balance():
    cfg = tiny_moe()
    e = cfg.moe
    # balanced counts give lower switch loss than concentrated ones
    t = 64
    p_uniform = jnp.full((t, e.n_experts), 1.0 / e.n_experts)
    # fake: loss = E * sum(f * pbar); compute directly
    f_bal = jnp.full((e.n_experts,), 1.0 / e.n_experts)
    f_conc = jnp.zeros((e.n_experts,)).at[0].set(1.0)
    pbar = jnp.full((e.n_experts,), 1.0 / e.n_experts)
    pbar_conc = jnp.zeros((e.n_experts,)).at[0].set(1.0)
    loss_bal = e.n_experts * jnp.sum(f_bal * pbar)
    loss_conc = e.n_experts * jnp.sum(f_conc * pbar_conc)
    assert float(loss_bal) < float(loss_conc)


def test_expected_coverage_reproduces_table1():
    """Paper Table 1 (Qwen3: 128 experts, top-8, ShareGPT): the calibrated
    correlated-routing model must land within 20% of every measured point
    and be exact at batch=1."""
    table1 = {1: 6.25, 2: 11.7, 4: 21.3, 8: 29.0, 16: 44.5, 32: 54.7,
              64: 69.4, 128: 86.3, 256: 93.4}
    for batch, pct in table1.items():
        got = expected_coverage(128, 8, batch) / 128 * 100
        assert abs(got - pct) / pct < 0.20, (batch, got, pct)
    assert expected_coverage(128, 8, 1) / 128 * 100 == pytest.approx(6.25)
    assert expected_coverage(128, 8, 512) / 128 >= 0.98   # ">=98% @ 512"


def test_shared_experts_always_active():
    from conftest import tiny_moe as tm
    import dataclasses
    cfg = tm()
    cfg = dataclasses.replace(
        cfg, moe=dataclasses.replace(cfg.moe, n_shared_experts=1,
                                     shared_d_ff=32))
    p = moe.init_moe(cfg, jax.random.PRNGKey(0))
    assert "shared" in p
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 4, cfg.d_model))
    out, _ = moe.apply_moe(cfg, p, x, dropless=True)
    assert out.shape == x.shape
