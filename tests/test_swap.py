"""Swap-to-host preemption: the second eviction mode next to
restore-by-recompute (DESIGN.md §Swap-to-host preemption).

Covers the acceptance bar for the mode: oversubscribed engine AND
simulator runs under ``preemption_mode="swap"`` must match unconstrained
runs token-for-token (the DMA-back restores KV verbatim), a victim swapped
twice must still agree, the swap-in bandwidth budget must throttle without
deadlocking, and — as a property over random workloads — swap accounting
must never leak a page from either pool.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:          # degrade to a deterministic seeded sweep
    from _hypothesis_fallback import given, settings, strategies as st

from conftest import tiny_dense
from repro.core.base import make_scheduler
from repro.core.plan import Request, RequestState
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2
from repro.serving.engine import Engine
from repro.serving.kvcache import PagedKVAllocator
from repro.serving.simulator import Simulator
from repro.serving.traffic import TraceRequest


def _run_engine(cfg, sched_name, jobs, **eng_kw):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler(sched_name, model.n_blocks, n_slots=4, quantum=8,
                           token_budget=16)
    eng = Engine(model, params, sched, n_slots=4, max_len=64, **eng_kw)
    for prompt, max_new in jobs:
        eng.submit(prompt, max_new)
    eng.run(max_iterations=100_000)
    return eng


OVERSUB_JOBS = None


def _oversub_jobs():
    global OVERSUB_JOBS
    if OVERSUB_JOBS is None:
        rng = np.random.default_rng(0)
        OVERSUB_JOBS = [
            (list(rng.integers(1, 200, int(rng.integers(4, 10)))), 12)
            for _ in range(32)]
    return OVERSUB_JOBS


@pytest.mark.parametrize("sched", ["layered", "chunked"])
def test_engine_oversubscribed_swap_matches_unconstrained(sched):
    """Acceptance: 32 requests into a ~3-resident pool under swap mode
    must complete via DMA-backed eviction with tokens identical to an
    unconstrained run — swap restores KV verbatim, so the greedy
    continuation is the same function."""
    cfg = tiny_dense()
    jobs = _oversub_jobs()
    tight = _run_engine(cfg, sched, jobs, pages=16, page_size=4,
                        decode_reserve=1, preemption_mode="swap")
    assert tight.n_swapped_out > 0, "scenario must actually swap"
    assert tight.n_swapped_out == tight.n_swapped_in
    assert tight.alloc.pages_in_use() == 0
    assert tight.alloc.host_pages_in_use() == 0
    assert not tight.host_kv                # every host copy consumed

    free = _run_engine(cfg, sched, jobs)    # unconstrained pool
    assert free.n_swapped_out == 0
    assert tight.outputs == free.outputs, "swap changed generated tokens"
    swapped = [rid for rid, r in tight.requests.items() if r.n_swaps > 0]
    assert swapped
    for rid in swapped:
        assert len(tight.outputs[rid]) == 12


@pytest.mark.parametrize("mode", ["swap", "auto"])
def test_simulator_oversubscribed_swap_matches_unconstrained(mode):
    """The simulator drives the same scheduler logic: per-request token
    counts (and every request completing) must match the unconstrained
    run under both swap and auto mode."""
    cfg = tiny_dense()
    rng = np.random.default_rng(1)
    trace = [TraceRequest(arrival_time=i * 1e-3,
                          prompt_len=int(rng.integers(4, 10)),
                          output_len=12) for i in range(32)]

    def gens(**kw):
        sim = Simulator(cfg, "layered", H100X2, n_slots=8, quantum=16,
                        token_budget=64, page_size=4, decode_reserve=1,
                        **kw)
        res = sim.run(trace)
        assert sim.kv.pages_in_use() == 0
        assert sim.kv.host_pages_in_use() == 0
        return res, sorted((r.req_id, r.n_generated) for r in res.requests)

    res_free, free = gens()
    res_tight, tight = gens(n_pages=16, preemption_mode=mode)
    assert res_tight.n_swap_outs > 0, "scenario must actually swap"
    assert res_tight.n_swap_outs == res_tight.n_swap_ins
    assert res_tight.swap_bytes > 0
    # DMA busy time is real; the stall is only the part the iteration's
    # compute could not hide (possibly zero under the overlap model)
    assert res_tight.swap_dma_time > 0
    assert 0 <= res_tight.swap_stall_time <= res_tight.swap_dma_time + 1e-12
    assert res_tight.host_pages_high_water > 0
    assert res_free.n_swap_outs == 0 and res_free.swap_bytes == 0
    assert tight == free


def test_simulator_swap_dma_overlap_vs_serial():
    """Satellite (ROADMAP PR-3 follow-up): the default charges swap DMA as
    overlappable with the iteration's compute — stall = max(0, dma -
    compute) — while ``swap_overlap=False`` keeps the PR-3 fully-serial
    model.  Same trace, same schedule: identical DMA busy time, but the
    serial run stalls for ALL of it and therefore finishes no earlier."""
    cfg = tiny_dense()
    rng = np.random.default_rng(1)
    # all arrivals at t=0 so both runs inject identically regardless of
    # how the clock advances — the SCHEDULE is then provably shared and
    # only the stall accounting can differ
    trace = [TraceRequest(arrival_time=0.0,
                          prompt_len=int(rng.integers(4, 10)),
                          output_len=12) for _ in range(32)]

    def run(overlap):
        sim = Simulator(cfg, "layered", H100X2, n_slots=8, quantum=16,
                        token_budget=64, page_size=4, decode_reserve=1,
                        n_pages=16, preemption_mode="swap",
                        swap_overlap=overlap)
        return sim.run(trace)

    ovl, ser = run(True), run(False)
    assert ser.n_swap_outs == ovl.n_swap_outs > 0   # same schedule
    assert ser.swap_dma_time == pytest.approx(ovl.swap_dma_time)
    assert ser.swap_stall_time == pytest.approx(ser.swap_dma_time)
    assert ovl.swap_stall_time <= ovl.swap_dma_time + 1e-12
    assert ovl.sim_time <= ser.sim_time + 1e-12
    hidden = ser.swap_stall_time - ovl.swap_stall_time
    assert ser.sim_time - ovl.sim_time == pytest.approx(hidden, abs=1e-9)


def test_engine_doubly_swapped_victim_tokens_identical():
    """Force the SAME request through two swap-out/swap-in cycles: the
    restored KV must continue the greedy decode exactly (and, unlike
    recompute, the prompt must NOT grow — nothing is folded)."""
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    sched = make_scheduler("layered", model.n_blocks, n_slots=2, quantum=8)
    eng = Engine(model, params, sched, n_slots=2, max_len=64,
                 preemption_mode="swap")
    rid = eng.submit(list(range(1, 9)), 12)
    forced = []
    while eng.scheduler.has_work():
        r = eng.requests[rid]
        if r.state == RequestState.DECODE and r.n_generated in (3, 7) \
                and r.n_generated not in forced:
            sched.swap_out(rid)           # what the pressure pass would do
            eng._swap_out(rid)            # what step() would execute
            forced.append(r.n_generated)
        eng.step()
    assert forced == [3, 7]
    assert eng.requests[rid].n_swaps == 2
    assert eng.requests[rid].n_preemptions == 0
    assert eng.requests[rid].prompt_len == 8     # no recompute fold
    clean = _run_engine(cfg, "layered", [(list(range(1, 9)), 12)])
    assert eng.outputs[rid] == clean.outputs[0]
    assert len(eng.outputs[rid]) == 12


def drive_swap(reqs, *, n_pages, n_host_pages, page_size=4,
               decode_reserve=2, swap_in_budget=None, mode="swap",
               n_blocks=6, max_iters=100_000, **sched_kw):
    """Drive a pure scheduler to drain under swap-mode pressure, checking
    page conservation in BOTH pools after every iteration."""
    sched = make_scheduler("continuous", n_blocks, **sched_kw)
    kv = PagedKVAllocator(n_pages, page_size, stash_factor=0.25,
                          n_host_pages=n_host_pages)
    sched.attach_kv(kv, decode_reserve=decode_reserve, mode=mode,
                    swap_in_budget=swap_in_budget)
    for r in reqs:
        sched.submit(r)
    plans = []
    it = 0
    while sched.has_work():
        pre = {rid for rid, r in sched.requests.items()
               if r.state == RequestState.DECODE}
        plan = sched.next_plan(now=float(it))
        plans.append(plan)
        # I1 modulo eviction: every pre-iteration DECODE request is either
        # decoded or was evicted THIS iteration (folded OR swapped out)
        assert pre.issubset(set(plan.decode_ids) | set(plan.preempted_ids)
                            | set(plan.swapped_out_ids))
        # conservation: no page is ever minted or leaked, in either pool
        assert kv.pages_in_use() + kv.n_free_pages == kv.n_pages
        assert kv.host_pages_in_use() + kv.n_free_host_pages \
            == kv.n_host_pages
        it += 1
        assert it < max_iters, "did not drain under swap pressure"
    return plans, sched, kv


swap_spec = st.lists(
    st.tuples(st.integers(1, 40), st.integers(1, 24)),
    min_size=2, max_size=10)


@given(spec=swap_spec, host_pages=st.integers(4, 40),
       budget=st.sampled_from([None, 4, 16]))
@settings(max_examples=25, deadline=None)
def test_swap_accounting_never_leaks_pages(spec, host_pages, budget):
    """Property: across a full oversubscribed run — arbitrary request
    mix, host pool size, and swap-in budget — both pools conserve pages
    every iteration, drain empty, every request finishes, and every
    swap-out is eventually matched by a swap-in."""
    reqs = [Request(req_id=i, prompt_len=p, max_new_tokens=m,
                    arrival_time=float(i))
            for i, (p, m) in enumerate(spec)]
    # pool floored so the biggest request always fits an empty pool
    worst = max(-(-(p + m + 2) // 4) + -(-(p // 4 + 1) // 4)
                for p, m in spec)
    plans, sched, kv = drive_swap(
        reqs, n_pages=max(16, worst + 2), n_host_pages=host_pages,
        swap_in_budget=budget, n_slots=8, token_budget=64, quantum=16)
    assert kv.pages_in_use() == 0
    assert kv.host_pages_in_use() == 0
    assert kv.n_swap_outs == kv.n_swap_ins
    assert kv.swapped_out_tokens == kv.swapped_in_tokens
    for r in reqs:
        assert r.n_generated == r.max_new_tokens, r.req_id
        assert len(r.swap_out_times) == len(r.swap_in_times) == r.n_swaps


def test_swap_in_budget_throttles_but_never_deadlocks():
    """A budget smaller than any single request still makes progress (one
    restore per iteration is always allowed) while capping restores: no
    iteration may DMA-in two requests whose combined KV beats the budget."""
    reqs = [Request(req_id=i, prompt_len=12, max_new_tokens=10,
                    arrival_time=float(i)) for i in range(5)]
    plans, sched, kv = drive_swap(
        reqs, n_pages=16, n_host_pages=64, swap_in_budget=1,
        n_slots=8, token_budget=64, quantum=16)
    assert kv.n_swap_outs > 0
    for plan in plans:
        assert len(plan.swapped_in_ids) <= 1       # budget 1 => one/iter
    for r in reqs:
        assert r.n_generated == r.max_new_tokens


def test_auto_mode_follows_cost_hook():
    """auto consults swap_cost_fn per victim: an always-False hook routes
    every eviction to recompute, an always-True hook to swap."""
    def run(hook):
        sched = make_scheduler("continuous", 4, n_slots=4)
        kv = PagedKVAllocator(n_pages=12, page_size=2, n_host_pages=24)
        sched.attach_kv(kv, decode_reserve=0, mode="auto",
                        swap_cost_fn=hook)
        for i in range(3):
            sched.submit(Request(req_id=i, prompt_len=7, max_new_tokens=10,
                                 arrival_time=float(i)))
        it = 0
        while sched.has_work():
            sched.next_plan(now=float(it))
            it += 1
            assert it < 2000
        return sched

    prefer_swap = run(lambda r: True)
    assert prefer_swap.n_swap_outs > 0 and prefer_swap.n_preemptions == 0
    prefer_recompute = run(lambda r: False)
    assert prefer_recompute.n_preemptions > 0
    assert prefer_recompute.n_swap_outs == 0


def test_swap_in_respects_class_headroom():
    """The DMA-back is a re-admission: a swapped-out batch request must
    not retake the pages reserved for interactive admissions — after any
    swap-in, the interactive headroom is still free."""
    headroom = 2
    sched = make_scheduler("continuous", 4, n_slots=4)
    kv = PagedKVAllocator(n_pages=12, page_size=2, n_host_pages=24)
    sched.attach_kv(kv, decode_reserve=0, mode="swap",
                    class_headroom={"interactive": headroom})
    sched.submit(Request(req_id=0, prompt_len=10, max_new_tokens=8,
                         arrival_time=0.0, slo_class="interactive"))
    sched.submit(Request(req_id=1, prompt_len=10, max_new_tokens=8,
                         arrival_time=1.0, slo_class="batch"))
    swapped_back = False
    it = 0
    while sched.has_work():
        plan = sched.next_plan(now=float(it))
        if 1 in plan.swapped_in_ids:
            swapped_back = True
            # the swap-in consumed pages but left the reserve intact
            assert kv.n_free_pages >= headroom, \
                "swap-in ate the interactive headroom"
        it += 1
        assert it < 2000
    assert swapped_back, "scenario must actually swap out and back"
    for r in sched.requests.values():
        assert r.n_generated == r.max_new_tokens


def test_swap_mode_requires_host_pool():
    sched = make_scheduler("continuous", 4, n_slots=4)
    kv = PagedKVAllocator(n_pages=8, page_size=2)      # no host pages
    with pytest.raises(ValueError, match="host pool"):
        sched.attach_kv(kv, mode="swap")
    with pytest.raises(ValueError, match="unknown preemption mode"):
        sched.attach_kv(kv, mode="dma")


def test_swap_falls_back_to_recompute_when_host_pool_full():
    """Host pool too small for any victim: swap mode must degrade to the
    recompute path, never raise or deadlock."""
    reqs = [Request(req_id=i, prompt_len=12, max_new_tokens=10,
                    arrival_time=float(i)) for i in range(4)]
    plans, sched, kv = drive_swap(
        reqs, n_pages=16, n_host_pages=1,   # 1 page: no victim ever fits
        n_slots=8, token_budget=64, quantum=16)
    assert kv.n_swap_outs == 0
    assert sched.n_preemptions > 0
    for r in reqs:
        assert r.n_generated == r.max_new_tokens
