"""Numerical equivalence of the §Perf execution paths against their
reference formulations: chunkwise mLSTM vs the per-step recurrence, and
the flash (kv-chunk online-softmax) attention vs dense attention. These
paths are what the optimized dry-run lowers; the tests pin them to the
same math the engine/equivalence suite validates."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.models.attention as A
import repro.models.xlstm as X


def _mlstm_inputs(b=2, s=384, hh=2, dk=16, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    q = jax.random.normal(ks[0], (b, s, hh, dk))
    k = jax.random.normal(ks[1], (b, s, hh, dk))
    v = jax.random.normal(ks[2], (b, s, hh, dk))
    li = jax.random.normal(ks[3], (b, s, hh)) * 2
    lf = -jax.nn.softplus(-jax.random.normal(ks[4], (b, s, hh)) * 2)
    state = (jnp.zeros((b, hh, dk, dk)), jnp.zeros((b, hh, dk)),
             jnp.full((b, hh), -1e30))
    return q, k, v, li, lf, state


def _mlstm_step_scan(q, k, v, li, lf, state, valid_sb):
    def step(st, inp):
        qt, kt, vt, it, ft, vm = inp
        new_st, h = X._mlstm_step(qt, kt, vt, it, ft, st)
        st = jax.tree_util.tree_map(
            lambda nw, old: jnp.where(
                vm.reshape((-1,) + (1,) * (nw.ndim - 1)), nw, old),
            new_st, st)
        return st, h
    xs = (q.swapaxes(0, 1), k.swapaxes(0, 1), v.swapaxes(0, 1),
          li.swapaxes(0, 1), lf.swapaxes(0, 1), valid_sb)
    st, hs = jax.lax.scan(step, state, xs)
    return st, hs.swapaxes(0, 1)


def test_mlstm_chunkwise_matches_stepwise():
    q, k, v, li, lf, state = _mlstm_inputs()
    b, s = q.shape[:2]
    valid = jnp.arange(s)[None, :] < jnp.asarray([s, 300])[:, None]
    st_ref, h_ref = _mlstm_step_scan(q, k, v, li, lf, state, valid.T)
    st_chk, h_chk = X._mlstm_chunkwise(q, k, v, li, lf, state,
                                       valid_sb=valid)
    np.testing.assert_allclose(np.asarray(h_chk[valid]),
                               np.asarray(h_ref[valid]),
                               atol=2e-4, rtol=2e-4)
    for a, b_ in zip(st_chk, st_ref):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   atol=2e-4, rtol=2e-3)


def test_mlstm_chunkwise_carried_state():
    """Second segment continues from a non-trivial (C, n, m) carry."""
    q, k, v, li, lf, state = _mlstm_inputs(seed=3)
    s = q.shape[1]
    ones = jnp.ones((q.shape[0], s), bool)
    st1, _ = _mlstm_step_scan(q, k, v, li, lf, state, ones.T)
    st1c, _ = X._mlstm_chunkwise(q, k, v, li, lf, state)
    st2_ref, h2_ref = _mlstm_step_scan(q, k, v, li, lf, st1, ones.T)
    st2_chk, h2_chk = X._mlstm_chunkwise(q, k, v, li, lf, st1c)
    np.testing.assert_allclose(np.asarray(h2_chk), np.asarray(h2_ref),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("window", [None, 700])
def test_flash_attention_path_matches_dense(window):
    b, sq, h, hkv, hd, skv = 1, 512, 4, 2, 32, 4096
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, hkv, hd))
    v = jax.random.normal(ks[2], (b, skv, hkv, hd))
    q_pos = jnp.arange(2048, 2048 + sq)[None].astype(jnp.int32)
    kv_pos = jnp.arange(skv).astype(jnp.int32)
    kv_valid = (kv_pos < 3000)[None]
    d = A._masked_attention_dense(q, k, v, q_pos, kv_pos, kv_valid,
                                  causal=True, window=window)
    f = A._masked_attention_flash(q, k, v, q_pos, kv_pos, kv_valid,
                                  causal=True, window=window)
    np.testing.assert_allclose(np.asarray(f), np.asarray(d),
                               atol=2e-5, rtol=2e-5)


def test_flash_fully_masked_rows_zero():
    b, sq, h, hkv, hd, skv = 1, 512, 2, 2, 16, 2048
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd))
    k = jax.random.normal(ks[1], (b, skv, hkv, hd))
    v = jax.random.normal(ks[2], (b, skv, hkv, hd))
    q_pos = jnp.arange(sq)[None].astype(jnp.int32)
    kv_pos = jnp.arange(skv).astype(jnp.int32)
    kv_valid = jnp.zeros((b, skv), bool)            # nothing to attend to
    f = A._masked_attention_flash(q, k, v, q_pos, kv_pos, kv_valid,
                                  causal=True, window=None)
    assert not bool(jnp.isnan(f).any())
    np.testing.assert_allclose(np.asarray(f), 0.0, atol=1e-6)


def test_gqa_g_major_grouping_convention():
    """Query head h attends to kv head h % n_kv (g-major): feed kv head j
    a distinctive V and check which q heads see it."""
    b, s, h, hkv, hd = 1, 8, 4, 2, 8
    q = jnp.ones((b, s, h, hd))
    k = jnp.ones((b, s, hkv, hd))
    v = jnp.zeros((b, s, hkv, hd)).at[:, :, 1, :].set(7.0)
    q_pos = jnp.arange(s)[None].astype(jnp.int32)
    kv_valid = jnp.ones((b, s), bool)
    out = A._masked_attention_dense(q, k, v, q_pos,
                                    jnp.arange(s, dtype=jnp.int32),
                                    kv_valid, causal=True)
    # g-major: heads 1 and 3 (h % 2 == 1) see kv head 1's values
    got = np.asarray(out[0, -1, :, 0])
    np.testing.assert_allclose(got, [0.0, 7.0, 0.0, 7.0], atol=1e-5)
