"""Workload generation (serving/traffic.py): lognormal length models fitted
to the paper's Table 4 (mean, std), clip bounds, and Poisson arrivals."""

from __future__ import annotations

import numpy as np
import pytest

from repro.serving.traffic import (ARXIV, DATASETS, SHAREGPT, ClassSpec,
                                   LengthModel, attach_prompt_tokens,
                                   bursty_trace, multi_class_trace,
                                   poisson_trace)


@pytest.mark.parametrize("mean,std", [(2340, 2088), (9194, 5754),
                                      (438, 265), (231, 104)])
def test_lognormal_moment_roundtrip(mean, std):
    """The (mu, sigma) fit must reproduce the requested (mean, std) —
    sampled WITHOUT clipping distortion (wide bounds)."""
    m = LengthModel(mean=mean, std=std, lo=1, hi=10_000_000)
    xs = m.sample(np.random.default_rng(0), 400_000).astype(float)
    assert xs.mean() == pytest.approx(mean, rel=0.03)
    assert xs.std() == pytest.approx(std, rel=0.05)


def test_clip_bounds_respected():
    m = LengthModel(mean=100, std=400, lo=16, hi=512)
    xs = m.sample(np.random.default_rng(1), 100_000)
    assert xs.min() >= 16 and xs.max() <= 512
    assert ((xs == 16).any() and (xs == 512).any())   # clipping really bites


def test_dataset_p90_sanity():
    """Table 4 p90s: arXiv input 17152, output 386; ShareGPT's long tail
    puts p90 well above the mean."""
    rng = np.random.default_rng(2)
    arxiv_in = ARXIV.input_len.sample(rng, 100_000)
    arxiv_out = ARXIV.output_len.sample(rng, 100_000)
    assert np.percentile(arxiv_in, 90) == pytest.approx(17152, rel=0.35)
    assert np.percentile(arxiv_out, 90) == pytest.approx(386, rel=0.35)
    sg_in = SHAREGPT.input_len.sample(rng, 100_000)
    assert np.percentile(sg_in, 90) > SHAREGPT.input_len.mean


def test_poisson_trace_shape_and_rate():
    trace = poisson_trace(DATASETS["sharegpt"], rate=4.0, n_requests=20_000,
                          seed=3)
    assert len(trace) == 20_000
    arr = np.array([t.arrival_time for t in trace])
    assert (np.diff(arr) > 0).all()              # strictly increasing
    assert np.diff(arr).mean() == pytest.approx(0.25, rel=0.05)
    assert all(t.prompt_len >= 16 and t.output_len >= 16 for t in trace)


def test_trace_is_deterministic_per_seed():
    a = poisson_trace(ARXIV, 1.0, 50, seed=7)
    b = poisson_trace(ARXIV, 1.0, 50, seed=7)
    c = poisson_trace(ARXIV, 1.0, 50, seed=8)
    assert a == b
    assert a != c


def test_bursty_trace_rate_and_burstiness():
    """On/off modulated Poisson: same long-run average rate as the plain
    Poisson process, but with a strictly higher index of dispersion
    (bursts + silences => window counts far from Poisson's var==mean)."""
    rate, n = 2.0, 20_000
    bursty = bursty_trace(SHAREGPT, rate, n, seed=5,
                          mean_on=4.0, mean_off=8.0)
    arr = np.array([t.arrival_time for t in bursty])
    assert (np.diff(arr) > 0).all()
    # long-run average rate matches the requested rate
    assert n / arr[-1] == pytest.approx(rate, rel=0.1)
    # dispersion: counts per 1s window; Poisson gives var/mean ~ 1
    def dispersion(ts):
        counts = np.bincount(ts.astype(int))
        return counts.var() / counts.mean()
    poisson = poisson_trace(SHAREGPT, rate, n, seed=5)
    d_bursty = dispersion(arr)
    d_poisson = dispersion(np.array([t.arrival_time for t in poisson]))
    assert d_poisson < 2.0
    assert d_bursty > 2.0 * d_poisson
    # seed-deterministic
    assert bursty == bursty_trace(SHAREGPT, rate, n, seed=5,
                                  mean_on=4.0, mean_off=8.0)
    assert bursty != bursty_trace(SHAREGPT, rate, n, seed=6,
                                  mean_on=4.0, mean_off=8.0)


def test_multi_class_trace_composition():
    specs = [ClassSpec("interactive", SHAREGPT, 2.0, 40),
             ClassSpec("batch", ARXIV, 1.0, 20, process="bursty")]
    trace = multi_class_trace(specs, seed=3)
    assert len(trace) == 60
    arr = [t.arrival_time for t in trace]
    assert arr == sorted(arr)                      # merge-sorted
    by_cls = {c: [t for t in trace if t.slo_class == c]
              for c in ("interactive", "batch")}
    assert len(by_cls["interactive"]) == 40
    assert len(by_cls["batch"]) == 20
    # per-class streams are independent: the batch substream matches a
    # standalone bursty trace under the same derived seed
    assert trace == multi_class_trace(specs, seed=3)
    assert trace != multi_class_trace(specs, seed=4)


def test_attach_prompt_tokens_for_engine_replay():
    trace = poisson_trace(SHAREGPT, 1.0, 10, seed=2)
    with_toks = attach_prompt_tokens(trace, vocab_size=256, seed=1)
    assert all(t.prompt_tokens is None for t in trace)   # input untouched
    for before, after in zip(trace, with_toks):
        assert after.arrival_time == before.arrival_time
        assert after.slo_class == before.slo_class
        assert len(after.prompt_tokens) == before.prompt_len
        assert all(1 <= tok < 256 for tok in after.prompt_tokens)
    assert with_toks == attach_prompt_tokens(trace, 256, seed=1)
    assert with_toks != attach_prompt_tokens(trace, 256, seed=9)
