"""Workload generation (serving/traffic.py): lognormal length models fitted
to the paper's Table 4 (mean, std), clip bounds, and Poisson arrivals."""

from __future__ import annotations

import numpy as np
import pytest

from repro.serving.traffic import (ARXIV, DATASETS, SHAREGPT, LengthModel,
                                   poisson_trace)


@pytest.mark.parametrize("mean,std", [(2340, 2088), (9194, 5754),
                                      (438, 265), (231, 104)])
def test_lognormal_moment_roundtrip(mean, std):
    """The (mu, sigma) fit must reproduce the requested (mean, std) —
    sampled WITHOUT clipping distortion (wide bounds)."""
    m = LengthModel(mean=mean, std=std, lo=1, hi=10_000_000)
    xs = m.sample(np.random.default_rng(0), 400_000).astype(float)
    assert xs.mean() == pytest.approx(mean, rel=0.03)
    assert xs.std() == pytest.approx(std, rel=0.05)


def test_clip_bounds_respected():
    m = LengthModel(mean=100, std=400, lo=16, hi=512)
    xs = m.sample(np.random.default_rng(1), 100_000)
    assert xs.min() >= 16 and xs.max() <= 512
    assert ((xs == 16).any() and (xs == 512).any())   # clipping really bites


def test_dataset_p90_sanity():
    """Table 4 p90s: arXiv input 17152, output 386; ShareGPT's long tail
    puts p90 well above the mean."""
    rng = np.random.default_rng(2)
    arxiv_in = ARXIV.input_len.sample(rng, 100_000)
    arxiv_out = ARXIV.output_len.sample(rng, 100_000)
    assert np.percentile(arxiv_in, 90) == pytest.approx(17152, rel=0.35)
    assert np.percentile(arxiv_out, 90) == pytest.approx(386, rel=0.35)
    sg_in = SHAREGPT.input_len.sample(rng, 100_000)
    assert np.percentile(sg_in, 90) > SHAREGPT.input_len.mean


def test_poisson_trace_shape_and_rate():
    trace = poisson_trace(DATASETS["sharegpt"], rate=4.0, n_requests=20_000,
                          seed=3)
    assert len(trace) == 20_000
    arr = np.array([t.arrival_time for t in trace])
    assert (np.diff(arr) > 0).all()              # strictly increasing
    assert np.diff(arr).mean() == pytest.approx(0.25, rel=0.05)
    assert all(t.prompt_len >= 16 and t.output_len >= 16 for t in trace)


def test_trace_is_deterministic_per_seed():
    a = poisson_trace(ARXIV, 1.0, 50, seed=7)
    b = poisson_trace(ARXIV, 1.0, 50, seed=7)
    c = poisson_trace(ARXIV, 1.0, 50, seed=8)
    assert a == b
    assert a != c
