"""Speculative verify-k decoding (DESIGN.md §Speculative decode).

The acceptance bar: greedy token streams with speculation ON are
BIT-IDENTICAL to speculation OFF — across both drafters (n-gram lookahead
and the tiny draft model), both preemption modes (recompute and swap),
on the multi-class oversubscribed trace — and the verify path leaves no
KV pages behind after rollback.  Speculation only changes how many tokens
each dispatch commits, never their values.
"""

from __future__ import annotations

import jax
import numpy as np
import pytest

from conftest import tiny_dense
from repro.core.base import make_scheduler
from repro.models.model import DecoderModel
from repro.serving.cost_model import H100X2
from repro.serving.engine import PREFILL_CACHE_SIZE, Engine
from repro.serving.runtime import EngineExecutor, ServingRuntime
from repro.serving.simulator import Simulator
from repro.serving.spec import NgramDrafter, accepted_prefix
from repro.serving.traffic import TraceRequest

from test_runtime import _make_engine, _mixed_trace


def _draft_kw(cfg, *, self_draft):
    """Draft-model wiring: the target as its own draft (acceptance -> 1,
    the all-accept path) or a differently-seeded twin (mostly-reject)."""
    model = DecoderModel(cfg)
    seed = 0 if self_draft else 7
    return dict(draft_model=model, draft_params=model.init(
        jax.random.PRNGKey(seed)))


def _run_trace(cfg, trace, mode, **eng_kw):
    eng = _make_engine(cfg, "layered", pages=16, page_size=4,
                       decode_reserve=1, preemption_mode=mode, **eng_kw)
    rt = ServingRuntime(EngineExecutor(eng), clock="iteration")
    rt.run(trace, max_iterations=100_000)
    return eng


# ------------------------------------------------------- bit-exact streams

@pytest.mark.parametrize("mode", ["recompute", "swap"])
@pytest.mark.parametrize("spec", ["ngram", "draft"])
def test_spec_streams_bit_identical_under_pressure(spec, mode):
    """Oversubscribed replay (evictions + restores happen) with
    speculation on: every request's token stream equals the spec-off run,
    and the allocator ends clean (no page leaked by any rollback)."""
    cfg = tiny_dense()
    trace = _mixed_trace()
    base = _run_trace(cfg, trace, mode)
    kw = dict(spec_mode=spec, spec_k=3)
    if spec == "draft":
        kw.update(_draft_kw(cfg, self_draft=True))
    eng = _run_trace(cfg, trace, mode, **kw)

    assert eng.outputs == base.outputs, "speculation changed token values"
    assert eng.n_verify_dispatches > 0, "speculation never engaged"
    if spec == "draft":
        # self-draft: the proposals ARE the target argmax, everything
        # accepted that the budget allows
        assert eng.n_spec_accepted == eng.n_spec_proposed > 0
    assert eng.alloc.pages_in_use() == 0
    assert not eng.alloc._spec_base, "stranded speculative reservation"
    # the scenario really stresses memory: the spec-off baseline evicts.
    # (Counts need not match across runs — accepted drafts finish requests
    # in fewer iterations, so pressure resolves earlier; speculation never
    # evicting WITHIN an iteration is what _spec_budgets guarantees.)
    assert base.n_preempted + base.n_swapped_out > 0


def test_rejecting_draft_model_still_bit_identical():
    """A drafter that is mostly WRONG (differently-seeded twin) exercises
    the rollback path hard; outputs still must not change."""
    cfg = tiny_dense()
    trace = _mixed_trace(n=12, seed=3, spread=10)
    base = _run_trace(cfg, trace, "recompute")
    eng = _run_trace(cfg, trace, "recompute", spec_mode="draft", spec_k=3,
                     spec_adaptive=False, **_draft_kw(cfg, self_draft=False))
    assert eng.outputs == base.outputs
    assert eng.n_verify_dispatches > 0
    assert eng.alloc.pages_in_use() == 0


def test_ngram_closed_loop_repetitive_prompts():
    """Closed-loop drain with repetitive-suffix prompts: the n-gram
    drafter must actually engage (propose > 0) and still match spec-off
    bit-for-bit; per-iteration spec reservations never outlive their
    iteration."""
    cfg = tiny_dense()
    prompts = [[7, 8, 9] * 4, [3, 4] * 5, [5, 6, 7, 5, 6, 7, 5, 6]]

    def drain(**kw):
        eng = _make_engine(cfg, "layered", **kw)
        for p in prompts:
            eng.submit(list(p), 24)
        while eng.scheduler.has_work():
            eng.step()
            assert not eng.alloc._spec_base, \
                "spec reservation leaked across iterations"
        return eng

    base = drain()
    eng = drain(spec_mode="ngram", spec_k=4)
    assert eng.outputs == base.outputs
    assert eng.n_spec_proposed > 0, "n-gram drafter never proposed"
    assert eng.n_spec_accepted > 0, "nothing accepted on repetitive prompts"
    # accepted tokens fold decode iterations together.  (Raw dispatch
    # count may RISE on a tiny mixed cohort — an iteration where some
    # rows verify and others fall back to plain decode launches both —
    # the dispatch-amortization claim is the benchmark's to make on a
    # uniformly lookahead-friendly trace.)
    assert eng.iteration < base.iteration
    for r in eng.requests.values():
        assert r.n_generated <= r.max_new_tokens
    m = {rid: r for rid, r in eng.requests.items()}
    assert all(len(eng.outputs[rid]) == m[rid].n_generated for rid in m)


def test_spec_respects_max_new_tokens_budget():
    """The budget cap k <= max_new - n_generated - 1: a request one token
    from done never speculates past its limit."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered", spec_mode="ngram", spec_k=8)
    eng.submit([1, 2] * 6, 3)          # highly repetitive, tiny budget
    eng.run(max_iterations=1_000)
    (r,) = eng.requests.values()
    assert r.n_generated == 3
    assert len(eng.outputs[r.req_id]) == 3


# ---------------------------------------------------- hot-path contracts

def test_one_device_sync_per_iteration_with_spec(monkeypatch):
    """Draft + verify launches join the single end-of-iteration fetch:
    the one-device_get contract survives speculation."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered", spec_mode="ngram", spec_k=3)
    for p in ([7, 8, 9] * 3, [1, 2] * 4, [4, 5, 6, 4, 5, 6]):
        eng.submit(list(p), 8)
    real = jax.device_get
    calls = []
    monkeypatch.setattr(jax, "device_get",
                        lambda x: calls.append(1) or real(x))
    while eng.scheduler.has_work():
        n0 = len(calls)
        eng.step()
        assert len(calls) - n0 <= 1, "extra device sync on the spec path"
    assert eng.n_spec_proposed > 0


def test_verify_executables_join_bounded_lru():
    """Satellite bugfix: verify/draft executables count against the SAME
    PREFILL_CACHE_SIZE bound as prefill executables."""
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered", spec_mode="ngram", spec_k=3)
    for p in ([7, 8, 9] * 3, [1, 2] * 4):
        eng.submit(list(p), 8)
    eng.run(max_iterations=1_000)
    assert eng.n_verify_compiles > 0
    keys = list(eng._jit_prefill)
    assert any(k[0] == "verify" for k in keys), \
        "verify executables must live in the shared LRU"
    assert len(keys) <= PREFILL_CACHE_SIZE


# -------------------------------------------------------------- simulator

def test_sim_spec_token_counts_match_off():
    """Analytic verify-k in the simulator: per-request generated-token
    counts are invariant, iteration count shrinks (accepted drafts fold
    iterations together), and the acceptance counters populate."""
    cfg = tiny_dense()
    trace = _mixed_trace(n=16, seed=1, spread=20)
    kw = dict(n_slots=4, quantum=8, token_budget=16, n_pages=16,
              page_size=4, decode_reserve=1)
    off = Simulator(cfg, "layered", H100X2, **kw).run(trace)
    on = Simulator(cfg, "layered", H100X2, spec_mode="ngram", spec_k=3,
                   spec_acceptance=0.8, spec_seed=5, **kw).run(trace)
    for a, b in zip(off.requests, on.requests):
        assert a.req_id == b.req_id
        assert a.n_generated == b.n_generated
    assert on.total_drafted > 0
    assert 0 < on.total_accepted <= on.total_drafted
    assert on.total_accepted == sum(r.n_draft_accepted for r in on.requests)
    assert on.n_iterations < off.n_iterations
    assert np.isfinite(on.acceptance_rate)
    assert sim_pages_clean(on)


def sim_pages_clean(res):
    return res.pages_high_water <= res.n_pool_pages


def test_sim_spec_deterministic_per_seed():
    cfg = tiny_dense()
    trace = _mixed_trace(n=8, seed=2, spread=10)
    kw = dict(n_slots=4, quantum=8, token_budget=16,
              spec_mode="draft", spec_k=4, spec_acceptance=0.6)
    a = Simulator(cfg, "layered", H100X2, spec_seed=3, **kw).run(trace)
    b = Simulator(cfg, "layered", H100X2, spec_seed=3, **kw).run(trace)
    assert a.total_drafted == b.total_drafted
    assert a.total_accepted == b.total_accepted
    assert a.sim_time == b.sim_time


# ------------------------------------------------------------- unit level

def test_ngram_drafter_proposals():
    d = NgramDrafter(max_n=3)
    h = np.array([5, 6, 7, 9, 5, 6, 7])
    np.testing.assert_array_equal(d.propose(h, 2), [9, 5])   # trigram match
    assert len(d.propose(np.array([1, 2, 3]), 4)) == 0       # no repeat
    # longest n wins over a more recent shorter match
    h2 = np.array([1, 2, 9, 3, 1, 2, 8, 1, 2, 9])
    np.testing.assert_array_equal(d.propose(h2, 1), [3])


def test_accepted_prefix():
    assert accepted_prefix(np.array([1, 2, 3]), np.array([1, 2, 3])) == 3
    assert accepted_prefix(np.array([1, 9, 3]), np.array([1, 2, 3])) == 1
    assert accepted_prefix(np.array([9]), np.array([1])) == 0
    assert accepted_prefix(np.array([], np.int64), np.array([1])) == 0


def test_engine_rejects_bad_spec_config():
    cfg = tiny_dense()
    with pytest.raises(ValueError, match="spec_mode"):
        _make_engine(cfg, "layered", spec_mode="warp")
    with pytest.raises(ValueError, match="draft"):
        _make_engine(cfg, "layered", spec_mode="draft")


def test_metrics_report_acceptance():
    from repro.serving.metrics import request_metrics
    cfg = tiny_dense()
    eng = _make_engine(cfg, "layered", spec_mode="ngram", spec_k=4)
    for p in ([7, 8, 9] * 3, [1, 2] * 4):
        eng.submit(list(p), 10)
    eng.run(max_iterations=1_000)
    m = request_metrics(eng.requests.values())
    assert m["spec_drafted"] == eng.n_spec_proposed > 0
    assert 0.0 <= m["spec_acceptance_rate"] <= 1.0
    assert m["accepted_len_p50"] >= 0.0
    assert m["accepted_len_p90"] >= m["accepted_len_p50"]
