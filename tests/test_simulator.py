"""Discrete-event simulator: the apparatus behind the paper-scale numbers.
These tests assert the paper's DIRECTIONAL claims hold end-to-end in the
simulator (exact magnitudes live in benchmarks/ with full workloads)."""

from __future__ import annotations

import pytest

from repro.configs import get_config
from repro.serving.cost_model import H100X2
from repro.serving.metrics import SLOConfig, request_metrics
from repro.serving.simulator import Simulator
from repro.serving.traffic import ARXIV, TraceRequest, poisson_trace


@pytest.fixture(scope="module")
def qwen():
    return get_config("qwen3-30b-a3b")


def _trace(n=30, rate=1.0, seed=0, prompt=8192, out=64):
    import numpy as np
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    return [TraceRequest(float(a), prompt, out) for a in t]


def run(cfg, sched, trace, **kw):
    sim = Simulator(cfg, sched, H100X2, n_slots=64, **kw)
    return sim.run(trace)


def test_all_requests_complete(qwen):
    trace = _trace(20)
    for name in ("chunked", "layered", "hybrid", "continuous", "static"):
        res = run(qwen, name, trace)
        assert len(res.requests) == 20
        for r in res.requests:
            assert r.first_token_time is not None, name
            assert r.n_generated == 64 or r.state.value == "done", name


def test_layered_beats_chunked_on_long_prompts(qwen):
    """The paper's headline: lower TTFT, lower expert traffic, lower energy
    per token on arXiv-like (long-prompt) workloads."""
    trace = _trace(40, rate=1.3)
    chunked = run(qwen, "chunked", trace, token_budget=512)
    layered = run(qwen, "layered", trace, quantum=512)
    mc = request_metrics(chunked.requests)
    ml = request_metrics(layered.requests)
    assert ml["ttft_mean"] < mc["ttft_mean"]
    assert layered.total_expert_bytes < chunked.total_expert_bytes
    assert layered.energy_per_token < chunked.energy_per_token
    assert ml["e2e_mean"] < mc["e2e_mean"]


def test_continuous_batching_stalls_decode(qwen):
    """Orca-style full prefill inflates concurrent decode TBT (the failure
    mode chunked/layered fix); layered keeps p99 TBT far below it."""
    trace = _trace(30, rate=1.5)
    cont = request_metrics(run(qwen, "continuous", trace).requests)
    layer = request_metrics(run(qwen, "layered", trace).requests)
    assert layer["tbt_p99"] < cont["tbt_p99"] / 3


def test_static_batching_inflates_ttft(qwen):
    trace = _trace(30, rate=1.5)
    static = request_metrics(run(qwen, "static", trace).requests)
    layer = request_metrics(run(qwen, "layered", trace).requests)
    assert layer["ttft_p99"] < static["ttft_p99"]


def test_slo_attainment_definition(qwen):
    trace = _trace(10, rate=0.5)
    res = run(qwen, "layered", trace)
    slo = SLOConfig(ttft_slo=10.0, tbt_slo=0.125)
    m = request_metrics(res.requests, slo)
    assert 0.0 <= m["slo_attainment"] <= 1.0
    # per-request rule: attained iff TTFT ok AND every TBT ok
    assert m["slo_attainment"] <= min(m["ttft_attainment"],
                                      m["tbt_attainment"]) + 1e-9


def test_poisson_trace_statistics():
    trace = poisson_trace(ARXIV, rate=2.0, n_requests=4000, seed=1)
    import numpy as np
    arr = np.array([t.arrival_time for t in trace])
    gaps = np.diff(arr)
    assert gaps.mean() == pytest.approx(0.5, rel=0.1)
    ins = np.array([t.prompt_len for t in trace])
    outs = np.array([t.output_len for t in trace])
    # paper Table 4: arXiv mean input 9194 (±15%), mean output 231 (±15%)
    assert ins.mean() == pytest.approx(9194, rel=0.15)
    assert outs.mean() == pytest.approx(231, rel=0.15)
    # p90 in the right ballpark (Table 4: 17152 / 386)
    assert np.percentile(ins, 90) == pytest.approx(17152, rel=0.35)


def test_oversubscribed_pool_completes_with_preemption(qwen):
    """Acceptance: a trace far beyond the page pool's capacity must drain
    through queueing + preemption — no 'pool exhausted', page high-water
    within the pool, every request fully generated."""
    # long decodes: growth (1024 tokens = 64 pages per request) dwarfs the
    # <=1-reservation slack that memory-gated admission leaves free
    trace = _trace(32, rate=50.0, prompt=4096, out=1024)  # near-simultaneous
    pool = 6 * 4096 // 16         # pool holds 6 residents' PROMPT KV exactly
    for name in ("chunked", "layered", "continuous"):
        sim = Simulator(qwen, name, H100X2, n_slots=64, n_pages=pool,
                        page_size=16, decode_reserve=0)
        res = sim.run(trace)
        assert res.n_preemptions > 0, name
        assert res.pages_high_water <= res.n_pool_pages, name
        assert sim.kv.pages_in_use() == 0, name
        for r in res.requests:
            assert r.n_generated == 1024, (name, r.req_id)
        # energy/token denominator must not double-count folded tokens
        assert res.total_tokens == 32 * (4096 + 1024), name
        # preempted requests paid a recompute penalty that the cost model saw
        assert res.recompute_tokens >= 4096
        m = request_metrics(res.requests)
        assert m["preemption_rate"] > 0
        assert m["queue_delay_mean"] > 0


def test_simulator_queueing_instead_of_crash_when_pool_small(qwen):
    """Admission gating alone (preemption off, reservation covering the
    full decode) must serialize an oversubscribed trace without errors."""
    trace = _trace(12, rate=50.0, prompt=2048, out=32)
    pool = 2 * (2048 + 64) // 16              # ~2 residents
    sim = Simulator(qwen, "layered", H100X2, n_slots=64, n_pages=pool,
                    page_size=16, decode_reserve=32, preemption=False)
    res = sim.run(trace)
    assert res.n_preemptions == 0
    for r in res.requests:
        assert r.n_generated == 32


def test_simulator_raises_on_no_progress(qwen):
    """Satellite: an empty plan with no pending arrivals must raise, not
    spin forever without advancing time."""
    from repro.core.base import Scheduler
    from repro.core.plan import IterationPlan

    class StuckScheduler(Scheduler):
        name = "stuck"

        def has_work(self):
            return True                       # lies forever

        def _plan(self, now):
            return IterationPlan()

    sim = Simulator(qwen, StuckScheduler(qwen.n_layers), H100X2)
    with pytest.raises(RuntimeError, match="no progress"):
        sim.run([])


def test_default_pool_sized_from_hbm(qwen):
    from repro.serving.cost_model import kv_pool_pages
    pages = kv_pool_pages(qwen, H100X2, page_size=16)
    # 2xH100 minus ~30B bf16 weights leaves O(100GB) for KV
    kv_bytes = qwen.kv_bytes_per_token(2) * 16 * pages
    assert 20e9 < kv_bytes < 160e9
    sim = Simulator(qwen, "layered", H100X2, n_slots=4)
    assert sim.kv.n_pages == pages


def test_simulator_time_monotone(qwen):
    trace = _trace(8, rate=1.0)
    res = run(qwen, "layered", trace)
    for r in res.requests:
        ts = ([r.first_token_time] if r.first_token_time else []) + r.token_times
        assert all(b >= a for a, b in zip(ts, ts[1:]))
        assert r.first_token_time >= r.arrival_time
