"""Discrete-event simulator: the apparatus behind the paper-scale numbers.
These tests assert the paper's DIRECTIONAL claims hold end-to-end in the
simulator (exact magnitudes live in benchmarks/ with full workloads)."""

from __future__ import annotations

import pytest

from repro.configs import get_config
from repro.serving.cost_model import H100X2
from repro.serving.metrics import SLOConfig, request_metrics
from repro.serving.simulator import Simulator
from repro.serving.traffic import ARXIV, TraceRequest, poisson_trace


@pytest.fixture(scope="module")
def qwen():
    return get_config("qwen3-30b-a3b")


def _trace(n=30, rate=1.0, seed=0, prompt=8192, out=64):
    import numpy as np
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate, size=n)
    t = np.cumsum(gaps)
    return [TraceRequest(float(a), prompt, out) for a in t]


def run(cfg, sched, trace, **kw):
    sim = Simulator(cfg, sched, H100X2, n_slots=64, **kw)
    return sim.run(trace)


def test_all_requests_complete(qwen):
    trace = _trace(20)
    for name in ("chunked", "layered", "hybrid", "continuous", "static"):
        res = run(qwen, name, trace)
        assert len(res.requests) == 20
        for r in res.requests:
            assert r.first_token_time is not None, name
            assert r.n_generated == 64 or r.state.value == "done", name


def test_layered_beats_chunked_on_long_prompts(qwen):
    """The paper's headline: lower TTFT, lower expert traffic, lower energy
    per token on arXiv-like (long-prompt) workloads."""
    trace = _trace(40, rate=1.3)
    chunked = run(qwen, "chunked", trace, token_budget=512)
    layered = run(qwen, "layered", trace, quantum=512)
    mc = request_metrics(chunked.requests)
    ml = request_metrics(layered.requests)
    assert ml["ttft_mean"] < mc["ttft_mean"]
    assert layered.total_expert_bytes < chunked.total_expert_bytes
    assert layered.energy_per_token < chunked.energy_per_token
    assert ml["e2e_mean"] < mc["e2e_mean"]


def test_continuous_batching_stalls_decode(qwen):
    """Orca-style full prefill inflates concurrent decode TBT (the failure
    mode chunked/layered fix); layered keeps p99 TBT far below it."""
    trace = _trace(30, rate=1.5)
    cont = request_metrics(run(qwen, "continuous", trace).requests)
    layer = request_metrics(run(qwen, "layered", trace).requests)
    assert layer["tbt_p99"] < cont["tbt_p99"] / 3


def test_static_batching_inflates_ttft(qwen):
    trace = _trace(30, rate=1.5)
    static = request_metrics(run(qwen, "static", trace).requests)
    layer = request_metrics(run(qwen, "layered", trace).requests)
    assert layer["ttft_p99"] < static["ttft_p99"]


def test_slo_attainment_definition(qwen):
    trace = _trace(10, rate=0.5)
    res = run(qwen, "layered", trace)
    slo = SLOConfig(ttft_slo=10.0, tbt_slo=0.125)
    m = request_metrics(res.requests, slo)
    assert 0.0 <= m["slo_attainment"] <= 1.0
    # per-request rule: attained iff TTFT ok AND every TBT ok
    assert m["slo_attainment"] <= min(m["ttft_attainment"],
                                      m["tbt_attainment"]) + 1e-9


def test_poisson_trace_statistics():
    trace = poisson_trace(ARXIV, rate=2.0, n_requests=4000, seed=1)
    import numpy as np
    arr = np.array([t.arrival_time for t in trace])
    gaps = np.diff(arr)
    assert gaps.mean() == pytest.approx(0.5, rel=0.1)
    ins = np.array([t.prompt_len for t in trace])
    outs = np.array([t.output_len for t in trace])
    # paper Table 4: arXiv mean input 9194 (±15%), mean output 231 (±15%)
    assert ins.mean() == pytest.approx(9194, rel=0.15)
    assert outs.mean() == pytest.approx(231, rel=0.15)
    # p90 in the right ballpark (Table 4: 17152 / 386)
    assert np.percentile(ins, 90) == pytest.approx(17152, rel=0.35)


def test_simulator_time_monotone(qwen):
    trace = _trace(8, rate=1.0)
    res = run(qwen, "layered", trace)
    for r in res.requests:
        ts = ([r.first_token_time] if r.first_token_time else []) + r.token_times
        assert all(b >= a for a, b in zip(ts, ts[1:]))
        assert r.first_token_time >= r.arrival_time
