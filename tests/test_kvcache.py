"""PagedKVAllocator: page accounting, block tables, grow-on-write, stash
charges, and exhaustion behaviour — the memory substrate the scheduler's
admission/preemption decisions rely on."""

from __future__ import annotations

import pytest

from repro.serving.kvcache import PagedKVAllocator, PagedPoolExhausted


def test_reserve_grow_free_roundtrip():
    kv = PagedKVAllocator(n_pages=10, page_size=4)
    kv.reserve(1, 9)                       # ceil(9/4) = 3 pages
    assert kv.pages_in_use() == 3
    assert len(kv.block_table(1)) == 3
    kv.grow_to(1, 12)                      # still covered: no new page
    assert kv.pages_in_use() == 3
    kv.grow_to(1, 13)                      # crosses the boundary
    assert kv.pages_in_use() == 4
    assert kv.length(1) == 13
    assert kv.n_grow_allocs == 1
    kv.free(1)
    assert kv.pages_in_use() == 0
    assert kv.n_free_pages == 10
    assert kv.pages_high_water == 4


def test_block_tables_are_disjoint_and_stable():
    kv = PagedKVAllocator(n_pages=8, page_size=2)
    kv.reserve(1, 4)
    kv.reserve(2, 4)
    t1, t2 = kv.block_table(1), kv.block_table(2)
    assert not set(t1) & set(t2)
    kv.grow_to(1, 6)
    assert kv.block_table(1)[:2] == t1     # logical order preserved
    kv.free(2)
    kv.reserve(3, 4)
    assert not set(kv.block_table(3)) & set(kv.block_table(1))


def test_admission_queries_and_exhaustion():
    kv = PagedKVAllocator(n_pages=4, page_size=4)
    assert kv.can_admit(16)
    assert not kv.can_admit(17)
    assert kv.fits_pool(16) and not kv.fits_pool(17)
    kv.reserve(1, 12)
    assert kv.can_admit(4) and not kv.can_admit(5)
    with pytest.raises(PagedPoolExhausted):
        kv.reserve(2, 8)
    with pytest.raises(PagedPoolExhausted):
        kv.grow_to(1, 21)
    # failed calls must not leak pages
    assert kv.pages_in_use() == 3
    assert kv.growth_deficit(1, 16) == 1
    kv.grow_to(1, 16)
    assert kv.n_free_pages == 0


def test_stash_charge_and_release():
    kv = PagedKVAllocator(n_pages=8, page_size=4, stash_factor=0.5)
    # 12 KV tokens -> 3 pages; stash 16 tokens * 0.5 -> 8 -> 2 pages
    assert kv.stash_pages_for(16) == 2
    kv.reserve(1, 12, stash_tokens=16)
    assert kv.pages_in_use() == 5
    kv.release_stash(1)
    assert kv.pages_in_use() == 3
    kv.free(1)
    assert kv.n_free_pages == 8


def test_free_returns_stash_too():
    kv = PagedKVAllocator(n_pages=6, page_size=4, stash_factor=1.0)
    kv.reserve(1, 8, stash_tokens=8)
    assert kv.pages_in_use() == 4
    kv.free(1)                              # without explicit release_stash
    assert kv.n_free_pages == 6
    assert not kv.owns(1)


def test_high_water_tracks_peak_not_current():
    kv = PagedKVAllocator(n_pages=10, page_size=1)
    kv.reserve(1, 6)
    kv.reserve(2, 3)
    kv.free(1)
    kv.reserve(3, 2)
    assert kv.pages_in_use() == 5
    assert kv.pages_high_water == 9


# ------------------------------------------------------------------------
# Swap-to-host: the second (host) pool behind swap-mode preemption
# ------------------------------------------------------------------------


def test_swap_roundtrip_moves_pages_between_pools():
    kv = PagedKVAllocator(n_pages=6, page_size=4, n_host_pages=3)
    kv.reserve(1, 10)                       # 3 HBM pages
    kv.set_length(1, 10)
    assert kv.can_swap_out(1)
    moved = kv.swap_out(1)
    assert moved == 10                      # filled KV tokens, not capacity
    assert kv.pages_in_use() == 0 and kv.host_pages_in_use() == 3
    assert not kv.is_resident(1) and kv.is_swapped(1) and kv.owns(1)
    assert kv.length(1) == 10               # length survives the swap
    assert kv.can_swap_in(1)
    assert kv.swap_in(1) == 10
    assert kv.pages_in_use() == 3 and kv.host_pages_in_use() == 0
    assert kv.block_table(1) and kv.is_resident(1)
    assert (kv.n_swap_outs, kv.n_swap_ins) == (1, 1)
    assert (kv.swapped_out_tokens, kv.swapped_in_tokens) == (10, 10)
    kv.free(1)
    assert kv.n_free_pages == 6 and kv.n_free_host_pages == 3


def test_swap_out_guards_host_room_stash_and_residency():
    kv = PagedKVAllocator(n_pages=8, page_size=4, n_host_pages=2,
                          stash_factor=1.0)
    kv.reserve(1, 12)                       # 3 pages > 2 host pages
    assert not kv.can_swap_out(1)
    kv.reserve(2, 4, stash_tokens=4)        # live stash: mid-prefill
    assert not kv.can_swap_out(2)
    kv.release_stash(2)
    assert kv.can_swap_out(2)
    assert not kv.can_swap_out(99)          # never reserved
    kv.swap_out(2)
    assert not kv.can_swap_out(2)           # already swapped
    assert not kv.can_swap_in(99)


def test_free_releases_host_pages_of_swapped_request():
    kv = PagedKVAllocator(n_pages=4, page_size=4, n_host_pages=4)
    kv.reserve(1, 8)
    kv.set_length(1, 8)
    kv.swap_out(1)
    kv.free(1)                              # finished/cancelled while on host
    assert not kv.owns(1)
    assert kv.n_free_pages == 4 and kv.n_free_host_pages == 4
    assert kv.host_pages_high_water == 2


def test_swap_in_requires_free_hbm_pages():
    kv = PagedKVAllocator(n_pages=3, page_size=4, n_host_pages=3)
    kv.reserve(1, 12)
    kv.set_length(1, 12)
    kv.swap_out(1)
    kv.reserve(2, 8)                        # occupies 2 of 3 HBM pages
    assert not kv.can_swap_in(1)            # needs 3, only 1 free
    kv.free(2)
    assert kv.can_swap_in(1)
