"""Incremental (cached) execution must equal one-shot full execution for
every mixer family: prefill(S) then decode(k) == full forward(S+k).
This is the numerical foundation the engine equivalence tests rest on."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_hybrid, tiny_mla, tiny_xlstm
from repro.models.model import DecoderModel

S, K = 24, 4          # prefill length, decode steps
B = 2


def full_vs_incremental(cfg):
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + K), 1,
                              cfg.vocab_size)
    # one-shot
    full_logits, _, _ = model.forward(params, toks)

    # incremental: prefill S then K single-token steps
    cache = model.init_cache(B, S + K + 8)
    logits_p, cache, _ = model.forward(params, toks[:, :S], cache=cache,
                                       offset=jnp.zeros((B,), jnp.int32))
    inc = [logits_p[:, -1]]
    for i in range(K):
        li, cache, _ = model.forward(
            params, toks[:, S + i:S + i + 1], cache=cache,
            offset=jnp.full((B,), S + i, jnp.int32))
        inc.append(li[:, -1])
    inc = jnp.stack(inc, axis=1)      # (B, K+1, V)
    return np.asarray(full_logits[:, S - 1:]), np.asarray(inc)


@pytest.mark.parametrize("make_cfg", [tiny_dense, tiny_mla, tiny_hybrid,
                                      tiny_xlstm],
                         ids=["gqa", "mla", "rglru+local", "xlstm"])
def test_incremental_matches_full(make_cfg):
    full, inc = full_vs_incremental(make_cfg())
    np.testing.assert_allclose(inc, full, atol=3e-4, rtol=3e-4)


def test_sliding_window_matches_full():
    cfg = tiny_dense(sliding_window=8)
    full, inc = full_vs_incremental(cfg)
    np.testing.assert_allclose(inc, full, atol=3e-4, rtol=3e-4)


def test_prefill_in_two_chunks_matches_one_shot():
    """Chunked prefill's cache continuation (the engine's mechanism)."""
    cfg = tiny_dense()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, S), 1,
                              cfg.vocab_size)
    cache = model.init_cache(1, S + 8)
    l1, cache, _ = model.forward(params, toks[:, :S // 2], cache=cache,
                                 offset=jnp.zeros((1,), jnp.int32))
    l2, cache, _ = model.forward(params, toks[:, S // 2:], cache=cache,
                                 offset=jnp.full((1,), S // 2, jnp.int32))
    full, _, _ = model.forward(params, toks)
    np.testing.assert_allclose(np.asarray(l2), np.asarray(full[:, S // 2:]),
                               atol=3e-4, rtol=3e-4)


def test_valid_masked_rows_do_not_corrupt_state():
    """The engine decodes the whole slot pool with masked inactive rows:
    a masked step must leave that row's cache and a later real decode
    unchanged."""
    cfg = tiny_xlstm()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(1))
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, S), 1,
                              cfg.vocab_size)
    cache = model.init_cache(2, S + 8)
    _, cache, _ = model.forward(params, toks, cache=cache,
                                offset=jnp.zeros((2,), jnp.int32))

    # a masked single-token step on row 1 (garbage token), valid row 0
    garbage = jnp.asarray([[3], [7]], jnp.int32)
    valid = jnp.asarray([[True], [False]])
    _, cache_after, _ = model.forward(
        params, garbage, cache=cache, offset=jnp.asarray([S, S], jnp.int32),
        valid=valid)

    # row 1's next real decode must be identical to not having stepped
    tok_next = jnp.asarray([[11], [11]], jnp.int32)
    l_ref, _, _ = model.forward(params, tok_next, cache=cache,
                                offset=jnp.asarray([S, S], jnp.int32))
    l_got, _, _ = model.forward(params, tok_next, cache=cache_after,
                                offset=jnp.asarray([S + 1, S], jnp.int32))
    np.testing.assert_allclose(np.asarray(l_got[1]), np.asarray(l_ref[1]),
                               atol=1e-5, rtol=1e-5)


def test_mrope_positions_change_logits():
    """M-RoPE (qwen2-vl): 3-D positions must actually be used."""
    from repro.configs import get_smoke_config
    cfg = get_smoke_config("qwen2-vl-72b")
    assert cfg.mrope_sections
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jnp.arange(1, 17, dtype=jnp.int32)[None]
    l1, _, _ = model.forward(params, toks)
    l2, _, _ = model.forward(params, toks,
                             positions=jnp.arange(16, dtype=jnp.int32)[None] + 5)
    assert not np.allclose(np.asarray(l1), np.asarray(l2))
