"""Training substrate: optimizer + schedules, data pipeline, checkpointing,
and an actual loss-goes-down integration run."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import tiny_dense, tiny_moe
from repro.models.model import DecoderModel
from repro.training import checkpoint as ckpt
from repro.training.data import PackedDataset, SyntheticCorpus
from repro.training.optimizer import adamw, cosine_schedule, wsd_schedule
from repro.training.train import Trainer


def test_adamw_minimizes_quadratic():
    opt = adamw(lr=0.1, schedule="const")
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip_applies():
    opt = adamw(lr=0.0, schedule="const", grad_clip=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, m = opt.update({"w": jnp.full((3,), 100.0)}, state, params)
    assert float(m["grad_norm"]) > 1.0   # reported pre-clip


def test_wsd_schedule_shape():
    fn = wsd_schedule(1e-3, total_steps=1000, warmup=100)
    s = lambda i: float(fn(jnp.asarray(i)))
    assert s(0) == 0.0
    assert s(50) == pytest.approx(5e-4)
    assert s(100) == pytest.approx(1e-3)
    assert s(500) == pytest.approx(1e-3)       # stable plateau
    assert s(899) == pytest.approx(1e-3)
    assert s(950) < 1e-3                       # decaying
    assert s(1000) == pytest.approx(1e-5, rel=0.01)


def test_cosine_schedule_shape():
    fn = cosine_schedule(1e-3, total_steps=1000, warmup=100)
    s = lambda i: float(fn(jnp.asarray(i)))
    assert s(100) == pytest.approx(1e-3)
    assert s(1000) == pytest.approx(1e-4, rel=0.01)   # 10% floor


def test_packed_dataset_shapes_and_mask():
    corpus = SyntheticCorpus(vocab_size=128, seed=0)
    ds = PackedDataset(corpus, seq_len=64, batch_size=4, seed=1)
    it = iter(ds)
    tokens, targets, mask = next(it)
    assert tokens.shape == targets.shape == mask.shape == (4, 64)
    assert tokens.dtype == np.int32
    # shifted-by-one relation within the packed stream
    t2, _, _ = next(it)
    assert not np.array_equal(tokens, t2)      # iterator advances
    # mask zeroes predictions across document starts (BOS id 0 in targets)
    assert (~mask[targets == 0]).all()


def test_packed_dataset_deterministic():
    c = SyntheticCorpus(vocab_size=128, seed=0)
    a = next(iter(PackedDataset(c, 32, 2, seed=7)))
    b = next(iter(PackedDataset(c, 32, 2, seed=7)))
    for x, y in zip(a, b):
        np.testing.assert_array_equal(x, y)


def test_checkpoint_roundtrip(tmp_path):
    cfg = tiny_moe()
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    path = os.path.join(tmp_path, "ckpt.msgpack")
    ckpt.save(path, {"params": params, "step": jnp.asarray(7)})
    restored = ckpt.restore(path, {"params": params, "step": jnp.asarray(0)})
    assert int(restored["step"]) == 7
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        params, restored["params"])


def test_checkpoint_missing_leaf_raises(tmp_path):
    path = os.path.join(tmp_path, "c.msgpack")
    ckpt.save(path, {"a": jnp.zeros(2)})
    with pytest.raises(KeyError):
        ckpt.restore(path, {"a": jnp.zeros(2), "b": jnp.zeros(1)})


def test_loss_decreases_on_synthetic_corpus():
    """End-to-end: a tiny model's loss must visibly drop on the structured
    synthetic corpus within 60 steps."""
    cfg = tiny_dense(n_layers=2, d_model=128, vocab_size=128)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3, schedule="cosine", total_steps=60, warmup=5)
    trainer = Trainer(model=model, opt=opt, params=params)
    corpus = SyntheticCorpus(vocab_size=cfg.vocab_size, seed=0)
    ds = PackedDataset(corpus, seq_len=64, batch_size=8, seed=0)
    hist = trainer.fit(iter(ds), steps=60, log_every=5)
    first, last = hist[0]["ce"], hist[-1]["ce"]
    assert last < first - 0.5, (first, last)


def test_trainer_checkpointing(tmp_path):
    cfg = tiny_dense(d_model=32, n_layers=1, vocab_size=64)
    model = DecoderModel(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3, total_steps=20, warmup=2)
    trainer = Trainer(model=model, opt=opt, params=params)
    corpus = SyntheticCorpus(vocab_size=64, seed=0)
    ds = PackedDataset(corpus, seq_len=32, batch_size=2, seed=0)
    path = os.path.join(tmp_path, "t.msgpack")
    trainer.fit(iter(ds), steps=10, checkpoint_path=path, checkpoint_every=5)
    assert os.path.exists(path)
    restored = ckpt.restore(path, {"params": trainer.params,
                                   "opt": trainer.opt_state})
    # restored state is the step-10 state
    assert int(restored["opt"].step) == 10
